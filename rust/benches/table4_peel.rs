//! Table IV reproduction: GPP vs PeelOne execution time (+ the Gunrock
//! system-level column, here the vertex-centric framework VC-Peel).
//!
//! Paper shape to check: PeelOne beats GPP on every dataset (1.0–4.1x,
//! avg 1.9x on the RTX 3090); the generic-framework implementation is far
//! slower than both. Both iteration counts (l1) are printed as in the
//! paper's table.
//!
//!     cargo bench --bench table4_peel

use pico::bench::{measure, print_preamble, suite::suite, suite::Tier, BenchOptions};
use pico::coordinator::report::{geomean_speedup, Table};
use pico::core::peel::{Gpp, PeelOne};
use pico::util::fmt;
use pico::vc::VcPeel;

fn main() {
    let opts = BenchOptions::default();
    print_preamble("Table IV — GPP vs PeelOne (+ Gunrock-analog)", &opts);

    let mut t = Table::new(&[
        "dataset", "GPP", "PeelOne", "SpeedUp", "VC-Peel(GR)", "l1",
    ]);
    let mut pairs = Vec::new();
    for entry in suite(Tier::from_env()) {
        let g = entry.build();
        let gpp = measure(&Gpp, &g, &opts);
        let po = measure(&PeelOne, &g, &opts);
        let vc = measure(&VcPeel, &g, &opts);
        pairs.push((gpp.ms(), po.ms()));
        t.row(vec![
            entry.name.to_string(),
            fmt::ms(gpp.ms()),
            fmt::ms(po.ms()),
            fmt::speedup(gpp.ms() / po.ms()),
            fmt::ms(vc.ms()),
            po.instrumented.iterations.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\ngeomean PeelOne speedup over GPP: {} (paper: avg 1.9x)",
        fmt::speedup(geomean_speedup(&pairs))
    );
}
