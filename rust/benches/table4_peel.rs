//! Table IV reproduction: GPP vs PeelOne execution time (+ the Gunrock
//! system-level column, here the vertex-centric framework VC-Peel), with
//! the hierarchical-bucket kernel (BucketPeel) alongside.
//!
//! Paper shape to check: PeelOne beats GPP on every dataset (1.0–4.1x,
//! avg 1.9x on the RTX 3090); the generic-framework implementation is far
//! slower than both. BucketPeel should close on or beat PeelOne exactly
//! where k_max is deep (its one-scan-per-bucket collection removes the
//! `l1` full-vertex scans). Both iteration counts (l1) are printed as in
//! the paper's table.
//!
//!     cargo bench --bench table4_peel
//!
//! `PICO_BENCH_QUICK=1` shrinks to the Small tier and writes
//! `BENCH_table4_peel.json` for the CI perf trail.

use pico::bench::{measure, print_preamble, BenchOptions};
use pico::bench::suite::{quick_bench, suite, write_bench_json, Tier};
use pico::coordinator::report::{geomean_speedup, Table};
use pico::core::peel::{BucketPeel, Gpp, PeelOne};
use pico::util::fmt;
use pico::vc::VcPeel;

fn main() {
    let opts = BenchOptions::default();
    print_preamble("Table IV — GPP vs PeelOne vs BucketPeel (+ Gunrock-analog)", &opts);

    let tier = if quick_bench() { Tier::Small } else { Tier::from_env() };
    let mut t = Table::new(&[
        "dataset", "GPP", "PeelOne", "SpeedUp", "BucketPeel", "SpeedUp(B)", "VC-Peel(GR)", "l1",
    ]);
    let mut pairs = Vec::new();
    let mut bucket_pairs = Vec::new();
    let mut last: Option<(String, f64, f64, f64)> = None;
    for entry in suite(tier) {
        let g = entry.build();
        let gpp = measure(&Gpp, &g, &opts);
        let po = measure(&PeelOne, &g, &opts);
        let bk = measure(&BucketPeel, &g, &opts);
        let vc = measure(&VcPeel, &g, &opts);
        pairs.push((gpp.ms(), po.ms()));
        bucket_pairs.push((po.ms(), bk.ms()));
        t.row(vec![
            entry.name.to_string(),
            fmt::ms(gpp.ms()),
            fmt::ms(po.ms()),
            fmt::speedup(gpp.ms() / po.ms()),
            fmt::ms(bk.ms()),
            fmt::speedup(po.ms() / bk.ms()),
            fmt::ms(vc.ms()),
            po.instrumented.iterations.to_string(),
        ]);
        last = Some((entry.name.to_string(), gpp.ms(), po.ms(), bk.ms()));
    }
    print!("{}", t.render());
    println!(
        "\ngeomean PeelOne speedup over GPP: {} (paper: avg 1.9x)",
        fmt::speedup(geomean_speedup(&pairs))
    );
    println!(
        "geomean BucketPeel speedup over PeelOne: {} (deep-k_max graphs drive it)",
        fmt::speedup(geomean_speedup(&bucket_pairs))
    );
    if let Some((name, gpp_ms, po_ms, bk_ms)) = last {
        write_bench_json(
            "table4_peel",
            &name,
            &[
                ("gpp_ms", gpp_ms),
                ("peelone_ms", po_ms),
                ("bucketpeel_ms", bk_ms),
                ("bucket_speedup_x", po_ms / bk_ms),
                ("geomean_bucket_speedup_x", geomean_speedup(&bucket_pairs)),
            ],
        );
    }
}
