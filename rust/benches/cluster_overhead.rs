//! Cluster-serving overhead: what does moving a shard behind the binary
//! protocol cost, per query class and per merge round?
//!
//! Three configurations over the same graph and shard count:
//!
//! 1. `sharded-local`  — the in-process `ShardedIndex` (no router RPC).
//! 2. `cluster-local`  — a `ClusterIndex` whose shards are all local:
//!    the trait-dispatch + router overhead without any network.
//! 3. `cluster-remote` — the same cluster with every shard hosted by a
//!    loopback `pico serve` process: each point read, fan-out partial,
//!    routed batch, and boundary-exchange round is one frame round trip
//!    per shard.
//!
//! Reported per configuration: routed point reads/sec, histogram
//! fan-outs/sec, flush latency p50, merge p50, exchange rounds per
//! flush, and the per-round cost — the loopback number is the floor for
//! what a real network round trip adds.
//!
//! A second section measures **replica catch-up**: a loopback replica is
//! left 1/4/16 epochs behind, then caught up via the journal's delta
//! chain and (for comparison) via a full-manifest re-ship — bytes and
//! latency for both, across two graph sizes, to show delta catch-up
//! cost scaling with the edit batches instead of the graph.
//!
//! A third section measures **live primary migration**: shard 0's
//! primary ping-pongs between two loopback hosts while routed point
//! reads keep flowing — per move the bytes shipped and the fenced
//! cutover pause, plus the read-qps dip vs an undisturbed baseline
//! (`migrate_*` keys in the json artifact).
//!
//!     cargo bench --bench cluster_overhead
//!     PICO_BENCH_QUICK=1 cargo bench --bench cluster_overhead  # CI smoke
//!
//! Every configuration is oracle-checked against `bz_coreness` on its
//! assembled graph before its numbers are printed. In quick mode the
//! headline numbers land in `BENCH_cluster_overhead.json` (uploaded as
//! a CI artifact).

use pico::bench::suite::{quick_bench, write_bench_json};
use pico::cluster::{ClusterConfig, ClusterIndex};
use pico::core::bz::bz_coreness;
use pico::core::maintenance::EdgeEdit;
use pico::graph::{gen, CsrGraph};
use pico::service::{serve, BatchConfig, CoreService};
use pico::shard::{PartitionStrategy, ShardedIndex, ShardedOutcome};
use pico::util::fmt;
use pico::util::rng::Rng;
use pico::util::timer::{Samples, Timer};
use std::sync::Arc;

const SHARDS: usize = 4;
const BATCH: usize = 32;

fn workload() -> CsrGraph {
    if quick_bench() {
        gen::barabasi_albert(800, 4, 42)
    } else {
        gen::barabasi_albert(5_000, 6, 42)
    }
}

fn cfg() -> BatchConfig {
    BatchConfig {
        threads: 1,
        ..BatchConfig::default()
    }
}

enum Target {
    Local(ShardedIndex),
    Cluster(ClusterIndex),
}

impl Target {
    fn coreness(&self, v: u32) -> Option<u32> {
        match self {
            Target::Local(s) => s.coreness(v),
            Target::Cluster(c) => c.coreness_routed(v).expect("cluster read failed"),
        }
    }

    fn histogram(&self) -> Vec<u64> {
        match self {
            Target::Local(s) => s.histogram(),
            Target::Cluster(c) => c.histogram_routed().expect("cluster fan-out failed"),
        }
    }

    fn submit(&self, e: EdgeEdit) {
        match self {
            Target::Local(s) => {
                s.submit(e);
            }
            Target::Cluster(c) => {
                c.submit(e);
            }
        }
    }

    fn flush(&self) -> ShardedOutcome {
        match self {
            Target::Local(s) => s.flush(),
            Target::Cluster(c) => c.flush().expect("cluster flush failed"),
        }
    }

    fn oracle_check(&self, label: &str) {
        let (snap, graph) = match self {
            Target::Local(s) => s.consistent_view(),
            Target::Cluster(c) => c.consistent_view().expect("cluster view failed"),
        };
        assert_eq!(
            snap.core,
            bz_coreness(&graph),
            "{label} diverged from the oracle"
        );
    }
}

struct Row {
    name: &'static str,
    point_qps: f64,
    histo_qps: f64,
    flush_p50: f64,
    merge_p50: f64,
    rounds: f64,
    round_ms: f64,
}

fn bench_target(name: &'static str, target: &Target, n: u32) -> Row {
    let points = if quick_bench() { 2_000 } else { 50_000 };
    let histos = if quick_bench() { 5 } else { 100 };
    let num_flushes = if quick_bench() { 3 } else { 15 };

    let mut rng = Rng::new(17);
    let mut sink = 0u64;
    let t = Timer::start();
    for _ in 0..points {
        let v = rng.below(n as u64) as u32;
        sink ^= target.coreness(v).unwrap_or(0) as u64;
    }
    let point_qps = points as f64 / t.elapsed().as_secs_f64();

    let t = Timer::start();
    for _ in 0..histos {
        sink ^= target.histogram().iter().sum::<u64>();
    }
    let histo_qps = histos as f64 / t.elapsed().as_secs_f64();
    std::hint::black_box(sink);

    let mut flushes = Samples::default();
    let mut merges = Samples::default();
    let mut rounds = 0usize;
    for _ in 0..num_flushes {
        let mut queued = 0usize;
        while queued < BATCH {
            let u = rng.below(n as u64) as u32;
            let v = rng.below(n as u64) as u32;
            if u == v {
                continue;
            }
            target.submit(if rng.chance(0.6) {
                EdgeEdit::Insert(u, v)
            } else {
                EdgeEdit::Delete(u, v)
            });
            queued += 1;
        }
        let out = target.flush();
        flushes.push(out.elapsed);
        merges.push(out.merge_elapsed);
        rounds += out.merge.rounds;
    }
    target.oracle_check(name);

    let merge_p50 = merges.percentile_ms(50.0);
    let avg_rounds = rounds as f64 / num_flushes as f64;
    Row {
        name,
        point_qps,
        histo_qps,
        flush_p50: flushes.percentile_ms(50.0),
        merge_p50,
        rounds: avg_rounds,
        round_ms: if avg_rounds > 0.0 { merge_p50 / avg_rounds } else { 0.0 },
    }
}

fn topology(name: &str, primaries: &[String]) -> ClusterConfig {
    let mut text = format!("[cluster]\nname = {name}\nshards = {}\n", primaries.len());
    for (i, p) in primaries.iter().enumerate() {
        text.push_str(&format!("[shard.{i}]\nprimary = {p}\n"));
    }
    ClusterConfig::parse(&text).expect("bench topology")
}

/// Replica catch-up: delta-chain replay vs full-manifest re-ship at
/// increasing lag, across two graph sizes. The point the table makes:
/// delta bytes track `lag × batch` (plus coreness churn) while the
/// manifest tracks `|V| + |E|` — the asymptotics the journal exists for.
fn bench_catchup(json: &mut Vec<(&'static str, f64)>) {
    let sizes: &[(&str, usize)] = if quick_bench() {
        &[("ba-800", 800), ("ba-2400", 2400)]
    } else {
        &[("ba-5000", 5_000), ("ba-20000", 20_000)]
    };
    println!(
        "\n== replica catch-up == (batch {BATCH} edits/epoch; delta = journal chain, full = manifest re-ship)\n"
    );
    println!(
        "{:>10}  {:>5}  {:>12}  {:>10}  {:>12}  {:>10}  {:>7}  {}",
        "graph", "lag", "delta bytes", "delta ms", "full bytes", "full ms", "ratio", "path"
    );
    for &(label, n) in sizes {
        let g = gen::barabasi_albert(n, 4, 99);
        let svc = Arc::new(CoreService::new(cfg()));
        let handle = serve(svc, "127.0.0.1:0").expect("bind loopback server");
        let topo_text = format!(
            "[cluster]\nname = cu\nshards = 1\njournal = 64\n\
             [shard.0]\nprimary = local\nreplicas = {}\n",
            handle.addr()
        );
        let topo = ClusterConfig::parse(&topo_text).expect("catch-up topology");
        let cl = ClusterIndex::build(&g, &topo, cfg()).expect("catch-up cluster");
        let mut rng = Rng::new(7 + n as u64);
        for &lag in &[1usize, 4, 16] {
            let base = cl.epoch();
            for _ in 0..lag {
                let mut queued = 0usize;
                while queued < BATCH {
                    let u = rng.below(n as u64) as u32;
                    let v = rng.below(n as u64) as u32;
                    if u == v {
                        continue;
                    }
                    cl.submit(if rng.chance(0.7) {
                        EdgeEdit::Insert(u, v)
                    } else {
                        EdgeEdit::Delete(u, v)
                    });
                    queued += 1;
                }
                cl.flush().expect("catch-up flush");
            }
            let want = cl.epoch();
            let delta_bytes = cl
                .journal_chain_bytes(0, base, want)
                .expect("journal must cover the lag") as u64;
            let t = Timer::start();
            let report = cl.sync_replicas().expect("delta sync");
            let delta_ms = t.elapsed_ms();
            // the sync picks by encoded size, so a pathologically churny
            // chain may legitimately lose to the manifest — report which
            // path actually served instead of asserting it
            let path = if report.deltas > 0 { "delta" } else { "full*" };
            // full-ship comparison against the same (now-current) replica
            let manifest = cl.groups()[0].primary_manifest(1).expect("manifest");
            let t = Timer::start();
            cl.groups()[0].replicas()[0].host(&manifest).expect("full re-ship");
            let full_ms = t.elapsed_ms();
            println!(
                "{:>10}  {:>5}  {:>12}  {:>10}  {:>12}  {:>10}  {:>6.1}x  {}",
                label,
                lag,
                delta_bytes,
                fmt::ms(delta_ms),
                manifest.len(),
                fmt::ms(full_ms),
                manifest.len() as f64 / delta_bytes.max(1) as f64,
                path
            );
            if lag == 4 {
                if label == sizes[0].0 {
                    json.push(("catchup_delta_bytes_lag4_small", delta_bytes as f64));
                    json.push(("catchup_full_bytes_small", manifest.len() as f64));
                    json.push(("catchup_delta_ms_lag4_small", delta_ms));
                    json.push(("catchup_full_ms_small", full_ms));
                } else {
                    json.push(("catchup_delta_bytes_lag4_large", delta_bytes as f64));
                    json.push(("catchup_full_bytes_large", manifest.len() as f64));
                }
            }
        }
        // same guarantee as the main configurations: the merged snapshot
        // still equals BZ on the assembled graph after all the churn
        let (snap, graph) = cl.consistent_view().expect("catch-up view");
        assert_eq!(
            snap.core,
            bz_coreness(&graph),
            "catch-up cluster {label} diverged from the oracle"
        );
        handle.stop();
    }
    println!(
        "\ndelta bytes grow with lag × batch (edit volume); full-manifest bytes grow\n\
         with the graph — the journal turns replica catch-up from O(|V|+|E|) into\n\
         O(changes), which is what keeps lagging replicas cheap at scale"
    );
}

/// Live primary migration: a two-shard cluster ping-pongs shard 0's
/// primary between two loopback hosts while point reads keep flowing.
/// Reported per move: catch-up bytes shipped and the fenced cutover
/// pause (the only pause writers observe); plus the read-qps dip while
/// a migration is in flight vs the undisturbed baseline.
fn bench_migration(json: &mut Vec<(&'static str, f64)>) {
    use std::time::Duration;

    let n: usize = if quick_bench() { 600 } else { 4_000 };
    let g = gen::barabasi_albert(n, 4, 123);
    let svc_a = Arc::new(CoreService::new(cfg()));
    let host_a = serve(svc_a, "127.0.0.1:0").expect("bind migration host A");
    let svc_b = Arc::new(CoreService::new(cfg()));
    let host_b = serve(svc_b, "127.0.0.1:0").expect("bind migration host B");
    let locals: Vec<String> = (0..2).map(|_| "local".to_string()).collect();
    let cl = Arc::new(
        ClusterIndex::build(&g, &topology("mig", &locals), cfg()).expect("migration cluster"),
    );
    let moves = if quick_bench() { 4 } else { 12 };
    let window = Duration::from_millis(if quick_bench() { 120 } else { 400 });

    let probe = |cl: &ClusterIndex, rng: &mut Rng, dur: Duration| -> f64 {
        let t = Timer::start();
        let mut count = 0u64;
        let mut sink = 0u64;
        while t.elapsed() < dur {
            let v = rng.below(n as u64) as u32;
            sink ^= cl.coreness_routed(v).expect("routed read").unwrap_or(0) as u64;
            count += 1;
        }
        std::hint::black_box(sink);
        count as f64 / t.elapsed().as_secs_f64()
    };
    let targets = [host_a.addr().to_string(), host_b.addr().to_string()];
    // warm-up move so the baseline probe reads shard 0 through the same
    // remote path as the in-flight probes — otherwise the "dip" would
    // mostly measure local-vs-loopback reads, not migration interference
    cl.migrate_primary(0, &targets[0]).expect("warm-up migration");
    let mut rng = Rng::new(5);
    let baseline_qps = probe(&cl, &mut rng, window);

    println!("\n== live primary migration == ({moves} moves, reads flowing throughout)\n");
    println!(
        "{:>5}  {:>22}  {:>12}  {:>12}  {:>12}",
        "move", "primary", "bytes", "cutover", "reads q/s"
    );
    let mut cutovers = Samples::default();
    let mut shipped = 0u64;
    let mut during = Vec::new();
    for i in 0..moves {
        // live routed edits between moves — the shard state the next
        // migration ships is never the state the last one shipped
        let mut queued = 0usize;
        while queued < BATCH {
            let u = rng.below(n as u64) as u32;
            let v = rng.below(n as u64) as u32;
            if u == v {
                continue;
            }
            cl.submit(if rng.chance(0.7) {
                EdgeEdit::Insert(u, v)
            } else {
                EdgeEdit::Delete(u, v)
            });
            queued += 1;
        }
        cl.flush().expect("flush between migrations");
        // warm-up parked the primary on host A, so move to B first
        let addr = targets[(i + 1) % 2].clone();
        let cl2 = cl.clone();
        let mig = std::thread::spawn(move || cl2.migrate_primary(0, &addr).expect("migration"));
        let qps = probe(&cl, &mut rng, window);
        let rec = mig.join().expect("migration thread");
        cutovers.push(Duration::from_micros(rec.cutover_us));
        shipped += rec.bytes;
        during.push(qps);
        println!(
            "{:>5}  {:>22}  {:>12}  {:>10}us  {:>12}",
            i,
            rec.to,
            rec.bytes,
            rec.cutover_us,
            fmt::si(qps as u64)
        );
    }
    // reads stayed correct through every cutover, and the state that
    // landed on the final host still equals BZ on the assembled graph
    let (snap, graph) = cl.consistent_view().expect("post-migration view");
    assert_eq!(
        snap.core,
        bz_coreness(&graph),
        "migrated cluster diverged from the oracle"
    );
    let during_qps = during.iter().sum::<f64>() / during.len().max(1) as f64;
    let dip_pct = if baseline_qps > 0.0 {
        ((1.0 - during_qps / baseline_qps) * 100.0).max(0.0)
    } else {
        0.0
    };
    let p50_us = cutovers.percentile_ms(50.0) * 1000.0;
    let p99_us = cutovers.percentile_ms(99.0) * 1000.0;
    println!(
        "\ncutover pause p50 {:.0}us p99 {:.0}us; {} bytes shipped across {moves} moves;\n\
         reads dipped {dip_pct:.1}% while migrations ran ({} -> {} q/s) — the pause\n\
         writers observe is the fenced chain/verify/swap, never the manifest ship",
        p50_us,
        p99_us,
        shipped,
        fmt::si(baseline_qps as u64),
        fmt::si(during_qps as u64)
    );
    json.push(("migrate_cutover_p50_us", p50_us));
    json.push(("migrate_cutover_p99_us", p99_us));
    json.push(("migrate_bytes_shipped", shipped as f64));
    json.push(("migrate_qps_dip_pct", dip_pct));
    host_a.stop();
    host_b.stop();
}

fn main() {
    let g = workload();
    let n = g.num_vertices() as u32;
    println!(
        "== cluster_overhead == dataset {} (|V|={}, |E|={}, {SHARDS} shards{})\n",
        g.name,
        fmt::si(g.num_vertices() as u64),
        fmt::si(g.num_edges()),
        if quick_bench() { ", quick mode" } else { "" }
    );

    let local = Target::Local(ShardedIndex::new(
        "bench",
        &g,
        SHARDS,
        PartitionStrategy::Hash,
        cfg(),
    ));

    let locals: Vec<String> = (0..SHARDS).map(|_| "local".to_string()).collect();
    let cluster_local = Target::Cluster(
        ClusterIndex::build(&g, &topology("cl", &locals), cfg()).expect("local cluster"),
    );

    // one loopback server hosts all four remote shards — every routed
    // operation is a real TCP frame round trip
    let svc = Arc::new(CoreService::new(cfg()));
    let handle = serve(svc, "127.0.0.1:0").expect("bind loopback server");
    let addr = handle.addr().to_string();
    let remotes: Vec<String> = (0..SHARDS).map(|_| addr.clone()).collect();
    let cluster_remote = Target::Cluster(
        ClusterIndex::build(&g, &topology("cr", &remotes), cfg()).expect("remote cluster"),
    );

    println!(
        "{:>16}  {:>11}  {:>10}  {:>10}  {:>10}  {:>7}  {:>9}",
        "config", "point q/s", "histo q/s", "flush p50", "merge p50", "rounds", "ms/round"
    );
    let mut rows = Vec::new();
    for (name, target) in [
        ("sharded-local", &local),
        ("cluster-local", &cluster_local),
        ("cluster-remote", &cluster_remote),
    ] {
        let r = bench_target(name, target, n);
        println!(
            "{:>16}  {:>11}  {:>10}  {:>10}  {:>10}  {:>7.1}  {:>9}",
            r.name,
            fmt::si(r.point_qps as u64),
            fmt::si(r.histo_qps as u64),
            fmt::ms(r.flush_p50),
            fmt::ms(r.merge_p50),
            r.rounds,
            fmt::ms(r.round_ms)
        );
        rows.push(r);
    }
    if let [ref l, _, ref r] = rows[..] {
        if r.point_qps > 0.0 && r.round_ms > 0.0 {
            println!(
                "\nloopback tax: point reads {:.0}x slower than in-process; one exchange\n\
                 round costs {} vs {} locally — the floor a real network adds to every\n\
                 merge round and replica read",
                l.point_qps / r.point_qps,
                fmt::ms(r.round_ms),
                fmt::ms(l.round_ms)
            );
        }
    }
    handle.stop();

    let mut json: Vec<(&'static str, f64)> = Vec::new();
    for r in &rows {
        match r.name {
            "sharded-local" => json.push(("local_point_qps", r.point_qps)),
            "cluster-local" => json.push(("cluster_local_point_qps", r.point_qps)),
            "cluster-remote" => {
                json.push(("cluster_remote_point_qps", r.point_qps));
                json.push(("cluster_remote_flush_p50_ms", r.flush_p50));
                json.push(("cluster_remote_round_ms", r.round_ms));
            }
            _ => {}
        }
    }
    bench_catchup(&mut json);
    bench_migration(&mut json);
    write_bench_json("cluster_overhead", &g.name, &json);
}
