//! Vector-engine comparison: the XLA-executed vectorised paradigms
//! (VETGA [20] lineage, our L1/L2/AOT path) against the hand-fused native
//! engine — the Table IV "system overhead" story told at the other end of
//! the stack, plus proof the AOT artifacts run on the request path.
//!
//!     make artifacts && cargo bench --bench xla_vs_native

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!("SKIP: built without the `xla` feature (cargo bench --bench xla_vs_native --features xla)");
}

#[cfg(feature = "xla")]
use pico::bench::{measure, print_preamble, suite::suite, suite::Tier, BenchOptions};
#[cfg(feature = "xla")]
use pico::coordinator::report::Table;
#[cfg(feature = "xla")]
use pico::core::index2core::HistoCore;
#[cfg(feature = "xla")]
use pico::core::peel::PoDyn;
#[cfg(feature = "xla")]
use pico::runtime::{default_worker, VecHindex, VecPeel};
#[cfg(feature = "xla")]
use pico::util::fmt;

#[cfg(feature = "xla")]
fn main() {
    let opts = BenchOptions {
        // the XLA path re-uploads literals per step; keep reps small
        reps: std::env::var("PICO_BENCH_REPS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(2),
        ..Default::default()
    };
    print_preamble("XLA vectorised engines vs native (XLA-tier suite)", &opts);

    let worker = match default_worker() {
        Ok(w) => w,
        Err(e) => {
            eprintln!("SKIP: {e}");
            return;
        }
    };
    println!("pjrt: {}\n", worker.platform().unwrap_or_default());
    let vec_peel = VecPeel::new(worker.clone());
    let vec_hindex = VecHindex::new(worker);

    let mut t = Table::new(&[
        "dataset", "VecPeel", "VecHindex", "PO-dyn", "HistoCore", "bucket fit",
    ]);
    for entry in suite(Tier::Xla) {
        let g = entry.build();
        let vp = measure(&vec_peel, &g, &opts);
        let vh = measure(&vec_hindex, &g, &opts);
        let pod = measure(&PoDyn, &g, &opts);
        let hst = measure(&HistoCore, &g, &opts);
        t.row(vec![
            entry.name.to_string(),
            fmt::ms(vp.ms()),
            fmt::ms(vh.ms()),
            fmt::ms(pod.ms()),
            fmt::ms(hst.ms()),
            format!("n<=4096,d<={}", g.max_degree()),
        ]);
    }
    print!("{}", t.render());
    println!("\nnote: the dense vectorised formulation pays O(N*D) per step — the");
    println!("paper's reason hand-fused CSR kernels beat vector-primitive engines.");
}
