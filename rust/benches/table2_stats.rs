//! Table II reproduction: statistical properties of the dataset suite
//! (|V|, |E|, d_avg, std, d_max, k_max, category). The synthetic suite
//! substitutes the paper's 24 public datasets (DESIGN.md §1); this bench
//! regenerates the table the other benches' rows are keyed against.
//!
//!     cargo bench --bench table2_stats        # PICO_SUITE=small|standard|large

use pico::bench::{print_preamble, suite::suite, suite::Tier, BenchOptions};
use pico::coordinator::report::Table;
use pico::core::bz::bz_coreness;
use pico::graph::GraphStats;
use pico::util::fmt;

fn main() {
    let opts = BenchOptions::default();
    print_preamble("Table II — dataset statistics", &opts);

    let mut t = Table::new(&[
        "dataset", "|V|", "|E|", "d_avg", "std", "d_max", "k_max", "skew", "category",
    ]);
    for entry in suite(Tier::from_env()) {
        let g = entry.build();
        let core = bz_coreness(&g);
        let s = GraphStats::measure(&g).with_kmax(&core);
        t.row(vec![
            entry.name.to_string(),
            fmt::si(s.vertices),
            fmt::si(s.edges),
            format!("{:.2}", s.d_avg),
            format!("{:.1}", s.d_std),
            s.d_max.to_string(),
            s.k_max.unwrap_or(0).to_string(),
            format!("{:.1}", s.skew()),
            entry.category.to_string(),
        ]);
    }
    print!("{}", t.render());
}
