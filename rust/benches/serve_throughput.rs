//! Serving-layer benchmark: concurrent read throughput under batched
//! updates, batched-update latency (p50/p99), and the incremental-vs-
//! recompute crossover that calibrates `BatchConfig::recompute_fraction`.
//!
//! The crossover table is the serving analog of the paper's Table VII
//! peel-vs-index2core crossover: below it, per-edit subcore maintenance
//! wins; above it, one full run of the `Hybrid`-selected decomposer is
//! cheaper. Run on a new host to recalibrate the default (see ROADMAP's
//! tuning follow-up).
//!
//!     cargo bench --bench serve_throughput
//!     PICO_SUITE=small cargo bench --bench serve_throughput   # quicker
//!     PICO_BENCH_QUICK=1 cargo bench --bench serve_throughput # CI smoke

use pico::bench::suite::{quick_bench, Tier};
use pico::core::bz::bz_coreness;
use pico::core::maintenance::{DynamicCore, EdgeEdit};
use pico::core::{Decomposer, Hybrid};
use pico::graph::{gen, CsrGraph};
use pico::service::{BatchConfig, CoreIndex, EditQueue};
use pico::util::fmt;
use pico::util::rng::Rng;
use pico::util::timer::{Samples, Timer};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

fn workload(tier: Tier) -> CsrGraph {
    if quick_bench() {
        return gen::barabasi_albert(1_500, 5, 42);
    }
    match tier {
        Tier::Small | Tier::Xla => gen::barabasi_albert(5_000, 6, 42),
        _ => gen::barabasi_albert(20_000, 8, 42),
    }
}

fn random_edits(rng: &mut Rng, n: u32, count: usize, p_insert: f64) -> Vec<EdgeEdit> {
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let u = rng.below(n as u64) as u32;
        let v = rng.below(n as u64) as u32;
        if u == v {
            continue;
        }
        out.push(if rng.chance(p_insert) {
            EdgeEdit::Insert(u, v)
        } else {
            EdgeEdit::Delete(u, v)
        });
    }
    out
}

/// Part 1 — queries/sec under 4 concurrent readers while a writer
/// streams batches, plus per-flush latency percentiles. Returns the
/// headline numbers for the CI json artifact.
fn bench_concurrent_serving(g: &CsrGraph) -> Vec<(&'static str, f64)> {
    const READERS: usize = 4;
    const ROUNDS: usize = 60;
    const BATCH: usize = 32;

    let n = g.num_vertices() as u32;
    let idx = Arc::new(CoreIndex::new("bench", g));
    let queue = EditQueue::new(idx.clone(), BatchConfig::default());

    let stop = Arc::new(AtomicBool::new(false));
    let total_queries = Arc::new(AtomicU64::new(0));
    let mut readers = Vec::new();
    for r in 0..READERS {
        let idx = idx.clone();
        let stop = stop.clone();
        let total = total_queries.clone();
        readers.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + r as u64);
            let mut local = 0u64;
            let mut sink = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let s = idx.snapshot();
                let v = rng.below(s.num_vertices().max(1) as u64) as u32;
                sink ^= s.coreness(v).unwrap_or(0) as u64 ^ s.epoch;
                local += 1;
            }
            total.fetch_add(local, Ordering::Relaxed);
            std::hint::black_box(sink);
        }));
    }

    let rounds = if quick_bench() { 8 } else { ROUNDS };
    let mut rng = Rng::new(7);
    let mut flushes = Samples::default();
    let wall = Timer::start();
    for _ in 0..rounds {
        for e in random_edits(&mut rng, n, BATCH, 0.6) {
            queue.submit(e);
        }
        let out = queue.flush();
        flushes.push(out.elapsed);
    }
    let wall_s = wall.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    for h in readers {
        h.join().unwrap();
    }

    let q = total_queries.load(Ordering::Relaxed);
    println!(
        "concurrent serving: {READERS} readers, {rounds} batches x {BATCH} edits over {:.2}s",
        wall_s
    );
    println!(
        "  reads:   {} total -> {} queries/sec",
        fmt::commas(q),
        fmt::si((q as f64 / wall_s) as u64)
    );
    println!(
        "  updates: flush latency p50 {} ms | p99 {} ms | max {} ms | epochs {}",
        fmt::ms(flushes.percentile_ms(50.0)),
        fmt::ms(flushes.percentile_ms(99.0)),
        fmt::ms(flushes.max_ms()),
        idx.epoch()
    );

    // correctness backstop: the bench never reports numbers for a broken index
    let (snap, graph) = idx.consistent_view();
    assert_eq!(snap.core, bz_coreness(&graph), "served state diverged from oracle");
    println!("  oracle check: ok\n");
    vec![
        ("reads_per_sec", q as f64 / wall_s),
        ("flush_p50_ms", flushes.percentile_ms(50.0)),
        ("flush_p99_ms", flushes.percentile_ms(99.0)),
    ]
}

/// Part 2 — the crossover: per-batch-size cost of incremental
/// maintenance vs structural-edits + full recompute.
fn bench_crossover(g: &CsrGraph) -> Option<f64> {
    let n = g.num_vertices() as u32;
    let m = g.num_edges();
    let base = DynamicCore::new(g);
    let fractions = [0.0005, 0.001, 0.002, 0.005, 0.01, 0.02, 0.05, 0.1];

    println!("incremental vs recompute crossover (|E| = {}):", fmt::commas(m));
    println!(
        "{:>10}  {:>8}  {:>12}  {:>12}  {:>10}",
        "fraction", "edits", "incr(ms)", "recomp(ms)", "winner"
    );
    let mut crossover: Option<f64> = None;
    let mut rng = Rng::new(99);
    for &frac in &fractions {
        let count = ((m as f64 * frac) as usize).max(1);
        let edits = random_edits(&mut rng, n, count, 0.6);

        let mut inc = base.clone();
        let t = Timer::start();
        inc.apply_batch(&edits);
        let inc_ms = t.elapsed().as_secs_f64() * 1e3;

        let mut rec = base.clone();
        let t = Timer::start();
        for &e in &edits {
            match e {
                EdgeEdit::Insert(u, v) => {
                    rec.insert_edge_structural(u, v);
                }
                EdgeEdit::Delete(u, v) => {
                    rec.delete_edge_structural(u, v);
                }
            }
        }
        rec.recompute_with(&Hybrid::default(), pico::util::default_threads());
        let rec_ms = t.elapsed().as_secs_f64() * 1e3;

        assert_eq!(inc.coreness(), rec.coreness(), "paths disagree at frac {frac}");
        let winner = if inc_ms <= rec_ms { "incremental" } else { "recompute" };
        if inc_ms > rec_ms && crossover.is_none() {
            crossover = Some(frac);
        }
        println!(
            "{:>9.2}%  {:>8}  {:>12}  {:>12}  {:>10}",
            frac * 100.0,
            count,
            fmt::ms(inc_ms),
            fmt::ms(rec_ms),
            winner
        );
    }
    match crossover {
        Some(f) => {
            // /etc/hostname first: bash keeps HOSTNAME unexported, so the
            // env var is absent from most non-interactive runs
            let host = std::fs::read_to_string("/etc/hostname")
                .ok()
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .or_else(|| std::env::var("HOSTNAME").ok())
                .unwrap_or_else(|| "unknown-host".into());
            println!(
                "\nmeasured crossover ≈ {:.2}% of |E| -> suggested BatchConfig.recompute_fraction = {f}",
                f * 100.0
            );
            println!(
                "deploy without rebuilding: PICO_RECOMPUTE_FRACTION={f}\n\
                 ROADMAP paste line: `recompute_fraction = {f} (measured on {host}, dataset {}, {} edges)`",
                g.name,
                fmt::commas(m)
            );
        }
        None => println!(
            "\nrecompute never won up to {:.0}% of |E| on this host; keep the incremental path",
            fractions.last().unwrap() * 100.0
        ),
    }
    crossover
}

/// Part 3 — one full-recompute decomposition on the serving graph, for
/// scale: what a cold index build / worst-case fallback costs.
fn bench_cold_build(g: &CsrGraph) -> f64 {
    let t = Timer::start();
    let r = Hybrid::default().decompose(g);
    let ms = t.elapsed_ms();
    println!(
        "\ncold index build (Hybrid): {} ms, k_max {}, {}",
        fmt::ms(ms),
        r.k_max(),
        fmt::meps(g.num_edges(), ms)
    );
    ms
}

fn main() {
    let tier = Tier::from_env();
    let g = workload(tier);
    println!(
        "== serve_throughput == dataset {} (|V|={}, |E|={}, tier {:?})\n",
        g.name,
        fmt::si(g.num_vertices() as u64),
        fmt::si(g.num_edges()),
        tier
    );
    let mut json = bench_concurrent_serving(&g);
    let crossover = bench_crossover(&g);
    let cold_ms = bench_cold_build(&g);
    json.push(("crossover_fraction", crossover.unwrap_or(f64::NAN)));
    json.push(("cold_build_ms", cold_ms));
    pico::bench::suite::write_bench_json("serve_throughput", &g.name, &json);
}
