//! Serving-layer benchmark: concurrent read throughput under batched
//! updates, batched-update latency (p50/p99), the incremental-vs-
//! recompute crossover that calibrates `BatchConfig::recompute_fraction`,
//! and two connection-churn sections over the bounded `net::pool`
//! transport: accept→first-reply latency + sustained qps at rising
//! concurrent-client counts (the capacity claim of the worker-pool
//! refactor), and sustained qps against a growing fleet of *idle*
//! parked connections (the capacity claim of the readiness poller —
//! an idle socket costs one `poll(2)` slot, not a worker). Two kernel
//! sections cover the flush recompute (fresh-alloc `Hybrid` run vs the
//! warm-scratch hierarchical-bucket peel) and the `MEMBERS` fast path
//! (sort-free single-k vs full decomposition at k = degeneracy). All
//! are recorded in the CI `BENCH_*.json` artifact.
//!
//! The crossover table is the serving analog of the paper's Table VII
//! peel-vs-index2core crossover: below it, per-edit subcore maintenance
//! wins; above it, one full run of the `Hybrid`-selected decomposer is
//! cheaper. Run on a new host to recalibrate the default (see ROADMAP's
//! tuning follow-up).
//!
//!     cargo bench --bench serve_throughput
//!     PICO_SUITE=small cargo bench --bench serve_throughput   # quicker
//!     PICO_BENCH_QUICK=1 cargo bench --bench serve_throughput # CI smoke

use pico::bench::suite::{quick_bench, Tier};
use pico::core::bz::bz_coreness;
use pico::core::maintenance::{DynamicCore, EdgeEdit};
use pico::core::{Decomposer, Hybrid};
use pico::graph::{gen, CsrGraph};
use pico::service::{BatchConfig, CoreIndex, EditQueue};
use pico::util::fmt;
use pico::util::rng::Rng;
use pico::util::timer::{Samples, Timer};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

fn workload(tier: Tier) -> CsrGraph {
    if quick_bench() {
        return gen::barabasi_albert(1_500, 5, 42);
    }
    match tier {
        Tier::Small | Tier::Xla => gen::barabasi_albert(5_000, 6, 42),
        _ => gen::barabasi_albert(20_000, 8, 42),
    }
}

fn random_edits(rng: &mut Rng, n: u32, count: usize, p_insert: f64) -> Vec<EdgeEdit> {
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let u = rng.below(n as u64) as u32;
        let v = rng.below(n as u64) as u32;
        if u == v {
            continue;
        }
        out.push(if rng.chance(p_insert) {
            EdgeEdit::Insert(u, v)
        } else {
            EdgeEdit::Delete(u, v)
        });
    }
    out
}

/// Part 1 — queries/sec under 4 concurrent readers while a writer
/// streams batches, plus per-flush latency percentiles. Returns the
/// headline numbers for the CI json artifact.
fn bench_concurrent_serving(g: &CsrGraph) -> Vec<(&'static str, f64)> {
    const READERS: usize = 4;
    const ROUNDS: usize = 60;
    const BATCH: usize = 32;

    let n = g.num_vertices() as u32;
    let idx = Arc::new(CoreIndex::new("bench", g));
    let queue = EditQueue::new(idx.clone(), BatchConfig::default());

    let stop = Arc::new(AtomicBool::new(false));
    let total_queries = Arc::new(AtomicU64::new(0));
    let mut readers = Vec::new();
    for r in 0..READERS {
        let idx = idx.clone();
        let stop = stop.clone();
        let total = total_queries.clone();
        readers.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + r as u64);
            let mut local = 0u64;
            let mut sink = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let s = idx.snapshot();
                let v = rng.below(s.num_vertices().max(1) as u64) as u32;
                sink ^= s.coreness(v).unwrap_or(0) as u64 ^ s.epoch;
                local += 1;
            }
            total.fetch_add(local, Ordering::Relaxed);
            std::hint::black_box(sink);
        }));
    }

    let rounds = if quick_bench() { 8 } else { ROUNDS };
    let mut rng = Rng::new(7);
    let mut flushes = Samples::default();
    let wall = Timer::start();
    for _ in 0..rounds {
        for e in random_edits(&mut rng, n, BATCH, 0.6) {
            queue.submit(e);
        }
        let out = queue.flush();
        flushes.push(out.elapsed);
    }
    let wall_s = wall.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    for h in readers {
        h.join().unwrap();
    }

    let q = total_queries.load(Ordering::Relaxed);
    println!(
        "concurrent serving: {READERS} readers, {rounds} batches x {BATCH} edits over {:.2}s",
        wall_s
    );
    println!(
        "  reads:   {} total -> {} queries/sec",
        fmt::commas(q),
        fmt::si((q as f64 / wall_s) as u64)
    );
    println!(
        "  updates: flush latency p50 {} ms | p99 {} ms | max {} ms | epochs {}",
        fmt::ms(flushes.percentile_ms(50.0)),
        fmt::ms(flushes.percentile_ms(99.0)),
        fmt::ms(flushes.max_ms()),
        idx.epoch()
    );

    // correctness backstop: the bench never reports numbers for a broken index
    let (snap, graph) = idx.consistent_view();
    assert_eq!(snap.core, bz_coreness(&graph), "served state diverged from oracle");
    println!("  oracle check: ok\n");
    let mut json = vec![
        ("reads_per_sec", q as f64 / wall_s),
        ("flush_p50_ms", flushes.percentile_ms(50.0)),
        ("flush_p99_ms", flushes.percentile_ms(99.0)),
        // the EWMA cost model's break-even point after this run's
        // flushes — the live counterpart of Part 2's offline sweep
        (
            "crossover_measured_fraction",
            idx.crossover_costs()
                .effective_fraction(graph.num_edges())
                .unwrap_or(f64::NAN),
        ),
    ];
    // the obs registry's per-stage flush histograms for this graph — CI's
    // bench smoke asserts these keys land in BENCH_serve_throughput.json
    let reg = pico::obs::global();
    let labels: &[(&str, &str)] = &[("graph", "bench")];
    for (key, name) in [
        ("flush_stage_queue_p99_us", pico::obs::names::FLUSH_QUEUE_SECONDS),
        ("flush_stage_apply_p99_us", pico::obs::names::FLUSH_APPLY_SECONDS),
        ("flush_stage_total_p99_us", pico::obs::names::FLUSH_TOTAL_SECONDS),
    ] {
        let h = reg.histogram(name, labels).snapshot();
        json.push((key, h.quantile(0.99) as f64));
    }
    json
}

/// Part 2 — the crossover: per-batch-size cost of incremental
/// maintenance vs structural-edits + full recompute.
fn bench_crossover(g: &CsrGraph) -> Option<f64> {
    let n = g.num_vertices() as u32;
    let m = g.num_edges();
    let base = DynamicCore::new(g);
    let fractions = [0.0005, 0.001, 0.002, 0.005, 0.01, 0.02, 0.05, 0.1];

    println!("incremental vs recompute crossover (|E| = {}):", fmt::commas(m));
    println!(
        "{:>10}  {:>8}  {:>12}  {:>12}  {:>10}",
        "fraction", "edits", "incr(ms)", "recomp(ms)", "winner"
    );
    let mut crossover: Option<f64> = None;
    let mut rng = Rng::new(99);
    // warm scratch across fractions: the production recompute path
    // (`apply_batch` -> `recompute_bucket`) holds one per index too
    let mut scratch = pico::core::peel::BucketScratch::with_capacity(0);
    for &frac in &fractions {
        let count = ((m as f64 * frac) as usize).max(1);
        let edits = random_edits(&mut rng, n, count, 0.6);

        let mut inc = base.clone();
        let t = Timer::start();
        inc.apply_batch(&edits);
        let inc_ms = t.elapsed().as_secs_f64() * 1e3;

        let mut rec = base.clone();
        let t = Timer::start();
        for &e in &edits {
            match e {
                EdgeEdit::Insert(u, v) => {
                    rec.insert_edge_structural(u, v);
                }
                EdgeEdit::Delete(u, v) => {
                    rec.delete_edge_structural(u, v);
                }
            }
        }
        rec.recompute_bucket(pico::util::default_threads(), &mut scratch);
        let rec_ms = t.elapsed().as_secs_f64() * 1e3;

        assert_eq!(inc.coreness(), rec.coreness(), "paths disagree at frac {frac}");
        let winner = if inc_ms <= rec_ms { "incremental" } else { "recompute" };
        if inc_ms > rec_ms && crossover.is_none() {
            crossover = Some(frac);
        }
        println!(
            "{:>9.2}%  {:>8}  {:>12}  {:>12}  {:>10}",
            frac * 100.0,
            count,
            fmt::ms(inc_ms),
            fmt::ms(rec_ms),
            winner
        );
    }
    match crossover {
        Some(f) => {
            // /etc/hostname first: bash keeps HOSTNAME unexported, so the
            // env var is absent from most non-interactive runs
            let host = std::fs::read_to_string("/etc/hostname")
                .ok()
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .or_else(|| std::env::var("HOSTNAME").ok())
                .unwrap_or_else(|| "unknown-host".into());
            println!(
                "\nmeasured crossover ≈ {:.2}% of |E| -> suggested BatchConfig.recompute_fraction = {f}",
                f * 100.0
            );
            println!(
                "deploy without rebuilding: PICO_RECOMPUTE_FRACTION={f}\n\
                 ROADMAP paste line: `recompute_fraction = {f} (measured on {host}, dataset {}, {} edges)`",
                g.name,
                fmt::commas(m)
            );
        }
        None => println!(
            "\nrecompute never won up to {:.0}% of |E| on this host; keep the incremental path",
            fractions.last().unwrap() * 100.0
        ),
    }
    crossover
}

/// Part 3 — connection churn over the bounded worker pool: per client
/// count, every client dials fresh (accept→first-PING-reply latency),
/// then hammers CORENESS queries for a fixed window (sustained qps
/// across all live connections). Client counts far above the worker
/// count are the point: the pool multiplexes them instead of spawning
/// a thread per connection.
fn bench_connection_churn(g: &CsrGraph) -> Vec<(&'static str, f64)> {
    use pico::net::NetConfig;
    use pico::service::{serve_with, CoreService};
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::time::{Duration, Instant};

    // (count, latency key, qps key): static keys for the json artifact
    let plans: &[(usize, &'static str, &'static str)] = if quick_bench() {
        &[
            (8, "churn_accept_p99_ms_8", "churn_qps_8"),
            (32, "churn_accept_p99_ms_32", "churn_qps_32"),
        ]
    } else {
        &[
            (64, "churn_accept_p99_ms_64", "churn_qps_64"),
            (256, "churn_accept_p99_ms_256", "churn_qps_256"),
            (1024, "churn_accept_p99_ms_1024", "churn_qps_1024"),
        ]
    };
    let window = if quick_bench() {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(2)
    };

    let svc = Arc::new(CoreService::new(BatchConfig::default()));
    svc.open("bench", g);
    // cap comfortably above the largest client count: the previous
    // plan's sockets are reaped asynchronously, and a capacity
    // rejection here would panic the bench instead of measuring it
    let net = NetConfig {
        max_connections: 4096,
        ..Default::default()
    };
    let handle = serve_with(svc, "127.0.0.1:0", net).expect("bind churn server");
    let addr = handle.addr();
    let n = g.num_vertices() as u32;

    println!("connection churn (bounded pool, default workers):");
    println!(
        "{:>8}  {:>16}  {:>16}  {:>12}",
        "clients", "accept p50", "accept p99", "qps"
    );
    let mut json = Vec::new();
    for &(clients, lat_key, qps_key) in plans {
        let queries = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let mut joins = Vec::with_capacity(clients);
        // the wall clock covers the same interval the query counter
        // does: from before the first client spawns to the stop store
        // (join time excluded) — at 1024 clients the spawn loop is a
        // real fraction of the window and must not skew qps
        let wall = Timer::start();
        for c in 0..clients {
            let queries = queries.clone();
            let stop = stop.clone();
            joins.push(std::thread::spawn(move || {
                // fresh dial: connection churn is part of the measurement
                let stream = TcpStream::connect(addr).expect("dial");
                let mut w = stream.try_clone().unwrap();
                let mut r = BufReader::new(stream);
                let mut line = String::new();
                let t = Instant::now();
                writeln!(w, "PING").unwrap();
                w.flush().unwrap();
                r.read_line(&mut line).unwrap();
                assert_eq!(line.trim_end(), "OK pong");
                let first_reply = t.elapsed();
                // sustained load until the window closes
                let mut rng = Rng::new(0xC0DE + c as u64);
                let mut local = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    line.clear();
                    writeln!(w, "CORENESS {}", rng.below(n as u64)).unwrap();
                    w.flush().unwrap();
                    r.read_line(&mut line).unwrap();
                    assert!(line.starts_with("OK core="), "{line}");
                    local += 1;
                }
                queries.fetch_add(local, Ordering::Relaxed);
                let _ = writeln!(w, "QUIT");
                first_reply
            }));
        }
        std::thread::sleep(window);
        stop.store(true, Ordering::Relaxed);
        let wall_s = wall.elapsed().as_secs_f64();
        let mut accepts = Samples::default();
        for j in joins {
            accepts.push(j.join().expect("churn client"));
        }
        let qps = queries.load(Ordering::Relaxed) as f64 / wall_s;
        println!(
            "{:>8}  {:>16}  {:>16}  {:>12}",
            clients,
            fmt::ms(accepts.percentile_ms(50.0)),
            fmt::ms(accepts.percentile_ms(99.0)),
            fmt::si(qps as u64)
        );
        json.push((lat_key, accepts.percentile_ms(99.0)));
        json.push((qps_key, qps));
    }
    handle.stop();
    println!();
    json
}

/// Part 3b — idle-fleet churn: the readiness poller's capacity claim.
/// A fleet of N connections goes idle (one `PING` round-trip each,
/// then silence) while 8 hammer clients drive `CORENESS` round-trips
/// for a fixed window. Sustained qps must stay flat as N grows 1k →
/// 10k (100k un-quick): a parked connection costs one slot in the
/// poller's `poll(2)` set and zero worker time, so the hammers never
/// queue behind the idle horde. Both ends of every idle connection
/// live in this process (~2 fds each), so the fd rlimit is raised
/// up-front and the fleet degrades — with a log line and an honest
/// `*_clients` json key — to whatever the limit affords.
fn bench_idle_churn(g: &CsrGraph) -> Vec<(&'static str, f64)> {
    use pico::net::{raise_nofile_limit, NetConfig};
    use pico::service::{serve_with, CoreService};
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::time::Duration;

    const HAMMERS: usize = 8;
    // (idle count, qps key, held-clients key): static keys for the CI
    // json artifact — the bench smoke asserts the quick-mode qps keys
    let plans: &[(usize, &'static str, &'static str)] = if quick_bench() {
        &[
            (1_000, "churn_idle1k_qps", "churn_idle1k_clients"),
            (10_000, "churn_idle10k_qps", "churn_idle10k_clients"),
        ]
    } else {
        &[
            (1_000, "churn_idle1k_qps", "churn_idle1k_clients"),
            (10_000, "churn_idle10k_qps", "churn_idle10k_clients"),
            (100_000, "churn_idle100k_qps", "churn_idle100k_clients"),
        ]
    };
    let window = if quick_bench() {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(2)
    };
    let max_idle = plans.iter().map(|p| p.0).max().unwrap();
    let limit = raise_nofile_limit((2 * max_idle + 1024) as u64);
    let affordable = if limit == 0 {
        max_idle // no rlimit probe on this platform; let dial errors surface
    } else {
        (limit.saturating_sub(1024) / 2) as usize
    };

    let svc = Arc::new(CoreService::new(BatchConfig::default()));
    svc.open("bench", g);
    let net = NetConfig {
        max_connections: max_idle + HAMMERS + 64,
        ..Default::default()
    };
    let handle = serve_with(svc, "127.0.0.1:0", net).expect("bind idle-churn server");
    let addr = handle.addr();
    let n = g.num_vertices() as u32;

    println!("idle-fleet churn ({HAMMERS} hammer clients over the readiness poller):");
    println!("{:>10}  {:>10}  {:>12}", "idle", "held", "qps");
    let mut json = Vec::new();
    let mut fleet: Vec<(TcpStream, BufReader<TcpStream>)> = Vec::new();
    for &(want_idle, qps_key, clients_key) in plans {
        let target = want_idle.min(affordable);
        if target < want_idle {
            println!("  (fd limit {limit}: holding {target} of {want_idle} idle clients)");
        }
        // grow the fleet, in chunks small enough to stay inside the
        // listener backlog: dial + PING a chunk, then read every reply
        // (the reply proves the server accepted and parked the socket)
        while fleet.len() < target {
            let chunk = (target - fleet.len()).min(128);
            let mut fresh = Vec::with_capacity(chunk);
            for _ in 0..chunk {
                let stream = TcpStream::connect(addr).expect("idle dial");
                let mut w = stream.try_clone().unwrap();
                writeln!(w, "PING").unwrap();
                w.flush().unwrap();
                fresh.push((w, BufReader::new(stream)));
            }
            for (w, mut r) in fresh {
                let mut line = String::new();
                r.read_line(&mut line).expect("idle PING reply");
                assert_eq!(line.trim_end(), "OK pong");
                fleet.push((w, r));
            }
        }

        let queries = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let mut joins = Vec::with_capacity(HAMMERS);
        let wall = Timer::start();
        for c in 0..HAMMERS {
            let queries = queries.clone();
            let stop = stop.clone();
            joins.push(std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).expect("hammer dial");
                let mut w = stream.try_clone().unwrap();
                let mut r = BufReader::new(stream);
                let mut line = String::new();
                let mut rng = Rng::new(0x1D7E + c as u64);
                let mut local = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    line.clear();
                    writeln!(w, "CORENESS {}", rng.below(n as u64)).unwrap();
                    w.flush().unwrap();
                    r.read_line(&mut line).unwrap();
                    assert!(line.starts_with("OK core="), "{line}");
                    local += 1;
                }
                queries.fetch_add(local, Ordering::Relaxed);
                let _ = writeln!(w, "QUIT");
            }));
        }
        std::thread::sleep(window);
        stop.store(true, Ordering::Relaxed);
        let wall_s = wall.elapsed().as_secs_f64();
        for j in joins {
            j.join().expect("hammer client");
        }
        let qps = queries.load(Ordering::Relaxed) as f64 / wall_s;
        println!(
            "{:>10}  {:>10}  {:>12}",
            want_idle,
            fleet.len(),
            fmt::si(qps as u64)
        );
        json.push((qps_key, qps));
        json.push((clients_key, fleet.len() as f64));
    }
    // dropping the fleet closes every idle socket; the server reaps them
    drop(fleet);
    handle.stop();
    println!();
    json
}

/// Part 4 — registry hot-path overhead: ns per counter bump and per
/// histogram record, and the share of the sustained served query rate
/// that cost amounts to (the acceptance bar is ≤ 2%). Also the stats
/// sampler's cost — one full registry snapshot recorded into the
/// time-series ring per `--sample-interval` tick — amortized over the
/// default 1 s interval (same ≤ 2% budget).
fn bench_registry_overhead(served_qps: f64) -> Vec<(&'static str, f64)> {
    use pico::obs::names;

    let iters: u64 = if quick_bench() { 200_000 } else { 2_000_000 };
    let reg = pico::obs::global();
    let labels: &[(&str, &str)] = &[("graph", "bench")];
    let counter = reg.counter(names::SERVE_QUERIES, labels);
    let t = Timer::start();
    for _ in 0..iters {
        counter.inc();
    }
    let counter_ns = t.elapsed().as_nanos() as f64 / iters as f64;
    let hist = reg.histogram(names::QUERY_SECONDS, labels);
    let t = Timer::start();
    for i in 0..iters {
        hist.record(i & 0xFFF);
    }
    let hist_ns = t.elapsed().as_nanos() as f64 / iters as f64;
    // one served query records one counter bump and one latency sample
    let per_query_ns = counter_ns + hist_ns;
    let overhead_pct = if served_qps > 0.0 {
        served_qps * per_query_ns / 1e9 * 100.0
    } else {
        0.0
    };
    println!(
        "registry overhead: counter {counter_ns:.1} ns, histogram record {hist_ns:.1} ns \
         -> {overhead_pct:.3}% of the sustained {} qps",
        fmt::si(served_qps as u64)
    );
    // the sampler tick: snapshot the whole registry (as populated by the
    // serving sections above) and push it into a bounded ring — measured
    // against a local ring so the bench leaves the global one alone
    let ts = pico::obs::Tsdb::new();
    let sample_iters: u64 = if quick_bench() { 200 } else { 2_000 };
    let t = Timer::start();
    for _ in 0..sample_iters {
        ts.record(reg.snapshot());
    }
    let sample_ns = t.elapsed().as_nanos() as f64 / sample_iters as f64;
    // one tick per default 1 s interval: the sampler thread's share of
    // one core
    let sampler_overhead_pct = sample_ns / 1e9 * 100.0;
    println!(
        "sampler overhead: snapshot+record {:.1} us/tick -> {sampler_overhead_pct:.4}% \
         of one core at the default 1 s --sample-interval",
        sample_ns / 1e3
    );
    vec![
        ("obs_counter_ns", counter_ns),
        ("obs_hist_record_ns", hist_ns),
        ("obs_overhead_pct", overhead_pct),
        ("sampler_overhead_pct", sampler_overhead_pct),
    ]
}

/// Part 5 — the flush-time recompute kernel: the old path (a fresh
/// `Hybrid`-selected run, all working arrays allocated per call) vs the
/// hierarchical-bucket peel with a warm caller-held scratch — the kernel
/// `apply_batch`/`LocalShard::apply` actually run when a batch crosses
/// the recompute threshold. Repeated runs, p99 in µs: the steady-state
/// flush picture, where scratch reuse and the one-scan-per-bucket
/// collection pay off on the powerlaw serving graph.
fn bench_recompute_kernel(g: &CsrGraph) -> Vec<(&'static str, f64)> {
    use pico::core::peel::BucketScratch;

    let threads = pico::util::default_threads();
    let iters = if quick_bench() { 6 } else { 20 };
    let base = DynamicCore::new(g);

    let mut hybrid = Samples::default();
    let mut dc = base.clone();
    for _ in 0..iters {
        let t = Timer::start();
        dc.recompute_with(&Hybrid::default(), threads);
        hybrid.push(t.elapsed());
    }
    let hybrid_core = dc.coreness().to_vec();

    let mut bucket = Samples::default();
    let mut dc = base.clone();
    let mut scratch = BucketScratch::with_capacity(0);
    for _ in 0..iters {
        let t = Timer::start();
        dc.recompute_bucket(threads, &mut scratch);
        bucket.push(t.elapsed());
    }
    assert_eq!(dc.coreness(), &hybrid_core[..], "recompute kernels disagree");

    let hybrid_us = hybrid.percentile_ms(99.0) * 1e3;
    let bucket_us = bucket.percentile_ms(99.0) * 1e3;
    println!(
        "flush recompute kernel ({iters} warm runs, {threads} threads):\n\
         \x20 Hybrid fresh-alloc p99 {:.0} us | BucketPeel warm-scratch p99 {:.0} us -> {}",
        hybrid_us,
        bucket_us,
        fmt::speedup(hybrid_us / bucket_us)
    );
    vec![
        ("recompute_p99_us", bucket_us),
        ("recompute_hybrid_p99_us", hybrid_us),
        ("recompute_speedup_x", hybrid_us / bucket_us),
    ]
}

/// Part 6 — the MEMBERS fast path: sort-free single-k extraction
/// ([`pico::core::peel::single_k`]) vs the full decomposition it
/// replaces, at k = degeneracy (the deep-core cohort query). The fast
/// path is one O(|V|+|E|) delete-below-k fixpoint; the old answer needed
/// every vertex's exact coreness first.
fn bench_members_fastpath(g: &CsrGraph) -> Vec<(&'static str, f64)> {
    use pico::core::peel::single_k;

    let core = bz_coreness(g);
    let k = core.iter().copied().max().unwrap_or(0);
    let iters = if quick_bench() { 8 } else { 30 };

    let mut full = Samples::default();
    let mut full_members = Vec::new();
    for _ in 0..iters {
        let t = Timer::start();
        let r = Hybrid::default().decompose(g);
        full_members = (0..r.core.len() as u32).filter(|&v| r.core[v as usize] >= k).collect();
        full.push(t.elapsed());
    }

    let mut fast = Samples::default();
    let mut fast_members = Vec::new();
    for _ in 0..iters {
        let t = Timer::start();
        fast_members = single_k(g, k).members();
        fast.push(t.elapsed());
    }
    assert_eq!(fast_members, full_members, "single_k disagrees with full decomposition");

    let full_us = full.percentile_ms(99.0) * 1e3;
    let fast_us = fast.percentile_ms(99.0) * 1e3;
    println!(
        "MEMBERS fast path (k = degeneracy = {k}, {} members):\n\
         \x20 full decomposition p99 {:.0} us | single-k p99 {:.0} us -> {} (bar: >= 5x)",
        fast_members.len(),
        full_us,
        fast_us,
        fmt::speedup(full_us / fast_us)
    );
    vec![
        ("members_fastpath_p99_us", fast_us),
        ("members_fastpath_full_p99_us", full_us),
        ("members_fastpath_speedup_x", full_us / fast_us),
    ]
}

/// Part 7 — one full-recompute decomposition on the serving graph, for
/// scale: what a cold index build / worst-case fallback costs.
fn bench_cold_build(g: &CsrGraph) -> f64 {
    let t = Timer::start();
    let r = Hybrid::default().decompose(g);
    let ms = t.elapsed_ms();
    println!(
        "\ncold index build (Hybrid): {} ms, k_max {}, {}",
        fmt::ms(ms),
        r.k_max(),
        fmt::meps(g.num_edges(), ms)
    );
    ms
}

fn main() {
    let tier = Tier::from_env();
    let g = workload(tier);
    println!(
        "== serve_throughput == dataset {} (|V|={}, |E|={}, tier {:?})\n",
        g.name,
        fmt::si(g.num_vertices() as u64),
        fmt::si(g.num_edges()),
        tier
    );
    let mut json = bench_concurrent_serving(&g);
    json.extend(bench_connection_churn(&g));
    json.extend(bench_idle_churn(&g));
    let served_qps = json
        .iter()
        .rev()
        .find(|(k, _)| k.starts_with("churn_qps"))
        .map(|&(_, v)| v)
        .unwrap_or(0.0);
    json.extend(bench_registry_overhead(served_qps));
    json.extend(bench_recompute_kernel(&g));
    json.extend(bench_members_fastpath(&g));
    let crossover = bench_crossover(&g);
    let cold_ms = bench_cold_build(&g);
    json.push(("crossover_fraction", crossover.unwrap_or(f64::NAN)));
    json.push(("cold_build_ms", cold_ms));
    pico::bench::suite::write_bench_json("serve_throughput", &g.name, &json);
}
