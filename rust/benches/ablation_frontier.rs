//! Ablation: each PeelOne design choice in isolation (§III.C).
//!
//!   GPP          — two arrays + rem flag, full rescan, plain atomicSub
//!   PeelOne      — + merged array + assertion (static frontiers)
//!   PO-dyn       — + dynamic frontier queue
//!
//! Shows where the paper's 1.9x (assertion/merge) and 5.2x (dynamic
//! frontier) multipliers come from, with launches and iteration counts.
//!
//!     cargo bench --bench ablation_frontier

use pico::bench::{measure, print_preamble, suite::suite, suite::Tier, BenchOptions};
use pico::coordinator::report::Table;
use pico::core::peel::{Gpp, PeelOne, PoDyn};
use pico::util::fmt;

fn main() {
    let opts = BenchOptions::default();
    print_preamble("Ablation — PeelOne design choices", &opts);

    let mut t = Table::new(&[
        "dataset",
        "GPP ms(l1)",
        "PeelOne ms(l1)",
        "PO-dyn ms(l1)",
        "launches G/P/D",
        "frontier pushes D",
    ]);
    for entry in suite(Tier::from_env()) {
        let g = entry.build();
        let gpp = measure(&Gpp, &g, &opts);
        let peel = measure(&PeelOne, &g, &opts);
        let pod = measure(&PoDyn, &g, &opts);
        t.row(vec![
            entry.name.to_string(),
            format!("{}({})", fmt::ms(gpp.ms()), gpp.instrumented.iterations),
            format!("{}({})", fmt::ms(peel.ms()), peel.instrumented.iterations),
            format!("{}({})", fmt::ms(pod.ms()), pod.instrumented.iterations),
            format!(
                "{}/{}/{}",
                gpp.instrumented.launches, peel.instrumented.launches, pod.instrumented.launches
            ),
            fmt::commas(pod.instrumented.metrics.frontier_pushes),
        ]);
    }
    print!("{}", t.render());
}
