//! Fig. 4 ablation: the assertion method's atomic-operation savings.
//!
//! For every dataset: (a) census the under-core events of a serial peel
//! (the exact `n`, `m` of the paper's 2n−m analysis), (b) measure PP-dyn
//! (atomicSub + corrective atomicAdd) vs PO-dyn (atomicSub_{>=k}) atomic
//! counts and times. Check: PO-dyn performs zero atomicAdds and
//! `below-floor decrements × 2` fewer total atomics, matching the census.
//!
//!     cargo bench --bench ablation_assertion

use pico::analysis::undercore_census;
use pico::bench::{measure, print_preamble, suite::suite, suite::Tier, BenchOptions};
use pico::coordinator::report::Table;
use pico::core::peel::{PoDyn, PpDyn};
use pico::util::fmt;

fn main() {
    let opts = BenchOptions::default();
    print_preamble("Fig. 4 ablation — assertion method atomic savings", &opts);

    let mut t = Table::new(&[
        "dataset",
        "undercore V",
        "belowfloor dec",
        "PP-dyn atomics",
        "PO-dyn atomics",
        "saved",
        "PP-dyn ms",
        "PO-dyn ms",
    ]);
    for entry in suite(Tier::from_env()) {
        let g = entry.build();
        let census = undercore_census(&g);
        let pp = measure(&PpDyn, &g, &opts);
        let po = measure(&PoDyn, &g, &opts);
        let pp_atomics = pp.instrumented.metrics.total_atomics();
        let po_atomics = po.instrumented.metrics.total_atomics();
        assert_eq!(
            po.instrumented.metrics.atomic_adds, 0,
            "assertion method must not need corrective adds"
        );
        t.row(vec![
            entry.name.to_string(),
            fmt::commas(census.undercore_vertices),
            fmt::commas(census.below_floor_decrements),
            fmt::commas(pp_atomics),
            fmt::commas(po_atomics),
            fmt::commas(pp_atomics.saturating_sub(po_atomics)),
            fmt::ms(pp.ms()),
            fmt::ms(po.ms()),
        ]);
    }
    print!("{}", t.render());
    println!("\npaper claim: assertion avoids 2(n-m) atomics per under-core vertex (Fig. 4).");
}
