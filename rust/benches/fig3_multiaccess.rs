//! Fig. 3 reproduction: the proportion of vertices and edges that need
//! multiple accesses in the Index2core paradigm, measured on power-law
//! graphs (the paper uses soc-twitter-2010; our analog is the RMAT/BA
//! social tier).
//!
//! Paper numbers to compare shape against: ~94% of frontier-neighbor
//! reactivations are wasted; 18.9% of vertices become frontiers >2 times;
//! 88% of edges accessed >2 times; 60.9% >5 times.
//!
//!     cargo bench --bench fig3_multiaccess

use pico::analysis::activation_profile;
use pico::bench::{print_preamble, BenchOptions};
use pico::coordinator::report::Table;
use pico::graph::gen;

fn main() {
    let opts = BenchOptions::default();
    print_preamble("Fig. 3 — Index2core multi-access proportions", &opts);

    let graphs = vec![
        gen::rmat(15, 12, 0.57, 0.19, 0.19, 7),
        gen::barabasi_albert(20_000, 8, 42),
        gen::power_law_cluster(20_000, 8, 0.7, 17),
    ];

    for g in &graphs {
        let p = activation_profile(g);
        println!(
            "{} (|V|={}, |E|={}): l2={}  wasted reactivations={:.1}% (paper: ~94%)",
            g.name,
            g.num_vertices(),
            g.num_edges(),
            p.iterations,
            p.wasted_reactivation_ratio * 100.0
        );
        let mut t = Table::new(&["threshold t", "% vertices changed > t", "% edges swept > t"]);
        for thr in [0u32, 1, 2, 5, 10] {
            t.row(vec![
                thr.to_string(),
                format!("{:.1}%", p.vertices_changed_more_than(thr) * 100.0),
                format!("{:.1}%", p.edges_accessed_more_than(g, thr) * 100.0),
            ]);
        }
        print!("{}", t.render());
        println!();
    }
    println!("paper series (soc-twitter-2010): vertices >2: 18.9%; edges >2: 88%; edges >5: 60.9%");
}
