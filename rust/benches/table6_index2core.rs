//! Table VI reproduction: NbrCore vs CntCore vs HistoCore (+ l2).
//!
//! Paper shape to check: CntCore beats NbrCore (precise frontiers, avg
//! 1.8x), HistoCore beats CntCore by a large factor (up-to-date
//! histograms, avg 8x). Edge-access counters are printed to attribute the
//! win to the removed neighbor re-reads (§IV).
//!
//!     cargo bench --bench table6_index2core

use pico::bench::{measure, print_preamble, suite::suite, suite::Tier, BenchOptions};
use pico::coordinator::report::{geomean_speedup, Table};
use pico::core::index2core::{CntCore, HistoCore, NbrCore};
use pico::util::fmt;

fn main() {
    let opts = BenchOptions::default();
    print_preamble("Table VI — Index2core: NbrCore / CntCore / HistoCore", &opts);

    let mut t = Table::new(&[
        "dataset", "NbrCore", "CntCore", "HistoCore", "SpeedUp", "l2", "edgeacc N/C/H",
    ]);
    let mut nbr_cnt = Vec::new();
    let mut cnt_hist = Vec::new();
    for entry in suite(Tier::from_env()) {
        let g = entry.build();
        let nbr = measure(&NbrCore, &g, &opts);
        let cnt = measure(&CntCore, &g, &opts);
        let hst = measure(&HistoCore, &g, &opts);
        nbr_cnt.push((nbr.ms(), cnt.ms()));
        cnt_hist.push((cnt.ms(), hst.ms()));
        t.row(vec![
            entry.name.to_string(),
            fmt::ms(nbr.ms()),
            fmt::ms(cnt.ms()),
            fmt::ms(hst.ms()),
            fmt::speedup(cnt.ms() / hst.ms()),
            hst.instrumented.iterations.to_string(),
            format!(
                "{}/{}/{}",
                fmt::si(nbr.instrumented.metrics.edge_accesses),
                fmt::si(cnt.instrumented.metrics.edge_accesses),
                fmt::si(hst.instrumented.metrics.edge_accesses)
            ),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\ngeomean CntCore speedup over NbrCore:   {} (paper: avg 1.8x)",
        fmt::speedup(geomean_speedup(&nbr_cnt))
    );
    println!(
        "geomean HistoCore speedup over CntCore: {} (paper: avg 8x)",
        fmt::speedup(geomean_speedup(&cnt_hist))
    );
}
