//! Table VII reproduction: the paradigm crossover — optimal Peel (PO-dyn)
//! vs optimal Index2core (HistoCore), with l1 and l2 side by side.
//!
//! Paper shape to check: PO-dyn wins where k_max is small relative to the
//! graph; HistoCore wins (1.1–3.2x) exactly on the graphs where l2 is far
//! below l1 = k_max (deep hierarchies). Extra deep-hierarchy graphs are
//! appended beyond the standard suite to chart where the crossover falls.
//!
//!     cargo bench --bench table7_crossover
//!
//! `PICO_BENCH_QUICK=1` shrinks to the Small tier plus scaled-down
//! deep-hierarchy extras and writes `BENCH_table7_crossover.json` for
//! the CI perf trail.

use pico::bench::suite::{quick_bench, suite, write_bench_json, Tier};
use pico::bench::{measure, print_preamble, BenchOptions};
use pico::coordinator::report::Table;
use pico::core::hybrid::{Choice, Hybrid};
use pico::core::index2core::HistoCore;
use pico::core::peel::PoDyn;
use pico::graph::{gen, CsrGraph};
use pico::util::fmt;

fn deep_extras() -> Vec<CsrGraph> {
    if quick_bench() {
        // same regimes, CI-sized: one core-periphery, one clique chain
        return vec![gen::core_periphery(8_000, 40, 3), gen::nested_cliques(12, 8, 5).0];
    }
    vec![
        // core-periphery: the regime of the paper's HistoCore-winning web
        // graphs (indochina/webbase/it): big sparse |V|, k_max set by a
        // small deep core -> l1 * |V| scans dwarf |E|
        gen::core_periphery(150_000, 120, 3),
        gen::core_periphery(300_000, 250, 5),
        // clique chains with ever deeper hierarchies: k_max 185 -> 388
        gen::nested_cliques(30, 12, 6).0,
        gen::nested_cliques(38, 15, 10).0,
        // planted ladders
        gen::planted_core(
            30_000,
            150_000,
            &[(6_000, 24), (1_500, 60), (300, 120), (60, 200)],
            23,
        ),
        gen::planted_core(
            20_000,
            80_000,
            &[(8_000, 16), (4_000, 32), (2_000, 64), (1_000, 96), (500, 128)],
            29,
        ),
    ]
}

fn main() {
    let opts = BenchOptions::default();
    print_preamble("Table VII — Peel vs Index2core crossover", &opts);

    let mut t = Table::new(&[
        "dataset", "|E|", "PO-dyn", "l1", "HistoCore", "l2", "l1/l2", "winner", "hybrid picks",
    ]);
    let mut hybrid_correct = 0usize;
    let mut hybrid_total = 0usize;
    let mut last: Option<(String, f64, f64)> = None;
    let mut run = |g: &CsrGraph| {
        let pod = measure(&PoDyn, g, &opts);
        let hst = measure(&HistoCore, g, &opts);
        let l1 = pod.instrumented.iterations.max(1);
        let l2 = hst.instrumented.iterations.max(1);
        // the paper's §VII future work: does the hybrid selector pick the
        // measured winner?
        let pick = Hybrid::default().choose(g);
        let winner_is_histo = hst.ms() < pod.ms();
        let pick_is_histo = pick == Choice::Index2core;
        hybrid_total += 1;
        // count near-ties (within 15%) as correct either way
        let tie = (hst.ms() - pod.ms()).abs() / pod.ms().max(hst.ms()) < 0.15;
        if tie || pick_is_histo == winner_is_histo {
            hybrid_correct += 1;
        }
        t.row(vec![
            g.name.clone(),
            fmt::si(g.num_edges()),
            fmt::ms(pod.ms()),
            l1.to_string(),
            fmt::ms(hst.ms()),
            l2.to_string(),
            format!("{:.1}", l1 as f64 / l2 as f64),
            if winner_is_histo {
                format!("HistoCore {}", fmt::speedup(pod.ms() / hst.ms()))
            } else {
                format!("PO-dyn {}", fmt::speedup(hst.ms() / pod.ms()))
            },
            format!("{pick:?}"),
        ]);
        last = Some((g.name.clone(), pod.ms(), hst.ms()));
    };

    let tier = if quick_bench() { Tier::Small } else { Tier::from_env() };
    for entry in suite(tier) {
        run(&entry.build());
    }
    for g in deep_extras() {
        run(&g);
    }
    print!("{}", t.render());
    println!("\npaper shape: HistoCore wins exactly where l1/l2 is large (deep hierarchies).");
    println!(
        "hybrid selector (paper §VII future work) picks the measured winner or a near-tie on {hybrid_correct}/{hybrid_total} graphs"
    );
    if let Some((name, podyn_ms, histocore_ms)) = last {
        write_bench_json(
            "table7_crossover",
            &name,
            &[
                ("podyn_ms", podyn_ms),
                ("histocore_ms", histocore_ms),
                ("histocore_speedup_x", podyn_ms / histocore_ms),
                ("hybrid_pick_accuracy", hybrid_correct as f64 / hybrid_total.max(1) as f64),
            ],
        );
    }
}
