//! Sharded-serving benchmark: routed query throughput and merge overhead
//! as the shard count scales 1 → 2 → 4 → 8.
//!
//! Three questions per shard count:
//!
//! 1. **Build** — what does partition + per-shard decomposition + the
//!    initial boundary-refinement merge cost versus a single index?
//! 2. **Queries** — routed point lookups (coreness via the owner shard)
//!    and fan-out aggregates (histogram merged from per-shard partials),
//!    in queries/sec.
//! 3. **Updates** — per-flush latency for a mixed edit batch, split into
//!    shard-apply time vs merge (refinement) time, with exchange rounds
//!    and boundary-value refreshes — the price of exact merged answers.
//!
//!     cargo bench --bench shard_scaling
//!     PICO_SUITE=small cargo bench --bench shard_scaling   # quicker
//!     PICO_BENCH_QUICK=1 cargo bench --bench shard_scaling # CI smoke
//!
//! Every configuration is oracle-checked against `bz_coreness` on the
//! assembled graph before its numbers are printed.

use pico::bench::suite::{quick_bench, Tier};
use pico::core::bz::bz_coreness;
use pico::core::maintenance::EdgeEdit;
use pico::graph::{gen, CsrGraph};
use pico::service::{BatchConfig, CoreIndex};
use pico::shard::{PartitionStrategy, ShardedIndex};
use pico::util::fmt;
use pico::util::rng::Rng;
use pico::util::timer::{Samples, Timer};

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];
const POINT_QUERIES: usize = 200_000;
const HISTO_QUERIES: usize = 200;
const FLUSHES: usize = 20;
const BATCH: usize = 64;

fn workload(tier: Tier) -> CsrGraph {
    if quick_bench() {
        return gen::barabasi_albert(1_200, 4, 42);
    }
    match tier {
        Tier::Small | Tier::Xla => gen::barabasi_albert(5_000, 6, 42),
        _ => gen::barabasi_albert(20_000, 8, 42),
    }
}

fn random_edits(rng: &mut Rng, n: u32, count: usize) -> Vec<EdgeEdit> {
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let u = rng.below(n as u64) as u32;
        let v = rng.below(n as u64) as u32;
        if u == v {
            continue;
        }
        out.push(if rng.chance(0.6) {
            EdgeEdit::Insert(u, v)
        } else {
            EdgeEdit::Delete(u, v)
        });
    }
    out
}

struct Row {
    shards: usize,
    build_ms: f64,
    boundary: u64,
    point_qps: f64,
    histo_qps: f64,
    flush_p50_ms: f64,
    merge_p50_ms: f64,
    merge_share: f64,
    rounds: f64,
    boundary_updates: f64,
}

fn bench_shard_count(g: &CsrGraph, shards: usize) -> Row {
    let n = g.num_vertices() as u32;
    let point_queries = if quick_bench() { 2_000 } else { POINT_QUERIES };
    let histo_queries = if quick_bench() { 10 } else { HISTO_QUERIES };
    let num_flushes = if quick_bench() { 3 } else { FLUSHES };

    let t = Timer::start();
    let idx = ShardedIndex::new(
        "bench",
        g,
        shards,
        PartitionStrategy::Hash,
        BatchConfig::default(),
    );
    let build_ms = t.elapsed_ms();

    // routed point queries (owner-shard lookup per vertex)
    let mut rng = Rng::new(7 + shards as u64);
    let mut sink = 0u64;
    let t = Timer::start();
    for _ in 0..point_queries {
        let v = rng.below(n as u64) as u32;
        sink ^= idx.coreness(v).unwrap_or(0) as u64;
    }
    let point_qps = point_queries as f64 / t.elapsed().as_secs_f64();

    // fan-out aggregates (per-shard histograms merged cell-wise)
    let t = Timer::start();
    for _ in 0..histo_queries {
        sink ^= idx.histogram().iter().sum::<u64>();
    }
    let histo_qps = histo_queries as f64 / t.elapsed().as_secs_f64();
    std::hint::black_box(sink);

    // update path: mixed batches, flush latency split apply vs merge
    let mut flushes = Samples::default();
    let mut merges = Samples::default();
    let mut rounds = 0usize;
    let mut boundary_updates = 0u64;
    for _ in 0..num_flushes {
        for e in random_edits(&mut rng, n, BATCH) {
            idx.submit(e);
        }
        let out = idx.flush();
        flushes.push(out.elapsed);
        merges.push(out.merge_elapsed);
        rounds += out.merge.rounds;
        boundary_updates += out.merge.boundary_updates;
    }

    // correctness backstop: never report numbers for a broken index
    let (snap, graph) = idx.consistent_view();
    assert_eq!(
        snap.core,
        bz_coreness(&graph),
        "sharded state diverged from the oracle at {shards} shards"
    );

    let flush_p50 = flushes.percentile_ms(50.0);
    let merge_p50 = merges.percentile_ms(50.0);
    Row {
        shards,
        build_ms,
        boundary: idx.boundary_edges(),
        point_qps,
        histo_qps,
        flush_p50_ms: flush_p50,
        merge_p50_ms: merge_p50,
        merge_share: if flush_p50 > 0.0 { merge_p50 / flush_p50 * 100.0 } else { 0.0 },
        rounds: rounds as f64 / num_flushes as f64,
        boundary_updates: boundary_updates as f64 / num_flushes as f64,
    }
}

fn main() {
    let tier = Tier::from_env();
    let g = workload(tier);
    println!(
        "== shard_scaling == dataset {} (|V|={}, |E|={}, tier {:?})\n",
        g.name,
        fmt::si(g.num_vertices() as u64),
        fmt::si(g.num_edges()),
        tier
    );

    // single-index baseline for the build + point-query columns
    let t = Timer::start();
    let single = CoreIndex::new("baseline", &g);
    let single_build = t.elapsed_ms();
    let snap = single.snapshot();
    let mut rng = Rng::new(3);
    let mut sink = 0u64;
    let base_queries = if quick_bench() { 2_000 } else { POINT_QUERIES };
    let t = Timer::start();
    for _ in 0..base_queries {
        let v = rng.below(g.num_vertices() as u64) as u32;
        sink ^= snap.coreness(v).unwrap_or(0) as u64;
    }
    std::hint::black_box(sink);
    println!(
        "single-index baseline: build {} | {} point queries/sec\n",
        fmt::ms(single_build),
        fmt::si((base_queries as f64 / t.elapsed().as_secs_f64()) as u64)
    );

    println!(
        "{:>6}  {:>10}  {:>10}  {:>11}  {:>10}  {:>10}  {:>10}  {:>7}  {:>9}  {:>9}",
        "shards",
        "build",
        "boundary",
        "point q/s",
        "histo q/s",
        "flush p50",
        "merge p50",
        "merge%",
        "rounds",
        "bnd-upd"
    );
    let mut json: Vec<(String, f64)> = Vec::new();
    for &shards in &SHARD_COUNTS {
        let r = bench_shard_count(&g, shards);
        println!(
            "{:>6}  {:>10}  {:>10}  {:>11}  {:>10}  {:>10}  {:>10}  {:>6.1}%  {:>9.1}  {:>9.0}",
            r.shards,
            fmt::ms(r.build_ms),
            fmt::commas(r.boundary),
            fmt::si(r.point_qps as u64),
            fmt::si(r.histo_qps as u64),
            fmt::ms(r.flush_p50_ms),
            fmt::ms(r.merge_p50_ms),
            r.merge_share,
            r.rounds,
            r.boundary_updates
        );
        json.push((format!("point_qps_{shards}shards"), r.point_qps));
        json.push((format!("flush_p50_ms_{shards}shards"), r.flush_p50_ms));
        json.push((format!("merge_p50_ms_{shards}shards"), r.merge_p50_ms));
    }
    println!(
        "\nmerge% = refinement share of flush latency — the overhead the\n\
         boundary exchange pays for exact merged coreness at each epoch"
    );
    let borrowed: Vec<(&str, f64)> = json.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    pico::bench::suite::write_bench_json("shard_scaling", &g.name, &borrowed);
}
