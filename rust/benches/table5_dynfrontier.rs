//! Table V reproduction: the dynamic frontier + assertion method.
//! Columns: PeelOne (static rounds, l1 = Σ per-level sub-iterations),
//! PP-dyn (SOTA [21], l1 = k_max, extra atomicAdds), PO-dyn (proposed).
//!
//! Paper shape to check: dynamic frontiers collapse l1 to k_max
//! (2–25.8x fewer iterations, avg 11x) and dominate time on almost every
//! dataset; PO-dyn edges out PP-dyn by eliminating under-core atomics.
//!
//!     cargo bench --bench table5_dynfrontier

use pico::bench::{measure, print_preamble, suite::suite, suite::Tier, BenchOptions};
use pico::coordinator::report::{geomean_speedup, Table};
use pico::core::peel::{PeelOne, PoDyn, PpDyn};
use pico::util::fmt;

fn main() {
    let opts = BenchOptions::default();
    print_preamble("Table V — dynamic frontiers + assertion", &opts);

    let mut t = Table::new(&[
        "dataset",
        "PeelOne(l1)",
        "PP-dyn(l1)",
        "SpeedUp",
        "PO-dyn(l1)",
        "iter-reduction",
    ]);
    let mut pairs = Vec::new();
    for entry in suite(Tier::from_env()) {
        let g = entry.build();
        let stat = measure(&PeelOne, &g, &opts);
        let ppd = measure(&PpDyn, &g, &opts);
        let pod = measure(&PoDyn, &g, &opts);
        pairs.push((stat.ms(), pod.ms()));
        t.row(vec![
            entry.name.to_string(),
            format!("{}({})", fmt::ms(stat.ms()), stat.instrumented.iterations),
            format!("{}({})", fmt::ms(ppd.ms()), ppd.instrumented.iterations),
            fmt::speedup(stat.ms() / ppd.ms()),
            format!("{}({})", fmt::ms(pod.ms()), pod.instrumented.iterations),
            fmt::speedup(stat.instrumented.iterations as f64 / pod.instrumented.iterations as f64),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\ngeomean PO-dyn speedup over static PeelOne: {} (paper: avg 5.2x for PP-dyn)",
        fmt::speedup(geomean_speedup(&pairs))
    );
}
