//! Cluster-serving acceptance tests:
//!
//! 1. **Loopback cluster vs oracle** — a `ClusterIndex` whose shards are
//!    split between the coordinator and remote `pico serve` loopback
//!    servers returns coreness / members / histogram / degeneracy
//!    answers byte-identical to a single `CoreIndex`, before and after
//!    routed edit batches (flushed in lockstep with the single index).
//! 2. **Fault paths** — replica failover on dead hosts and truncated
//!    connections, stale-epoch replicas rejected by epoch-checked reads,
//!    and catch-up restoring them *without recomputing*.
//! 3. **Delta catch-up** — a replica lagging N epochs catches up via the
//!    journal's `SHARDDELTA` chain to a manifest byte-identical to the
//!    primary's; journal gaps and corrupt/mismatched chains fall back to
//!    the full-manifest re-ship; served `FLUSH` never blocks on replica
//!    sync (the background daemon converges the replicas).
//! 4. **Multi-process equivalence** — the same pinning against real
//!    `pico serve` child processes, plus graceful SIGTERM shutdown.
//! 5. **Elastic resharding** — shard split/merge and live primary
//!    migration under routed edits stay byte-identical to the
//!    single-index oracle; aborted migrations leave the group
//!    recoverable; the `CLUSTER` admin namespace answers over the wire
//!    with its legacy aliases byte-identical; the full-ship size hint
//!    refreshes after any ownership change.

use pico::cluster::{manifest_for, ClusterConfig, ClusterIndex, Primary, RemoteShard, ReplicaGroup};
use pico::core::bz::bz_coreness;
use pico::core::maintenance::EdgeEdit;
use pico::graph::gen;
use pico::service::{apply_batch, serve, BatchConfig, CoreIndex, CoreService, ServerHandle};
use pico::shard::backend::{LocalShard, ShardBackend};
use pico::shard::partition::{partition, PartitionStrategy};
use pico::shard::router::refine;
use pico::util::rng::Rng;
use std::sync::Arc;

fn cfg() -> BatchConfig {
    BatchConfig {
        threads: 1,
        ..BatchConfig::default()
    }
}

/// An in-process `pico serve` on a loopback port — "remote" to every
/// `RemoteShard` that dials it.
fn spawn_server() -> (Arc<CoreService>, ServerHandle, String) {
    let svc = Arc::new(CoreService::new(cfg()));
    let handle = serve(svc.clone(), "127.0.0.1:0").expect("bind");
    let addr = handle.addr().to_string();
    (svc, handle, addr)
}

fn check_against_oracle(cl: &ClusterIndex, single: &CoreIndex) {
    let want = single.snapshot();
    let got = cl.snapshot();
    assert_eq!(got.core, want.core, "merged snapshot must be byte-identical");
    assert_eq!(got.epoch, want.epoch);
    assert_eq!(got.num_edges, want.num_edges);
    assert_eq!(cl.degeneracy(), want.degeneracy());
    assert_eq!(cl.histogram_routed().unwrap(), want.histogram());
    for v in 0..want.num_vertices() as u32 {
        assert_eq!(cl.coreness_routed(v).unwrap(), want.coreness(v), "v{v}");
    }
    assert_eq!(
        cl.coreness_routed(want.num_vertices() as u32).unwrap(),
        None
    );
    for k in 0..=want.k_max + 1 {
        assert_eq!(cl.members_routed(k).unwrap(), want.kcore_members(k), "k={k}");
        assert_eq!(cl.kcore_size_routed(k).unwrap(), want.kcore_size(k), "k={k}");
    }
}

#[test]
fn loopback_cluster_matches_single_index_oracle() {
    let g = gen::barabasi_albert(120, 3, 7);
    let (_svc_a, _handle_a, addr_a) = spawn_server();
    let (_svc_b, _handle_b, addr_b) = spawn_server();
    // mixed topology: a local shard (with remote replicas on both
    // servers) and one remote primary on each server
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = soc\nshards = 3\n\
         [shard.0]\nprimary = local\nreplicas = {addr_a}, {addr_b}\n\
         [shard.1]\nprimary = {addr_a}\n\
         [shard.2]\nprimary = {addr_b}\n"
    ))
    .unwrap();
    let cl = ClusterIndex::build(&g, &topo, cfg()).unwrap();
    let single = CoreIndex::new("single", &g);
    check_against_oracle(&cl, &single);

    // routed edit batches in lockstep with the single index; ids may
    // exceed |V| so the vertex set grows across hosts too
    let mut rng = Rng::new(0xC1);
    let mut n = g.num_vertices() as u64;
    for round in 0..4 {
        let mut edits = Vec::new();
        while edits.len() < 10 {
            let u = rng.below(n + 8) as u32;
            let v = rng.below(n + 8) as u32;
            if u == v {
                continue;
            }
            edits.push(if rng.chance(0.6) {
                EdgeEdit::Insert(u, v)
            } else {
                EdgeEdit::Delete(u, v)
            });
        }
        for &e in &edits {
            cl.submit(e);
        }
        let out = cl.flush().unwrap();
        let single_out = apply_batch(&single, &edits, &cfg());
        assert_eq!(out.snapshot.epoch, single_out.snapshot.epoch, "round {round}");
        assert_eq!(out.snapshot.core, single_out.snapshot.core, "round {round}");
        assert_eq!(out.applied, single_out.applied, "round {round}");
        assert_eq!(out.changed, single_out.changed, "round {round}");
        n = out.snapshot.num_vertices() as u64;
        cl.sync_replicas().unwrap();
        check_against_oracle(&cl, &single);
    }
    let (snap, graph) = cl.consistent_view().unwrap();
    assert_eq!(snap.core, bz_coreness(&graph), "assembled graph vs BZ oracle");
}

#[test]
fn replica_failover_and_stale_rejection() {
    let g = gen::erdos_renyi(80, 200, 11);
    let oracle = bz_coreness(&g);
    let plan = partition(&g, 1, PartitionStrategy::Hash);
    let local = Arc::new(LocalShard::from_plan("f", &plan.shards[0], cfg()));
    let backends: Vec<Arc<dyn ShardBackend>> = vec![local.clone() as Arc<dyn ShardBackend>];
    let refined = refine(&backends, g.num_vertices(), None, 0, 1).unwrap();
    assert_eq!(refined.core, oracle);

    let (_svc, _handle, addr) = spawn_server();
    let live = Arc::new(RemoteShard::new(0, addr, "f/shard0"));
    live.host(&manifest_for(&local, 1)).unwrap();
    // reserved port: every dial is refused
    let dead = Arc::new(RemoteShard::new(0, "127.0.0.1:1", "f/shard0"));
    let group = ReplicaGroup::new(Primary::Local(local.clone()), vec![dead, live.clone()]);

    for v in 0..g.num_vertices() as u32 {
        let got = group.read(0, |b| b.refined_coreness(v)).unwrap();
        assert_eq!(got, Some(oracle[v as usize]), "v{v}");
    }
    assert!(group.failovers() > 0, "dead replica must fail over");
    assert_eq!(group.stale_reads(), 0);

    // advance the primary one committed epoch: the live replica is now
    // stale, must be rejected, and answers still come out correct
    refine(&backends, g.num_vertices(), Some(0), 1, 1).unwrap();
    let before = group.stale_reads();
    for v in 0..20u32 {
        let got = group.read(1, |b| b.refined_coreness(v)).unwrap();
        assert_eq!(got, Some(oracle[v as usize]));
    }
    assert!(group.stale_reads() > before, "stale replies must be rejected");

    // snapshot catch-up: after re-shipping the committed manifest the
    // live replica serves epoch-1 reads without further rejections
    live.host(&manifest_for(&local, 1)).unwrap();
    let frozen = group.stale_reads();
    for v in 0..20u32 {
        let got = group.read(1, |b| b.refined_coreness(v)).unwrap();
        assert_eq!(got, Some(oracle[v as usize]));
    }
    assert_eq!(group.stale_reads(), frozen);
}

#[test]
fn truncated_and_garbage_connections_error_cleanly() {
    use std::io::{Read, Write};
    use std::net::TcpListener;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake = std::thread::spawn(move || {
        // first connection truncates a reply frame mid-body; the second
        // answers the upgrade with garbage
        for (i, stream) in listener.incoming().take(2).enumerate() {
            let mut s = stream.unwrap();
            let mut buf = [0u8; 256];
            let _ = s.read(&mut buf); // swallow "BINARY\n"
            if i == 0 {
                let _ = s.write_all(b"OK binary\n");
                let _ = s.read(&mut buf); // the USE frame
                // length prefix promises 100 bytes; ship 4 and close
                let _ = s.write_all(&100u32.to_le_bytes());
                let _ = s.write_all(b"oops");
            } else {
                let _ = s.write_all(b"garbage\n");
            }
        }
    });
    let truncated = RemoteShard::new(0, addr.clone(), "x/shard0");
    assert!(truncated.status().is_err(), "truncated reply must error");
    let garbage = RemoteShard::new(0, addr, "x/shard0");
    assert!(garbage.status().is_err(), "bad upgrade ack must error");
    fake.join().unwrap();
}

#[test]
fn stale_replicas_catch_up_via_snapshot_ship() {
    let g = gen::barabasi_albert(100, 3, 13);
    let (_svc, _handle, addr) = spawn_server();
    // journal = 0 pins the *full-manifest* path: with the journal
    // disabled, every catch-up must re-ship the whole shard
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = cc\nshards = 2\njournal = 0\n\
         [shard.0]\nprimary = local\nreplicas = {addr}\n\
         [shard.1]\nprimary = local\n"
    ))
    .unwrap();
    let cl = ClusterIndex::build(&g, &topo, cfg()).unwrap();
    let st = cl.status();
    assert_eq!(st[0].replicas[0].1.as_ref().unwrap().cluster_epoch, 0);

    // flush without syncing: the replica misses epoch 1
    for i in 0..6u32 {
        cl.submit(EdgeEdit::Insert(i, i + 40));
    }
    cl.flush().unwrap();
    let stale_before = cl.groups()[0].stale_reads();
    for v in 0..g.num_vertices() as u32 {
        cl.coreness_routed(v).unwrap();
    }
    assert!(
        cl.groups()[0].stale_reads() > stale_before,
        "epoch-checked reads must reject the stale replica"
    );
    assert_eq!(cl.status()[0].replicas[0].1.as_ref().unwrap().cluster_epoch, 0);

    // snapshot catch-up
    let report = cl.sync_replicas().unwrap();
    assert_eq!(report.shipped(), 1);
    assert_eq!(report.snapshots, 1, "journal disabled: the full path must serve");
    assert_eq!(report.deltas, 0);
    assert!(report.snapshot_bytes > 0);
    let rs = cl.status();
    let replica = rs[0].replicas[0].1.as_ref().unwrap();
    assert_eq!(replica.cluster_epoch, 1, "replica caught up to the flush epoch");
    // hydrated, not recomputed: the replica resumes at the primary's own
    // shard epoch (a recompute would have published a fresh one)
    assert_eq!(replica.epoch, rs[0].primary.as_ref().unwrap().epoch);

    // reads at the new epoch land on the replica with no rejections
    let frozen = cl.groups()[0].stale_reads();
    let (snap, graph) = cl.consistent_view().unwrap();
    assert_eq!(snap.core, bz_coreness(&graph));
    for v in 0..snap.num_vertices() as u32 {
        assert_eq!(cl.coreness_routed(v).unwrap(), snap.coreness(v));
    }
    assert_eq!(cl.groups()[0].stale_reads(), frozen);
    // everything already in sync: nothing ships
    assert_eq!(cl.sync_replicas().unwrap().shipped(), 0);
}

#[test]
fn lagging_replica_catches_up_via_delta_chain() {
    let g = gen::barabasi_albert(120, 3, 19);
    let (_svc, _handle, addr) = spawn_server();
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = dc\nshards = 2\n\
         [shard.0]\nprimary = local\nreplicas = {addr}\n\
         [shard.1]\nprimary = local\n"
    ))
    .unwrap();
    let cl = ClusterIndex::build(&g, &topo, cfg()).unwrap();
    let single = CoreIndex::new("single", &g);

    // let the replica fall 3 epochs behind (no sync between flushes),
    // in lockstep with the single-index oracle
    let mut rng = Rng::new(0xDE17A);
    let mut n = g.num_vertices() as u64;
    for _ in 0..3 {
        let mut edits = Vec::new();
        while edits.len() < 8 {
            let u = rng.below(n + 6) as u32;
            let v = rng.below(n + 6) as u32;
            if u == v {
                continue;
            }
            edits.push(if rng.chance(0.7) {
                EdgeEdit::Insert(u, v)
            } else {
                EdgeEdit::Delete(u, v)
            });
        }
        for &e in &edits {
            cl.submit(e);
        }
        let out = cl.flush().unwrap();
        apply_batch(&single, &edits, &cfg());
        n = out.snapshot.num_vertices() as u64;
    }
    assert_eq!(cl.epoch(), 3);
    let st = cl.status();
    assert_eq!(
        st[0].replicas[0].1.as_ref().unwrap().cluster_epoch,
        0,
        "replica must be 3 epochs behind before the sync"
    );

    // catch-up must take the delta path, and the chain must be smaller
    // than the full manifest it replaces
    let report = cl.sync_replicas().unwrap();
    assert_eq!(report.deltas, 1, "the journal covers the lag: delta path");
    assert_eq!(report.snapshots, 0);
    assert_eq!(report.failed, 0);
    assert_eq!(report.max_lag_epochs, 3);
    let full = cl.groups()[0].primary_manifest(2).unwrap();
    assert!(
        report.delta_bytes < full.len() as u64,
        "delta chain ({} B) must undercut the manifest ({} B)",
        report.delta_bytes,
        full.len()
    );
    assert_eq!(cl.groups()[0].sync_stats().deltas_shipped, 1);

    // the replayed replica is byte-identical to the primary: same
    // manifest (graph, id tables, refined coreness, both epochs) —
    // nothing was recomputed, everything was replayed
    let replica_manifest = cl.groups()[0].replicas()[0].fetch_manifest().unwrap();
    assert_eq!(replica_manifest, full, "replica manifest must equal the primary's");
    let rs = cl.status();
    let replica = rs[0].replicas[0].1.as_ref().unwrap();
    assert_eq!(replica.cluster_epoch, 3);
    assert_eq!(replica.epoch, rs[0].primary.as_ref().unwrap().epoch);

    // reads at the head land on the replica with no stale rejections,
    // and the merged answers still equal the single-index oracle
    let frozen = cl.groups()[0].stale_reads();
    check_against_oracle(&cl, &single);
    assert_eq!(cl.groups()[0].stale_reads(), frozen);
    // a second pass has nothing to do
    assert_eq!(cl.sync_replicas().unwrap().shipped(), 0);
}

#[test]
fn journal_gap_falls_back_to_full_manifest_ship() {
    let g = gen::erdos_renyi(80, 220, 23);
    let (_svc, _handle, addr) = spawn_server();
    // retention 2 < the 4 epochs of lag we create: the chain has a gap
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = gap\nshards = 1\njournal = 2\n\
         [shard.0]\nprimary = local\nreplicas = {addr}\n"
    ))
    .unwrap();
    let cl = ClusterIndex::build(&g, &topo, cfg()).unwrap();
    for i in 0..4u32 {
        cl.submit(EdgeEdit::Insert(i, i + 30));
        cl.submit(EdgeEdit::Insert(i + 1, i + 50));
        cl.flush().unwrap();
    }
    assert_eq!(cl.epoch(), 4);
    let gap = cl.journal_chain_bytes(0, 0, 4);
    assert!(gap.is_none(), "retention 2 cannot cover a 4-epoch chain");
    assert!(cl.journal_chain_bytes(0, 2, 4).is_some());

    let report = cl.sync_replicas().unwrap();
    assert_eq!(report.snapshots, 1, "the gap forces the full-manifest path");
    assert_eq!(report.deltas, 0);
    assert_eq!(report.failed, 0);
    // the re-shipped replica equals the primary byte-for-byte too
    let full = cl.groups()[0].primary_manifest(1).unwrap();
    assert_eq!(cl.groups()[0].replicas()[0].fetch_manifest().unwrap(), full);
    let (snap, graph) = cl.consistent_view().unwrap();
    assert_eq!(snap.core, bz_coreness(&graph));

    // ...and now that the replica is within retention, deltas serve again
    cl.submit(EdgeEdit::Insert(2, 70));
    cl.flush().unwrap();
    let report = cl.sync_replicas().unwrap();
    assert_eq!((report.deltas, report.snapshots), (1, 0));
}

#[test]
fn corrupt_or_mismatched_deltas_fall_back_to_full_ship() {
    use pico::cluster::EpochDelta;
    use pico::cluster::wire;

    let g = gen::erdos_renyi(60, 150, 29);
    let plan = partition(&g, 1, PartitionStrategy::Hash);
    let primary = Arc::new(LocalShard::from_plan("cx", &plan.shards[0], cfg()));
    let backends: Vec<Arc<dyn ShardBackend>> = vec![primary.clone() as Arc<dyn ShardBackend>];
    refine(&backends, g.num_vertices(), None, 0, 1).unwrap();

    let (_svc, _handle, addr) = spawn_server();
    let replica = RemoteShard::new(0, addr, "cx/shard0");
    replica.host(&manifest_for(&primary, 1)).unwrap();
    let epoch_before = replica.status().unwrap().cluster_epoch;
    assert_eq!(epoch_before, 0);

    // a chain whose base is ahead of the replica's epoch is refused
    let stray = [EpochDelta {
        to_epoch: 6,
        batch: Default::default(),
        diff: vec![],
    }];
    let refs: Vec<&EpochDelta> = stray.iter().collect();
    let chain = wire::encode_delta_chain(5, 6, &refs);
    let err = replica.apply_delta(5, 6, &chain).unwrap_err();
    assert!(format!("{err:#}").contains("replica is at 0"), "{err:#}");

    // corrupt payloads (truncated, bit-flipped magic) are refused
    let ok = [EpochDelta {
        to_epoch: 1,
        batch: Default::default(),
        diff: vec![],
    }];
    let refs: Vec<&EpochDelta> = ok.iter().collect();
    let chain = wire::encode_delta_chain(0, 1, &refs);
    assert!(replica.apply_delta(0, 1, &chain[..chain.len() - 1]).is_err());
    let mut evil = chain.clone();
    evil[0] ^= 0xFF;
    assert!(replica.apply_delta(0, 1, &evil).is_err());
    // a diff claiming an impossible coreness is refused
    let lying = [EpochDelta {
        to_epoch: 1,
        batch: Default::default(),
        diff: vec![(0, 10_000)],
    }];
    let refs: Vec<&EpochDelta> = lying.iter().collect();
    let lying_chain = wire::encode_delta_chain(0, 1, &refs);
    assert!(replica.apply_delta(0, 1, &lying_chain).is_err());

    // every rejection left the replica untouched at its old epoch...
    assert_eq!(replica.status().unwrap().cluster_epoch, epoch_before);
    // ...and a full-manifest re-ship still recovers it completely
    refine(&backends, g.num_vertices(), Some(0), 1, 1).unwrap();
    replica.host(&manifest_for(&primary, 1)).unwrap();
    assert_eq!(replica.status().unwrap().cluster_epoch, 1);
    assert_eq!(replica.fetch_manifest().unwrap(), manifest_for(&primary, 1));
}

#[test]
fn failed_flush_forces_full_ship_before_deltas_resume() {
    use pico::shard::hash_owner;

    let g = gen::erdos_renyi(70, 180, 37);
    let (_rsvc, _rhandle, replica_addr) = spawn_server();
    let (doomed_svc, doomed_handle, doomed_addr) = spawn_server();
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = po\nshards = 2\n\
         [shard.0]\nprimary = local\nreplicas = {replica_addr}\n\
         [shard.1]\nprimary = {doomed_addr}\n"
    ))
    .unwrap();
    let cl = ClusterIndex::build(&g, &topo, cfg()).unwrap();
    // shard-internal edits, one per shard, so the failing flush below
    // applies shard 0 (local) first and then dies on shard 1 (remote)
    let pick = |shard: u32| -> (u32, u32) {
        let mut it = (0..70u32).filter(|&v| hash_owner(v, 2) == shard);
        (it.next().unwrap(), it.next().unwrap())
    };
    let (a0, b0) = pick(0);
    let (a1, b1) = pick(1);

    // healthy round first: the delta path serves
    cl.submit(EdgeEdit::Insert(a0, b0));
    cl.submit(EdgeEdit::Insert(a1, b1));
    cl.flush().unwrap();
    assert_eq!(cl.sync_replicas().unwrap().deltas, 1);

    // kill shard 1's primary mid-life (drain closes its connections so
    // the next SHARDAPPLY fails instead of reusing the pooled socket)
    doomed_handle.drain(std::time::Duration::from_secs(5));
    drop(doomed_handle);
    drop(doomed_svc);
    cl.submit(EdgeEdit::Delete(a0, b0));
    cl.submit(EdgeEdit::Delete(a1, b1));
    assert!(cl.flush().is_err(), "shard 1's primary is gone");

    // shard 0's primary now holds the orphaned delete with no published
    // epoch: the replica's committed epoch still MATCHES the router's,
    // but epoch equality no longer implies state equality — the next
    // sync must re-ship the full manifest, not trust a delta chain
    let report = cl.sync_replicas().unwrap();
    assert_eq!(
        (report.deltas, report.snapshots),
        (0, 1),
        "poisoned group must full-ship even an epoch-matching replica"
    );
    // ...and the re-shipped replica carries the orphaned edit too
    let full = cl.groups()[0].primary_manifest(2).unwrap();
    assert_eq!(cl.groups()[0].replicas()[0].fetch_manifest().unwrap(), full);
    // the poison clears once the group is whole again
    assert_eq!(cl.sync_replicas().unwrap().shipped(), 0);
}

#[test]
fn served_flush_never_blocks_on_sync_and_the_daemon_converges() {
    use pico::service::{ReplicaSyncDaemon, Session};
    use std::time::{Duration, Instant};

    let g = gen::barabasi_albert(90, 3, 31);
    let (_replica_svc, _replica_handle, addr) = spawn_server();
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = async\nshards = 2\n\
         [shard.0]\nprimary = local\nreplicas = {addr}\n\
         [shard.1]\nprimary = local\n"
    ))
    .unwrap();
    let cl = Arc::new(ClusterIndex::build(&g, &topo, cfg()).unwrap());
    let svc = CoreService::new(cfg());
    svc.open_cluster("async", cl.clone());
    let mut session = Session::new("async");

    // a served FLUSH publishes the primary epoch and returns — it must
    // not probe or ship replicas (no synced= field, replica still stale)
    svc.handle_command(&mut session, "INSERT 0 44", 0);
    svc.handle_command(&mut session, "INSERT 2 61", 0);
    let flush = svc.handle_command(&mut session, "FLUSH", 0);
    assert!(flush.starts_with("OK epoch=1"), "{flush}");
    assert!(!flush.contains("synced="), "FLUSH must not sync inline: {flush}");
    assert_eq!(
        cl.status()[0].replicas[0].1.as_ref().unwrap().cluster_epoch,
        0,
        "the replica must still be stale right after FLUSH"
    );

    // the background daemon converges it without any further flushes
    let daemon = ReplicaSyncDaemon::spawn(cl.clone(), Duration::from_millis(20));
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let caught_up = cl.status()[0].replicas[0]
            .1
            .as_ref()
            .map(|st| st.cluster_epoch == cl.epoch())
            .unwrap_or(false);
        if caught_up {
            break;
        }
        assert!(Instant::now() < deadline, "daemon never converged the replica");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(daemon.syncs() >= 1);
    daemon.stop();
    let stats = cl.groups()[0].sync_stats();
    assert!(
        stats.deltas_shipped + stats.snapshots_shipped >= 1,
        "the daemon's ship must be visible in the group counters: {stats:?}"
    );
    // the SHARDS verb surfaces the aggregate sync metrics
    let shards = svc.handle_command(&mut session, "SHARDS", 0);
    assert!(shards.contains("deltas="), "{shards}");
    assert!(shards.contains("lag="), "{shards}");
}

/// Kills the `pico serve` child even when an assertion fails first.
#[cfg(unix)]
struct ChildGuard(std::process::Child);

#[cfg(unix)]
impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[cfg(unix)]
fn spawn_pico_serve() -> (
    ChildGuard,
    std::io::BufReader<std::process::ChildStdout>,
    String,
) {
    use std::io::BufRead;
    use std::process::{Command, Stdio};

    let mut child = Command::new(env!("CARGO_BIN_EXE_pico"))
        .args(["serve", "--addr", "127.0.0.1:0", "--dataset", "g1", "--threads", "1"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning pico serve");
    let stdout = child.stdout.take().unwrap();
    let mut reader = std::io::BufReader::new(stdout);
    let mut addr = String::new();
    for _ in 0..50 {
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap() == 0 {
            break;
        }
        if line.starts_with("serving") {
            if let Some(rest) = line.split(" on ").nth(1) {
                addr = rest.split_whitespace().next().unwrap_or("").to_string();
                break;
            }
        }
    }
    assert!(!addr.is_empty(), "child never printed its bound address");
    (ChildGuard(child), reader, addr)
}

#[cfg(unix)]
#[test]
fn multiprocess_cluster_equivalence_and_graceful_shutdown() {
    use std::io::Read;

    let g = gen::erdos_renyi(90, 260, 17);
    let (mut child_a, mut out_a, addr_a) = spawn_pico_serve();
    let (child_b, _out_b, addr_b) = spawn_pico_serve();
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = mp\nshards = 2\n\
         [shard.0]\nprimary = {addr_a}\nreplicas = {addr_b}\n\
         [shard.1]\nprimary = {addr_b}\n"
    ))
    .unwrap();
    let cl = ClusterIndex::build(&g, &topo, cfg()).unwrap();
    let single = CoreIndex::new("single", &g);
    check_against_oracle(&cl, &single);

    // one routed batch across both processes (including vertex growth)
    let edits = vec![
        EdgeEdit::Insert(0, 1),
        EdgeEdit::Insert(2, 95),
        EdgeEdit::Delete(3, 4),
    ];
    for &e in &edits {
        cl.submit(e);
    }
    let out = cl.flush().unwrap();
    let single_out = apply_batch(&single, &edits, &cfg());
    assert_eq!(out.snapshot.core, single_out.snapshot.core);
    assert_eq!(out.snapshot.epoch, single_out.snapshot.epoch);
    cl.sync_replicas().unwrap();
    check_against_oracle(&cl, &single);

    // graceful shutdown: SIGTERM drains and exits 0, announcing it
    let pid = child_a.0.id().to_string();
    let killed = std::process::Command::new("kill")
        .args(["-TERM", &pid])
        .status()
        .unwrap();
    assert!(killed.success());
    let status = child_a.0.wait().unwrap();
    assert!(status.success(), "pico serve must exit cleanly on SIGTERM");
    let mut rest = String::new();
    out_a.read_to_string(&mut rest).unwrap();
    assert!(
        rest.contains("draining"),
        "shutdown must announce the drain, got: {rest}"
    );
    drop(child_b);
}

#[test]
fn auth_gated_shard_verbs_reject_then_accept() {
    use pico::net::{ConnConfig, NetConfig};
    use pico::service::serve_with;

    let g = gen::erdos_renyi(50, 120, 17);
    let plan = partition(&g, 1, PartitionStrategy::Hash);
    let primary = Arc::new(LocalShard::from_plan("au", &plan.shards[0], cfg()));
    let backends: Vec<Arc<dyn ShardBackend>> = vec![primary.clone() as Arc<dyn ShardBackend>];
    refine(&backends, g.num_vertices(), None, 0, 1).unwrap();
    let manifest = manifest_for(&primary, 1);

    // a shard host serving with a configured token
    let svc = Arc::new(CoreService::new(cfg()));
    let net = NetConfig {
        conn: ConnConfig {
            auth_token: Some("s3cret".into()),
            ..Default::default()
        },
        ..Default::default()
    };
    let handle = serve_with(svc, "127.0.0.1:0", net).expect("bind");
    let addr = handle.addr().to_string();

    // no AUTH preamble: the gated install is rejected before dispatch
    let unauthed = RemoteShard::new(0, addr.clone(), "au/shard0");
    let err = unauthed.host(&manifest).unwrap_err();
    assert!(
        format!("{err:#}").contains("auth required for SHARDHOST"),
        "{err:#}"
    );

    // wrong token: the preamble itself is refused (constant-time compare)
    let wrong = RemoteShard::new(0, addr.clone(), "au/shard0").with_auth(Some("nope".into()));
    let err = wrong.ping().unwrap_err();
    assert!(format!("{err:#}").contains("auth token"), "{err:#}");

    // right token: install, probe, and re-fetch all work
    let authed =
        Arc::new(RemoteShard::new(0, addr.clone(), "au/shard0").with_auth(Some("s3cret".into())));
    authed.host(&manifest).unwrap();
    assert_eq!(authed.status().unwrap().cluster_epoch, 0);
    assert_eq!(authed.fetch_manifest().unwrap(), manifest);

    // with the graph hosted, a token-less session can pin it (USE) but
    // still may not touch the gated verbs…
    let err = unauthed.fetch_manifest().unwrap_err();
    assert!(
        format!("{err:#}").contains("auth required for SHARDSNAP"),
        "{err:#}"
    );
    // …while ungated probes (SHARDINFO) never needed the token
    assert_eq!(unauthed.status().unwrap().cluster_epoch, 0);
    handle.stop();
}

#[test]
fn cluster_coordinator_redirects_shard_probes_one_hop() {
    use pico::net::client::{follow_redirect, parse_redirect, Client};
    use pico::service::serve;

    let g = gen::barabasi_albert(90, 3, 23);
    let (_shard_svc, _shard_handle, shard_addr) = spawn_server();
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = rd\nshards = 2\n\
         [shard.0]\nprimary = local\n\
         [shard.1]\nprimary = {shard_addr}\n"
    ))
    .unwrap();
    let cl = Arc::new(ClusterIndex::build(&g, &topo, cfg()).unwrap());
    let oracle = bz_coreness(&g);

    // front the cluster with a serve process, as `pico serve --cluster`
    let front = Arc::new(CoreService::new(cfg()));
    front.open_cluster("rd", cl.clone());
    let front_handle = serve(front, "127.0.0.1:0").expect("bind");
    let mut probe = Client::connect(&front_handle.addr().to_string()).unwrap();

    let mut redirected = 0usize;
    let mut inline = 0usize;
    for v in 0..g.num_vertices() as u32 {
        let reply = probe.send_line(&format!("SHARDCORE {v}")).unwrap();
        let line = match parse_redirect(&reply) {
            Some(rd) => {
                // the hint names the remote shard host and its graph
                assert_eq!(rd.addr, shard_addr, "v{v}: {reply}");
                assert_eq!(rd.graph, "rd/shard1", "v{v}: {reply}");
                redirected += 1;
                follow_redirect(&rd, &format!("SHARDCORE {v}"), None).unwrap()
            }
            None => {
                inline += 1;
                reply
            }
        };
        // redirected or inline, the answer is the exact coreness
        assert_eq!(
            line,
            format!("OK core={} cluster=0", oracle[v as usize]),
            "v{v}"
        );
    }
    assert!(redirected > 0, "shard 1 probes must redirect");
    assert!(inline > 0, "shard 0 probes answer in the coordinator");

    // out-of-range vertices stay structured errors
    let reply = probe
        .send_line(&format!("SHARDCORE {}", g.num_vertices()))
        .unwrap();
    assert!(reply.starts_with("ERR vertex"), "{reply}");
    front_handle.stop();
}

/// Observability e2e: a killed replica trips failover events and a
/// degraded HEALTH verdict; standing a server back up at the same
/// address and re-syncing recovers the verdict to ok. STATS / EVENTS /
/// HEALTH are exercised over the wire against the fronted cluster, and
/// the `pico cluster status --health` exit code is pinned via the real
/// binary.
#[cfg(unix)]
#[test]
fn dead_replica_degrades_health_and_recovery_restores_ok() {
    use pico::net::client::{field, Client};
    use pico::obs::Verdict;
    use pico::service::serve;

    let g = gen::erdos_renyi(60, 150, 43);
    let (replica_svc, replica_handle, addr) = spawn_server();
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = hl\nshards = 1\n\
         [shard.0]\nprimary = local\nreplicas = {addr}\n"
    ))
    .unwrap();
    let cl = Arc::new(ClusterIndex::build(&g, &topo, cfg()).unwrap());
    assert_eq!(
        pico::obs::health::evaluate_global(Some("hl")).verdict,
        Verdict::Ok,
        "a freshly hydrated cluster is healthy"
    );

    // kill the replica host; the bound address frees for the recovery
    // rebind below (closed listeners don't linger in TIME_WAIT)
    replica_handle.drain(std::time::Duration::from_secs(5));
    drop(replica_handle);
    drop(replica_svc);

    // reads fail over to the primary, journaling the failovers
    let failovers_before = cl.groups()[0].failovers();
    for v in 0..10u32 {
        assert!(cl.coreness_routed(v).unwrap().is_some(), "v{v}");
    }
    assert!(cl.groups()[0].failovers() > failovers_before);

    // the sync pass cannot reach the replica: the failure lands in the
    // gauge the SLO rules read, and in the event journal
    let report = cl.sync_replicas().unwrap();
    assert_eq!(report.failed, 1, "the dead replica must count as failing");
    let health = pico::obs::health::evaluate_global(Some("hl"));
    assert!(
        health.verdict >= Verdict::Degraded,
        "a failing replica must degrade the verdict: {health:?}"
    );
    assert!(
        health.reasons.iter().any(|r| r.contains("failing sync")),
        "{health:?}"
    );

    // the same state over the wire, through a fronting serve process
    let front = Arc::new(CoreService::new(cfg()));
    front.open_cluster("hl", cl.clone());
    let front_handle = serve(front, "127.0.0.1:0").expect("bind");
    let mut client = Client::connect(&front_handle.addr().to_string()).unwrap();
    let (hhead, hlines) = client.send_multiline("HEALTH hl").unwrap();
    let verdict = Verdict::parse(field(&hhead, "health").unwrap()).unwrap();
    assert!(verdict >= Verdict::Degraded, "{hhead}");
    assert!(
        hlines.iter().any(|l| l.contains("failing sync")),
        "{hhead}: {hlines:?}"
    );
    let (ehead, elines) = client.send_multiline("EVENTS 256").unwrap();
    assert!(ehead.starts_with("OK events"), "{ehead}");
    assert!(
        elines.iter().any(|l| l.contains(" sync_failed graph=hl ")),
        "the failed sync must be journaled: {elines:?}"
    );
    assert!(
        elines
            .iter()
            .any(|l| l.contains("replica_failover") && l.contains(&addr)),
        "the failovers must be journaled: {elines:?}"
    );
    // windowed STATS answers on a cluster backend too (no sampler runs
    // in this process, so the keys are present but n/a)
    let (shead, slines) = client.send_multiline("STATS 60").unwrap();
    assert!(shead.starts_with("OK stats window=60s"), "{shead}");
    assert!(slines.iter().any(|l| l.starts_with("qps ")), "{slines:?}");
    let (jhead, jlines) = client.send_multiline("STATS 60 JSON").unwrap();
    assert!(jhead.contains("format=json"), "{jhead}");
    assert!(jlines[0].starts_with("{\"window_s\":"), "{jlines:?}");

    // the CLI surfaces the outage in its exit code: the topology's only
    // remote endpoint is down
    let topo_path = std::env::temp_dir().join(format!("pico-health-{}.toml", std::process::id()));
    std::fs::write(
        &topo_path,
        format!(
            "[cluster]\nname = hl\nshards = 1\n\
             [shard.0]\nprimary = local\nreplicas = {addr}\n"
        ),
    )
    .unwrap();
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_pico"))
        .args([
            "cluster",
            "status",
            "--cluster",
            topo_path.to_str().unwrap(),
            "--health",
        ])
        .output()
        .unwrap();
    assert!(
        !out.status.success(),
        "health below ok must exit non-zero: {}",
        String::from_utf8_lossy(&out.stdout)
    );
    assert!(
        String::from_utf8_lossy(&out.stdout).contains("down"),
        "{}",
        String::from_utf8_lossy(&out.stdout)
    );
    std::fs::remove_file(&topo_path).ok();

    // recovery: a fresh server at the same address, one sync pass, and
    // the graph-scoped verdict returns to ok
    let recovered_svc = Arc::new(CoreService::new(cfg()));
    let recovered_handle = serve(recovered_svc, &addr).expect("rebinding the freed address");
    let report = cl.sync_replicas().unwrap();
    assert_eq!(report.failed, 0, "the rebound replica must hydrate");
    assert!(report.shipped() >= 1, "recovery re-ships state");
    assert_eq!(
        pico::obs::health::evaluate_global(Some("hl")).verdict,
        Verdict::Ok,
        "recovery must clear the verdict"
    );
    let (hhead, _hlines) = client.send_multiline("HEALTH hl").unwrap();
    assert_eq!(field(&hhead, "health").unwrap(), "ok", "{hhead}");
    client.quit();
    recovered_handle.stop();
    front_handle.stop();
}

/// `check_against_oracle` minus the epoch pin: structural moves publish
/// a fresh epoch from a warm refinement, so the cluster's epoch runs
/// ahead of a lockstep single index — the *answers* must still match.
fn check_answers(cl: &ClusterIndex, single: &CoreIndex) {
    let want = single.snapshot();
    let got = cl.snapshot();
    assert_eq!(got.core, want.core, "merged snapshot must be byte-identical");
    assert_eq!(got.num_edges, want.num_edges);
    assert_eq!(cl.degeneracy(), want.degeneracy());
    assert_eq!(cl.histogram_routed().unwrap(), want.histogram());
    for v in 0..want.num_vertices() as u32 {
        assert_eq!(cl.coreness_routed(v).unwrap(), want.coreness(v), "v{v}");
    }
    for k in 0..=want.k_max + 1 {
        assert_eq!(cl.members_routed(k).unwrap(), want.kcore_members(k), "k={k}");
    }
}

#[test]
fn elastic_split_merge_and_migration_match_the_oracle_under_live_edits() {
    let g = gen::barabasi_albert(130, 3, 47);
    let (_rsvc, _rhandle, replica_addr) = spawn_server();
    let (_msvc, _mhandle, mig_addr) = spawn_server();
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = els\nshards = 2\n\
         [shard.0]\nprimary = local\nreplicas = {replica_addr}\n\
         [shard.1]\nprimary = local\n"
    ))
    .unwrap();
    let cl = ClusterIndex::build(&g, &topo, cfg()).unwrap();
    let single = CoreIndex::new("single", &g);
    check_against_oracle(&cl, &single);

    // each round: a routed edit batch (in lockstep with the oracle),
    // then one structural change — a split-direction move, a live
    // primary migration, a merge-direction move — and the answers must
    // stay byte-identical throughout
    let mut rng = Rng::new(0xE1A);
    let mut n = g.num_vertices() as u64;
    for round in 0..3 {
        let mut edits = Vec::new();
        while edits.len() < 12 {
            let u = rng.below(n + 6) as u32;
            let v = rng.below(n + 6) as u32;
            if u == v {
                continue;
            }
            edits.push(if rng.chance(0.7) {
                EdgeEdit::Insert(u, v)
            } else {
                EdgeEdit::Delete(u, v)
            });
        }
        for &e in &edits {
            cl.submit(e);
        }
        let out = cl.flush().unwrap();
        let single_out = apply_batch(&single, &edits, &cfg());
        assert_eq!(out.snapshot.core, single_out.snapshot.core, "round {round}");
        assert_eq!(out.applied, single_out.applied, "round {round}");
        n = out.snapshot.num_vertices() as u64;

        let rec = match round {
            0 => cl.move_vertices(0, 1, 10).unwrap(),
            // round 1+: shard 1 lives on a loopback host, so the
            // later merge-direction move exercises the remote
            // handoff path (SHARDHAND EXPORT/ADOPT/RELEASE frames)
            1 => cl.migrate_primary(1, &mig_addr).unwrap(),
            _ => cl.move_vertices(1, 0, 10).unwrap(),
        };
        if rec.kind == "migrate" {
            assert_eq!(rec.to, mig_addr, "round {round}");
        } else {
            assert_eq!(rec.vertices, 10, "round {round}: {rec:?}");
        }
        cl.sync_replicas().unwrap();
        check_answers(&cl, &single);
    }
    // the move history holds the three steps, oldest first
    let kinds: Vec<&str> = cl.moves().iter().map(|m| m.kind).collect();
    assert_eq!(kinds, ["split", "migrate", "split"], "{:?}", cl.moves());
    let (snap, graph) = cl.consistent_view().unwrap();
    assert_eq!(snap.core, bz_coreness(&graph), "assembled graph vs BZ oracle");
}

#[test]
fn aborted_migration_leaves_the_cluster_recoverable() {
    let g = gen::erdos_renyi(70, 180, 53);
    let topo = ClusterConfig::parse(
        "[cluster]\nname = ab\nshards = 1\n[shard.0]\nprimary = local\n",
    )
    .unwrap();
    let cl = ClusterIndex::build(&g, &topo, cfg()).unwrap();
    let epoch_before = cl.epoch();

    // reserved port: the target is unreachable, the migration aborts
    // before anything ships — no move recorded, no fence left latched,
    // the migrating flag cleared
    let err = cl.migrate_primary(0, "127.0.0.1:1").unwrap_err();
    assert!(
        format!("{err:#}").contains("unreachable"),
        "{err:#}"
    );
    assert!(cl.moves().is_empty(), "aborted moves must not be recorded");
    assert!(!cl.groups()[0].migrating());
    assert_eq!(cl.epoch(), epoch_before);

    // writes still flow and the answers stay exact
    cl.submit(EdgeEdit::Insert(0, 60));
    cl.flush().unwrap();
    let (snap, graph) = cl.consistent_view().unwrap();
    assert_eq!(snap.core, bz_coreness(&graph));

    // the structural latch was released by the abort: a retry against a
    // live host is admitted and completes
    let (_svc, _handle, addr) = spawn_server();
    let rec = cl.migrate_primary(0, &addr).unwrap();
    assert_eq!((rec.kind, rec.to.as_str()), ("migrate", addr.as_str()));
    assert_eq!(rec.epoch, cl.epoch(), "cutover verified at the head epoch");

    // edits route through the migrated primary and stay oracle-exact
    cl.submit(EdgeEdit::Insert(1, 61));
    cl.flush().unwrap();
    let (snap, graph) = cl.consistent_view().unwrap();
    assert_eq!(snap.core, bz_coreness(&graph));
    assert_eq!(cl.moves().len(), 1);
}

#[test]
fn cluster_namespace_over_the_wire_aliases_and_reply_shapes() {
    use pico::net::client::Client;
    use pico::service::serve;

    let g = gen::barabasi_albert(80, 3, 59);
    let topo = ClusterConfig::parse(
        "[cluster]\nname = ns\nshards = 2\n\
         [shard.0]\nprimary = local\n[shard.1]\nprimary = local\n",
    )
    .unwrap();
    let cl = Arc::new(ClusterIndex::build(&g, &topo, cfg()).unwrap());
    // one completed move so MOVES has something to render
    cl.move_vertices(0, 1, 6).unwrap();

    let front = Arc::new(CoreService::new(cfg()));
    front.open_cluster("ns", cl.clone());
    let front_handle = serve(front, "127.0.0.1:0").expect("bind");
    let mut client = Client::connect(&front_handle.addr().to_string()).unwrap();

    // the legacy verb and its CLUSTER spelling answer byte-identically
    let shards = client.send_line("SHARDS").unwrap();
    assert!(shards.starts_with("OK shards=2 strategy=cluster"), "{shards}");
    assert_eq!(client.send_line("CLUSTER TOPOLOGY").unwrap(), shards);

    // MOVES: head carries the line count, each line one completed move
    let (head, lines) = client.send_multiline("CLUSTER MOVES").unwrap();
    assert!(head.starts_with("OK moves n=1 lines=1"), "{head}");
    assert!(
        lines[0].starts_with("split from=shard0 to=shard1 vertices=6 "),
        "{lines:?}"
    );
    let (jhead, jlines) = client.send_multiline("CLUSTER MOVES JSON").unwrap();
    assert!(jhead.contains("format=json"), "{jhead}");
    assert!(jlines[0].starts_with("[{\"kind\":\"split\""), "{jlines:?}");

    // PLAN: one load line per shard (the planner's input signals), a
    // dry run that records nothing
    let (phead, plines) = client.send_multiline("CLUSTER REBALANCE PLAN").unwrap();
    assert!(phead.starts_with("OK rebalance plan moves="), "{phead}");
    assert!(plines.iter().any(|l| l.starts_with("load shard=0 ")), "{plines:?}");
    assert!(plines.iter().any(|l| l.starts_with("load shard=1 ")), "{plines:?}");
    let (head, _) = client.send_multiline("CLUSTER MOVES").unwrap();
    assert!(head.starts_with("OK moves n=1 "), "PLAN must not execute: {head}");

    // APPLY answers with the executed move count (zero on a balanced
    // cluster is a valid outcome — the head shape is the contract)
    let (ahead, _alines) = client.send_multiline("CLUSTER REBALANCE APPLY").unwrap();
    assert!(ahead.starts_with("OK rebalance applied moves="), "{ahead}");

    // refusals carry machine-readable codes over the wire too
    let bad = client.send_line("CLUSTER NOPE").unwrap();
    assert!(bad.starts_with("ERR BADREQ unknown CLUSTER subverb 'NOPE'"), "{bad}");
    let bare = client.send_line("CLUSTER").unwrap();
    assert!(bare.starts_with("ERR BADREQ usage: CLUSTER"), "{bare}");
    client.quit();
    front_handle.stop();
}

#[test]
fn ownership_change_refreshes_the_full_ship_hint() {
    let g = gen::barabasi_albert(100, 3, 61);
    let (_svc, _handle, addr) = spawn_server();
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = hint\nshards = 2\n\
         [shard.0]\nprimary = local\nreplicas = {addr}\n\
         [shard.1]\nprimary = local\n"
    ))
    .unwrap();
    let cl = ClusterIndex::build(&g, &topo, cfg()).unwrap();
    let exact_before = cl.groups()[0].primary_manifest(2).unwrap().len() as u64;
    assert_eq!(
        cl.groups()[0].manifest_bytes_hint(),
        exact_before,
        "hydration leaves an exact hint"
    );

    // an ownership change invalidates the hint: shard 0 adopts vertices,
    // its manifest grows, and the delta-vs-snapshot comparison must not
    // keep shipping against the stale pre-move size
    cl.move_vertices(1, 0, 8).unwrap();
    let report = cl.sync_replicas().unwrap();
    assert!(report.snapshots >= 1, "a move forces the full-ship path: {report:?}");
    let exact_after = cl.groups()[0].primary_manifest(2).unwrap().len() as u64;
    assert_ne!(exact_before, exact_after, "the move must change the manifest size");
    assert_eq!(
        cl.groups()[0].manifest_bytes_hint(),
        exact_after,
        "the post-move sync must recompute the hint against the new ownership"
    );
}

#[test]
fn flush_through_a_remote_shard_stitches_a_cross_host_trace() {
    use pico::net::client::Client;
    use pico::obs::recent_traces;
    use pico::obs::trace::TRACE_RING_CAP;

    let g = gen::barabasi_albert(80, 3, 41);
    let (_shard_svc, _shard_handle, shard_addr) = spawn_server();
    let topo = ClusterConfig::parse(&format!(
        "[cluster]\nname = trace-e2e\nshards = 2\n\
         [shard.0]\nprimary = local\n\
         [shard.1]\nprimary = {shard_addr}\n"
    ))
    .unwrap();
    let cl = Arc::new(ClusterIndex::build(&g, &topo, cfg()).unwrap());
    cl.submit(EdgeEdit::Insert(0, 1));
    cl.submit(EdgeEdit::Insert(2, 50));
    cl.flush().unwrap();

    // the coordinator's ring holds the flush as one span tree: stage
    // spans measured on the coordinator, host-side spans stitched in
    // from the shard host's `us=` reply fields, all under one trace id
    let trace = recent_traces(TRACE_RING_CAP)
        .into_iter()
        .find(|t| t.graph == "trace-e2e" && t.kind == "flush")
        .expect("the flush must land in the trace ring");
    assert_ne!(trace.id, 0);
    for stage in ["queue", "route", "apply", "refine", "commit", "publish"] {
        assert!(
            trace.spans.iter().any(|s| s.name == stage),
            "missing stage '{stage}' in {:?}",
            trace.spans.iter().map(|s| &s.name).collect::<Vec<_>>()
        );
    }
    let remote_spans: Vec<_> = trace
        .spans
        .iter()
        .flat_map(|s| s.children.iter())
        .filter(|c| c.remote.is_some())
        .collect();
    assert!(
        !remote_spans.is_empty(),
        "host-side spans must be stitched under the coordinator's trace"
    );
    for c in &remote_spans {
        assert_eq!(c.remote.as_deref(), Some(shard_addr.as_str()), "{c:?}");
    }

    // front the cluster as `pico serve --cluster` would, and read the
    // same stitched trace plus the stage histograms over the wire
    let front = Arc::new(CoreService::new(cfg()));
    front.open_cluster("trace-e2e", cl.clone());
    let front_handle = serve(front, "127.0.0.1:0").expect("bind");
    let mut client = Client::connect(&front_handle.addr().to_string()).unwrap();
    let (head, lines) = client
        .send_multiline(&format!("TRACES {TRACE_RING_CAP}"))
        .unwrap();
    assert!(head.starts_with("OK traces"), "{head}");
    let header = format!("trace=0x{:x} kind=flush graph=trace-e2e", trace.id);
    assert!(
        lines.iter().any(|l| l.starts_with(&header)),
        "TRACES must carry the stitched flush ({header}): {head}"
    );
    assert!(
        lines
            .iter()
            .any(|l| l.contains(&format!("remote={shard_addr}"))),
        "the rendered tree must show the remote host's span"
    );

    let (mhead, mlines) = client.send_multiline("METRICS PROM").unwrap();
    assert!(mhead.starts_with("OK metrics"), "{mhead}");
    let body = mlines.join("\n");
    for name in [
        "pico_flush_refine_seconds",
        "pico_flush_commit_seconds",
        "pico_flush_total_seconds",
    ] {
        assert!(
            body.contains(&format!("{name}_count{{graph=\"trace-e2e\"}}")),
            "missing {name} for the cluster graph in the exposition"
        );
    }
    client.quit();
    front_handle.stop();
}
