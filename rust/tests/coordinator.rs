//! Coordinator invariants, property-tested: routing (every job produces
//! exactly one result, in order), batching across job-slot counts, state
//! (outcome classification is total and accurate).

use pico::coordinator::{
    DatasetSpec, Job, JobOutcome, Scheduler, SchedulerConfig,
};
use pico::graph::{examples, gen};
use pico::util::quickcheck::{assert_prop, Arbitrary, Config};
use pico::util::rng::Rng;
use std::sync::Arc;

/// A random batch of jobs mixing valid/invalid algorithms and datasets.
#[derive(Clone, Debug)]
struct JobBatch {
    specs: Vec<(u8, u8)>, // (algo selector, dataset selector)
    slots: usize,
}

impl Arbitrary for JobBatch {
    fn generate(rng: &mut Rng, size: usize) -> Self {
        let n = 1 + rng.below_usize(size.max(1).min(12));
        let specs = (0..n)
            .map(|_| (rng.below(6) as u8, rng.below(4) as u8))
            .collect();
        Self {
            specs,
            slots: 1 + rng.below_usize(3),
        }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.specs.len() > 1 {
            out.push(Self {
                specs: self.specs[..self.specs.len() / 2].to_vec(),
                slots: self.slots,
            });
        }
        if self.slots > 1 {
            out.push(Self {
                specs: self.specs.clone(),
                slots: 1,
            });
        }
        out
    }
}

fn algo_name(sel: u8) -> &'static str {
    match sel {
        0 => "BZ",
        1 => "PeelOne",
        2 => "PO-dyn",
        3 => "HistoCore",
        4 => "CntCore",
        _ => "NoSuchAlgorithm", // deliberately invalid
    }
}

fn dataset(sel: u8) -> DatasetSpec {
    match sel {
        0 => DatasetSpec::InMemory(Arc::new(examples::g1())),
        1 => DatasetSpec::Lazy {
            name: "er".into(),
            build: Arc::new(|| gen::erdos_renyi(60, 150, 3)),
        },
        2 => DatasetSpec::InMemory(Arc::new(examples::complete(8))),
        _ => DatasetSpec::Path("/nonexistent/graph.el".into()), // invalid
    }
}

#[test]
fn prop_scheduler_routing_and_state() {
    assert_prop::<JobBatch>(
        &Config {
            cases: 25,
            seed: 0xBA7C4,
            ..Config::default()
        },
        "scheduler routing/batching/state",
        |batch| {
            let jobs: Vec<Job> = batch
                .specs
                .iter()
                .map(|&(a, d)| Job::new(dataset(d), algo_name(a)).with_threads(1))
                .collect();
            let scheduler = Scheduler::new(SchedulerConfig {
                job_slots: batch.slots,
                ..Default::default()
            });
            let results = scheduler.run(jobs.clone());

            // routing: one result per job, in submission order
            if results.len() != jobs.len() {
                return Err(format!("{} jobs -> {} results", jobs.len(), results.len()));
            }
            for (i, (job, res)) in jobs.iter().zip(&results).enumerate() {
                if res.algorithm != job.algorithm {
                    return Err(format!("slot {i}: algorithm mismatch"));
                }
                if res.dataset != job.dataset.name() {
                    return Err(format!("slot {i}: dataset mismatch"));
                }
                // state: outcome classification must match the job's shape
                let (a, d) = batch.specs[i];
                let valid = a <= 4 && d <= 2;
                match (&res.outcome, valid) {
                    (JobOutcome::Ok, true) => {}
                    (JobOutcome::Rejected(_), false) => {}
                    (other, v) => {
                        return Err(format!("slot {i}: outcome {other:?} but valid={v}"))
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn slots_do_not_change_results() {
    let jobs: Vec<Job> = (0..6)
        .map(|i| {
            Job::new(
                DatasetSpec::InMemory(Arc::new(examples::complete(5 + i))),
                "PO-dyn",
            )
            .with_threads(1)
        })
        .collect();
    let r1 = Scheduler::new(SchedulerConfig {
        job_slots: 1,
        ..Default::default()
    })
    .run(jobs.clone());
    let r3 = Scheduler::new(SchedulerConfig {
        job_slots: 3,
        ..Default::default()
    })
    .run(jobs);
    for (a, b) in r1.iter().zip(&r3) {
        assert_eq!(a.k_max, b.k_max);
        assert_eq!(a.outcome, b.outcome);
    }
}

#[test]
fn validation_failure_is_detected_not_fatal() {
    // a job with validation disabled still completes; with a bogus
    // algorithm name it is rejected — both keep the batch running
    let jobs = vec![
        Job::new(DatasetSpec::InMemory(Arc::new(examples::g1())), "Bogus"),
        Job::new(DatasetSpec::InMemory(Arc::new(examples::g1())), "PO-dyn").with_validation(false),
    ];
    let results = Scheduler::new(SchedulerConfig::default()).run(jobs);
    assert!(matches!(results[0].outcome, JobOutcome::Rejected(_)));
    assert_eq!(results[1].outcome, JobOutcome::Ok);
}
