//! Sharded-serving acceptance tests:
//!
//! 1. **Sharded-vs-single equivalence** — for every generator family and
//!    shard count in {1, 2, 4, 8} (both partition strategies), the
//!    `ShardedIndex`'s merged snapshot *and* its routed fan-out answers
//!    (coreness / members / histogram / degeneracy) are identical to a
//!    single `CoreIndex` over the same graph.
//! 2. **Equivalence under updates** — a property test drives random edit
//!    scripts through a sharded index and a single index in lockstep;
//!    after every flush both must publish the same epoch and coreness,
//!    and the final state must match the BZ oracle.
//! 3. **Snapshot round trip** — `CoreIndex` → binary snapshot → restore
//!    is exact (coreness, histogram, epoch) for random graphs, the empty
//!    graph, and graphs with isolated vertices, with byte-identical
//!    re-encoding.

use pico::core::bz::bz_coreness;
use pico::core::maintenance::EdgeEdit;
use pico::graph::{examples, gen, CsrGraph, GraphBuilder};
use pico::service::{apply_batch, BatchConfig, CoreIndex};
use pico::shard::{decode, encode, encode_index, PartitionStrategy, ShardedIndex};
use pico::util::quickcheck::{assert_prop, Arbitrary, Config};
use pico::util::rng::Rng;

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];
const STRATEGIES: [PartitionStrategy; 2] =
    [PartitionStrategy::Hash, PartitionStrategy::DegreeRange];

fn cfg() -> BatchConfig {
    BatchConfig {
        threads: 1,
        ..BatchConfig::default()
    }
}

/// One small graph per generator family, plus the degenerate shapes.
fn families() -> Vec<CsrGraph> {
    vec![
        examples::g1(),
        gen::erdos_renyi(120, 400, 13),
        gen::barabasi_albert(150, 3, 42),
        gen::rmat(7, 6, 0.57, 0.19, 0.19, 7),
        gen::power_law_cluster(100, 4, 0.5, 17),
        gen::caveman(8, 5, 19),
        gen::grid2d(8, 9),
        gen::star_burst(3, 20, 30, 11),
        gen::nested_cliques(3, 4, 3).0,
        gen::planted_core(150, 300, &[(40, 8), (10, 16)], 23),
        gen::core_periphery(200, 12, 3),
        examples::star(40),
        examples::complete(12),
        examples::path(25),
        GraphBuilder::new(0).build("empty"),
        GraphBuilder::new(1).build("single-vertex"),
        GraphBuilder::new(7).build("isolated"),
    ]
}

#[test]
fn sharded_answers_equal_single_index_answers() {
    for g in families() {
        let single = CoreIndex::new("single", &g);
        let want = single.snapshot();
        for &shards in &SHARD_COUNTS {
            for strategy in STRATEGIES {
                let label = format!("{} x{shards} [{}]", g.name, strategy.name());
                let sh = ShardedIndex::new("sh", &g, shards, strategy, cfg());
                let got = sh.snapshot();
                // merged snapshot: identical decomposition + metadata
                assert_eq!(got.core, want.core, "{label}: coreness");
                assert_eq!(got.k_max, want.k_max, "{label}: k_max");
                assert_eq!(got.num_edges, want.num_edges, "{label}: |E|");
                assert_eq!(got.epoch, 0, "{label}: epoch");
                // routed answers: coreness via the owner shard, members /
                // histogram / degeneracy via fan-out + merge
                for v in 0..g.num_vertices() as u32 {
                    assert_eq!(sh.coreness(v), want.coreness(v), "{label}: v{v}");
                }
                assert_eq!(sh.coreness(g.num_vertices() as u32), None, "{label}");
                assert_eq!(sh.degeneracy(), want.degeneracy(), "{label}");
                assert_eq!(sh.histogram(), want.histogram(), "{label}");
                for k in 0..=want.k_max + 1 {
                    assert_eq!(sh.kcore_members(k), want.kcore_members(k), "{label}: k={k}");
                    assert_eq!(sh.kcore_size(k), want.kcore_size(k), "{label}: k={k}");
                }
            }
        }
    }
}

/// Random edit script applied in lockstep to a sharded and a single
/// index; compared after every flush.
#[derive(Clone, Debug)]
struct ShardScript {
    n: u32,
    shards: usize,
    strategy_range: bool,
    edits: Vec<(u32, u32, bool)>,
    chunk: usize,
}

impl Arbitrary for ShardScript {
    fn generate(rng: &mut Rng, size: usize) -> Self {
        let n = 4 + rng.below(16) as u32; // small id space -> dense collisions
        let len = rng.below_usize(size.max(1) * 4 + 1);
        let edits = (0..len)
            .map(|_| {
                (
                    rng.below(n as u64) as u32,
                    rng.below(n as u64) as u32,
                    rng.chance(0.6),
                )
            })
            .collect();
        Self {
            n,
            shards: 1 + rng.below_usize(8),
            strategy_range: rng.chance(0.5),
            edits,
            chunk: 1 + rng.below_usize(6),
        }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.edits.len() > 1 {
            out.push(Self {
                edits: self.edits[..self.edits.len() / 2].to_vec(),
                ..self.clone()
            });
            out.push(Self {
                edits: self.edits[1..].to_vec(),
                ..self.clone()
            });
        }
        if self.shards > 1 {
            out.push(Self {
                shards: 1,
                ..self.clone()
            });
        }
        out
    }
}

fn run_lockstep(s: &ShardScript) -> Result<(), String> {
    let g = GraphBuilder::new(s.n as usize).build("lockstep");
    let strategy = if s.strategy_range {
        PartitionStrategy::DegreeRange
    } else {
        PartitionStrategy::Hash
    };
    let sharded = ShardedIndex::new("sh", &g, s.shards, strategy, cfg());
    let single = CoreIndex::new("single", &g);
    for (i, chunk) in s.edits.chunks(s.chunk).enumerate() {
        let edits: Vec<EdgeEdit> = chunk
            .iter()
            .map(|&(u, v, ins)| {
                if ins {
                    EdgeEdit::Insert(u, v)
                } else {
                    EdgeEdit::Delete(u, v)
                }
            })
            .collect();
        for &e in &edits {
            sharded.submit(e);
        }
        let out = sharded.flush();
        let single_out = apply_batch(&single, &edits, &cfg());
        let (a, b) = (&out.snapshot, &single_out.snapshot);
        if a.epoch != b.epoch {
            return Err(format!("batch {i}: epoch {} != {}", a.epoch, b.epoch));
        }
        if a.core != b.core {
            return Err(format!("batch {i}: core {:?} != {:?}", a.core, b.core));
        }
        if a.num_edges != b.num_edges {
            return Err(format!("batch {i}: |E| {} != {}", a.num_edges, b.num_edges));
        }
        if out.applied != single_out.applied || out.changed != single_out.changed {
            return Err(format!(
                "batch {i}: accounting applied {}/{} changed {}/{}",
                out.applied, single_out.applied, out.changed, single_out.changed
            ));
        }
    }
    // final state against the from-scratch oracle on the assembled graph
    let (snap, graph) = sharded.consistent_view();
    let expected = bz_coreness(&graph);
    if snap.core != expected {
        return Err(format!("final: served {:?} != oracle {expected:?}", snap.core));
    }
    Ok(())
}

#[test]
fn property_sharded_updates_match_single_index() {
    let qc = Config {
        cases: 40,
        seed: 0x5AA2D,
        ..Config::default()
    };
    assert_prop::<ShardScript>(&qc, "sharded flush == single flush", run_lockstep);
}

#[test]
fn sharded_updates_match_on_real_generators() {
    // denser lockstep runs on structured graphs (boundary cascades cross
    // shards far more often than on the tiny property-test id spaces)
    for (g, seed) in [
        (gen::barabasi_albert(200, 3, 5), 1u64),
        (gen::erdos_renyi(150, 500, 9), 2),
        (gen::caveman(6, 6, 3), 3),
    ] {
        let sharded = ShardedIndex::new("sh", &g, 4, PartitionStrategy::Hash, cfg());
        let single = CoreIndex::new("single", &g);
        let n = g.num_vertices() as u32;
        let mut rng = Rng::new(seed);
        for round in 0..10 {
            let mut edits = Vec::new();
            while edits.len() < 12 {
                let u = rng.below(n as u64) as u32;
                let v = rng.below(n as u64) as u32;
                if u == v {
                    continue;
                }
                edits.push(if rng.chance(0.6) {
                    EdgeEdit::Insert(u, v)
                } else {
                    EdgeEdit::Delete(u, v)
                });
            }
            for &e in &edits {
                sharded.submit(e);
            }
            let out = sharded.flush();
            let single_out = apply_batch(&single, &edits, &cfg());
            assert_eq!(
                out.snapshot.core, single_out.snapshot.core,
                "{} round {round}",
                g.name
            );
            assert_eq!(out.snapshot.epoch, single_out.snapshot.epoch);
        }
        let (snap, graph) = sharded.consistent_view();
        assert_eq!(snap.core, bz_coreness(&graph), "{} final", g.name);
    }
}

/// Random graph for the snapshot round-trip property.
#[derive(Clone, Debug)]
struct SnapGraph {
    n: usize,
    edges: Vec<(u32, u32)>,
    epoch_edits: usize,
}

impl SnapGraph {
    fn build(&self) -> CsrGraph {
        let mut b = GraphBuilder::new(self.n);
        b.add_edges(self.edges.iter().copied());
        b.build("snap")
    }
}

impl Arbitrary for SnapGraph {
    fn generate(rng: &mut Rng, size: usize) -> Self {
        // n can be 0 (empty graph) and edges sparse (isolated vertices)
        let n = rng.below_usize(size.max(1) * 3 + 1);
        let m = if n < 2 { 0 } else { rng.below_usize(n * 2 + 1) };
        let edges = (0..m)
            .map(|_| (rng.below(n as u64) as u32, rng.below(n as u64) as u32))
            .filter(|(u, v)| u != v)
            .collect();
        Self {
            n,
            edges,
            epoch_edits: rng.below_usize(4),
        }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.edges.is_empty() {
            out.push(Self {
                edges: self.edges[..self.edges.len() / 2].to_vec(),
                ..self.clone()
            });
        }
        if self.epoch_edits > 0 {
            out.push(Self {
                epoch_edits: 0,
                ..self.clone()
            });
        }
        out
    }
}

#[test]
fn property_snapshot_round_trip_is_exact() {
    let qc = Config {
        cases: 60,
        seed: 0x54AF,
        ..Config::default()
    };
    assert_prop::<SnapGraph>(&qc, "snapshot -> restore is identity", |sg| {
        let g = sg.build();
        let idx = CoreIndex::new("snap", &g);
        // advance the epoch so restore must preserve a non-zero one
        for i in 0..sg.epoch_edits {
            let v = (i as u32) % (sg.n.max(2) as u32);
            let w = (v + 1) % (sg.n.max(2) as u32);
            if v != w {
                idx.update(|dc| {
                    dc.ensure_vertex(v.max(w));
                    dc.insert_edge(v, w)
                });
            }
        }
        let bytes = encode_index(&idx);
        let snap = decode(&bytes).map_err(|e| format!("decode: {e:#}"))?;
        // byte-identical re-encoding
        let re = encode(&snap.name, snap.epoch, &snap.core, &snap.graph);
        if re != bytes {
            return Err("re-encoding differs".into());
        }
        let restored = snap.hydrate();
        let (a, b) = (restored.snapshot(), idx.snapshot());
        if a.epoch != b.epoch {
            return Err(format!("epoch {} != {}", a.epoch, b.epoch));
        }
        if a.core != b.core {
            return Err(format!("core {:?} != {:?}", a.core, b.core));
        }
        if a.histogram() != b.histogram() {
            return Err("histogram differs".into());
        }
        if a.num_edges != b.num_edges {
            return Err(format!("|E| {} != {}", a.num_edges, b.num_edges));
        }
        Ok(())
    });
}

#[test]
fn snapshot_round_trip_empty_and_isolated() {
    for g in [
        GraphBuilder::new(0).build("empty"),
        GraphBuilder::new(9).build("isolated"),
    ] {
        let idx = CoreIndex::new(g.name.clone(), &g);
        let restored = decode(&encode_index(&idx)).unwrap().hydrate();
        let (a, b) = (restored.snapshot(), idx.snapshot());
        assert_eq!(a.core, b.core, "{}", g.name);
        assert_eq!(a.epoch, 0);
        assert_eq!(a.num_edges, 0);
        assert_eq!(a.histogram(), b.histogram());
        // the restored index is live, not a dead copy
        let (changed, s) = restored.update(|dc| {
            dc.ensure_vertex(1);
            dc.insert_edge(0, 1)
        });
        assert!(changed);
        assert_eq!(s.epoch, 1);
    }
}

#[test]
fn sharded_snapshot_ships_and_restores_per_shard() {
    // ship every shard of a sharded index; each replica must serve the
    // shard's local subgraph at the shard's epoch without recomputation
    let g = gen::barabasi_albert(120, 3, 77);
    let sh = ShardedIndex::new("ba", &g, 4, PartitionStrategy::Hash, cfg());
    for s in 0..4 {
        let shard_idx = sh.shard_index(s).unwrap();
        let restored = decode(&encode_index(&shard_idx)).unwrap().hydrate();
        assert_eq!(restored.name(), format!("ba/shard{s}"));
        assert_eq!(restored.snapshot().core, shard_idx.snapshot().core);
        assert_eq!(restored.snapshot().epoch, shard_idx.snapshot().epoch);
    }
    assert!(sh.shard_index(4).is_none());
}
