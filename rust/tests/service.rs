//! Serving-layer integration tests — the acceptance surface for the
//! core-index service:
//!
//! 1. ≥4 concurrent query threads observe only epoch-consistent
//!    snapshots while edit batches are applied (never a partially
//!    updated index), both in-process and over the TCP protocol.
//! 2. A randomized edit script through the batched path (with coalesced
//!    insert/delete pairs) yields coreness identical to a from-scratch
//!    `bz_coreness` run — the property-test extension of the per-edit
//!    verification in `core::maintenance`.
//! 3. Batches above the configured threshold take the full-recompute
//!    fallback, and its results also match the oracle.

use pico::core::bz::bz_coreness;
use pico::core::maintenance::EdgeEdit;
use pico::graph::{examples, gen};
use pico::service::{
    apply_batch, coalesce, serve, BatchConfig, CoreIndex, CoreService, EditQueue, Session,
};
use pico::util::quickcheck::{assert_prop, Arbitrary, Config};
use pico::util::rng::Rng;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Deterministic checksum of a coreness vector (order-sensitive).
fn checksum(core: &[u32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64; // FNV-1a
    for &c in core {
        h ^= c as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The headline guarantee: four readers hammer snapshots while a writer
/// applies edit batches; every observed (epoch, coreness) pair must be
/// one the writer actually published — no torn or intermediate states.
#[test]
fn concurrent_readers_observe_only_published_epochs() {
    let g = gen::barabasi_albert(500, 4, 77);
    let idx = Arc::new(CoreIndex::new("ba", &g));
    let queue = Arc::new(EditQueue::new(
        idx.clone(),
        BatchConfig {
            recompute_fraction: 0.05,
            min_recompute_edits: 40,
            threads: 2,
        },
    ));

    // epoch -> checksum of every snapshot the writer publishes
    let published = Arc::new(Mutex::new(HashMap::<u64, u64>::new()));
    published
        .lock()
        .unwrap()
        .insert(0, checksum(&idx.snapshot().core));

    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for _ in 0..4 {
        let idx = idx.clone();
        let stop = stop.clone();
        readers.push(std::thread::spawn(move || {
            let mut observations: Vec<(u64, u64)> = Vec::new();
            let mut last_epoch = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let s = idx.snapshot();
                assert!(s.epoch >= last_epoch, "epochs must be monotone per reader");
                last_epoch = s.epoch;
                observations.push((s.epoch, checksum(&s.core)));
            }
            observations
        }));
    }

    // writer: 30 batches of mixed inserts/deletes (some above the
    // recompute threshold, some below)
    let mut rng = Rng::new(0xBEEF);
    for round in 0..30u32 {
        let batch_len = if round % 5 == 4 { 60 } else { 8 };
        for _ in 0..batch_len {
            let u = rng.below(500) as u32;
            let v = rng.below(500) as u32;
            if u == v {
                continue;
            }
            let e = if rng.chance(0.6) {
                EdgeEdit::Insert(u, v)
            } else {
                EdgeEdit::Delete(u, v)
            };
            queue.submit(e);
        }
        let out = queue.flush();
        published
            .lock()
            .unwrap()
            .insert(out.snapshot.epoch, checksum(&out.snapshot.core));
    }
    stop.store(true, Ordering::Relaxed);

    let published = published.lock().unwrap();
    let mut total_obs = 0usize;
    for r in readers {
        for (epoch, sum) in r.join().unwrap() {
            total_obs += 1;
            let expected = published
                .get(&epoch)
                .unwrap_or_else(|| panic!("reader saw unpublished epoch {epoch}"));
            assert_eq!(*expected, sum, "torn snapshot at epoch {epoch}");
        }
    }
    assert!(total_obs > 0, "readers observed nothing");

    // and the final maintained state matches a from-scratch decomposition
    let (snap, graph) = idx.consistent_view();
    assert_eq!(snap.core, bz_coreness(&graph));
}

/// Randomized edit scripts (insert/delete mixes over a small vertex set,
/// guaranteeing coalesced pairs) through the batched path match the
/// from-scratch oracle after every flush.
#[derive(Clone, Debug)]
struct EditScript {
    n: u32,
    // (u, v, is_insert), chunked into batches of `chunk`
    edits: Vec<(u32, u32, bool)>,
    chunk: usize,
}

impl Arbitrary for EditScript {
    fn generate(rng: &mut Rng, size: usize) -> Self {
        let n = 4 + rng.below(12) as u32; // small id space -> repeated pairs
        let len = rng.below_usize(size.max(1) * 4 + 1);
        let edits = (0..len)
            .map(|_| {
                (
                    rng.below(n as u64) as u32,
                    rng.below(n as u64) as u32,
                    rng.chance(0.6),
                )
            })
            .collect();
        Self {
            n,
            edits,
            chunk: 1 + rng.below_usize(8),
        }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.edits.len() > 1 {
            out.push(Self {
                edits: self.edits[..self.edits.len() / 2].to_vec(),
                ..self.clone()
            });
            out.push(Self {
                edits: self.edits[1..].to_vec(),
                ..self.clone()
            });
        }
        if self.chunk > 1 {
            out.push(Self {
                chunk: self.chunk / 2,
                ..self.clone()
            });
        }
        out
    }
}

fn run_script(script: &EditScript, cfg: &BatchConfig) -> Result<(), String> {
    let idx = CoreIndex::new(
        "prop",
        &pico::graph::GraphBuilder::new(script.n as usize).build("prop"),
    );
    for (i, chunk) in script.edits.chunks(script.chunk).enumerate() {
        let edits: Vec<EdgeEdit> = chunk
            .iter()
            .map(|&(u, v, ins)| {
                if ins {
                    EdgeEdit::Insert(u, v)
                } else {
                    EdgeEdit::Delete(u, v)
                }
            })
            .collect();
        apply_batch(&idx, &edits, cfg);
        let (snap, g) = idx.consistent_view();
        let expected = bz_coreness(&g);
        if snap.core != expected {
            return Err(format!(
                "batch {i}: served {:?} != oracle {:?}",
                snap.core, expected
            ));
        }
    }
    Ok(())
}

#[test]
fn property_batched_edits_match_fresh_bz() {
    let cfg = Config {
        cases: 48,
        seed: 0x5EED5,
        ..Config::default()
    };
    assert_prop::<EditScript>(&cfg, "batched coreness == bz_coreness", |s| {
        run_script(
            s,
            &BatchConfig {
                recompute_fraction: 0.02,
                min_recompute_edits: 1 << 30, // force the incremental path
                threads: 1,
            },
        )
    });
}

#[test]
fn property_recompute_path_matches_fresh_bz() {
    let cfg = Config {
        cases: 32,
        seed: 0xFA11BACC,
        ..Config::default()
    };
    assert_prop::<EditScript>(&cfg, "recompute-path coreness == bz_coreness", |s| {
        run_script(
            s,
            &BatchConfig {
                recompute_fraction: 0.0,
                min_recompute_edits: 1, // force the recompute fallback
                threads: 1,
            },
        )
    });
}

#[test]
fn coalesced_insert_delete_pairs_cancel() {
    // (2,5) is inserted then deleted in the same batch: last-wins
    // coalescing must apply only the delete (a no-op on G1 + (2,5) absent)
    let edits = [
        EdgeEdit::Insert(2, 5),
        EdgeEdit::Insert(0, 2),
        EdgeEdit::Delete(5, 2),
    ];
    let c = coalesce(&edits);
    assert_eq!(c, vec![EdgeEdit::Delete(5, 2), EdgeEdit::Insert(0, 2)]);

    let idx = CoreIndex::new("g1", &examples::g1());
    let out = apply_batch(&idx, &edits, &BatchConfig::default());
    assert_eq!(out.applied, 2);
    assert_eq!(out.coalesced, 1);
    assert_eq!(out.changed, 1); // only (0,2) changed the edge set
    let (snap, g) = idx.consistent_view();
    assert!(!g.has_edge(2, 5));
    assert_eq!(snap.core, bz_coreness(&g));
}

/// The fallback trigger: a batch above the configured fraction recomputes
/// (and matches the oracle); the same edits below the threshold do not.
#[test]
fn fallback_threshold_is_respected() {
    let g = gen::erdos_renyi(300, 1200, 9);
    let mut rng = Rng::new(31337);
    let mut edits = Vec::new();
    while edits.len() < 100 {
        let u = rng.below(300) as u32;
        let v = rng.below(300) as u32;
        if u != v {
            edits.push(EdgeEdit::Insert(u, v));
        }
    }

    let tight = CoreIndex::new("tight", &g);
    let out = apply_batch(
        &tight,
        &edits,
        &BatchConfig {
            recompute_fraction: 0.01, // 100 edits >> 1% of 1200 edges
            min_recompute_edits: 4,
            threads: 1,
        },
    );
    assert!(out.recomputed, "batch above threshold must recompute");
    let (snap, graph) = tight.consistent_view();
    assert_eq!(snap.core, bz_coreness(&graph));

    let loose = CoreIndex::new("loose", &g);
    let out = apply_batch(
        &loose,
        &edits,
        &BatchConfig {
            recompute_fraction: 0.5, // threshold 600: stay incremental
            min_recompute_edits: 4,
            threads: 1,
        },
    );
    assert!(!out.recomputed, "batch below threshold must stay incremental");
    let (snap, graph) = loose.consistent_view();
    assert_eq!(snap.core, bz_coreness(&graph));
    // both routes landed on the same decomposition
    assert_eq!(snap.core, tight.snapshot().core);
}

/// End-to-end over TCP: 4 client threads issue whole-snapshot queries
/// (HISTO carries the full histogram in one reply) while the main thread
/// streams edits and flushes; every reply must belong to a published
/// epoch, and the final state matches the oracle.
#[test]
fn tcp_clients_stay_consistent_during_batched_updates() {
    let g = gen::barabasi_albert(300, 3, 5);
    let service = Arc::new(CoreService::new(BatchConfig {
        recompute_fraction: 0.05,
        min_recompute_edits: 30,
        threads: 2,
    }));
    service.open("ba", &g);
    let handle = serve(service.clone(), "127.0.0.1:0").expect("bind");
    let addr = handle.addr();

    // epoch -> expected HISTO payload, recorded by the writer via the
    // in-process service handle (same objects the TCP path serves)
    let expected: Arc<Mutex<HashMap<u64, String>>> = Arc::new(Mutex::new(HashMap::new()));
    let histo_of = |svc: &CoreService| -> (u64, String) {
        let idx = svc.index("ba").unwrap();
        let s = idx.snapshot();
        let cells: Vec<String> = s
            .histogram()
            .iter()
            .enumerate()
            .map(|(k, n)| format!("{k}:{n}"))
            .collect();
        (s.epoch, cells.join(","))
    };
    {
        let (e, h) = histo_of(&service);
        expected.lock().unwrap().insert(e, h);
    }

    let stop = Arc::new(AtomicBool::new(false));
    let mut clients = Vec::new();
    for _ in 0..4 {
        let stop = stop.clone();
        clients.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).expect("connect");
            let mut w = stream.try_clone().unwrap();
            let mut r = BufReader::new(stream);
            let mut replies = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                writeln!(w, "HISTO").unwrap();
                w.flush().unwrap();
                let mut line = String::new();
                if r.read_line(&mut line).unwrap() == 0 {
                    break;
                }
                replies.push(line.trim_end().to_string());
            }
            let _ = writeln!(w, "QUIT");
            replies
        }));
    }

    // writer drives edits through its own TCP connection
    {
        let stream = TcpStream::connect(addr).expect("connect writer");
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let mut rng = Rng::new(424242);
        for round in 0..20u32 {
            let batch = if round % 4 == 3 { 40 } else { 6 };
            for _ in 0..batch {
                let u = rng.below(300) as u32;
                let v = rng.below(300) as u32;
                if u == v {
                    continue;
                }
                let verb = if rng.chance(0.65) { "INSERT" } else { "DELETE" };
                writeln!(w, "{verb} {u} {v}").unwrap();
                w.flush().unwrap();
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
                assert!(line.starts_with("OK"), "{line}");
            }
            writeln!(w, "FLUSH").unwrap();
            w.flush().unwrap();
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            assert!(line.starts_with("OK epoch="), "{line}");
            // record this epoch's ground-truth histogram; no other writer
            // exists, so the snapshot cannot advance between these lines
            let (e, h) = histo_of(&service);
            expected.lock().unwrap().insert(e, h);
        }
        let _ = writeln!(w, "QUIT");
    }
    stop.store(true, Ordering::Relaxed);

    let expected = expected.lock().unwrap();
    let mut seen = 0usize;
    for c in clients {
        for reply in c.join().unwrap() {
            // "OK epoch=<e> histo=<cells>"
            let epoch: u64 = reply
                .split("epoch=")
                .nth(1)
                .and_then(|rest| rest.split_whitespace().next())
                .and_then(|e| e.parse().ok())
                .unwrap_or_else(|| panic!("malformed reply '{reply}'"));
            let histo = reply.split("histo=").nth(1).unwrap_or("");
            let want = expected
                .get(&epoch)
                .unwrap_or_else(|| panic!("client saw unpublished epoch {epoch}"));
            assert_eq!(want, histo, "inconsistent HISTO at epoch {epoch}");
            seen += 1;
        }
    }
    assert!(seen > 0, "clients observed nothing");

    // final served state == from-scratch oracle
    let idx = service.index("ba").unwrap();
    let (snap, graph) = idx.consistent_view();
    assert_eq!(snap.core, bz_coreness(&graph));
    assert!(service.stats().serve_batches >= 20);
    handle.stop();
}

/// Sessions and protocol-level multi-graph behaviour, in-process.
#[test]
fn service_sessions_and_densest_query() {
    let svc = CoreService::new(BatchConfig {
        threads: 1,
        ..BatchConfig::default()
    });
    svc.open("g1", &examples::g1());
    let mut s = Session::new(svc.default_graph());
    let d = svc.handle_command(&mut s, "DENSEST", 0);
    assert!(d.starts_with("OK k=2 vertices=4 edges=5"), "{d}");
    svc.handle_command(&mut s, "INSERT 2 5", 0);
    let f = svc.handle_command(&mut s, "FLUSH", 0);
    assert!(f.starts_with("OK epoch=1"), "{f}");
    let d = svc.handle_command(&mut s, "DENSEST", 0);
    assert!(d.starts_with("OK k=3 vertices=4 edges=6"), "{d}");
}
