//! Cross-algorithm conformance suite: every decomposition algorithm ×
//! every generator family × the BZ oracle × the structural invariants.
//!
//! The nine engines (BZ, PeelOne, GPP, PO-dyn, PP-dyn, BucketPeel,
//! NbrCore, CntCore, HistoCore) are resolved through the coordinator
//! registry — the same
//! construction path `pico run` uses — and run over one representative
//! graph per `graph::gen` family plus the degenerate shapes (empty,
//! single-vertex, all-isolated, star, clique, path). Each result must
//!
//! 1. agree exactly with `bz_coreness`, and
//! 2. pass `core::verify::check_invariants` (degree bound, support,
//!    h-index fixpoint) — so a future engine refactor that breaks any
//!    algorithm on any structural regime is caught by one `cargo test`.
//!
//! Runs are repeated at 1 and 4 SPMD threads: the single-threaded run
//! pins down sequential semantics, the multi-threaded run catches
//! synchronisation bugs that only parallel scheduling exposes.

use pico::coordinator::algorithm_by_name;
use pico::core::bz::bz_coreness;
use pico::core::verify::check_invariants;
use pico::core::Decomposer;
use pico::graph::{examples, gen, CsrGraph, GraphBuilder};

/// The paper's eight decomposition algorithms plus the theory-practice
/// hierarchical-bucket recompute kernel (registry names).
const ALGORITHMS: [&str; 9] = [
    "BZ",
    "PeelOne",
    "GPP",
    "PO-dyn",
    "PP-dyn",
    "BucketPeel",
    "NbrCore",
    "CntCore",
    "HistoCore",
];

/// One representative per `graph::gen` family plus edge-case shapes.
fn conformance_graphs() -> Vec<CsrGraph> {
    vec![
        // random families
        gen::erdos_renyi(300, 1100, 13),
        gen::barabasi_albert(300, 4, 42),
        gen::rmat(8, 8, 0.57, 0.19, 0.19, 7),
        gen::power_law_cluster(250, 5, 0.6, 17),
        gen::star_burst(4, 40, 80, 11),
        gen::grid2d(12, 14),
        gen::caveman(10, 7, 19),
        // planted families (controlled deep hierarchies)
        gen::nested_cliques(4, 5, 4).0,
        gen::planted_core(400, 900, &[(80, 10), (20, 24)], 23),
        gen::core_periphery(400, 20, 3),
        // edge-case shapes
        examples::g1(),
        examples::star(30),
        examples::complete(15),
        examples::path(40),
        examples::cycle(17),
        GraphBuilder::new(0).build("empty"),
        GraphBuilder::new(1).build("single-vertex"),
        GraphBuilder::new(11).build("all-isolated"),
    ]
}

#[test]
fn all_algorithms_match_oracle_and_invariants_on_all_families() {
    for g in conformance_graphs() {
        let oracle = bz_coreness(&g);
        // the oracle itself must satisfy the invariants it anchors
        check_invariants(&g, &oracle)
            .unwrap_or_else(|e| panic!("{}: oracle fails invariants: {e}", g.name));
        for name in ALGORITHMS {
            let algo = algorithm_by_name(name).expect(name);
            for threads in [1, 4] {
                let r = algo.decompose_with(&g, threads, false);
                assert_eq!(
                    r.core, oracle,
                    "{name} on '{}' ({} threads) disagrees with BZ",
                    g.name, threads
                );
                check_invariants(&g, &r.core).unwrap_or_else(|e| {
                    panic!("{name} on '{}' ({threads} threads): {e}", g.name)
                });
            }
        }
    }
}

#[test]
fn all_algorithms_are_deterministic_per_graph() {
    // same graph, same thread count -> bit-identical coreness across runs
    let g = gen::barabasi_albert(500, 5, 7);
    for name in ALGORITHMS {
        let algo = algorithm_by_name(name).expect(name);
        let a = algo.decompose_with(&g, 4, false);
        let b = algo.decompose_with(&g, 4, false);
        assert_eq!(a.core, b.core, "{name} is nondeterministic");
    }
}

#[test]
fn metrics_runs_do_not_change_results() {
    // the instrumented path must be observation-only
    let g = gen::planted_core(300, 700, &[(60, 10)], 5);
    let oracle = bz_coreness(&g);
    for name in ALGORITHMS {
        let algo = algorithm_by_name(name).expect(name);
        let r = algo.decompose_with(&g, 2, true);
        assert_eq!(r.core, oracle, "{name} with metrics enabled");
    }
}

#[test]
fn single_k_matches_bz_members_on_all_families() {
    // the sort-free single-k extractor (not a registry Decomposer — it
    // answers one k, not all) must agree with the oracle's k-core at
    // every k, including k = 0 (whole vertex set) and k > degeneracy
    // (empty), on every family and degenerate shape above
    use pico::core::peel::{single_k, single_k_size};
    for g in conformance_graphs() {
        let oracle = bz_coreness(&g);
        let k_max = oracle.iter().copied().max().unwrap_or(0);
        for k in 0..=k_max + 2 {
            let expected: Vec<u32> = (0..g.num_vertices() as u32)
                .filter(|&v| oracle[v as usize] >= k)
                .collect();
            let set = single_k(&g, k);
            assert_eq!(set.members(), expected, "single_k({k}) on '{}'", g.name);
            assert_eq!(set.size(), expected.len(), "size({k}) on '{}'", g.name);
            assert_eq!(
                single_k_size(&g, k),
                expected.len(),
                "single_k_size({k}) on '{}'",
                g.name
            );
        }
    }
}
