//! Property-based tests (quickcheck-lite, see `pico::util::quickcheck`):
//! random graphs in, algorithm/oracle agreement and structural invariants
//! out — with shrinking to minimal counterexamples on failure.

use pico::core::bz::bz_coreness;
use pico::core::hindex::hindex;
use pico::core::{index2core, peel, Decomposer};
use pico::graph::{CsrGraph, GraphBuilder};
use pico::util::quickcheck::{assert_prop, Arbitrary, Config};
use pico::util::rng::Rng;

/// Random simple graph: edge list drives generation and shrinks
/// edge-by-edge, which keeps counterexamples readable.
#[derive(Clone, Debug)]
struct RandGraph {
    n: usize,
    edges: Vec<(u32, u32)>,
}

impl RandGraph {
    fn build(&self) -> CsrGraph {
        let mut b = GraphBuilder::new(self.n);
        b.add_edges(self.edges.iter().copied());
        b.build("prop")
    }
}

impl Arbitrary for RandGraph {
    fn generate(rng: &mut Rng, size: usize) -> Self {
        let n = 2 + rng.below_usize(size.max(2) * 3);
        let m = rng.below_usize(n * 3 + 1);
        let edges = (0..m)
            .map(|_| (rng.below_usize(n) as u32, rng.below_usize(n) as u32))
            .filter(|(u, v)| u != v)
            .collect();
        Self { n, edges }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.edges.is_empty() {
            out.push(Self {
                n: self.n,
                edges: self.edges[..self.edges.len() / 2].to_vec(),
            });
            let mut e = self.edges.clone();
            e.pop();
            out.push(Self { n: self.n, edges: e });
        }
        if self.n > 2 {
            // drop the highest-id vertex and its edges
            let n = self.n - 1;
            out.push(Self {
                n,
                edges: self
                    .edges
                    .iter()
                    .copied()
                    .filter(|&(u, v)| (u as usize) < n && (v as usize) < n)
                    .collect(),
            });
        }
        out
    }
}

fn cfg(cases: usize, seed: u64) -> Config {
    Config {
        cases,
        seed,
        ..Config::default()
    }
}

#[test]
fn prop_all_peel_algorithms_match_bz() {
    assert_prop::<RandGraph>(&cfg(60, 11), "peel == BZ", |rg| {
        let g = rg.build();
        let expected = bz_coreness(&g);
        for (name, r) in [
            ("GPP", peel::Gpp.decompose_with(&g, 2, false)),
            ("PeelOne", peel::PeelOne.decompose_with(&g, 2, false)),
            ("PP-dyn", peel::PpDyn.decompose_with(&g, 2, false)),
            ("PO-dyn", peel::PoDyn.decompose_with(&g, 2, false)),
            ("BucketPeel", peel::BucketPeel.decompose_with(&g, 2, false)),
        ] {
            if r.core != expected {
                return Err(format!("{name}: got {:?}, want {expected:?}", r.core));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_all_index2core_algorithms_match_bz() {
    assert_prop::<RandGraph>(&cfg(60, 13), "index2core == BZ", |rg| {
        let g = rg.build();
        let expected = bz_coreness(&g);
        for (name, r) in [
            ("NbrCore", index2core::NbrCore.decompose_with(&g, 2, false)),
            ("CntCore", index2core::CntCore.decompose_with(&g, 2, false)),
            ("HistoCore", index2core::HistoCore.decompose_with(&g, 2, false)),
        ] {
            if r.core != expected {
                return Err(format!("{name}: got {:?}, want {expected:?}", r.core));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_coreness_monotone_under_edge_insertion() {
    // adding an edge never decreases any coreness
    assert_prop::<RandGraph>(&cfg(50, 17), "monotone insertion", |rg| {
        if rg.n < 3 {
            return Ok(());
        }
        let g = rg.build();
        let before = bz_coreness(&g);
        // add one fresh edge deterministically
        let (mut u, mut v) = (0u32, 1u32);
        'search: for a in 0..rg.n as u32 {
            for b in (a + 1)..rg.n as u32 {
                if !g.has_edge(a, b) {
                    u = a;
                    v = b;
                    break 'search;
                }
            }
        }
        if g.has_edge(u, v) {
            return Ok(()); // complete graph
        }
        let mut b = GraphBuilder::new(rg.n);
        b.add_edges(rg.edges.iter().copied());
        b.add_edge(u, v);
        let g2 = b.build("prop+e");
        let after = bz_coreness(&g2);
        for i in 0..before.len() {
            if after[i] < before[i] {
                return Err(format!(
                    "vertex {i}: coreness dropped {} -> {} after adding ({u},{v})",
                    before[i], after[i]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hindex_fixpoint_characterisation() {
    // H(coreness of neighbors) == coreness, and coreness <= h-index of
    // degrees (the first Index2core iterate).
    assert_prop::<RandGraph>(&cfg(60, 19), "h-index fixpoint", |rg| {
        let g = rg.build();
        let core = bz_coreness(&g);
        for v in 0..g.num_vertices() {
            let nbr_cores: Vec<u32> = g
                .neighbors(v as u32)
                .iter()
                .map(|&u| core[u as usize])
                .collect();
            let h = hindex(&nbr_cores);
            if h != core[v] {
                return Err(format!("v{v}: H(nbrs)={h} != core={}", core[v]));
            }
            let nbr_degs: Vec<u32> = g
                .neighbors(v as u32)
                .iter()
                .map(|&u| g.degree(u))
                .collect();
            if core[v] > hindex(&nbr_degs) {
                return Err(format!("v{v}: core exceeds first h-index iterate"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kcore_subgraph_min_degree() {
    // the k-core (vertices with coreness >= k) induces min degree >= k
    assert_prop::<RandGraph>(&cfg(50, 23), "k-core min degree", |rg| {
        let g = rg.build();
        let core = bz_coreness(&g);
        let k_max = core.iter().copied().max().unwrap_or(0);
        for k in 1..=k_max {
            for v in 0..g.num_vertices() {
                if core[v] >= k {
                    let deg_in_core = g
                        .neighbors(v as u32)
                        .iter()
                        .filter(|&&u| core[u as usize] >= k)
                        .count() as u32;
                    if deg_in_core < k {
                        return Err(format!("v{v} has degree {deg_in_core} in the {k}-core"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_builder_is_canonical() {
    // builder output passes full CSR validation whatever the input order
    assert_prop::<RandGraph>(&cfg(80, 29), "CSR canonical", |rg| {
        rg.build().validate()
    });
}

#[test]
fn prop_single_k_matches_bz_derived_members() {
    // the sort-free extractor's k-core == {v : bz coreness(v) >= k} at
    // every k from 0 (whole vertex set) through degeneracy + 2 (empty)
    assert_prop::<RandGraph>(&cfg(60, 37), "single_k == BZ members", |rg| {
        let g = rg.build();
        let core = bz_coreness(&g);
        let k_max = core.iter().copied().max().unwrap_or(0);
        for k in 0..=k_max + 2 {
            let expected: Vec<u32> = (0..g.num_vertices() as u32)
                .filter(|&v| core[v as usize] >= k)
                .collect();
            let set = peel::single_k(&g, k);
            if set.members() != expected {
                return Err(format!(
                    "k={k}: got {:?}, want {expected:?}",
                    set.members()
                ));
            }
            if set.size() != expected.len() {
                return Err(format!("k={k}: size {} != {}", set.size(), expected.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_metrics_counts_bound_edge_work() {
    // every edge access counted by an instrumented PeelOne run is at most
    // 2|E| per direction of the peel (each arc visited at most once per
    // endpoint removal)
    assert_prop::<RandGraph>(&cfg(40, 31), "edge access bound", |rg| {
        let g = rg.build();
        let r = peel::PoDyn.decompose_with(&g, 1, true);
        let bound = g.num_arcs();
        if r.metrics.edge_accesses > bound {
            return Err(format!(
                "edge accesses {} exceed 2|E| = {bound}",
                r.metrics.edge_accesses
            ));
        }
        Ok(())
    });
}
