//! Cross-module integration tests: every algorithm on every small-tier
//! suite dataset agrees with the BZ oracle and satisfies the structural
//! invariants; loaders feed algorithms; the CLI command layer works
//! end-to-end in-process.

use pico::bench::suite::{suite, Tier};
use pico::core::bz::bz_coreness;
use pico::core::verify::{check_against_oracle, check_invariants};
use pico::core::Decomposer;
use pico::coordinator::{algorithm_by_name, algorithm_names};
use pico::graph::{examples, gen, io};

/// The native (non-XLA) algorithms — XLA needs artifacts; covered in
/// runtime_xla.rs.
fn native_algorithms() -> Vec<Box<dyn Decomposer>> {
    algorithm_names()
        .into_iter()
        .filter(|n| !n.contains("XLA"))
        .map(|n| algorithm_by_name(n).unwrap())
        .collect()
}

#[test]
fn all_algorithms_agree_on_small_suite() {
    for entry in suite(Tier::Small) {
        let g = entry.build();
        let expected = bz_coreness(&g);
        for algo in native_algorithms() {
            for threads in [1, 3] {
                let r = algo.decompose_with(&g, threads, false);
                assert_eq!(
                    r.core, expected,
                    "{} with {} threads disagrees on {}",
                    algo.name(),
                    threads,
                    entry.name
                );
            }
        }
    }
}

#[test]
fn all_algorithms_satisfy_invariants_on_skewed_graph() {
    let g = gen::star_burst(4, 300, 600, 5);
    for algo in native_algorithms() {
        let r = algo.decompose_with(&g, 2, false);
        check_invariants(&g, &r.core).unwrap_or_else(|e| panic!("{}: {e}", algo.name()));
    }
}

#[test]
fn iteration_counters_reported() {
    let (g, _) = gen::nested_cliques(4, 4, 3);
    let pod = algorithm_by_name("PO-dyn").unwrap().decompose_with(&g, 2, false);
    // dyn frontier: l1 == k_max
    assert_eq!(pod.iterations as u32, pod.k_max());
    let hst = algorithm_by_name("HistoCore").unwrap().decompose_with(&g, 2, false);
    assert!(hst.iterations >= 1);
    assert!(pod.launches > 0);
}

#[test]
fn loader_to_algorithm_pipeline() {
    // serialize G1, reload, decompose
    let dir = std::env::temp_dir().join("pico_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g1.el");
    std::fs::write(&path, pico::graph::io::edgelist::serialize(&examples::g1())).unwrap();
    let g = io::load(&path).unwrap();
    let r = algorithm_by_name("HistoCore").unwrap().decompose(&g);
    assert_eq!(r.core, examples::g1_coreness());

    // binary cache round trip through an algorithm
    let bin = dir.join("g1.pico");
    io::binfmt::write_file(&g, &bin).unwrap();
    let g2 = io::load(&bin).unwrap();
    let r2 = algorithm_by_name("PO-dyn").unwrap().decompose(&g2);
    assert_eq!(r2.core, examples::g1_coreness());
}

#[test]
fn oracle_check_round_trips_every_generator() {
    let graphs = vec![
        gen::erdos_renyi(300, 900, 1),
        gen::barabasi_albert(300, 3, 2),
        gen::rmat(8, 6, 0.57, 0.19, 0.19, 3),
        gen::power_law_cluster(300, 3, 0.5, 4),
        gen::star_burst(3, 50, 100, 5),
        gen::grid2d(15, 15),
        gen::caveman(10, 6, 6),
        gen::planted_core(400, 800, &[(100, 8), (25, 16)], 7),
        gen::nested_cliques(4, 3, 3).0,
    ];
    for g in &graphs {
        let core = bz_coreness(g);
        check_against_oracle(g, &core).unwrap_or_else(|e| panic!("{}: {e}", g.name));
    }
}

#[test]
fn metrics_are_consistent_across_runs() {
    // deterministic single-thread instrumented runs give identical counts
    let g = gen::barabasi_albert(500, 4, 9);
    let algo = algorithm_by_name("PeelOne").unwrap();
    let a = algo.decompose_with(&g, 1, true);
    let b = algo.decompose_with(&g, 1, true);
    assert_eq!(a.metrics, b.metrics);
    assert_eq!(a.iterations, b.iterations);
}

#[test]
fn paradigms_report_expected_iteration_relation_on_deep_graph() {
    // Table VII's structural claim: l2 << l1 = k_max on deep hierarchies.
    let (g, _) = gen::nested_cliques(10, 6, 6);
    let pod = algorithm_by_name("PO-dyn").unwrap().decompose_with(&g, 2, false);
    let hst = algorithm_by_name("HistoCore").unwrap().decompose_with(&g, 2, false);
    assert_eq!(pod.core, hst.core);
    assert!(
        hst.iterations * 5 < pod.iterations,
        "expected l2 ({}) << l1 ({})",
        hst.iterations,
        pod.iterations
    );
}
