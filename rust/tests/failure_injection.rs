//! Failure injection: corrupted artifacts, malformed inputs, and
//! panicking algorithms must surface as structured errors — never hangs,
//! never silent wrong answers.

use pico::coordinator::{DatasetSpec, Job, JobOutcome, Scheduler, SchedulerConfig};
use pico::graph::{examples, io};
use pico::runtime::artifacts::{ArtifactStore, Kind};
use pico::runtime::Bucket;
use std::sync::Arc;

fn temp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("pico_failures").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_structured_error() {
    let dir = temp_dir("no_manifest");
    let err = ArtifactStore::open(&dir).unwrap_err();
    assert!(err.to_string().contains("make artifacts"), "{err}");
}

#[test]
fn malformed_manifest_is_rejected() {
    let dir = temp_dir("bad_manifest");
    std::fs::write(dir.join("manifest.txt"), "eight four\n").unwrap();
    assert!(ArtifactStore::open(&dir).is_err());
    std::fs::write(dir.join("manifest.txt"), "").unwrap();
    assert!(ArtifactStore::open(&dir).is_err());
}

#[cfg(feature = "xla")]
#[test]
fn truncated_hlo_artifact_fails_to_parse() {
    let dir = temp_dir("trunc_hlo");
    std::fs::write(dir.join("manifest.txt"), "8 4\n").unwrap();
    std::fs::write(dir.join("peel_n8_d4.hlo.txt"), "HloModule garbage {{{").unwrap();
    let store = ArtifactStore::open(&dir).unwrap();
    let err = store
        .load_computation(Kind::Peel, Bucket { n: 8, d: 4 })
        .map(|_| ())
        .unwrap_err();
    assert!(err.to_string().contains("peel_n8_d4"), "{err}");
}

#[test]
fn missing_artifact_file_reports_path() {
    let dir = temp_dir("missing_file");
    std::fs::write(dir.join("manifest.txt"), "8 4\n").unwrap();
    let store = ArtifactStore::open(&dir).unwrap();
    assert!(store
        .load_hlo_text(Kind::Hindex, Bucket { n: 8, d: 4 })
        .is_err());
}

#[test]
fn malformed_graph_files_are_rejected() {
    let dir = temp_dir("bad_graphs");
    let p = dir.join("bad.el");
    std::fs::write(&p, "1 2\nthree four\n").unwrap();
    assert!(io::load(&p).is_err());
    let p = dir.join("bad.mtx");
    std::fs::write(&p, "%%MatrixMarket matrix coordinate\n2 2 1\n0 1\n").unwrap();
    assert!(io::load(&p).is_err());
    let p = dir.join("bad.pico");
    std::fs::write(&p, b"NOTMAGIC").unwrap();
    assert!(io::load(&p).is_err());
}

#[cfg(feature = "xla")]
#[test]
fn scheduler_contains_panicking_algorithm() {
    if pico::runtime::default_worker().is_err() {
        eprintln!("SKIP scheduler_contains_panicking_algorithm: XLA artifacts not built");
        return;
    }
    // VecPeel's Decomposer impl panics on bucket overflow when invoked
    // through the non-fallible trait path; the scheduler must contain it.
    let big_star = pico::graph::gen::star_burst(1, 300, 0, 1); // d_max 300 > 64
    let jobs = vec![
        Job::new(DatasetSpec::InMemory(Arc::new(big_star)), "VecPeel(XLA)").with_threads(1),
        Job::new(DatasetSpec::InMemory(Arc::new(examples::g1())), "PO-dyn").with_threads(1),
    ];
    let results = Scheduler::new(SchedulerConfig::default()).run(jobs);
    assert!(
        matches!(results[0].outcome, JobOutcome::Panicked(_)),
        "expected contained panic, got {:?}",
        results[0].outcome
    );
    // the batch survived: the second job still ran fine
    assert_eq!(results[1].outcome, JobOutcome::Ok);
}

#[test]
fn scheduler_rejects_unloadable_dataset_before_dispatch() {
    let jobs = vec![Job::new(DatasetSpec::Path("/dev/null/nope.el".into()), "BZ")];
    let results = Scheduler::new(SchedulerConfig::default()).run(jobs);
    assert!(matches!(results[0].outcome, JobOutcome::Rejected(_)));
}

#[test]
fn config_failures_are_structured() {
    use pico::config::parser::KvFile;
    assert!(KvFile::parse("no equals sign").is_err());
    let kv = KvFile::parse("threads = NaN").unwrap();
    let mut cfg = pico::config::Config::default();
    assert!(cfg.apply_file(&kv).is_err());
}

#[cfg(not(feature = "xla"))]
#[test]
fn xla_algorithms_rejected_without_feature() {
    // Built without the XLA backend, the registry rejects the vectorised
    // engines with a pointer to the feature flag instead of panicking.
    let jobs = vec![
        Job::new(DatasetSpec::InMemory(Arc::new(examples::g1())), "VecPeel(XLA)").with_threads(1),
        Job::new(DatasetSpec::InMemory(Arc::new(examples::g1())), "PO-dyn").with_threads(1),
    ];
    let results = Scheduler::new(SchedulerConfig::default()).run(jobs);
    assert!(
        matches!(results[0].outcome, JobOutcome::Rejected(ref m) if m.contains("xla")),
        "expected rejection naming the feature, got {:?}",
        results[0].outcome
    );
    assert_eq!(results[1].outcome, JobOutcome::Ok);
}
