//! Adversarial protocol tests: malformed, oversized, truncated, and
//! random-garbage input over both the line protocol and the binary
//! framing. The server must answer structured `ERR`s (or close the
//! connection), never panic, and never leak or corrupt a hosted graph
//! slot — after every attack the service still answers `PING` and hosts
//! exactly the graphs it hosted before.

use pico::net::codec;
use pico::net::{ConnConfig, NetConfig};
use pico::service::server::{read_frame, write_frame, MAX_FRAME_BYTES, MAX_LINE_BYTES};
use pico::service::{serve, serve_with, BatchConfig, CoreService, ServerHandle};
use pico::shard::encode_index;
use pico::util::rng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

fn spawn_server() -> (Arc<CoreService>, ServerHandle) {
    let svc = Arc::new(CoreService::new(BatchConfig {
        threads: 1,
        ..BatchConfig::default()
    }));
    svc.open("g1", &pico::graph::examples::g1());
    let handle = serve(svc.clone(), "127.0.0.1:0").expect("bind");
    (svc, handle)
}

/// A server with a tiny bounded pool — the worker/cap/timeout paths
/// under test, on top of the same service.
fn spawn_bounded(
    workers: usize,
    max_conns: usize,
    stall_ms: u64,
) -> (Arc<CoreService>, ServerHandle) {
    let svc = Arc::new(CoreService::new(BatchConfig {
        threads: 1,
        ..BatchConfig::default()
    }));
    svc.open("g1", &pico::graph::examples::g1());
    let cfg = NetConfig {
        workers,
        max_connections: max_conns,
        conn: ConnConfig {
            poll_timeout: Duration::from_millis(20),
            stall_timeout: Duration::from_millis(stall_ms),
            ..Default::default()
        },
    };
    let handle = serve_with(svc.clone(), "127.0.0.1:0", cfg).expect("bind");
    (svc, handle)
}

struct Client {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Client {
    fn connect(handle: &ServerHandle) -> Self {
        let stream = TcpStream::connect(handle.addr()).expect("connect");
        let w = stream.try_clone().unwrap();
        Self {
            w,
            r: BufReader::new(stream),
        }
    }

    fn send_line(&mut self, cmd: &str) -> Option<String> {
        writeln!(self.w, "{cmd}").ok()?;
        self.w.flush().ok()?;
        self.read_line()
    }

    fn read_line(&mut self) -> Option<String> {
        let mut line = String::new();
        match self.r.read_line(&mut line) {
            Ok(0) | Err(_) => None,
            Ok(_) => Some(line.trim_end().to_string()),
        }
    }

    fn upgrade_binary(&mut self) {
        let reply = self.send_line("BINARY").expect("upgrade reply");
        assert!(reply.starts_with("OK binary proto="), "{reply}");
    }

    fn send_frame(&mut self, body: &[u8]) -> Option<Vec<u8>> {
        write_frame(&mut self.w, body).ok()?;
        self.read_frame()
    }

    fn read_frame(&mut self) -> Option<Vec<u8>> {
        read_frame(&mut self.r, MAX_FRAME_BYTES).ok().flatten()
    }
}

/// Parse one `key=<n>` counter out of a `METRICS` reply line.
fn metric_field(metrics: &str, key: &str) -> u64 {
    metrics
        .split(key)
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("no {key}<n> in {metrics}"))
}

/// The liveness + no-slot-leak probe run after every attack.
fn assert_healthy(handle: &ServerHandle, hosted: &str) {
    let mut c = Client::connect(handle);
    assert_eq!(c.send_line("PING").as_deref(), Some("OK pong"));
    assert_eq!(c.send_line("GRAPHS").as_deref(), Some(hosted));
    let _ = c.send_line("QUIT");
}

#[test]
fn malformed_line_commands_get_structured_errors() {
    let (_svc, handle) = spawn_server();
    let mut c = Client::connect(&handle);
    for cmd in [
        "NOPE",
        "CORENESS",
        "CORENESS x",
        "CORENESS -1",
        "CORENESS 999999999999999999999",
        "MEMBERS",
        "MEMBERS banana",
        "INSERT 1",
        "INSERT 1 1",
        "INSERT a b",
        "DELETE 4294967295 0",
        "USE",
        "USE nope",
        "OPEN",
        "OPEN x",
        "OPEN x nosuchdataset",
        "OPEN x g1 0",
        "OPEN x g1 65",
        "OPEN x g1 banana",
        "SNAPSHOT",
        "RESTORE r",
        "\u{1F980} unicode verb",
    ] {
        let reply = c.send_line(cmd).unwrap_or_else(|| panic!("closed on '{cmd}'"));
        assert!(reply.starts_with("ERR"), "'{cmd}' -> '{reply}'");
    }
    // the connection survived all of it
    assert_eq!(c.send_line("PING").as_deref(), Some("OK pong"));
    assert_healthy(&handle, "OK n=1 g1");
    handle.stop();
}

#[test]
fn oversized_line_is_rejected_and_connection_closed() {
    let (_svc, handle) = spawn_server();
    let mut c = Client::connect(&handle);
    let huge = "A".repeat(MAX_LINE_BYTES + 10);
    let reply = c.send_line(&huge).expect("error reply before close");
    assert!(reply.starts_with("ERR BADREQ line exceeds"), "{reply}");
    // server closes this connection afterwards
    assert!(c.send_line("PING").is_none());
    assert_healthy(&handle, "OK n=1 g1");
    handle.stop();
}

#[test]
fn unterminated_line_stream_cannot_grow_the_buffer() {
    let (_svc, handle) = spawn_server();
    let mut c = Client::connect(&handle);
    // stream line-less bytes; the cap must cut the reader off
    let chunk = vec![b'x'; 1024];
    let mut rejected = false;
    for _ in 0..((MAX_LINE_BYTES / 1024) + 2) {
        if c.w.write_all(&chunk).and_then(|_| c.w.flush()).is_err() {
            rejected = true; // server already closed on us
            break;
        }
    }
    if !rejected {
        let reply = c.read_line();
        assert!(
            reply.is_none()
                || reply.as_deref().unwrap_or("").starts_with("ERR BADREQ line exceeds"),
            "{reply:?}"
        );
    }
    assert_healthy(&handle, "OK n=1 g1");
    handle.stop();
}

#[test]
fn oversized_binary_frame_is_rejected() {
    let (_svc, handle) = spawn_server();
    let mut c = Client::connect(&handle);
    c.upgrade_binary();
    // declare a frame bigger than the cap; send no body
    c.w
        .write_all(&((MAX_FRAME_BYTES + 1) as u32).to_le_bytes())
        .unwrap();
    c.w.flush().unwrap();
    let reply = c.read_frame().expect("error frame before close");
    assert!(
        std::str::from_utf8(&reply)
            .unwrap()
            .starts_with("ERR BADREQ frame exceeds"),
        "{reply:?}"
    );
    assert!(c.read_frame().is_none(), "connection must close");
    assert_healthy(&handle, "OK n=1 g1");
    handle.stop();
}

#[test]
fn truncated_binary_frame_just_closes() {
    let (_svc, handle) = spawn_server();
    {
        let mut c = Client::connect(&handle);
        c.upgrade_binary();
        // declare 100 bytes, send 10, then hang up
        c.w.write_all(&100u32.to_le_bytes()).unwrap();
        c.w.write_all(b"0123456789").unwrap();
        c.w.flush().unwrap();
        let _ = c.w.shutdown(std::net::Shutdown::Write);
        assert!(c.read_frame().is_none());
    }
    // half a header, then hang up
    {
        let mut c = Client::connect(&handle);
        c.upgrade_binary();
        c.w.write_all(&[0xFF, 0x00]).unwrap();
        c.w.flush().unwrap();
        let _ = c.w.shutdown(std::net::Shutdown::Write);
        assert!(c.read_frame().is_none());
    }
    assert_healthy(&handle, "OK n=1 g1");
    handle.stop();
}

#[test]
fn corrupt_restore_payloads_never_leak_a_slot() {
    let (_svc, handle) = spawn_server();
    let mut c = Client::connect(&handle);
    c.upgrade_binary();
    // take a valid snapshot to mutate
    let frame = c.send_frame(b"SNAPSHOT").expect("snapshot");
    let nl = frame.iter().position(|&b| b == b'\n').unwrap();
    let good = frame[nl + 1..].to_vec();

    let mut corruptions: Vec<Vec<u8>> = vec![
        Vec::new(),                      // empty payload
        b"garbage".to_vec(),             // not a snapshot at all
        good[..good.len() / 2].to_vec(), // truncated
    ];
    let mut tampered = good.clone();
    let off = tampered.len() - 4;
    tampered[off..].copy_from_slice(&77u32.to_le_bytes()); // bogus coreness
    corruptions.push(tampered);
    let mut bad_magic = good.clone();
    bad_magic[0] ^= 0xFF;
    corruptions.push(bad_magic);

    for (i, payload) in corruptions.iter().enumerate() {
        let mut req = b"RESTORE leak\n".to_vec();
        req.extend_from_slice(payload);
        let reply = c.send_frame(&req).unwrap_or_else(|| panic!("closed on corruption {i}"));
        let reply = String::from_utf8_lossy(&reply).into_owned();
        assert!(reply.starts_with("ERR"), "corruption {i}: {reply}");
        // no partial slot installed
        let graphs = c.send_frame(b"GRAPHS").unwrap();
        assert_eq!(graphs, b"OK n=1 g1", "after corruption {i}");
    }

    // the genuine payload still restores fine on the same connection
    let mut req = b"RESTORE replica\n".to_vec();
    req.extend_from_slice(&good);
    let reply = c.send_frame(&req).unwrap();
    assert!(reply.starts_with(b"OK restore=replica"), "{reply:?}");
    assert_healthy(&handle, "OK n=2 g1 replica");
    handle.stop();
}

#[test]
fn random_byte_corpus_never_kills_the_server() {
    let (_svc, handle) = spawn_server();
    let mut rng = Rng::new(0xF0220_5EED);
    for case in 0..48 {
        let mut c = Client::connect(&handle);
        let len = 1 + rng.below_usize(600);
        let blob: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        // half the corpus attacks the binary framing, half the line mode
        if case % 2 == 0 {
            c.upgrade_binary();
        }
        let _ = c.w.write_all(&blob);
        let _ = c.w.flush();
        let _ = c.w.shutdown(std::net::Shutdown::Write);
        // drain whatever the server replies until it closes our end
        if case % 2 == 0 {
            while c.read_frame().is_some() {}
        } else {
            while c.read_line().is_some() {}
        }
        assert_healthy(&handle, "OK n=1 g1");
    }
    handle.stop();
}

// ---- codec-direct adversarial corpus -------------------------------
// The frame codec and the payload magics live in `net::codec`; drive
// them without a socket so a framing regression fails here before any
// network test touches it.

#[test]
fn codec_rejects_oversized_and_truncated_frames_directly() {
    // declared length above the cap: InvalidData, nothing consumed past
    // the header
    let mut buf = Vec::new();
    buf.extend_from_slice(&((MAX_FRAME_BYTES + 1) as u32).to_le_bytes());
    buf.extend_from_slice(b"should never be read");
    let mut r = std::io::Cursor::new(buf);
    let err = codec::read_frame(&mut r, MAX_FRAME_BYTES).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert_eq!(r.position(), 4, "only the header may be consumed");

    // body shorter than declared: UnexpectedEof at every truncation
    let mut good = Vec::new();
    codec::write_frame(&mut good, b"0123456789").unwrap();
    for cut in 4..good.len() {
        let mut r = std::io::Cursor::new(good[..cut].to_vec());
        let err = codec::read_frame(&mut r, MAX_FRAME_BYTES).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "cut at {cut}");
    }
    // a cut inside the header is a clean EOF only at exactly zero bytes
    let mut r = std::io::Cursor::new(Vec::<u8>::new());
    assert!(codec::read_frame(&mut r, MAX_FRAME_BYTES).unwrap().is_none());
}

#[test]
fn codec_cursor_rejects_truncated_magic_and_hostile_counts() {
    // every payload decoder starts by taking its 8-byte magic off the
    // shared cursor; a short buffer must error, never panic
    for cut in 0..8 {
        let mut c = codec::Cursor::new(&codec::SNAPSHOT_MAGIC[..cut]);
        assert!(c.take(8).is_err(), "cut at {cut}");
    }
    let mut c = codec::Cursor::new(codec::MANIFEST_MAGIC);
    assert_eq!(c.take(8).unwrap(), codec::MANIFEST_MAGIC);
    c.done("manifest magic").unwrap();
    // wrong magic still reads — rejection is the decoder's job — but a
    // count pointing past the payload must fail before any allocation
    let mut evil = codec::DELTA_MAGIC.to_vec();
    evil.extend_from_slice(&u64::MAX.to_le_bytes());
    let mut c = codec::Cursor::new(&evil);
    c.take(8).unwrap();
    assert!(c.count(8, "steps").is_err());
}

#[test]
fn mid_upgrade_garbage_is_contained() {
    let (_svc, handle) = spawn_server();
    // upgrade, then stream bytes that parse as a frame whose body is
    // garbage — the server must answer a structured ERR per frame and
    // stay healthy
    let mut c = Client::connect(&handle);
    c.upgrade_binary();
    let blob: Vec<u8> = (0..64u8).map(|b| b.wrapping_mul(37) ^ 0xA5).collect();
    let reply = c.send_frame(&blob).expect("structured reply to garbage");
    assert!(reply.starts_with(b"ERR"), "{reply:?}");
    // a declared length with a half-shipped body, then hangup
    let mut c = Client::connect(&handle);
    c.upgrade_binary();
    c.w.write_all(&64u32.to_le_bytes()).unwrap();
    c.w.write_all(b"half").unwrap();
    c.w.flush().unwrap();
    let _ = c.w.shutdown(std::net::Shutdown::Write);
    assert!(c.read_frame().is_none(), "mid-frame hangup closes cleanly");
    assert_healthy(&handle, "OK n=1 g1");
    handle.stop();
}

// ---- bounded-pool behaviour ----------------------------------------

#[test]
fn pool_stays_responsive_with_all_workers_busy() {
    // 2 workers; pin both behind *slow* requests (bytes trickling in
    // with no newline) and prove a third connection is still served —
    // i.e. a slow sender yields its worker instead of pinning it
    let (_svc, handle) = spawn_bounded(2, 16, 60_000);
    let mut slow = Vec::new();
    for _ in 0..2 {
        let mut c = Client::connect(&handle);
        c.w.write_all(b"CORENESS").unwrap(); // a started, unfinished line
        c.w.flush().unwrap();
        slow.push(c);
    }
    // give the pool a beat to pick both up
    std::thread::sleep(Duration::from_millis(100));
    let mut live = Client::connect(&handle);
    for _ in 0..5 {
        assert_eq!(live.send_line("PING").as_deref(), Some("OK pong"));
    }
    // the slow requests complete once their bytes arrive
    for c in &mut slow {
        c.w.write_all(b" 3\n").unwrap();
        c.w.flush().unwrap();
    }
    for c in &mut slow {
        assert_eq!(c.read_line().as_deref(), Some("OK core=2 epoch=0"));
    }
    let _ = live.send_line("QUIT");
    handle.stop();
}

#[test]
fn pool_rejects_connection_over_the_cap_with_a_clean_error_line() {
    let cap = 4;
    let (_svc, handle) = spawn_bounded(2, cap, 60_000);
    let mut held = Vec::new();
    for i in 0..cap {
        let mut c = Client::connect(&handle);
        assert_eq!(c.send_line("PING").as_deref(), Some("OK pong"), "conn {i}");
        held.push(c);
    }
    // connection #cap+1: one structured error line, then close
    let mut over = Client::connect(&handle);
    let reply = over.read_line().expect("rejection line");
    assert!(
        reply.starts_with("ERR server at connection capacity"),
        "{reply}"
    );
    assert!(over.read_line().is_none(), "rejected connection must close");
    // held connections keep working, and the rejection is counted
    let metrics = held[0].send_line("METRICS").expect("metrics");
    assert!(metrics.starts_with("OK workers=2 "), "{metrics}");
    assert!(metrics.contains(&format!("conn_cap={cap}")), "{metrics}");
    assert!(metrics.contains("rejected=1"), "{metrics}");
    assert!(metrics.contains(&format!("active={cap}")), "{metrics}");
    // freeing a slot lets a new connection in
    let _ = held.pop().unwrap().send_line("QUIT");
    std::thread::sleep(Duration::from_millis(100));
    let mut fresh = Client::connect(&handle);
    assert_eq!(fresh.send_line("PING").as_deref(), Some("OK pong"));
    handle.stop();
}

#[test]
fn slow_loris_requests_are_timed_out_and_counted() {
    // stall budget of 150ms: a started-but-never-finished request gets
    // a structured timeout error and the connection is closed
    let (_svc, handle) = spawn_bounded(2, 8, 150);
    let mut c = Client::connect(&handle);
    c.w.write_all(b"CORENESS").unwrap(); // no newline, ever
    c.w.flush().unwrap();
    let reply = c.read_line().expect("timeout error line");
    assert!(reply.starts_with("ERR read timed out mid-request"), "{reply}");
    assert!(c.read_line().is_none(), "timed-out connection must close");
    let mut probe = Client::connect(&handle);
    let metrics = probe.send_line("METRICS").expect("metrics");
    assert!(metrics.contains("timed_out=1"), "{metrics}");
    assert_healthy(&handle, "OK n=1 g1");
    handle.stop();
}

#[test]
fn idle_connections_are_reclaimed_only_at_the_cap() {
    let svc = Arc::new(CoreService::new(BatchConfig {
        threads: 1,
        ..BatchConfig::default()
    }));
    svc.open("g1", &pico::graph::examples::g1());
    let cfg = NetConfig {
        workers: 2,
        max_connections: 2,
        conn: ConnConfig {
            poll_timeout: Duration::from_millis(20),
            idle_reclaim: Duration::from_millis(150),
            ..Default::default()
        },
    };
    let handle = serve_with(svc, "127.0.0.1:0", cfg).expect("bind");
    // two idle holders fill the cap…
    let mut holders = [Client::connect(&handle), Client::connect(&handle)];
    for h in &mut holders {
        assert_eq!(h.send_line("PING").as_deref(), Some("OK pong"));
    }
    // …so the next accept is rejected with the capacity line…
    let mut over = Client::connect(&handle);
    let reply = over.read_line().expect("rejection line");
    assert!(reply.starts_with("ERR server at connection capacity"), "{reply}");
    // …but once a holder sits idle past the reclaim budget, its slot
    // comes back and a fresh client gets served
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let mut fresh = None;
    while std::time::Instant::now() < deadline {
        let mut c = Client::connect(&handle);
        if c.send_line("PING").as_deref() == Some("OK pong") {
            fresh = Some(c);
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    let mut fresh = fresh.expect("an idle slot must be reclaimed at the cap");
    let metrics = fresh.send_line("METRICS").expect("metrics");
    let reclaimed: u64 = metrics
        .rsplit("reclaimed=")
        .next()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or_else(|| panic!("no reclaimed= in {metrics}"));
    assert!(reclaimed >= 1, "{metrics}");
    handle.stop();
}

#[test]
fn at_cap_rejections_are_bounded_and_never_block_the_accept_thread() {
    // fill a tiny cap, then park a horde of rejected sockets that never
    // read their `ERR` line — a blocking reject write would wedge the
    // accept thread behind the first deadbeat and starve every accept
    // after it
    let cap = 2;
    let (_svc, handle) = spawn_bounded(2, cap, 60_000);
    let mut held = Vec::new();
    for i in 0..cap {
        let mut c = Client::connect(&handle);
        assert_eq!(c.send_line("PING").as_deref(), Some("OK pong"), "conn {i}");
        held.push(c);
    }
    let deadbeats: Vec<TcpStream> = (0..8)
        .map(|i| TcpStream::connect(handle.addr()).unwrap_or_else(|e| panic!("deadbeat {i}: {e}")))
        .collect();
    // give the accept thread time to chew through (and reject) them all
    std::thread::sleep(Duration::from_millis(300));
    // a well-behaved over-cap client still gets its rejection promptly
    let probe = TcpStream::connect(handle.addr()).expect("probe connect");
    probe
        .set_read_timeout(Some(Duration::from_secs(2)))
        .unwrap();
    let mut probe = BufReader::new(probe);
    let mut line = String::new();
    probe.read_line(&mut line).expect("prompt rejection line");
    assert!(
        line.starts_with("ERR server at connection capacity"),
        "{line}"
    );
    // every deadbeat and the probe were counted, none served
    let metrics = held[0].send_line("METRICS").expect("metrics");
    assert!(metric_field(&metrics, "rejected=") >= 9, "{metrics}");
    assert!(metrics.contains(&format!("active={cap}")), "{metrics}");
    // freeing a slot lets a real client in past the deadbeat horde
    let _ = held.pop().unwrap().send_line("QUIT");
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let mut served = false;
    while std::time::Instant::now() < deadline {
        let mut c = Client::connect(&handle);
        if c.send_line("PING").as_deref() == Some("OK pong") {
            served = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(served, "freed slot never went to a fresh client");
    drop(deadbeats);
    handle.stop();
}

/// Stage a glut of un-read reply bytes on one connection: `OPEN` a
/// graph whose snapshot is ~1.5 MiB, pipeline `frames` `SNAPSHOT`
/// requests, and never read a byte back. The combined replies exceed
/// any sane kernel socket buffering, so the server's staged output
/// stops making progress and the write-stall path must engage.
fn stall_writes(handle: &ServerHandle, frames: usize) -> Client {
    let mut glut = Client::connect(handle);
    let reply = glut.send_line("OPEN big social-ba").expect("open");
    assert!(reply.starts_with("OK open=big"), "{reply}");
    glut.upgrade_binary();
    for _ in 0..frames {
        write_frame(&mut glut.w, b"SNAPSHOT").expect("pipeline request");
    }
    glut.w.flush().unwrap();
    glut
}

#[test]
fn non_draining_reader_is_cut_off_and_counted() {
    // write-side slow-loris: the peer takes replies but stops draining
    // them. The connection must be cut off after the stall budget —
    // with its worker released the whole time — and counted.
    let (_svc, handle) = spawn_bounded(2, 8, 400);
    let glut = stall_writes(&handle, 32);
    let mut probe = Client::connect(&handle);
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    loop {
        // the stalled connection never pins a worker: the probe is
        // served continuously while the server waits out the stall
        assert_eq!(probe.send_line("PING").as_deref(), Some("OK pong"));
        let metrics = probe.send_line("METRICS").expect("metrics");
        if metric_field(&metrics, "write_stalled=") >= 1 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "never cut off: {metrics}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    // the deadbeat's slot came back; the server is unharmed
    drop(glut);
    let mut fresh = Client::connect(&handle);
    assert_eq!(fresh.send_line("PING").as_deref(), Some("OK pong"));
    handle.stop();
}

#[test]
fn drain_completes_while_a_connection_is_write_stalled() {
    // a graceful drain must not wait forever on a peer that stopped
    // reading: the stall budget reclaims the connection and the drain
    // finishes in bounded time
    let (_svc, handle) = spawn_bounded(2, 8, 300);
    let glut = stall_writes(&handle, 32);
    // let the staged replies fill the kernel buffers and jam
    std::thread::sleep(Duration::from_millis(250));
    assert!(
        handle.drain(Duration::from_secs(10)),
        "drain wedged behind a write-stalled peer"
    );
    let stalled = handle
        .stats()
        .write_stalled
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(stalled >= 1, "write_stalled={stalled}");
    drop(glut);
}

#[test]
fn binary_snapshot_restore_round_trip_over_tcp_matches_in_process() {
    let (svc, handle) = spawn_server();
    let expected = encode_index(&svc.index("g1").unwrap());
    let mut c = Client::connect(&handle);
    c.upgrade_binary();
    let frame = c.send_frame(b"SNAPSHOT").unwrap();
    let nl = frame.iter().position(|&b| b == b'\n').unwrap();
    assert_eq!(&frame[nl + 1..], &expected[..], "wire bytes == in-process bytes");
    // restore under a new name, then query it through the same connection
    let mut req = b"RESTORE replica\n".to_vec();
    req.extend_from_slice(&expected);
    assert!(c.send_frame(&req).unwrap().starts_with(b"OK restore=replica"));
    assert_eq!(c.send_frame(b"CORENESS 3").unwrap(), b"OK core=2 epoch=0");
    assert_eq!(c.send_frame(b"EPOCH").unwrap(), b"OK epoch=0");
    // edits on the replica leave the primary untouched
    assert_eq!(c.send_frame(b"INSERT 2 5").unwrap(), b"OK pending=1");
    assert!(c.send_frame(b"FLUSH").unwrap().starts_with(b"OK epoch=1"));
    assert_eq!(c.send_frame(b"USE g1").unwrap(), b"OK use=g1");
    assert_eq!(c.send_frame(b"EPOCH").unwrap(), b"OK epoch=0");
    let _ = c.send_frame(b"QUIT");
    handle.stop();
}
