//! XLA runtime integration: the vectorised engines against the oracle on
//! the XLA-tier suite, bucket-selection edge cases, and scheduler-driven
//! execution of the XLA path.
//!
//! The whole file is gated on the `xla` cargo feature (the backend links
//! the `xla` crate); with the feature on but no AOT artifacts on disk,
//! each test skips with a message rather than failing.

#![cfg(feature = "xla")]

use pico::bench::suite::{suite, Tier};
use pico::coordinator::{DatasetSpec, Job, Scheduler, SchedulerConfig};
use pico::core::bz::bz_coreness;
use pico::graph::examples;
use pico::runtime::{default_worker, select_bucket, Bucket, VecHindex, VecPeel};
use std::sync::Arc;

/// Skip (not fail) when AOT artifacts have not been built.
fn artifacts_missing(test: &str) -> bool {
    if default_worker().is_err() {
        eprintln!("SKIP {test}: XLA artifacts not built (run `make artifacts`)");
        return true;
    }
    false
}

#[test]
fn vec_engines_match_oracle_on_xla_tier() {
    if artifacts_missing("vec_engines_match_oracle_on_xla_tier") {
        return;
    }
    let peel = VecPeel::open_default().unwrap();
    let hindex = VecHindex::open_default().unwrap();
    for entry in suite(Tier::Xla) {
        let g = entry.build();
        let expected = bz_coreness(&g);
        let p = peel.try_decompose(&g).unwrap();
        assert_eq!(p.core, expected, "VecPeel on {}", entry.name);
        let h = hindex.try_decompose(&g).unwrap();
        assert_eq!(h.core, expected, "VecHindex on {}", entry.name);
    }
}

#[test]
fn xla_engines_via_scheduler() {
    if artifacts_missing("xla_engines_via_scheduler") {
        return;
    }
    let jobs = vec![
        Job::new(DatasetSpec::InMemory(Arc::new(examples::g1())), "VecPeel(XLA)").with_threads(1),
        Job::new(DatasetSpec::InMemory(Arc::new(examples::g1())), "VecHindex(XLA)").with_threads(1),
    ];
    let results = Scheduler::new(SchedulerConfig::default()).run(jobs);
    for r in &results {
        assert!(r.ok(), "{}: {:?}", r.algorithm, r.outcome);
        assert_eq!(r.k_max, 2);
    }
}

#[test]
fn bucket_selection_boundaries() {
    let buckets = [
        Bucket { n: 8, d: 4 },
        Bucket { n: 64, d: 8 },
        Bucket { n: 4096, d: 64 },
    ];
    // exact fit
    assert_eq!(select_bucket(&buckets, 8, 4).unwrap(), Bucket { n: 8, d: 4 });
    // one over on either axis climbs a bucket
    assert_eq!(select_bucket(&buckets, 9, 4).unwrap(), Bucket { n: 64, d: 8 });
    assert_eq!(select_bucket(&buckets, 8, 5).unwrap(), Bucket { n: 64, d: 8 });
    // empty graph fits the smallest
    assert_eq!(select_bucket(&buckets, 0, 0).unwrap(), Bucket { n: 8, d: 4 });
    // too big in either dimension
    assert!(select_bucket(&buckets, 5000, 4).is_err());
    assert!(select_bucket(&buckets, 8, 65).is_err());
}

#[test]
fn worker_shared_across_engines() {
    if artifacts_missing("worker_shared_across_engines") {
        return;
    }
    // both engines over one worker (one PJRT client), interleaved calls
    let worker = default_worker().unwrap();
    let peel = VecPeel::new(worker.clone());
    let hindex = VecHindex::new(worker);
    let g = examples::complete(6);
    for _ in 0..3 {
        assert_eq!(peel.try_decompose(&g).unwrap().core, vec![5; 6]);
        assert_eq!(hindex.try_decompose(&g).unwrap().core, vec![5; 6]);
    }
}
