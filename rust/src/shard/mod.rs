//! Layer 3.6 — sharded serving: partition → route → merge.
//!
//! The serving layer (Layer 3.5) answers queries from one
//! [`crate::service::CoreIndex`]; this layer spreads one logical graph
//! across shards so no single worker has to hold (or re-decompose) the
//! whole thing, while keeping every answer **exactly** equal to the
//! single-index answer:
//!
//! * [`partition`] — vertex partitioners (stateless hash, degree-balanced
//!   ranges) producing per-shard subgraphs with boundary-edge
//!   bookkeeping. Owned vertices keep their complete adjacency; remote
//!   neighbors become ghosts.
//! * [`backend`] — the [`backend::ShardBackend`] interface (routed
//!   edits, boundary-exchange rounds, refined reads) and its in-process
//!   implementation [`backend::LocalShard`]. The multi-host
//!   implementation is [`crate::cluster::RemoteShard`], which speaks the
//!   same interface over the binary protocol — routers cannot tell the
//!   difference.
//! * [`router`] — edit routing over the owner map and the
//!   boundary-exchange loop ([`router::refine`]) shared by the local and
//!   cluster routers: warm-started estimates, concurrent per-shard
//!   sweeps, exact merged coreness at the fixpoint.
//! * [`sharded`] — [`sharded::ShardedIndex`]: one epoch-versioned
//!   `CoreIndex` per shard, a query router (coreness / members /
//!   histogram / degeneracy fan-out + merge), and the boundary-refinement
//!   merge publishing single-index-identical snapshots.
//! * [`snapshot`] — binary snapshot shipping: serialise a `CoreIndex`
//!   epoch (graph + coreness + epoch) so a replica hydrates without
//!   recomputing; the wire side is the server's `SNAPSHOT`/`RESTORE`
//!   verbs over the length-prefixed binary protocol.
//!
//! Scaling behaviour (query throughput, merge overhead per shard count)
//! is measured by `benches/shard_scaling.rs`; exactness versus a single
//! index is property-tested in `tests/shard.rs`. The multi-host cluster
//! built on this layer lives in [`crate::cluster`].

pub mod backend;
pub mod partition;
pub mod router;
pub mod sharded;
pub mod snapshot;

pub use backend::{
    ApplyOutcome, LocalShard, RefineInit, RefineRound, RoutedBatch, ShardBackend, ShardStatus,
};
pub use partition::{
    assign_owners, hash_owner, partition, PartitionStrategy, Partitioning, ShardPlan,
};
pub use router::{refine, refine_traced, route, MergeStats, RefineOutcome, RoutePlan};
pub use sharded::{ShardView, ShardedIndex, ShardedOutcome};
pub use snapshot::{decode, encode, encode_index, IndexSnapshot};
