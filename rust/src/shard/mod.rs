//! Layer 3.6 — sharded serving: partition → route → merge.
//!
//! The serving layer (Layer 3.5) answers queries from one
//! [`crate::service::CoreIndex`]; this layer spreads one logical graph
//! across shards so no single worker has to hold (or re-decompose) the
//! whole thing, while keeping every answer **exactly** equal to the
//! single-index answer:
//!
//! * [`partition`] — vertex partitioners (stateless hash, degree-balanced
//!   ranges) producing per-shard subgraphs with boundary-edge
//!   bookkeeping. Owned vertices keep their complete adjacency; remote
//!   neighbors become ghosts.
//! * [`sharded`] — [`sharded::ShardedIndex`]: one epoch-versioned
//!   `CoreIndex` per shard, a query router (coreness / members /
//!   histogram / degeneracy fan-out + merge), and the boundary-refinement
//!   merge (distributed h-index fixpoint) that makes merged coreness
//!   exact. The TCP server serves the merged published snapshot; the
//!   fan-out methods are the embedding API and what `shard_scaling`
//!   measures.
//! * [`snapshot`] — binary snapshot shipping: serialise a `CoreIndex`
//!   epoch (graph + coreness + epoch) so a replica hydrates without
//!   recomputing; the wire side is the server's `SNAPSHOT`/`RESTORE`
//!   verbs over the length-prefixed binary protocol.
//!
//! Scaling behaviour (query throughput, merge overhead per shard count)
//! is measured by `benches/shard_scaling.rs`; exactness versus a single
//! index is property-tested in `tests/shard.rs`.

pub mod partition;
pub mod sharded;
pub mod snapshot;

pub use partition::{
    assign_owners, hash_owner, partition, PartitionStrategy, Partitioning, ShardPlan,
};
pub use sharded::{MergeStats, ShardView, ShardedIndex, ShardedOutcome};
pub use snapshot::{decode, encode, encode_index, IndexSnapshot};
