//! Router-side flush machinery shared by the in-process
//! [`crate::shard::ShardedIndex`] and the multi-host
//! [`crate::cluster::ClusterIndex`]: edit routing over an owner map, and
//! the boundary-exchange loop of the distributed h-index fixpoint over
//! any mix of [`ShardBackend`]s.
//!
//! # The exchange loop
//!
//! [`refine`] is round-based (bulk-synchronous): every round it ships
//! each shard the ghost estimates that changed since the previous round,
//! the shards sweep to their local fixpoints **concurrently** (dirty
//! shards are distributed over the batch thread pool — for remote shards
//! the round is one frame each way, so parallelism hides network latency
//! too), and the returned owned-estimate deltas feed the next round.
//! Estimates start as upper bounds (degrees, or warm-started committed
//! coreness + insert slack) and the router only accepts strict
//! decreases, so the loop terminates; at the fixpoint the merged values
//! equal global coreness exactly (see `shard::sharded` module docs for
//! the argument).

use super::backend::{RefineRound, RoutedBatch, ShardBackend};
use super::partition::hash_owner;
use crate::core::maintenance::EdgeEdit;
use crate::graph::VertexId;
use crate::obs::{FlushTrace, Span};
use anyhow::{bail, Result};
use std::sync::Arc;
use std::time::Instant;

/// What one boundary-refinement (merge) pass did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MergeStats {
    /// Global exchange rounds until the fixpoint.
    pub rounds: usize,
    /// Shard-local sweep passes (a shard sweeps only when dirty).
    pub sweeps: usize,
    /// Ghost-copy refreshes that actually changed a value.
    pub boundary_updates: u64,
    /// Estimate bytes exchanged across shard boundaries: every shipped
    /// ghost update and every returned owned-estimate delta is one
    /// `(vertex, estimate)` pair, 8 bytes on the wire. Feeds the
    /// `pico_refine_boundary_bytes_total` counter.
    pub boundary_bytes: u64,
}

/// Everything one refinement pass computes.
pub struct RefineOutcome {
    /// Exact global coreness, indexed by global vertex id.
    pub core: Vec<u32>,
    pub stats: MergeStats,
    /// Undirected global edge count (`Σ per-shard owned arcs / 2`).
    pub num_edges: u64,
    /// Distinct global boundary edges.
    pub boundary_edges: u64,
    /// Arcs from each shard's owned vertices to ghosts (backend order).
    /// The rebalance planner reads this as each shard's boundary-edge
    /// share; the cluster router caches it on the replica group between
    /// refinement passes.
    pub per_shard_boundary_arcs: Vec<u64>,
    /// Per-shard refined diffs from the commit (backend order) — what
    /// each shard's `refine_commit` changed. The cluster router journals
    /// these for delta replica catch-up.
    pub diffs: Vec<Vec<(VertexId, u32)>>,
    /// Time in the estimate-exchange loop (init + rounds) — the flush's
    /// `refine` stage.
    pub refine_elapsed: std::time::Duration,
    /// Time in the per-shard commit pass — the flush's `commit` stage.
    pub commit_elapsed: std::time::Duration,
}

/// One flush's dispatch: per-shard routed batches plus accounting.
pub struct RoutePlan {
    pub per_shard: Vec<RoutedBatch>,
    /// Shards that received new owned vertices or edits.
    pub touched: Vec<bool>,
    /// Insert edits in the batch — the warm-start slack (each inserted
    /// edge can raise any coreness by at most one).
    pub inserts: u32,
}

/// Route a coalesced batch: grow the owner map exactly like a single
/// index grows its vertex set (intermediate ids exist too, owned by
/// [`hash_owner`]), then dispatch each edit to its endpoint-owner
/// shard(s) with the first endpoint's owner as the primary copy.
pub fn route(owner: &mut Vec<u32>, num_shards: usize, batch: &[EdgeEdit]) -> RoutePlan {
    let num_shards = num_shards.max(1);
    let mut per_shard: Vec<RoutedBatch> = vec![RoutedBatch::default(); num_shards];
    let mut touched = vec![false; num_shards];
    let mut new_n = owner.len();
    for e in batch {
        let (_, hi) = e.endpoints();
        new_n = new_n.max(hi as usize + 1);
    }
    for v in owner.len()..new_n {
        let s = hash_owner(v as VertexId, num_shards);
        owner.push(s);
        per_shard[s as usize].new_owned.push(v as VertexId);
        touched[s as usize] = true;
    }
    let mut inserts = 0u32;
    for &e in batch {
        if e.is_insert() {
            inserts = inserts.saturating_add(1);
        }
        let (u, v) = e.endpoints();
        let a = owner[u as usize] as usize;
        let b = owner[v as usize] as usize;
        for &(s, primary) in &[(a, true), (b, false)] {
            if !primary && s == a {
                continue; // shard-internal edit: dispatch once
            }
            per_shard[s].edits.push((e, primary));
            touched[s] = true;
        }
    }
    RoutePlan {
        per_shard,
        touched,
        inserts,
    }
}

/// Repoint owner-map entries at a new shard — the router half of a
/// rebalance move. [`route`] consults `owner[v]` for every vertex it has
/// seen before, so flipping the entries here is all it takes for
/// subsequent flushes to deliver the moved vertices' edits to their new
/// home; only vertices the map has never seen fall through to
/// [`hash_owner`]. Returns how many entries actually changed hands.
pub fn reassign(owner: &mut [u32], vertices: &[VertexId], to: u32) -> Result<usize> {
    let mut moved = 0;
    for &v in vertices {
        let Some(slot) = owner.get_mut(v as usize) else {
            bail!("reassign: vertex {v} outside the owner map (len {})", owner.len());
        };
        if *slot != to {
            *slot = to;
            moved += 1;
        }
    }
    Ok(moved)
}

/// Fan-out read over the shard backends — the per-shard single-k
/// primitive: each shard lists its owned k-core members from committed
/// refined state (no decomposition runs anywhere), and the partials
/// merge into the global ascending membership list. Returns the minimum
/// cluster epoch among the partials so callers can detect a read that
/// straddled an in-flight commit.
pub fn members_merged(
    backends: &[Arc<dyn ShardBackend>],
    k: u32,
) -> Result<(Vec<VertexId>, u64)> {
    let mut out = Vec::new();
    let mut epoch = u64::MAX;
    for b in backends {
        let (members, ce) = b.members_partial(k)?;
        out.extend(members);
        epoch = epoch.min(ce);
    }
    out.sort_unstable();
    Ok((out, if epoch == u64::MAX { 0 } else { epoch }))
}

/// One exchange round on every shard, dirty sweeps running concurrently.
/// `threads` bounds the worker count (1 falls back to in-place calls).
fn round_all(
    backends: &[Arc<dyn ShardBackend>],
    updates: &[Vec<(VertexId, u32)>],
    threads: usize,
) -> Vec<Result<RefineRound>> {
    let k = backends.len();
    let workers = threads.max(1).min(k.max(1));
    if workers <= 1 || k <= 1 {
        return backends
            .iter()
            .zip(updates)
            .map(|(b, u)| b.refine_round(u))
            .collect();
    }
    let mut out: Vec<Option<Result<RefineRound>>> = (0..k).map(|_| None).collect();
    let per = k.div_ceil(workers);
    crossbeam_utils::thread::scope(|scope| {
        for ((bs, us), os) in backends
            .chunks(per)
            .zip(updates.chunks(per))
            .zip(out.chunks_mut(per))
        {
            scope.spawn(move |_| {
                for ((b, u), o) in bs.iter().zip(us).zip(os.iter_mut()) {
                    *o = Some(b.refine_round(u));
                }
            });
        }
    })
    .expect("refine sweep worker panicked");
    out.into_iter()
        .map(|o| o.expect("uncovered shard in refine round"))
        .collect()
}

/// Run the distributed h-index fixpoint over `backends` and commit the
/// result at `cluster_epoch`. `n` is the global vertex count; `slack`
/// warm-starts estimates from each shard's committed coreness (pass
/// `None` for the cold, degree-initialised pass of an initial build).
pub fn refine(
    backends: &[Arc<dyn ShardBackend>],
    n: usize,
    slack: Option<u32>,
    cluster_epoch: u64,
    threads: usize,
) -> Result<RefineOutcome> {
    refine_traced(backends, n, slack, cluster_epoch, threads, None)
}

/// [`refine`] with an optional flush trace: each exchange round lands as
/// a child span under the `refine` stage and each shard's commit under
/// the `commit` stage, so `TRACES` shows where a slow merge spent its
/// rounds. Remote shards additionally report their own handler time
/// through the trace-id wire field (see [`crate::obs::trace`]).
pub fn refine_traced(
    backends: &[Arc<dyn ShardBackend>],
    n: usize,
    slack: Option<u32>,
    cluster_epoch: u64,
    threads: usize,
    trace: Option<&FlushTrace>,
) -> Result<RefineOutcome> {
    let offset_of = |ft: &FlushTrace, at: Instant| {
        at.saturating_duration_since(ft.t0()).as_micros() as u64
    };
    let refine_start = Instant::now();
    let mut mailbox = vec![0u32; n];
    let mut stats = MergeStats::default();
    let mut arcs = 0u64;
    let mut boundary_arcs = 0u64;
    let mut per_shard_boundary_arcs = Vec::with_capacity(backends.len());
    let mut ghost_lists: Vec<Vec<VertexId>> = Vec::with_capacity(backends.len());
    for b in backends {
        let init = b.refine_start(slack)?;
        for &(v, e) in &init.owned_est {
            let Some(slot) = mailbox.get_mut(v as usize) else {
                bail!("shard {} reports owned vertex {v} outside 0..{n}", b.id());
            };
            *slot = e;
        }
        arcs += init.arcs;
        boundary_arcs += init.boundary_arcs;
        per_shard_boundary_arcs.push(init.boundary_arcs);
        ghost_lists.push(init.ghosts);
    }
    // `changed[v]` — did v's mailbox value change since the last round?
    // Round 1 delivers every ghost its owner's initial estimate.
    let mut changed = vec![true; n];
    loop {
        stats.rounds += 1;
        let round_start = Instant::now();
        let updates: Vec<Vec<(VertexId, u32)>> = ghost_lists
            .iter()
            .map(|gl| {
                gl.iter()
                    .filter(|&&v| (v as usize) < n && changed[v as usize])
                    .map(|&v| (v, mailbox[v as usize]))
                    .collect()
            })
            .collect();
        for u in &updates {
            stats.boundary_bytes += 8 * u.len() as u64;
        }
        let replies = round_all(backends, &updates, threads);
        for c in changed.iter_mut() {
            *c = false;
        }
        let mut any = false;
        for (i, reply) in replies.into_iter().enumerate() {
            let r = match reply {
                Ok(r) => r,
                Err(e) => {
                    crate::obs::events::emit(
                        crate::obs::Severity::Error,
                        crate::obs::events::kind::REFINE_ROUND_FAILED,
                        "",
                        format!(
                            "round {} lost shard {} ({e:#})",
                            stats.rounds,
                            backends[i].id()
                        ),
                    );
                    return Err(e);
                }
            };
            stats.sweeps += r.sweeps;
            stats.boundary_updates += r.ghost_updates;
            stats.boundary_bytes += 8 * r.changed.len() as u64;
            for (v, e) in r.changed {
                let Some(slot) = mailbox.get_mut(v as usize) else {
                    bail!("shard {} refined vertex {v} outside 0..{n}", backends[i].id());
                };
                // estimates only ever decrease; rejecting anything else
                // keeps the loop terminating even against a misbehaving
                // remote shard
                if e < *slot {
                    *slot = e;
                    changed[v as usize] = true;
                    any = true;
                }
            }
        }
        if let Some(ft) = trace {
            ft.child(
                "refine",
                Span {
                    name: format!("round {}", stats.rounds),
                    start_us: offset_of(ft, round_start),
                    dur_us: round_start.elapsed().as_micros() as u64,
                    remote: None,
                    children: Vec::new(),
                },
            );
        }
        if !any {
            break;
        }
    }
    let refine_elapsed = refine_start.elapsed();
    if let Some(ft) = trace {
        ft.stage("refine", refine_start, refine_elapsed);
    }
    let commit_all = Instant::now();
    let mut diffs = Vec::with_capacity(backends.len());
    for b in backends {
        let commit_start = Instant::now();
        diffs.push(b.refine_commit(cluster_epoch)?);
        if let Some(ft) = trace {
            ft.child(
                "commit",
                Span {
                    name: format!("commit shard={}", b.id()),
                    start_us: offset_of(ft, commit_start),
                    dur_us: commit_start.elapsed().as_micros() as u64,
                    remote: None,
                    children: Vec::new(),
                },
            );
        }
    }
    let commit_elapsed = commit_all.elapsed();
    if let Some(ft) = trace {
        ft.stage("commit", commit_all, commit_elapsed);
    }
    Ok(RefineOutcome {
        core: mailbox,
        stats,
        num_edges: arcs / 2,
        boundary_edges: boundary_arcs / 2,
        per_shard_boundary_arcs,
        diffs,
        refine_elapsed,
        commit_elapsed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::gen;
    use crate::service::batch::BatchConfig;
    use crate::shard::backend::LocalShard;
    use crate::shard::partition::{partition, PartitionStrategy};

    fn backends(g: &crate::graph::CsrGraph, k: usize) -> Vec<Arc<dyn ShardBackend>> {
        partition(g, k, PartitionStrategy::Hash)
            .shards
            .iter()
            .map(|p| {
                Arc::new(LocalShard::from_plan(
                    "t",
                    p,
                    BatchConfig {
                        threads: 1,
                        ..BatchConfig::default()
                    },
                )) as Arc<dyn ShardBackend>
            })
            .collect()
    }

    #[test]
    fn refine_reaches_the_oracle_cold_and_warm() {
        let g = gen::erdos_renyi(120, 420, 11);
        let want = bz_coreness(&g);
        for threads in [1, 4] {
            let bs = backends(&g, 4);
            let cold = refine(&bs, g.num_vertices(), None, 0, threads).unwrap();
            assert_eq!(cold.core, want, "cold, {threads} threads");
            assert_eq!(cold.num_edges, g.num_edges());
            assert_eq!(cold.per_shard_boundary_arcs.len(), 4);
            assert_eq!(
                cold.per_shard_boundary_arcs.iter().sum::<u64>(),
                cold.boundary_edges * 2,
                "per-shard boundary arcs sum to twice the distinct boundary edges"
            );
            assert!(cold.stats.rounds >= 1 && cold.stats.sweeps >= 4);
            // round 1 ships every ghost its owner's estimate: a 4-way
            // hash partition of an ER graph always crosses boundaries
            assert!(cold.stats.boundary_bytes > 0);
            // warm restart from the committed pass: slack 0, same answer
            let warm = refine(&bs, g.num_vertices(), Some(0), 1, threads).unwrap();
            assert_eq!(warm.core, want, "warm, {threads} threads");
            // warm start should not sweep harder than the cold pass
            assert!(warm.stats.sweeps <= cold.stats.sweeps);
        }
    }

    #[test]
    fn traced_refine_records_round_and_commit_spans() {
        let g = gen::erdos_renyi(60, 180, 7);
        let bs = backends(&g, 2);
        let ft = FlushTrace::new(0x51);
        let out = refine_traced(&bs, g.num_vertices(), None, 0, 1, Some(&ft)).unwrap();
        assert_eq!(out.core, bz_coreness(&g));
        let t = ft.finish("flush", "t");
        // per-round spans nest under the refine stage, per-shard commits
        // under the commit stage
        let refine_stage = t.spans.iter().find(|s| s.name == "refine").unwrap();
        assert!(!refine_stage.children.is_empty(), "round spans under refine");
        assert_eq!(refine_stage.children[0].name, "round 1");
        let commit_stage = t.spans.iter().find(|s| s.name == "commit").unwrap();
        assert_eq!(commit_stage.children.len(), 2, "one commit span per shard");
        let lines = t.render();
        assert!(lines.iter().any(|l| l.trim_start().starts_with("commit shard=0")), "{lines:?}");
    }

    #[test]
    fn route_grows_owner_map_and_dispatches_once() {
        let mut owner = vec![0u32, 1, 0, 1];
        let plan = route(
            &mut owner,
            2,
            &[
                EdgeEdit::Insert(0, 2), // internal to shard 0
                EdgeEdit::Insert(0, 1), // boundary: two copies, one primary
                EdgeEdit::Insert(3, 6), // grows vertex set to 7
            ],
        );
        assert_eq!(owner.len(), 7);
        assert_eq!(plan.inserts, 3);
        let copies: usize = plan.per_shard.iter().map(|b| b.edits.len()).sum();
        let primaries: usize = plan
            .per_shard
            .iter()
            .flat_map(|b| b.edits.iter())
            .filter(|&&(_, p)| p)
            .count();
        assert_eq!(primaries, 3);
        assert!(copies >= 4 && copies <= 6, "boundary edits ship twice");
        let new_owned: usize = plan.per_shard.iter().map(|b| b.new_owned.len()).sum();
        assert_eq!(new_owned, 3); // vertices 4, 5, 6
        assert!(plan.touched.iter().any(|&t| t));
    }

    #[test]
    fn reassign_flips_owners_and_routing_follows() {
        let mut owner = vec![0u32, 0, 1, 1];
        assert_eq!(reassign(&mut owner, &[0, 1, 2], 1).unwrap(), 2);
        assert_eq!(owner, vec![1, 1, 1, 1]);
        // an edit touching a moved vertex now routes to its new owner
        let plan = route(&mut owner, 2, &[EdgeEdit::Insert(0, 3)]);
        assert!(plan.per_shard[0].is_empty() && !plan.per_shard[1].is_empty());
        // out-of-map vertices are a hard error, not a silent grow
        assert!(reassign(&mut owner, &[9], 0).is_err());
    }
}
