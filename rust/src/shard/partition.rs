//! Vertex partitioning: split one [`CsrGraph`] into per-shard subgraphs
//! with boundary-edge bookkeeping.
//!
//! A partition assigns every vertex to exactly one **owner** shard. Each
//! shard's subgraph then contains:
//!
//! * its **owned** vertices with their *complete* global adjacency (every
//!   edge incident to an owned vertex is present), and
//! * **ghost** vertices — one-hop neighbors owned by other shards — which
//!   carry only their edges to this shard's owned vertices.
//!
//! Two consequences the sharded index relies on:
//!
//! 1. an owned vertex's local degree equals its global degree, so the
//!    degree initialisation of the boundary refinement is exact; and
//! 2. a **boundary edge** (endpoints owned by different shards) appears
//!    in exactly the two endpoint-owner subgraphs, so per-shard edge
//!    counts merge to the global count as `Σ|E_s| − |E_boundary|`.
//!
//! Local vertex ids are dense: owned vertices first (in ascending global
//! order), ghosts after (in first-encounter order).

use crate::graph::{CsrGraph, GraphBuilder, VertexId};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// How vertices are assigned to shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// `owner(v) = mix(v) mod shards` — stateless and stable under vertex
    /// growth, at the cost of ignoring locality entirely.
    Hash,
    /// Contiguous id ranges cut so every shard holds roughly the same
    /// total degree (arc mass), which balances refinement sweep work on
    /// skewed graphs. Vertices created after partitioning route by hash.
    DegreeRange,
}

impl PartitionStrategy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "hash" => Ok(Self::Hash),
            "range" | "degree-range" => Ok(Self::DegreeRange),
            other => bail!("unknown partition strategy '{other}' (hash|range)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Hash => "hash",
            Self::DegreeRange => "range",
        }
    }
}

/// Deterministic vertex → shard assignment (splitmix64 finaliser). Also
/// the growth rule for vertices created after partitioning, whatever the
/// build-time strategy.
pub fn hash_owner(v: VertexId, num_shards: usize) -> u32 {
    let mut x = (v as u64) ^ 0x9E37_79B9_7F4A_7C15;
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    (x % num_shards.max(1) as u64) as u32
}

/// One shard's slice of the graph.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub id: usize,
    /// Owned vertices (global ids, ascending). Local ids `0..owned.len()`.
    pub owned: Vec<VertexId>,
    /// Ghost vertices (global ids). Local ids continue after the owned.
    pub ghosts: Vec<VertexId>,
    /// Local-id CSR over owned + ghosts.
    pub subgraph: CsrGraph,
    /// Edges with both endpoints owned here.
    pub internal_edges: u64,
    /// Edges from an owned vertex to a ghost (each such global edge is a
    /// boundary edge of exactly two shards).
    pub boundary_edges: u64,
}

/// A complete partition of one graph.
#[derive(Clone, Debug)]
pub struct Partitioning {
    pub strategy: PartitionStrategy,
    pub num_shards: usize,
    /// `owner[v]` = shard owning global vertex `v`.
    pub owner: Vec<u32>,
    pub shards: Vec<ShardPlan>,
}

impl Partitioning {
    /// Distinct global boundary edges (each is counted by two shards).
    pub fn boundary_edges(&self) -> u64 {
        self.shards.iter().map(|s| s.boundary_edges).sum::<u64>() / 2
    }
}

/// Assign owners without building subgraphs.
pub fn assign_owners(g: &CsrGraph, num_shards: usize, strategy: PartitionStrategy) -> Vec<u32> {
    let n = g.num_vertices();
    let num_shards = num_shards.max(1);
    match strategy {
        PartitionStrategy::Hash => (0..n as VertexId).map(|v| hash_owner(v, num_shards)).collect(),
        PartitionStrategy::DegreeRange => {
            // Weight each vertex by degree + 1 (the +1 spreads isolated
            // vertices too); cut contiguous ranges at even weight.
            let total: u64 = g.num_arcs() + n as u64;
            let target = (total / num_shards as u64).max(1);
            let mut owner = vec![0u32; n];
            let mut shard = 0u32;
            let mut acc = 0u64;
            for v in 0..n {
                owner[v] = shard;
                acc += g.degree(v as VertexId) as u64 + 1;
                if acc >= target && (shard as usize) < num_shards - 1 {
                    shard += 1;
                    acc = 0;
                }
            }
            owner
        }
    }
}

/// Partition `g` into `num_shards` subgraphs under `strategy`.
pub fn partition(g: &CsrGraph, num_shards: usize, strategy: PartitionStrategy) -> Partitioning {
    let num_shards = num_shards.max(1);
    let owner = assign_owners(g, num_shards, strategy);
    let n = g.num_vertices();
    let mut shards = Vec::with_capacity(num_shards);
    for s in 0..num_shards {
        let sid = s as u32;
        let owned: Vec<VertexId> =
            (0..n as VertexId).filter(|&v| owner[v as usize] == sid).collect();
        let mut local: HashMap<VertexId, u32> = owned
            .iter()
            .enumerate()
            .map(|(i, &v)| (v, i as u32))
            .collect();
        let mut ghosts: Vec<VertexId> = Vec::new();
        for &v in &owned {
            for &w in g.neighbors(v) {
                if owner[w as usize] != sid && !local.contains_key(&w) {
                    local.insert(w, (owned.len() + ghosts.len()) as u32);
                    ghosts.push(w);
                }
            }
        }
        let mut b = GraphBuilder::new(owned.len() + ghosts.len());
        let mut internal_edges = 0u64;
        let mut boundary_edges = 0u64;
        for &v in &owned {
            let lv = local[&v];
            for &w in g.neighbors(v) {
                let lw = local[&w];
                if owner[w as usize] == sid {
                    // internal edge: both endpoints iterated, add once
                    if v < w {
                        b.add_edge(lv, lw);
                        internal_edges += 1;
                    }
                } else {
                    // boundary edge: only the owned endpoint is iterated
                    b.add_edge(lv, lw);
                    boundary_edges += 1;
                }
            }
        }
        shards.push(ShardPlan {
            id: s,
            owned,
            ghosts,
            subgraph: b.build(format!("{}::shard{s}", g.name)),
            internal_edges,
            boundary_edges,
        });
    }
    Partitioning {
        strategy,
        num_shards,
        owner,
        shards,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{examples, gen};

    fn check_plan(g: &CsrGraph, p: &Partitioning) {
        // every vertex owned exactly once
        let owned_total: usize = p.shards.iter().map(|s| s.owned.len()).sum();
        assert_eq!(owned_total, g.num_vertices());
        for s in &p.shards {
            assert_eq!(s.subgraph.validate(), Ok(()));
            assert_eq!(s.subgraph.num_vertices(), s.owned.len() + s.ghosts.len());
            // owned vertices keep their global degree
            for (l, &v) in s.owned.iter().enumerate() {
                assert_eq!(s.subgraph.degree(l as u32), g.degree(v), "shard {} v{v}", s.id);
            }
            for &gv in &s.ghosts {
                assert_ne!(p.owner[gv as usize] as usize, s.id);
            }
        }
        // edge conservation: Σ internal + Σ boundary/2 == |E|
        let internal: u64 = p.shards.iter().map(|s| s.internal_edges).sum();
        let boundary2: u64 = p.shards.iter().map(|s| s.boundary_edges).sum();
        assert_eq!(boundary2 % 2, 0);
        assert_eq!(internal + boundary2 / 2, g.num_edges());
        assert_eq!(p.boundary_edges(), boundary2 / 2);
    }

    #[test]
    fn hash_partition_covers_g1() {
        let g = examples::g1();
        for k in [1, 2, 4, 8] {
            check_plan(&g, &partition(&g, k, PartitionStrategy::Hash));
        }
    }

    #[test]
    fn range_partition_balances_degree() {
        let g = gen::barabasi_albert(400, 3, 7);
        let p = partition(&g, 4, PartitionStrategy::DegreeRange);
        check_plan(&g, &p);
        // no shard should hold more than half the arc mass
        for s in &p.shards {
            let arcs: u64 = s.owned.iter().map(|&v| g.degree(v) as u64).sum();
            assert!(arcs <= g.num_arcs() / 2 + 1, "shard {} holds {arcs} arcs", s.id);
        }
        // ranges are contiguous
        for w in p.owner.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn single_shard_is_identity() {
        let g = gen::erdos_renyi(60, 150, 3);
        let p = partition(&g, 1, PartitionStrategy::Hash);
        assert_eq!(p.shards.len(), 1);
        let s = &p.shards[0];
        assert!(s.ghosts.is_empty());
        assert_eq!(s.boundary_edges, 0);
        assert_eq!(s.subgraph.num_edges(), g.num_edges());
    }

    #[test]
    fn empty_and_tiny_graphs() {
        let empty = crate::graph::GraphBuilder::new(0).build("empty");
        let p = partition(&empty, 4, PartitionStrategy::DegreeRange);
        check_plan(&empty, &p);
        let one = crate::graph::GraphBuilder::new(1).build("one");
        let p = partition(&one, 8, PartitionStrategy::Hash);
        check_plan(&one, &p);
        // more shards than vertices: some shards are empty
        assert!(p.shards.iter().any(|s| s.owned.is_empty()));
    }

    #[test]
    fn hash_owner_is_stable_and_in_range() {
        for v in 0..100u32 {
            let a = hash_owner(v, 4);
            assert_eq!(a, hash_owner(v, 4));
            assert!(a < 4);
        }
        assert_eq!(hash_owner(7, 1), 0);
    }

    #[test]
    fn strategy_parsing() {
        assert_eq!(PartitionStrategy::parse("hash").unwrap(), PartitionStrategy::Hash);
        assert_eq!(PartitionStrategy::parse("range").unwrap(), PartitionStrategy::DegreeRange);
        assert!(PartitionStrategy::parse("nope").is_err());
        assert_eq!(PartitionStrategy::DegreeRange.name(), "range");
    }
}
