//! The shard interface the routers consume — and its in-process
//! implementation.
//!
//! [`ShardBackend`] is the contract extracted from the original
//! `ShardedIndex` internals so that a shard can live *anywhere*: in this
//! process ([`LocalShard`]) or behind a `pico serve` on another host
//! ([`crate::cluster::RemoteShard`] speaks exactly this interface over
//! the length-prefixed binary protocol). Everything crosses the boundary
//! in **global** vertex ids; the shard owns its local-id translation.
//!
//! The interface has three facets, mirroring the three things a router
//! does per flush:
//!
//! * **Routed edits** — [`ShardBackend::apply`] takes a [`RoutedBatch`]
//!   (new owned vertices + the edits touching this shard) through the
//!   incremental-vs-recompute pipeline of the shard's own `CoreIndex`.
//! * **Boundary exchange** — [`ShardBackend::refine_start`] /
//!   [`ShardBackend::refine_round`] / [`ShardBackend::refine_commit`]
//!   are the distributed h-index fixpoint, cut at its natural network
//!   boundary: one `refine_round` is one boundary exchange (install
//!   ghost estimates, sweep to the local fixpoint, report owned
//!   estimates that changed).
//! * **Reads** — refined (exact, post-merge) per-shard answers, each
//!   stamped with the cluster epoch it was committed at so replica
//!   readers can reject stale state.
//!
//! **Warm start.** `refine_start` takes an optional `slack`: when given,
//! owned estimates start from `min(degree, committed + slack)` instead of
//! raw degrees, where `committed` is the previous pass's exact coreness.
//! A single edge insertion raises any coreness by at most one, so with
//! `slack` = the number of inserted edges in the batch, the warm value is
//! still a pointwise upper bound — and the fixpoint argument (upper bound
//! + `est[v] ≤ H(est[N(v)])` everywhere forces `est == coreness`) goes
//! through unchanged. On small batches this replaces the full
//! Index2core-shaped pass per flush with a few localised corrections.

use crate::core::hindex::{hindex_capped, HindexScratch};
use crate::core::maintenance::EdgeEdit;
use crate::graph::VertexId;
use crate::service::batch::BatchConfig;
use crate::service::index::CoreIndex;
use crate::shard::partition::ShardPlan;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// The edits a router dispatches to one shard for one flush.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RoutedBatch {
    /// Vertices newly assigned to this shard as owned (global ids,
    /// ascending). May be non-empty with `edits` empty: isolated
    /// intermediate ids created by an edit like `INSERT 5 9`.
    pub new_owned: Vec<VertexId>,
    /// Edits touching this shard (global ids). `true` marks the primary
    /// copy — the one routed to the first endpoint's owner, which
    /// accounts for the edit's `changed` bit (boundary edits reach two
    /// shards but must be counted once).
    pub edits: Vec<(EdgeEdit, bool)>,
}

impl RoutedBatch {
    /// Whether this batch would leave the shard untouched — the exact
    /// condition under which a router skips [`ShardBackend::apply`], so
    /// delta replay must skip it too to reproduce the shard's index
    /// epoch.
    pub fn is_empty(&self) -> bool {
        self.new_owned.is_empty() && self.edits.is_empty()
    }
}

/// What one routed batch did on the shard.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ApplyOutcome {
    /// Primary edits that changed the edge set.
    pub changed: usize,
    /// Whether the shard took the full-recompute fallback.
    pub recomputed: bool,
    /// Shard-local `CoreIndex` epoch after the batch.
    pub epoch: u64,
}

/// What a refinement pass needs from each shard before the first
/// exchange round.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RefineInit {
    /// Initial estimates for every owned vertex (global id, estimate).
    pub owned_est: Vec<(VertexId, u32)>,
    /// This shard's ghost vertices (global ids) — the router only ships
    /// estimate updates a shard can actually use.
    pub ghosts: Vec<VertexId>,
    /// Arcs out of owned vertices (internal + boundary). Summed over all
    /// shards this double-counts every edge: `|E| = Σ arcs / 2`.
    pub arcs: u64,
    /// Arcs from an owned vertex to a ghost. Each global boundary edge
    /// contributes one such arc in exactly two shards.
    pub boundary_arcs: u64,
}

/// What one boundary-exchange round did on the shard.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RefineRound {
    /// Owned estimates lowered by this round's sweep (global id, value).
    pub changed: Vec<(VertexId, u32)>,
    /// 1 if the shard swept (it was dirty or a ghost install changed a
    /// value), 0 if the round was a no-op.
    pub sweeps: usize,
    /// Ghost installs that actually changed a stored value.
    pub ghost_updates: u64,
}

/// Probe result for health / epoch checks (`SHARDINFO` on the wire).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardStatus {
    pub id: usize,
    /// Shard-local `CoreIndex` epoch (one per applied batch).
    pub epoch: u64,
    /// Cluster epoch of the last committed refinement pass.
    pub cluster_epoch: u64,
    /// Owned-vertex count.
    pub owned: usize,
    /// Max committed refined coreness among owned vertices.
    pub k_max: u32,
    /// Exact encoded size of this shard's full manifest — what a
    /// snapshot re-ship would put on the wire (`pico cluster status`
    /// reports it as the full-catch-up cost; deltas are measured against
    /// it). Pinned to `cluster::manifest_for(...).len()` by a test.
    pub state_bytes: u64,
}

/// The `cluster_epoch` a shard reports before its first
/// [`ShardBackend::refine_commit`]. Deliberately unequal to every real
/// epoch so epoch-checked replica reads can never accept answers from a
/// shard that has no committed refined state yet.
pub const NEVER_COMMITTED: u64 = u64::MAX;

/// One shard of a partitioned index, local or remote. All ids crossing
/// this interface are global; fallible methods exist for the sake of
/// remote implementations (local shards never fail).
pub trait ShardBackend: Send + Sync {
    /// Shard id within the partition.
    fn id(&self) -> usize;

    /// `"local"` or `"remote"` — topology display only.
    fn kind(&self) -> &'static str;

    /// Health / epoch probe.
    fn status(&self) -> Result<ShardStatus>;

    /// Apply a routed batch (grow the vertex set, then incremental
    /// maintenance or structural edits + recompute — the shard decides).
    fn apply(&self, batch: &RoutedBatch) -> Result<ApplyOutcome>;

    /// Reset refinement estimates (optionally warm-started, see module
    /// docs) and report the ghost list + arc accounting.
    fn refine_start(&self, slack: Option<u32>) -> Result<RefineInit>;

    /// One boundary exchange: install `updates` on ghost copies, sweep
    /// owned vertices to the local h-index fixpoint if anything changed,
    /// return the owned estimates this round lowered.
    fn refine_round(&self, updates: &[(VertexId, u32)]) -> Result<RefineRound>;

    /// Freeze the current estimates as the shard's exact refined
    /// coreness at cluster epoch `cluster_epoch` (read + catch-up
    /// state). Returns the **refined diff**: `(global vertex, new
    /// value)` for every entry this commit changed, plus every local the
    /// shard registered since the previous commit — exactly what a
    /// lagging replica needs to replay the epoch without recomputing
    /// (the epoch-journal payload, see [`crate::cluster::journal`]).
    fn refine_commit(&self, cluster_epoch: u64) -> Result<Vec<(VertexId, u32)>>;

    /// Committed refined coreness of an owned vertex, plus the cluster
    /// epoch it was committed at (`None` for unknown / non-owned ids).
    fn refined_coreness(&self, v: VertexId) -> Result<(Option<u32>, u64)>;

    /// Committed coreness histogram over owned vertices (index = k),
    /// plus the commit epoch.
    fn histogram_partial(&self) -> Result<(Vec<u64>, u64)>;

    /// Owned vertices with committed coreness >= k (unsorted), plus the
    /// commit epoch.
    fn members_partial(&self, k: u32) -> Result<(Vec<VertexId>, u64)>;

    /// The in-process `CoreIndex`, when there is one (snapshot shipping
    /// and global-graph assembly for local shards).
    fn local_index(&self) -> Option<Arc<CoreIndex>> {
        None
    }

    /// Encode up to `count` of this shard's owned vertices — complete
    /// adjacency plus committed refined coreness — as a handoff payload
    /// ([`crate::cluster::wire::encode_handoff`]). Boundary-heavy
    /// vertices are picked first so a split sheds the vertices whose
    /// edits already cross shards. Ownership does **not** change here:
    /// the rebalance executor calls [`ShardBackend::handoff_release`]
    /// only after the receiving shard has adopted the payload, so a
    /// failure between the two calls never leaves a vertex unowned.
    fn handoff_export(&self, count: usize) -> Result<Vec<u8>> {
        let _ = count;
        bail!("shard {}: handoff is not supported by this backend", self.id())
    }

    /// Adopt a handoff payload: register its vertices as owned, splice
    /// their shipped adjacency into the local subgraph, install their
    /// committed coreness. Refuses vertices this shard already owns —
    /// the double-apply fence for a retried move. Returns the adopted
    /// global ids (the set the coordinator remaps and releases).
    fn handoff_adopt(&self, bytes: &[u8]) -> Result<Vec<VertexId>> {
        let _ = bytes;
        bail!("shard {}: handoff is not supported by this backend", self.id())
    }

    /// Demote previously-exported owned vertices to ghosts after the
    /// receiving shard adopted them. Their adjacency stays in the local
    /// subgraph (ghost neighborhoods are never read for owned answers);
    /// only the ownership bookkeeping — and with it arc accounting,
    /// reads, manifests — changes hands.
    fn handoff_release(&self, vertices: &[VertexId]) -> Result<()> {
        let _ = vertices;
        bail!("shard {}: handoff is not supported by this backend", self.id())
    }
}

/// Writer-side state of an in-process shard.
struct LocalState {
    /// local id → global id.
    globals: Vec<VertexId>,
    /// global id → local id.
    locals: HashMap<VertexId, u32>,
    /// Local ids owned by this shard (registration order == ascending
    /// global id: new vertices always carry larger ids).
    owned_locals: Vec<u32>,
    /// `owned_mask[l]` — is local `l` owned (vs ghost)?
    owned_mask: Vec<bool>,
    /// Refinement working estimates, one per local id.
    est: Vec<u32>,
    /// Whether the next `refine_round` must sweep even without installs.
    dirty: bool,
    /// Committed estimates from the last `refine_commit`.
    refined: Vec<u32>,
    /// Cluster epoch of the last commit.
    cluster_epoch: u64,
}

impl LocalState {
    /// Local id of `v`, registering it (as a ghost — callers flip the
    /// mask for owned adoptions) if unseen.
    fn local_id(&mut self, v: VertexId) -> u32 {
        if let Some(&l) = self.locals.get(&v) {
            return l;
        }
        let l = self.globals.len() as u32;
        self.globals.push(v);
        self.locals.insert(v, l);
        self.owned_mask.push(false);
        l
    }
}

/// The in-process [`ShardBackend`]: a shard-local epoch-versioned
/// [`CoreIndex`] plus the global↔local translation tables.
pub struct LocalShard {
    id: usize,
    index: Arc<CoreIndex>,
    cfg: BatchConfig,
    state: Mutex<LocalState>,
}

impl LocalShard {
    /// Build from a partition plan (decomposes the subgraph).
    pub fn from_plan(index_name: &str, plan: &ShardPlan, cfg: BatchConfig) -> Self {
        let mut globals = plan.owned.clone();
        globals.extend_from_slice(&plan.ghosts);
        let index = Arc::new(CoreIndex::new(
            format!("{index_name}/shard{}", plan.id),
            &plan.subgraph,
        ));
        Self::assemble(
            plan.id,
            index,
            globals,
            plan.owned.len(),
            Vec::new(),
            NEVER_COMMITTED,
            cfg,
        )
    }

    /// Rebuild from shipped state (the `SHARDHOST` restore path): a
    /// hydrated index plus the translation tables and committed refined
    /// estimates — no decomposition runs.
    pub fn from_parts(
        id: usize,
        index: Arc<CoreIndex>,
        globals: Vec<VertexId>,
        owned_locals: Vec<u32>,
        refined: Vec<u32>,
        cluster_epoch: u64,
        cfg: BatchConfig,
    ) -> Result<Self> {
        let n = globals.len();
        let locals: HashMap<VertexId, u32> = globals
            .iter()
            .enumerate()
            .map(|(i, &v)| (v, i as u32))
            .collect();
        if locals.len() != n {
            bail!("duplicate global ids in shard state");
        }
        let mut owned_mask = vec![false; n];
        for &l in &owned_locals {
            let Some(m) = owned_mask.get_mut(l as usize) else {
                bail!("owned local {l} out of range (n={n})");
            };
            if *m {
                bail!("owned local {l} listed twice");
            }
            *m = true;
        }
        if !refined.is_empty() && refined.len() != n {
            bail!("refined length {} != vertex count {n}", refined.len());
        }
        // no committed refined state must never masquerade as a real
        // epoch, or epoch-checked replica reads would trust it
        let cluster_epoch = if refined.is_empty() {
            NEVER_COMMITTED
        } else {
            cluster_epoch
        };
        Ok(Self {
            id,
            index,
            cfg,
            state: Mutex::new(LocalState {
                globals,
                locals,
                owned_locals,
                owned_mask,
                est: Vec::new(),
                dirty: true,
                refined,
                cluster_epoch,
            }),
        })
    }

    fn assemble(
        id: usize,
        index: Arc<CoreIndex>,
        globals: Vec<VertexId>,
        owned_len: usize,
        refined: Vec<u32>,
        cluster_epoch: u64,
        cfg: BatchConfig,
    ) -> Self {
        let locals: HashMap<VertexId, u32> = globals
            .iter()
            .enumerate()
            .map(|(i, &v)| (v, i as u32))
            .collect();
        let mut owned_mask = vec![false; globals.len()];
        for m in owned_mask.iter_mut().take(owned_len) {
            *m = true;
        }
        Self {
            id,
            index,
            cfg,
            state: Mutex::new(LocalState {
                globals,
                locals,
                owned_locals: (0..owned_len as u32).collect(),
                owned_mask,
                est: Vec::new(),
                dirty: true,
                refined,
                cluster_epoch,
            }),
        }
    }

    /// The shard's own epoch-versioned index (what snapshot shipping
    /// serialises).
    pub fn index(&self) -> Arc<CoreIndex> {
        self.index.clone()
    }

    /// Everything a manifest needs — `(globals, owned_locals, refined,
    /// cluster_epoch, encoded index snapshot)` captured atomically: the
    /// state lock is held while the snapshot is encoded (state→index is
    /// the established lock order), so a concurrent apply can never
    /// produce a torn manifest whose id table disagrees with the graph.
    pub fn export_state(&self) -> (Vec<VertexId>, Vec<u32>, Vec<u32>, u64, Vec<u8>) {
        let st = self.state.lock().unwrap();
        let snapshot = crate::shard::snapshot::encode_index(&self.index);
        (
            st.globals.clone(),
            st.owned_locals.clone(),
            st.refined.clone(),
            st.cluster_epoch,
            snapshot,
        )
    }

    /// Replica-side delta replay, step 2 of 2: after the epoch's routed
    /// batch has been replayed through [`ShardBackend::apply`], install
    /// the refined-coreness diff the primary's commit produced and stamp
    /// the new cluster epoch. The diff is untrusted wire input: every
    /// vertex must be a known local, every new local must be covered
    /// (the primary's commit diff always covers them), and owned values
    /// are capped by the owned vertex's (complete) local degree — the
    /// same invariant `cluster::wire::decode_manifest` enforces. Nothing
    /// is installed on a rejected diff.
    pub fn install_refined_diff(
        &self,
        diff: &[(VertexId, u32)],
        cluster_epoch: u64,
    ) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let st = &mut *st;
        let n = st.globals.len();
        let old_len = st.refined.len();
        // Pass 1 — validate everything, mutate nothing. Degrees come
        // from the maintained structure directly (O(1) per entry); a
        // per-step CSR rebuild or a scratch copy of the refined vector
        // would make delta replay O(|V|+|E|) per epoch — the exact
        // asymptotics the journal exists to avoid.
        let mut covered = vec![false; n - old_len.min(n)];
        self.index.with_dynamic(|dc| {
            if dc.num_vertices() != n {
                bail!(
                    "shard {}: index has {} vertices but {n} locals are registered",
                    self.id,
                    dc.num_vertices()
                );
            }
            for &(v, c) in diff {
                let Some(&l) = st.locals.get(&v) else {
                    bail!("refined diff names vertex {v}, unknown to shard {}", self.id);
                };
                let l = l as usize;
                if st.owned_mask[l] {
                    let d = dc.degree(l as u32);
                    if c > d {
                        bail!("refined diff sets owned {v} to {c}, above its degree {d}");
                    }
                }
                if l >= old_len {
                    covered[l - old_len] = true;
                }
            }
            Ok(())
        })?;
        if let Some(l) = covered.iter().position(|&c| !c) {
            bail!(
                "refined diff leaves new local {} (vertex {}) uninitialised",
                old_len + l,
                st.globals[old_len + l]
            );
        }
        // Pass 2 — apply in place (every entry pre-validated; new slots
        // all proven covered, so the resize fill is always overwritten).
        st.refined.resize(n, 0);
        for &(v, c) in diff {
            st.refined[st.locals[&v] as usize] = c;
        }
        st.cluster_epoch = cluster_epoch;
        Ok(())
    }

    /// All arcs out of owned vertices as global-id pairs — the
    /// assembly input for a router-side global CSR (boundary edges show
    /// up once per endpoint owner; the builder's dedup collapses them).
    pub fn owned_edges(&self) -> Vec<(VertexId, VertexId)> {
        let st = self.state.lock().unwrap();
        let g = self.index.graph();
        let mut out = Vec::new();
        for &l in &st.owned_locals {
            let gu = st.globals[l as usize];
            for &w in g.neighbors(l) {
                out.push((gu, st.globals[w as usize]));
            }
        }
        out
    }
}

impl ShardBackend for LocalShard {
    fn id(&self) -> usize {
        self.id
    }

    fn kind(&self) -> &'static str {
        "local"
    }

    fn status(&self) -> Result<ShardStatus> {
        let st = self.state.lock().unwrap();
        let k_max = st
            .owned_locals
            .iter()
            .filter_map(|&l| st.refined.get(l as usize).copied())
            .max()
            .unwrap_or(0);
        // Exact manifest size from counts alone, without encoding
        // anything: the manifest header (8 magic + 2×u32 + u64 + 4×u64
        // counts = 56) + the three u32 tables + the embedded snapshot
        // (8 magic + u32 name length + name + u64 epoch + 3×u64 counts
        // = 44 + name, then (n+1) u64 offsets + 2m u32 adjacency + n u32
        // core). Keep in lockstep with `cluster::wire::encode_manifest`
        // and `shard::snapshot::encode` — pinned by a test against
        // `cluster::manifest_for(...).len()`.
        let snap = self.index.snapshot();
        let n = snap.num_vertices() as u64;
        let snapshot_bytes =
            44 + self.index.name().len() as u64 + 8 * (n + 1) + 4 * 2 * snap.num_edges + 4 * n;
        let state_bytes = 56
            + 4 * st.globals.len() as u64
            + 4 * st.owned_locals.len() as u64
            + 4 * st.refined.len() as u64
            + snapshot_bytes;
        Ok(ShardStatus {
            id: self.id,
            epoch: self.index.epoch(),
            cluster_epoch: st.cluster_epoch,
            owned: st.owned_locals.len(),
            k_max,
            state_bytes,
        })
    }

    fn apply(&self, batch: &RoutedBatch) -> Result<ApplyOutcome> {
        let mut st = self.state.lock().unwrap();
        for &v in &batch.new_owned {
            let l = st.local_id(v);
            if !st.owned_mask[l as usize] {
                st.owned_mask[l as usize] = true;
                st.owned_locals.push(l);
            }
        }
        // translate to local ids, registering unseen endpoints as ghosts
        let mut local_edits: Vec<(EdgeEdit, bool)> = Vec::with_capacity(batch.edits.len());
        for &(e, primary) in &batch.edits {
            let (u, v) = e.endpoints();
            if u == v {
                bail!("self-loop edit ({u},{u}) routed to shard {}", self.id);
            }
            let lu = st.local_id(u);
            let lv = st.local_id(v);
            let local = match e {
                EdgeEdit::Insert(_, _) => EdgeEdit::Insert(lu, lv),
                EdgeEdit::Delete(_, _) => EdgeEdit::Delete(lu, lv),
            };
            local_edits.push((local, primary));
        }
        // same crossover policy as `service::batch::apply_batch`:
        // measured break-even when warm, static calibration when cold,
        // bucket-peel recompute against the shard index's scratch
        let last_local = st.globals.len().checked_sub(1).map(|l| l as u32);
        let cfg = &self.cfg;
        let index = &self.index;
        let costs = index.crossover_costs();
        let ((changed, recomputed), _snap) = self.index.update(|dc| {
            if let Some(last) = last_local {
                dc.ensure_vertex(last);
            }
            let num_edges = dc.num_edges();
            let threshold = costs
                .measured_threshold(num_edges)
                .map(|t| t.max(cfg.min_recompute_edits))
                .unwrap_or_else(|| cfg.recompute_threshold(num_edges));
            let mut changed = 0usize;
            if !local_edits.is_empty() && local_edits.len() >= threshold {
                for &(e, primary) in &local_edits {
                    let did = match e {
                        EdgeEdit::Insert(u, v) => dc.insert_edge_structural(u, v),
                        EdgeEdit::Delete(u, v) => dc.delete_edge_structural(u, v),
                    };
                    if did && primary {
                        changed += 1;
                    }
                }
                let t0 = std::time::Instant::now();
                dc.recompute_bucket(cfg.threads, &mut index.recompute_scratch());
                costs.observe_recompute(dc.num_edges(), t0.elapsed());
                (changed, true)
            } else {
                let t0 = std::time::Instant::now();
                for &(e, primary) in &local_edits {
                    if dc.apply(e) && primary {
                        changed += 1;
                    }
                }
                costs.observe_incremental(local_edits.len(), t0.elapsed());
                (changed, false)
            }
        });
        st.dirty = true;
        Ok(ApplyOutcome {
            changed,
            recomputed,
            epoch: self.index.epoch(),
        })
    }

    fn refine_start(&self, slack: Option<u32>) -> Result<RefineInit> {
        let mut st = self.state.lock().unwrap();
        let st = &mut *st;
        let g = self.index.graph();
        let n = g.num_vertices();
        if n != st.globals.len() {
            bail!(
                "shard {}: index has {n} vertices but {} locals are registered",
                self.id,
                st.globals.len()
            );
        }
        st.est = (0..n as u32).map(|l| g.degree(l)).collect();
        if let Some(slack) = slack {
            // warm start: committed coreness + slack is still an upper
            // bound (see module docs); degrees stay the cap
            for l in 0..st.refined.len().min(n) {
                let warm = st.refined[l].saturating_add(slack);
                if warm < st.est[l] {
                    st.est[l] = warm;
                }
            }
        }
        st.dirty = true;
        let mut owned_est = Vec::with_capacity(st.owned_locals.len());
        let mut arcs = 0u64;
        let mut boundary_arcs = 0u64;
        for &l in &st.owned_locals {
            owned_est.push((st.globals[l as usize], st.est[l as usize]));
            for &w in g.neighbors(l) {
                arcs += 1;
                if !st.owned_mask[w as usize] {
                    boundary_arcs += 1;
                }
            }
        }
        let ghosts: Vec<VertexId> = (0..n as u32)
            .filter(|&l| !st.owned_mask[l as usize])
            .map(|l| st.globals[l as usize])
            .collect();
        Ok(RefineInit {
            owned_est,
            ghosts,
            arcs,
            boundary_arcs,
        })
    }

    fn refine_round(&self, updates: &[(VertexId, u32)]) -> Result<RefineRound> {
        let mut st = self.state.lock().unwrap();
        let st = &mut *st;
        let g = self.index.graph();
        let mut ghost_updates = 0u64;
        for &(v, val) in updates {
            if let Some(&l) = st.locals.get(&v) {
                let l = l as usize;
                if !st.owned_mask[l] && l < st.est.len() && st.est[l] != val {
                    st.est[l] = val;
                    ghost_updates += 1;
                    st.dirty = true;
                }
            }
        }
        if !st.dirty {
            return Ok(RefineRound {
                changed: Vec::new(),
                sweeps: 0,
                ghost_updates,
            });
        }
        st.dirty = false;
        let mut changed_mask = vec![false; st.est.len()];
        let mut scratch = HindexScratch::new();
        loop {
            let mut changed = false;
            for &l in &st.owned_locals {
                let cap = st.est[l as usize];
                if cap == 0 {
                    continue;
                }
                let h = {
                    let vals: &[u32] = &st.est;
                    hindex_capped(
                        g.neighbors(l).iter().map(|&w| vals[w as usize]),
                        cap,
                        &mut scratch,
                    )
                };
                if h < cap {
                    st.est[l as usize] = h;
                    changed_mask[l as usize] = true;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
        let changed: Vec<(VertexId, u32)> = st
            .owned_locals
            .iter()
            .filter(|&&l| changed_mask[l as usize])
            .map(|&l| (st.globals[l as usize], st.est[l as usize]))
            .collect();
        Ok(RefineRound {
            changed,
            sweeps: 1,
            ghost_updates,
        })
    }

    fn refine_commit(&self, cluster_epoch: u64) -> Result<Vec<(VertexId, u32)>> {
        let mut st = self.state.lock().unwrap();
        let st = &mut *st;
        // the journal payload: entries the commit changes, plus every
        // local registered since the previous commit (est is full-length
        // after refine_start; refined may still have the old length)
        let diff: Vec<(VertexId, u32)> = st
            .est
            .iter()
            .enumerate()
            .filter(|&(l, &e)| st.refined.get(l).copied() != Some(e))
            .map(|(l, &e)| (st.globals[l], e))
            .collect();
        st.refined = st.est.clone();
        st.cluster_epoch = cluster_epoch;
        Ok(diff)
    }

    fn refined_coreness(&self, v: VertexId) -> Result<(Option<u32>, u64)> {
        let st = self.state.lock().unwrap();
        let val = st.locals.get(&v).and_then(|&l| {
            let l = l as usize;
            if st.owned_mask[l] {
                st.refined.get(l).copied()
            } else {
                None
            }
        });
        Ok((val, st.cluster_epoch))
    }

    fn histogram_partial(&self) -> Result<(Vec<u64>, u64)> {
        let st = self.state.lock().unwrap();
        let mut hist: Vec<u64> = Vec::new();
        for &l in &st.owned_locals {
            let Some(&c) = st.refined.get(l as usize) else {
                continue;
            };
            let c = c as usize;
            if c >= hist.len() {
                hist.resize(c + 1, 0);
            }
            hist[c] += 1;
        }
        Ok((hist, st.cluster_epoch))
    }

    fn members_partial(&self, k: u32) -> Result<(Vec<VertexId>, u64)> {
        let st = self.state.lock().unwrap();
        let members: Vec<VertexId> = st
            .owned_locals
            .iter()
            .filter(|&&l| st.refined.get(l as usize).is_some_and(|&c| c >= k))
            .map(|&l| st.globals[l as usize])
            .collect();
        Ok((members, st.cluster_epoch))
    }

    fn local_index(&self) -> Option<Arc<CoreIndex>> {
        Some(self.index.clone())
    }

    fn handoff_export(&self, count: usize) -> Result<Vec<u8>> {
        if count == 0 {
            bail!("shard {}: handoff of zero vertices", self.id);
        }
        let st = self.state.lock().unwrap();
        if st.refined.len() != st.globals.len() {
            bail!(
                "shard {}: no committed refined state to hand off (run a flush first)",
                self.id
            );
        }
        let g = self.index.graph();
        // Shed boundary-heavy vertices first: their edits already ship
        // to two shards, so moving them is the cheapest way to change
        // the balance. Global-id tiebreak keeps the pick deterministic.
        let mut ranked: Vec<(u64, VertexId, u32)> = st
            .owned_locals
            .iter()
            .map(|&l| {
                let ghost_arcs = g
                    .neighbors(l)
                    .iter()
                    .filter(|&&w| !st.owned_mask[w as usize])
                    .count() as u64;
                (ghost_arcs, st.globals[l as usize], l)
            })
            .collect();
        ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        ranked.truncate(count);
        // the codec ships vertices in ascending id order
        ranked.sort_by_key(|&(_, v, _)| v);
        let picked: Vec<crate::cluster::wire::HandoffVertex> = ranked
            .iter()
            .map(|&(_, v, l)| crate::cluster::wire::HandoffVertex {
                id: v,
                refined: st.refined[l as usize],
                neighbors: {
                    let mut ns: Vec<VertexId> =
                        g.neighbors(l).iter().map(|&w| st.globals[w as usize]).collect();
                    ns.sort_unstable();
                    ns
                },
            })
            .collect();
        crate::cluster::wire::encode_handoff(self.id as u32, &picked)
    }

    fn handoff_adopt(&self, bytes: &[u8]) -> Result<Vec<VertexId>> {
        let payload = crate::cluster::wire::decode_handoff(bytes)?;
        if payload.from_shard as usize == self.id {
            bail!("shard {}: refusing to adopt its own handoff", self.id);
        }
        let mut st = self.state.lock().unwrap();
        let st = &mut *st;
        // Pass 1 — validate against current state before mutating
        // anything: a vertex this shard already owns means the move was
        // already applied (the retry / double-apply fence).
        for hv in &payload.vertices {
            if let Some(&l) = st.locals.get(&hv.id) {
                if st.owned_mask[l as usize] {
                    bail!(
                        "shard {}: already owns vertex {} (handoff replayed?)",
                        self.id,
                        hv.id
                    );
                }
            }
        }
        // Pass 2 — register vertices (adoptees owned, unseen neighbors
        // as ghosts) and collect the edge splice in local ids.
        let mut adopted = Vec::with_capacity(payload.vertices.len());
        let mut splice: Vec<(u32, u32)> = Vec::new();
        for hv in &payload.vertices {
            let lv = st.local_id(hv.id);
            st.owned_mask[lv as usize] = true;
            st.owned_locals.push(lv);
            adopted.push(hv.id);
            for &w in &hv.neighbors {
                let lw = st.local_id(w);
                splice.push((lv, lw));
            }
        }
        // Splice the shipped neighborhoods into the subgraph (inserts on
        // edges the shard already held as ghost arcs no-op) and refresh
        // the shard-local coreness the embedded snapshot carries — same
        // structural-edit + recompute pipeline as a bulk apply.
        let last_local = st.globals.len() as u32 - 1;
        let threads = self.cfg.threads;
        let index = &self.index;
        self.index.update(|dc| {
            dc.ensure_vertex(last_local);
            for &(lu, lv) in &splice {
                dc.insert_edge_structural(lu, lv);
            }
            dc.recompute_bucket(threads, &mut index.recompute_scratch());
        });
        // Committed coreness follows the vertices; a never-committed
        // shard stays never-committed (the post-move refinement pass
        // commits everything at the next epoch anyway).
        if !st.refined.is_empty() {
            st.refined.resize(st.globals.len(), 0);
            for hv in &payload.vertices {
                st.refined[st.locals[&hv.id] as usize] = hv.refined;
            }
        }
        st.dirty = true;
        Ok(adopted)
    }

    fn handoff_release(&self, vertices: &[VertexId]) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        let st = &mut *st;
        let mut demote = Vec::with_capacity(vertices.len());
        for &v in vertices {
            let Some(&l) = st.locals.get(&v) else {
                bail!("shard {}: cannot release unknown vertex {v}", self.id);
            };
            if !st.owned_mask[l as usize] {
                bail!("shard {}: cannot release vertex {v} it does not own", self.id);
            }
            demote.push(l);
        }
        for &l in &demote {
            st.owned_mask[l as usize] = false;
        }
        let kept = std::mem::take(&mut st.owned_locals);
        st.owned_locals = kept
            .into_iter()
            .filter(|&l| st.owned_mask[l as usize])
            .collect();
        st.dirty = true;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;
    use crate::shard::partition::{partition, PartitionStrategy};

    fn cfg() -> BatchConfig {
        BatchConfig {
            threads: 1,
            ..BatchConfig::default()
        }
    }

    fn shards_for(g: &crate::graph::CsrGraph, k: usize) -> Vec<LocalShard> {
        partition(g, k, PartitionStrategy::Hash)
            .shards
            .iter()
            .map(|p| LocalShard::from_plan("t", p, cfg()))
            .collect()
    }

    #[test]
    fn refine_init_accounts_arcs_exactly() {
        let g = examples::g1();
        let shards = shards_for(&g, 3);
        let mut arcs = 0u64;
        let mut boundary = 0u64;
        for s in &shards {
            let init = s.refine_start(None).unwrap();
            arcs += init.arcs;
            boundary += init.boundary_arcs;
            for &(_, e) in &init.owned_est {
                assert!(e <= g.max_degree());
            }
        }
        assert_eq!(arcs / 2, g.num_edges());
        assert_eq!(boundary % 2, 0);
    }

    #[test]
    fn apply_routes_and_counts_primaries_once() {
        let g = examples::g1();
        let shards = shards_for(&g, 2);
        // find an edit and dispatch to both endpoint owners, primary once
        let out0 = shards[0]
            .apply(&RoutedBatch {
                new_owned: vec![],
                edits: vec![(EdgeEdit::Insert(2, 5), true)],
            })
            .unwrap();
        let out1 = shards[1]
            .apply(&RoutedBatch {
                new_owned: vec![],
                edits: vec![(EdgeEdit::Insert(2, 5), false)],
            })
            .unwrap();
        assert_eq!(out0.changed + out1.changed, 1);
        assert!(shards[0].apply(&RoutedBatch {
            new_owned: vec![],
            edits: vec![(EdgeEdit::Insert(7, 7), true)],
        }).is_err());
    }

    #[test]
    fn commit_freezes_reads_with_epoch() {
        let g = examples::complete(4);
        let shards = shards_for(&g, 1);
        let s = &shards[0];
        let init = s.refine_start(None).unwrap();
        assert_eq!(init.ghosts.len(), 0);
        let round = s.refine_round(&[]).unwrap();
        assert_eq!(round.sweeps, 1);
        s.refine_commit(7).unwrap();
        let (c, ce) = s.refined_coreness(0).unwrap();
        assert_eq!((c, ce), (Some(3), 7));
        let (hist, _) = s.histogram_partial().unwrap();
        assert_eq!(hist, vec![0, 0, 0, 4]);
        let (members, _) = s.members_partial(3).unwrap();
        assert_eq!(members.len(), 4);
        let st = s.status().unwrap();
        assert_eq!((st.cluster_epoch, st.owned, st.k_max), (7, 4, 3));
    }

    #[test]
    fn commit_diff_names_exactly_what_changed() {
        let g = examples::complete(4);
        let shards = shards_for(&g, 1);
        let s = &shards[0];
        s.refine_start(None).unwrap();
        s.refine_round(&[]).unwrap();
        // first commit: everything is new (refined was empty)
        let diff = s.refine_commit(1).unwrap();
        assert_eq!(diff.len(), 4);
        assert!(diff.iter().all(|&(_, c)| c == 3));
        // a second pass over the unchanged graph commits an empty diff
        s.refine_start(Some(0)).unwrap();
        s.refine_round(&[]).unwrap();
        assert!(s.refine_commit(2).unwrap().is_empty());
        // growth: a new owned vertex appears in the next commit's diff
        s.apply(&RoutedBatch {
            new_owned: vec![9],
            edits: vec![(EdgeEdit::Insert(0, 9), true)],
        })
        .unwrap();
        s.refine_start(Some(1)).unwrap();
        s.refine_round(&[]).unwrap();
        let diff = s.refine_commit(3).unwrap();
        assert!(diff.iter().any(|&(v, _)| v == 9), "{diff:?}");
    }

    #[test]
    fn install_refined_diff_validates_and_mirrors_commits() {
        let g = examples::complete(4);
        let primaries = shards_for(&g, 1);
        let replicas = shards_for(&g, 1);
        let (primary, replica) = (&primaries[0], &replicas[0]);
        primary.refine_start(None).unwrap();
        primary.refine_round(&[]).unwrap();
        let diff = primary.refine_commit(5).unwrap();
        replica.install_refined_diff(&diff, 5).unwrap();
        for v in 0..4u32 {
            assert_eq!(
                replica.refined_coreness(v).unwrap(),
                primary.refined_coreness(v).unwrap()
            );
        }
        // unknown vertex refused
        assert!(replica.install_refined_diff(&[(99, 1)], 6).is_err());
        // owned value above its degree refused
        assert!(replica.install_refined_diff(&[(0, 50)], 6).is_err());
        // a batch registering a vertex the diff does not cover is refused
        replica
            .apply(&RoutedBatch {
                new_owned: vec![7],
                edits: vec![],
            })
            .unwrap();
        let err = replica.install_refined_diff(&[], 6).unwrap_err();
        assert!(format!("{err:#}").contains("uninitialised"), "{err:#}");
        // rejected installs leave the committed epoch untouched
        assert_eq!(replica.refined_coreness(0).unwrap().1, 5);
        // covering the new local succeeds
        replica.install_refined_diff(&[(7, 0)], 6).unwrap();
        assert_eq!(replica.refined_coreness(7).unwrap(), (Some(0), 6));
    }

    #[test]
    fn state_bytes_matches_the_encoded_manifest() {
        let g = examples::g1();
        let shards = shards_for(&g, 2);
        for s in &shards {
            s.refine_start(None).unwrap();
            s.refine_round(&[]).unwrap();
            s.refine_commit(1).unwrap();
            let want = crate::cluster::manifest_for(s, 2).len() as u64;
            assert_eq!(s.status().unwrap().state_bytes, want);
        }
    }

    #[test]
    fn handoff_moves_ownership_and_refine_still_reaches_the_oracle() {
        let g = crate::graph::gen::erdos_renyi(80, 260, 3);
        let want = crate::core::bz::bz_coreness(&g);
        let backends: Vec<Arc<dyn ShardBackend>> = shards_for(&g, 2)
            .into_iter()
            .map(|s| Arc::new(s) as Arc<dyn ShardBackend>)
            .collect();
        // commit a first pass so the export has refined state to carry
        crate::shard::router::refine(&backends, g.num_vertices(), None, 0, 1).unwrap();
        let owned_before: usize = backends.iter().map(|b| b.status().unwrap().owned).sum();
        // split: shard 0 sheds 10 vertices to shard 1
        let payload = backends[0].handoff_export(10).unwrap();
        let adopted = backends[1].handoff_adopt(&payload).unwrap();
        assert_eq!(adopted.len(), 10);
        backends[0].handoff_release(&adopted).unwrap();
        let s0 = backends[0].status().unwrap();
        let s1 = backends[1].status().unwrap();
        assert_eq!(s0.owned + s1.owned, owned_before, "no vertex unowned or doubled");
        // moved vertices answer (with their committed value) on the new
        // owner and are ghosts on the old one
        for &v in &adopted {
            assert_eq!(backends[0].refined_coreness(v).unwrap().0, None);
            assert_eq!(backends[1].refined_coreness(v).unwrap().0, Some(want[v as usize]));
        }
        // the arc accounting still closes and a warm pass still lands on
        // the oracle — the boundary rebookkeeping is exact
        let out = crate::shard::router::refine(&backends, g.num_vertices(), Some(0), 1, 1).unwrap();
        assert_eq!(out.core, want);
        assert_eq!(out.num_edges, g.num_edges());
        // replaying the same payload is refused (double-apply fence)
        let err = backends[1].handoff_adopt(&payload).unwrap_err();
        assert!(format!("{err:#}").contains("already owns"), "{err:#}");
        // merge: shard 0 empties entirely into shard 1
        let rest = backends[0].status().unwrap().owned;
        let payload = backends[0].handoff_export(rest).unwrap();
        let adopted = backends[1].handoff_adopt(&payload).unwrap();
        backends[0].handoff_release(&adopted).unwrap();
        assert_eq!(backends[0].status().unwrap().owned, 0);
        assert_eq!(backends[1].status().unwrap().owned, owned_before);
        let out = crate::shard::router::refine(&backends, g.num_vertices(), Some(0), 2, 1).unwrap();
        assert_eq!(out.core, want);
        assert_eq!(out.num_edges, g.num_edges());
    }

    #[test]
    fn handoff_guards_reject_bad_transfers() {
        let g = examples::g1();
        let shards = shards_for(&g, 2);
        // no committed refined state yet: export refuses
        let err = shards[0].handoff_export(1).unwrap_err();
        assert!(format!("{err:#}").contains("no committed refined state"), "{err:#}");
        let bs: Vec<Arc<dyn ShardBackend>> = shards
            .into_iter()
            .map(|s| Arc::new(s) as Arc<dyn ShardBackend>)
            .collect();
        crate::shard::router::refine(&bs, g.num_vertices(), None, 0, 1).unwrap();
        assert!(bs[0].handoff_export(0).is_err(), "zero-vertex handoff");
        let payload = bs[0].handoff_export(1).unwrap();
        // a shard never adopts its own export
        let err = bs[0].handoff_adopt(&payload).unwrap_err();
        assert!(format!("{err:#}").contains("its own handoff"), "{err:#}");
        // releasing something unknown, or a vertex the shard has only as
        // a ghost, is refused — release is for the exporting owner only
        assert!(bs[1].handoff_release(&[999]).is_err());
        let adopted = bs[1].handoff_adopt(&payload).unwrap();
        bs[0].handoff_release(&adopted).unwrap();
        // the old owner cannot release twice
        assert!(bs[0].handoff_release(&adopted).is_err());
    }

    #[test]
    fn warm_start_is_capped_by_degree() {
        let g = examples::complete(4);
        let shards = shards_for(&g, 1);
        let s = &shards[0];
        s.refine_start(None).unwrap();
        s.refine_round(&[]).unwrap();
        s.refine_commit(1).unwrap();
        // slack 100 must not push estimates above the degree cap
        let init = s.refine_start(Some(100)).unwrap();
        for &(_, e) in &init.owned_est {
            assert_eq!(e, 3);
        }
        // slack 0 warm-starts directly at the committed coreness
        let init = s.refine_start(Some(0)).unwrap();
        for &(_, e) in &init.owned_est {
            assert_eq!(e, 3);
        }
    }
}
