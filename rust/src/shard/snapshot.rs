//! Binary snapshot shipping: serialise one [`CoreIndex`] epoch so a
//! replica can hydrate it **without recomputing** the decomposition.
//!
//! The format follows `graph/io/binfmt`'s framing conventions (magic,
//! little-endian scalars, length-prefixed name) and extends the CSR
//! payload with the epoch and the coreness vector. The magic is
//! [`crate::net::codec::SNAPSHOT_MAGIC`] — defined there, like every
//! other wire magic — and the decode path reads through the shared
//! bounds-checked [`crate::net::codec::Cursor`]:
//!
//! ```text
//! magic     SNAPSHOT_MAGIC                   8 bytes
//! name      u32 length + UTF-8 bytes
//! epoch     u64
//! counts    u64 offsets_len, u64 adjacency_len, u64 core_len
//! offsets   offsets_len × u64
//! adjacency adjacency_len × u32
//! core      core_len × u32
//! ```
//!
//! [`decode`] treats input as untrusted wire bytes: besides structural
//! CSR validation it re-checks the coreness vector against
//! [`crate::core::verify::check_invariants`], so a tampered or corrupt
//! snapshot is rejected instead of being served. Hydration
//! ([`IndexSnapshot::hydrate`]) then installs the shipped coreness
//! directly — no decomposition runs on the restore path.

use crate::graph::csr::{CsrGraph, VertexId};
use crate::net::codec::{Cursor, SNAPSHOT_MAGIC as MAGIC};
use crate::service::index::CoreIndex;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Longest index name accepted by the decoder (same cap as binfmt).
const MAX_NAME_BYTES: usize = 4096;

/// A decoded snapshot, ready to hydrate.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IndexSnapshot {
    pub name: String,
    pub epoch: u64,
    pub core: Vec<u32>,
    pub graph: CsrGraph,
}

impl IndexSnapshot {
    /// Build a serving index from shipped state. No decomposition runs:
    /// the decoder already vouched for the coreness.
    pub fn hydrate(self) -> CoreIndex {
        CoreIndex::hydrate(self.name, &self.graph, self.core, self.epoch)
    }
}

/// Serialise an index state to bytes.
pub fn encode(name: &str, epoch: u64, core: &[u32], graph: &CsrGraph) -> Vec<u8> {
    let name = name.as_bytes();
    let mut out = Vec::with_capacity(
        MAGIC.len()
            + 4
            + name.len()
            + 8 * 4
            + graph.offsets().len() * 8
            + graph.adjacency().len() * 4
            + core.len() * 4,
    );
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(name.len() as u32).to_le_bytes());
    out.extend_from_slice(name);
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&(graph.offsets().len() as u64).to_le_bytes());
    out.extend_from_slice(&(graph.adjacency().len() as u64).to_le_bytes());
    out.extend_from_slice(&(core.len() as u64).to_le_bytes());
    for &o in graph.offsets() {
        out.extend_from_slice(&o.to_le_bytes());
    }
    for &a in graph.adjacency() {
        out.extend_from_slice(&a.to_le_bytes());
    }
    for &c in core {
        out.extend_from_slice(&c.to_le_bytes());
    }
    out
}

/// Serialise one index's current published epoch (a mutually consistent
/// snapshot + graph pair).
pub fn encode_index(index: &CoreIndex) -> Vec<u8> {
    let (snap, g) = index.consistent_view();
    encode(index.name(), snap.epoch, &snap.core, &g)
}

/// Parse and validate untrusted snapshot bytes.
pub fn decode(bytes: &[u8]) -> Result<IndexSnapshot> {
    let mut c = Cursor::new(bytes);
    if c.take(MAGIC.len())? != MAGIC {
        bail!("not a pico snapshot (bad magic)");
    }
    let name_len = c.u32()? as usize;
    if name_len > MAX_NAME_BYTES {
        bail!("unreasonable name length {name_len}");
    }
    let name = String::from_utf8(c.take(name_len)?.to_vec()).context("name not UTF-8")?;
    let epoch = c.u64()?;
    let offsets_len = c.u64()? as usize;
    let adjacency_len = c.u64()? as usize;
    let core_len = c.u64()? as usize;
    if offsets_len == 0 {
        bail!("offsets array empty");
    }
    // Exact payload-size check before allocating anything: declared
    // lengths may not exceed (or undershoot) the bytes actually shipped.
    let expected = offsets_len
        .checked_mul(8)
        .and_then(|b| b.checked_add(adjacency_len.checked_mul(4)?))
        .and_then(|b| b.checked_add(core_len.checked_mul(4)?));
    match expected {
        Some(want) if want == c.remaining() => {}
        _ => bail!(
            "payload size mismatch: header declares {offsets_len}/{adjacency_len}/{core_len} entries but {} bytes remain",
            c.remaining()
        ),
    }
    let mut offsets = Vec::with_capacity(offsets_len);
    for _ in 0..offsets_len {
        offsets.push(c.u64()?);
    }
    let mut adjacency: Vec<VertexId> = Vec::with_capacity(adjacency_len);
    for _ in 0..adjacency_len {
        adjacency.push(c.u32()?);
    }
    let mut core = Vec::with_capacity(core_len);
    for _ in 0..core_len {
        core.push(c.u32()?);
    }
    if core.len() != offsets.len() - 1 {
        bail!(
            "coreness length {} does not match vertex count {}",
            core.len(),
            offsets.len() - 1
        );
    }
    let graph = CsrGraph::try_from_parts(offsets, adjacency, name.clone())
        .map_err(|e| anyhow::anyhow!("corrupt snapshot graph: {e}"))?;
    crate::core::verify::check_invariants(&graph, &core)
        .map_err(|e| anyhow::anyhow!("snapshot coreness fails invariants: {e}"))?;
    Ok(IndexSnapshot {
        name,
        epoch,
        core,
        graph,
    })
}

/// Write a snapshot file (`pico query --binary --snapshot-file` sink).
pub fn write_file(bytes: &[u8], path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path.as_ref(), bytes)
        .with_context(|| format!("writing snapshot {}", path.as_ref().display()))
}

/// Read a snapshot file back.
pub fn read_file(path: impl AsRef<Path>) -> Result<Vec<u8>> {
    std::fs::read(path.as_ref())
        .with_context(|| format!("reading snapshot {}", path.as_ref().display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{examples, GraphBuilder};

    #[test]
    fn round_trip_preserves_everything() {
        let idx = CoreIndex::new("g1", &examples::g1());
        idx.update(|dc| dc.insert_edge(2, 5));
        let bytes = encode_index(&idx);
        let snap = decode(&bytes).unwrap();
        assert_eq!(snap.name, "g1");
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.core, idx.snapshot().core);
        // re-encoding the decoded snapshot is byte-identical
        assert_eq!(encode(&snap.name, snap.epoch, &snap.core, &snap.graph), bytes);

        let restored = snap.hydrate();
        let (a, b) = (restored.snapshot(), idx.snapshot());
        assert_eq!(a.epoch, b.epoch);
        assert_eq!(a.core, b.core);
        assert_eq!(a.num_edges, b.num_edges);
        // the restored index keeps serving updates from the shipped epoch
        let (_, s) = restored.update(|dc| dc.delete_edge(2, 5));
        assert_eq!(s.epoch, 2);
    }

    #[test]
    fn empty_and_isolated_graphs_round_trip() {
        for g in [
            GraphBuilder::new(0).build("empty"),
            GraphBuilder::new(5).build("isolated"),
        ] {
            let idx = CoreIndex::new(g.name.clone(), &g);
            let bytes = encode_index(&idx);
            let restored = decode(&bytes).unwrap().hydrate();
            assert_eq!(restored.snapshot().core, idx.snapshot().core);
            assert_eq!(restored.snapshot().epoch, 0);
        }
    }

    #[test]
    fn corrupt_bytes_are_rejected() {
        let idx = CoreIndex::new("g1", &examples::g1());
        let good = encode_index(&idx);
        // bad magic
        assert!(decode(b"NOTASNAPxxxxxxxx").is_err());
        // truncations at every length are rejected, never panic
        for cut in [0, 7, 9, 20, good.len() / 2, good.len() - 1] {
            assert!(decode(&good[..cut]).is_err(), "cut at {cut}");
        }
        // tampered coreness fails the invariant check
        let mut evil = good.clone();
        let off = evil.len() - 4; // last core entry
        evil[off..].copy_from_slice(&99u32.to_le_bytes());
        let err = decode(&evil).unwrap_err();
        assert!(format!("{err:#}").contains("invariants"), "{err:#}");
        // oversize declared lengths are caught by the size check
        let mut huge = good.clone();
        let counts_at = 8 + 4 + 2 + 8; // magic + name_len + "g1" + epoch
        huge[counts_at..counts_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode(&huge).is_err());
    }
}
