//! The sharded core index: one epoch-versioned [`CoreIndex`] per shard, a
//! router that fans queries out and merges per-shard answers, and the
//! boundary refinement that makes the merged coreness *exact*.
//!
//! # Why merged answers are exact
//!
//! A shard's local coreness (what its own [`CoreIndex`] maintains) is only
//! a lower bound on global coreness — ghost vertices under-report their
//! degree. The merge therefore runs the distributed h-index fixpoint
//! (Montresor et al., the streaming/partitioned k-core line of work): every
//! owned vertex starts from its *global* degree (exact in our partitions —
//! owned vertices keep their full adjacency), each shard sweeps
//! `est[v] ← min(est[v], H(est[N(v)]))` to a local fixpoint, and the
//! router exchanges boundary-vertex estimates between rounds. Estimates
//! are always upper bounds and only decrease, so the iteration terminates;
//! at the global fixpoint `est[v] ≤ H(est[N(v)])` for every vertex, which
//! (with the upper-bound invariant) forces `est == coreness` — the same
//! argument as the Index2core paradigm, distributed across shards.
//!
//! The number of exchange rounds and refreshed boundary values is reported
//! per flush ([`MergeStats`]) and measured by `benches/shard_scaling.rs`.
//!
//! # Epochs
//!
//! The sharded index publishes *global* epochs exactly like a single
//! [`CoreIndex`]: epoch 0 is the initial decomposition, one epoch per
//! non-empty flush. Readers grab the published [`CoreSnapshot`] (or the
//! per-shard [`ShardView`]s) and never block on writers. Per-shard
//! `CoreIndex` epochs advance independently (one per flush that touched
//! the shard) and are what [`super::snapshot`] ships to replicas.

use super::partition::{hash_owner, partition, PartitionStrategy};
use crate::core::hindex::{hindex_capped, HindexScratch};
use crate::core::maintenance::EdgeEdit;
use crate::core::Hybrid;
use crate::graph::{CsrGraph, GraphBuilder, VertexId};
use crate::service::batch::{coalesce, BatchConfig};
use crate::service::index::{CoreIndex, CoreSnapshot};
use crate::util::timer::Timer;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// What one boundary-refinement (merge) pass did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MergeStats {
    /// Global exchange rounds until the fixpoint.
    pub rounds: usize,
    /// Shard-local sweep passes (a shard sweeps only when dirty).
    pub sweeps: usize,
    /// Ghost-copy refreshes that actually changed a value.
    pub boundary_updates: u64,
}

/// One shard's published slice of the merged decomposition.
#[derive(Clone, Debug)]
pub struct ShardView {
    pub shard: usize,
    /// The shard-local `CoreIndex` epoch this view was built from.
    pub epoch: u64,
    /// Owned vertices (global ids).
    pub owned: Vec<VertexId>,
    /// Refined *global* coreness, aligned with `owned`.
    pub core: Vec<u32>,
    /// Max refined coreness among owned vertices.
    pub k_max: u32,
}

/// Immutable published state: the merged global snapshot plus the
/// per-shard views the router fans out over.
struct Published {
    global: Arc<CoreSnapshot>,
    views: Vec<Arc<ShardView>>,
    owner: Arc<Vec<u32>>,
    /// `slot[v]` = index of `v` inside its owner's view.
    slot: Vec<u32>,
    merge: MergeStats,
    boundary_edges: u64,
}

/// Writer-side state of one shard.
struct Shard {
    id: usize,
    index: Arc<CoreIndex>,
    /// local id → global id.
    globals: Vec<VertexId>,
    /// global id → local id.
    locals: HashMap<VertexId, u32>,
    /// Local ids owned by this shard.
    owned_locals: Vec<u32>,
}

impl Shard {
    /// Local id of `v`, registering it as a new local (ghost or owned —
    /// the caller maintains `owned_locals`) if unseen.
    fn local_id(&mut self, v: VertexId) -> u32 {
        if let Some(&l) = self.locals.get(&v) {
            return l;
        }
        let l = self.globals.len() as u32;
        self.globals.push(v);
        self.locals.insert(v, l);
        l
    }
}

struct WriterState {
    owner: Vec<u32>,
    shards: Vec<Shard>,
}

/// Everything one refinement pass computes.
struct RefineResult {
    /// Exact global coreness, indexed by global vertex id.
    core: Vec<u32>,
    stats: MergeStats,
    num_edges: u64,
    boundary_edges: u64,
}

/// What one sharded flush did (the sharded analog of
/// [`crate::service::BatchOutcome`], plus merge accounting).
#[derive(Clone, Debug)]
pub struct ShardedOutcome {
    /// Merged global snapshot published by this flush.
    pub snapshot: Arc<CoreSnapshot>,
    pub submitted: usize,
    pub applied: usize,
    pub coalesced: usize,
    /// Edits that changed the global edge set (boundary edits counted
    /// once, by the owner of their lower endpoint).
    pub changed: usize,
    /// Shards whose batch took the full-recompute fallback.
    pub recomputed_shards: usize,
    pub merge: MergeStats,
    /// Time inside the boundary refinement (the merge overhead).
    pub merge_elapsed: Duration,
    pub elapsed: Duration,
}

impl ShardedOutcome {
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed.as_secs_f64() * 1e3
    }

    pub fn merge_ms(&self) -> f64 {
        self.merge_elapsed.as_secs_f64() * 1e3
    }
}

/// A partitioned, epoch-versioned core index with exact merged answers.
pub struct ShardedIndex {
    name: String,
    strategy: PartitionStrategy,
    num_shards: usize,
    cfg: BatchConfig,
    state: Mutex<WriterState>,
    published: RwLock<Arc<Published>>,
    epoch: AtomicU64,
    /// Per-epoch assembled-global-CSR cache (structure queries).
    graph_cache: Mutex<Option<(u64, Arc<CsrGraph>)>>,
    pending: Mutex<Vec<EdgeEdit>>,
    /// Serialises whole flushes (same contract as `EditQueue`).
    flush_lock: Mutex<()>,
}

impl ShardedIndex {
    /// Partition `g`, build one `CoreIndex` per shard, refine, and publish
    /// the merged decomposition as epoch 0.
    pub fn new(
        name: impl Into<String>,
        g: &CsrGraph,
        num_shards: usize,
        strategy: PartitionStrategy,
        cfg: BatchConfig,
    ) -> Self {
        let name = name.into();
        let num_shards = num_shards.max(1);
        let plan = partition(g, num_shards, strategy);
        let mut shards = Vec::with_capacity(num_shards);
        for p in plan.shards {
            let mut globals = p.owned.clone();
            globals.extend_from_slice(&p.ghosts);
            let locals: HashMap<VertexId, u32> = globals
                .iter()
                .enumerate()
                .map(|(i, &v)| (v, i as u32))
                .collect();
            let owned_locals: Vec<u32> = (0..p.owned.len() as u32).collect();
            shards.push(Shard {
                id: p.id,
                index: Arc::new(CoreIndex::new(format!("{name}/shard{}", p.id), &p.subgraph)),
                globals,
                locals,
                owned_locals,
            });
        }
        let state = WriterState {
            owner: plan.owner,
            shards,
        };
        let refined = Self::refine(&state);
        let published = Self::build_published(&state, 0, refined);
        Self {
            name,
            strategy,
            num_shards,
            cfg,
            state: Mutex::new(state),
            published: RwLock::new(Arc::new(published)),
            epoch: AtomicU64::new(0),
            graph_cache: Mutex::new(None),
            pending: Mutex::new(Vec::new()),
            flush_lock: Mutex::new(()),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn num_shards(&self) -> usize {
        self.num_shards
    }

    pub fn strategy(&self) -> PartitionStrategy {
        self.strategy
    }

    /// Last published global epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// The merged global snapshot — identical in shape and content to a
    /// single `CoreIndex`'s snapshot over the same graph.
    pub fn snapshot(&self) -> Arc<CoreSnapshot> {
        self.published.read().unwrap().global.clone()
    }

    fn published(&self) -> Arc<Published> {
        self.published.read().unwrap().clone()
    }

    /// Routed point query: owner shard's view answers.
    pub fn coreness(&self, v: VertexId) -> Option<u32> {
        let p = self.published();
        let owner = *p.owner.get(v as usize)? as usize;
        let i = p.slot[v as usize] as usize;
        Some(p.views[owner].core[i])
    }

    /// Fan-out + merge: per-shard k-core members, merged into the global
    /// ascending membership list.
    pub fn kcore_members(&self, k: u32) -> Vec<VertexId> {
        let p = self.published();
        let mut out: Vec<VertexId> = Vec::new();
        for view in &p.views {
            out.extend(
                view.owned
                    .iter()
                    .zip(&view.core)
                    .filter(|&(_, &c)| c >= k)
                    .map(|(&v, _)| v),
            );
        }
        out.sort_unstable();
        out
    }

    /// Fan-out + merge: |k-core| as the sum of per-shard partial counts.
    pub fn kcore_size(&self, k: u32) -> usize {
        let p = self.published();
        p.views
            .iter()
            .map(|view| view.core.iter().filter(|&&c| c >= k).count())
            .sum()
    }

    /// Fan-out + merge: per-shard histograms summed cell-wise.
    pub fn histogram(&self) -> Vec<u64> {
        let p = self.published();
        let mut hist = vec![0u64; p.global.k_max as usize + 1];
        for view in &p.views {
            for &c in &view.core {
                hist[c as usize] += 1;
            }
        }
        hist
    }

    /// Fan-out + merge: global degeneracy = max per-shard refined k_max.
    pub fn degeneracy(&self) -> u32 {
        let p = self.published();
        p.views.iter().map(|v| v.k_max).max().unwrap_or(0)
    }

    /// Merge accounting of the refinement that produced the current epoch.
    pub fn merge_stats(&self) -> MergeStats {
        self.published.read().unwrap().merge
    }

    /// Distinct global boundary edges at the current epoch.
    pub fn boundary_edges(&self) -> u64 {
        self.published.read().unwrap().boundary_edges
    }

    /// Per-shard published views (router inputs).
    pub fn shard_views(&self) -> Vec<Arc<ShardView>> {
        self.published.read().unwrap().views.clone()
    }

    /// Shard-local `CoreIndex` epochs at the current published state.
    pub fn shard_epochs(&self) -> Vec<u64> {
        self.published
            .read()
            .unwrap()
            .views
            .iter()
            .map(|v| v.epoch)
            .collect()
    }

    /// A shard's own epoch-versioned index — what snapshot shipping
    /// serialises for replicas.
    pub fn shard_index(&self, shard: usize) -> Option<Arc<CoreIndex>> {
        self.state
            .lock()
            .unwrap()
            .shards
            .get(shard)
            .map(|s| s.index.clone())
    }

    /// Enqueue one edit; returns the pending count after the push.
    pub fn submit(&self, e: EdgeEdit) -> usize {
        let mut p = self.pending.lock().unwrap();
        p.push(e);
        p.len()
    }

    pub fn pending(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// Drain pending edits, route them to their owner shards, apply each
    /// shard's batch through the incremental-vs-recompute pipeline, then
    /// refine boundary estimates and publish one merged epoch.
    pub fn flush(&self) -> ShardedOutcome {
        let _in_flight = self.flush_lock.lock().unwrap();
        let edits: Vec<EdgeEdit> = std::mem::take(&mut *self.pending.lock().unwrap());
        if edits.is_empty() {
            return ShardedOutcome {
                snapshot: self.snapshot(),
                submitted: 0,
                applied: 0,
                coalesced: 0,
                changed: 0,
                recomputed_shards: 0,
                merge: MergeStats::default(),
                merge_elapsed: Duration::ZERO,
                elapsed: Duration::ZERO,
            };
        }
        let timer = Timer::start();
        let batch = coalesce(&edits);
        let applied = batch.len();
        let mut state = self.state.lock().unwrap();

        // 1. Grow the global vertex set exactly like a single index does
        //    (`ensure_vertex(max endpoint)`: intermediate ids exist too).
        let mut new_n = state.owner.len();
        for e in &batch {
            let (_, hi) = e.endpoints();
            new_n = new_n.max(hi as usize + 1);
        }
        let mut touched = vec![false; state.shards.len()];
        for v in state.owner.len()..new_n {
            let s = hash_owner(v as VertexId, self.num_shards);
            state.owner.push(s);
            let shard = &mut state.shards[s as usize];
            let l = shard.local_id(v as VertexId);
            shard.owned_locals.push(l);
            touched[s as usize] = true;
        }

        // 2. Route each edit to its endpoint-owner shard(s), translating
        //    to local ids. The owner of the lower endpoint is "primary"
        //    and accounts for the edit's `changed` bit.
        let mut per_shard: Vec<Vec<(EdgeEdit, bool)>> = vec![Vec::new(); state.shards.len()];
        for &e in &batch {
            let (u, v) = e.endpoints();
            let a = state.owner[u as usize] as usize;
            let b = state.owner[v as usize] as usize;
            for &(s, primary) in &[(a, true), (b, false)] {
                if !primary && s == a {
                    continue; // shard-internal edit: dispatch once
                }
                let shard = &mut state.shards[s];
                let lu = shard.local_id(u);
                let lv = shard.local_id(v);
                let local = match e {
                    EdgeEdit::Insert(_, _) => EdgeEdit::Insert(lu, lv),
                    EdgeEdit::Delete(_, _) => EdgeEdit::Delete(lu, lv),
                };
                per_shard[s].push((local, primary));
                touched[s] = true;
            }
        }

        // 3. Apply per-shard batches (one shard epoch per touched shard).
        let mut changed = 0usize;
        let mut recomputed_shards = 0usize;
        for (s, shard_edits) in per_shard.iter().enumerate() {
            if !touched[s] {
                continue;
            }
            let (c, recomputed) = Self::apply_to_shard(&state.shards[s], shard_edits, &self.cfg);
            changed += c;
            if recomputed {
                recomputed_shards += 1;
            }
        }

        // 4. Merge: boundary refinement, then publish the new epoch.
        let merge_timer = Timer::start();
        let refined = Self::refine(&state);
        let merge_elapsed = merge_timer.elapsed();
        let merge = refined.stats;
        let epoch = self.epoch.load(Ordering::SeqCst) + 1;
        let published = Self::build_published(&state, epoch, refined);
        let snapshot = published.global.clone();
        *self.published.write().unwrap() = Arc::new(published);
        self.epoch.store(epoch, Ordering::SeqCst);

        ShardedOutcome {
            snapshot,
            submitted: edits.len(),
            applied,
            coalesced: edits.len() - applied,
            changed,
            recomputed_shards,
            merge,
            merge_elapsed,
            elapsed: timer.elapsed(),
        }
    }

    /// One shard's batch: grow the local vertex set, then either per-edit
    /// incremental maintenance or structural edits + full recompute — the
    /// same crossover policy as `service::batch::apply_batch`.
    fn apply_to_shard(
        shard: &Shard,
        edits: &[(EdgeEdit, bool)],
        cfg: &BatchConfig,
    ) -> (usize, bool) {
        let last_local = shard.globals.len().checked_sub(1).map(|l| l as u32);
        let ((changed, recomputed), _snap) = shard.index.update(|dc| {
            if let Some(last) = last_local {
                dc.ensure_vertex(last);
            }
            let threshold = cfg.recompute_threshold(dc.num_edges());
            let mut changed = 0usize;
            if !edits.is_empty() && edits.len() >= threshold {
                for &(e, primary) in edits {
                    let did = match e {
                        EdgeEdit::Insert(u, v) => dc.insert_edge_structural(u, v),
                        EdgeEdit::Delete(u, v) => dc.delete_edge_structural(u, v),
                    };
                    if did && primary {
                        changed += 1;
                    }
                }
                dc.recompute_with(&Hybrid::default(), cfg.threads);
                (changed, true)
            } else {
                for &(e, primary) in edits {
                    if dc.apply(e) && primary {
                        changed += 1;
                    }
                }
                (changed, false)
            }
        });
        (changed, recomputed)
    }

    /// The distributed h-index fixpoint over all shards (see module docs).
    fn refine(state: &WriterState) -> RefineResult {
        let n = state.owner.len();
        let num_shards = state.shards.len();
        let graphs: Vec<Arc<CsrGraph>> = state.shards.iter().map(|s| s.index.graph()).collect();

        // Per-shard ghost lists + edge accounting in one setup pass.
        let mut ghost_locals: Vec<Vec<u32>> = Vec::with_capacity(num_shards);
        let mut internal_arcs = 0u64;
        let mut boundary_arcs = 0u64;
        for (shard, g) in state.shards.iter().zip(&graphs) {
            let sid = shard.id as u32;
            let ghosts: Vec<u32> = (0..g.num_vertices() as u32)
                .filter(|&l| state.owner[shard.globals[l as usize] as usize] != sid)
                .collect();
            let is_ghost: Vec<bool> = {
                let mut m = vec![false; g.num_vertices()];
                for &l in &ghosts {
                    m[l as usize] = true;
                }
                m
            };
            for &l in &shard.owned_locals {
                for &w in g.neighbors(l) {
                    if is_ghost[w as usize] {
                        boundary_arcs += 1;
                    } else {
                        internal_arcs += 1;
                    }
                }
            }
            ghost_locals.push(ghosts);
        }

        // Estimates: owned vertices start at their (global == local)
        // degree; ghost copies are overwritten from the mailbox before the
        // first sweep. The mailbox holds every vertex's current estimate
        // per its owner.
        let mut est: Vec<Vec<u32>> = graphs
            .iter()
            .map(|g| (0..g.num_vertices() as u32).map(|l| g.degree(l)).collect())
            .collect();
        let mut mailbox = vec![0u32; n];
        for (shard, e) in state.shards.iter().zip(&est) {
            for &l in &shard.owned_locals {
                mailbox[shard.globals[l as usize] as usize] = e[l as usize];
            }
        }

        let mut stats = MergeStats::default();
        let mut scratch = HindexScratch::new();
        let mut dirty = vec![true; num_shards];
        loop {
            stats.rounds += 1;
            // Exchange: pull each ghost copy from its owner's estimate.
            for (si, shard) in state.shards.iter().enumerate() {
                let e = &mut est[si];
                for &l in &ghost_locals[si] {
                    let v = shard.globals[l as usize];
                    let nv = mailbox[v as usize];
                    if e[l as usize] != nv {
                        e[l as usize] = nv;
                        stats.boundary_updates += 1;
                        dirty[si] = true;
                    }
                }
            }
            // Sweep each dirty shard to its local fixpoint, then publish
            // its owned estimates back into the mailbox.
            let mut any = false;
            for (si, shard) in state.shards.iter().enumerate() {
                if !dirty[si] {
                    continue;
                }
                dirty[si] = false;
                any = true;
                stats.sweeps += 1;
                let g = &graphs[si];
                let e = &mut est[si];
                loop {
                    let mut changed = false;
                    for &l in &shard.owned_locals {
                        let cap = e[l as usize];
                        if cap == 0 {
                            continue;
                        }
                        let h = {
                            let vals = &*e;
                            hindex_capped(
                                g.neighbors(l).iter().map(|&w| vals[w as usize]),
                                cap,
                                &mut scratch,
                            )
                        };
                        if h < cap {
                            e[l as usize] = h;
                            changed = true;
                        }
                    }
                    if !changed {
                        break;
                    }
                }
                for &l in &shard.owned_locals {
                    mailbox[shard.globals[l as usize] as usize] = e[l as usize];
                }
            }
            if !any {
                break;
            }
        }

        RefineResult {
            core: mailbox,
            stats,
            num_edges: (internal_arcs + boundary_arcs) / 2,
            boundary_edges: boundary_arcs / 2,
        }
    }

    /// Assemble the published read-side state for `epoch`.
    fn build_published(state: &WriterState, epoch: u64, refined: RefineResult) -> Published {
        let RefineResult {
            core,
            stats,
            num_edges,
            boundary_edges,
        } = refined;
        let k_max = core.iter().copied().max().unwrap_or(0);
        let mut slot = vec![0u32; core.len()];
        let mut views = Vec::with_capacity(state.shards.len());
        for shard in &state.shards {
            let owned: Vec<VertexId> = shard
                .owned_locals
                .iter()
                .map(|&l| shard.globals[l as usize])
                .collect();
            let vcore: Vec<u32> = owned.iter().map(|&v| core[v as usize]).collect();
            for (i, &v) in owned.iter().enumerate() {
                slot[v as usize] = i as u32;
            }
            views.push(Arc::new(ShardView {
                shard: shard.id,
                epoch: shard.index.epoch(),
                k_max: vcore.iter().copied().max().unwrap_or(0),
                owned,
                core: vcore,
            }));
        }
        Published {
            global: Arc::new(CoreSnapshot {
                epoch,
                core,
                k_max,
                num_edges,
            }),
            views,
            owner: Arc::new(state.owner.clone()),
            slot,
            merge: stats,
            boundary_edges,
        }
    }

    /// Assembled global CSR at the current epoch (per-epoch cached). Like
    /// `CoreIndex::graph`, this is the one heavyweight read: it serialises
    /// with writers.
    pub fn graph(&self) -> Arc<CsrGraph> {
        let state = self.state.lock().unwrap();
        self.graph_locked(&state)
    }

    fn graph_locked(&self, state: &WriterState) -> Arc<CsrGraph> {
        let epoch = self.epoch.load(Ordering::SeqCst);
        let mut cache = self.graph_cache.lock().unwrap();
        if let Some((e, g)) = cache.as_ref() {
            if *e == epoch {
                return g.clone();
            }
        }
        let g = Arc::new(Self::assemble_global(state, &self.name));
        *cache = Some((epoch, g.clone()));
        g
    }

    /// A mutually consistent (merged snapshot, assembled graph) pair.
    pub fn consistent_view(&self) -> (Arc<CoreSnapshot>, Arc<CsrGraph>) {
        let state = self.state.lock().unwrap();
        let g = self.graph_locked(&state);
        (self.published.read().unwrap().global.clone(), g)
    }

    /// Union of shard subgraphs mapped back to global ids. Boundary edges
    /// exist in two shards; the builder's dedup collapses them.
    fn assemble_global(state: &WriterState, name: &str) -> CsrGraph {
        let mut b = GraphBuilder::new(state.owner.len());
        for shard in &state.shards {
            let g = shard.index.graph();
            for &l in &shard.owned_locals {
                let gu = shard.globals[l as usize];
                for &w in g.neighbors(l) {
                    b.add_edge(gu, shard.globals[w as usize]);
                }
            }
        }
        b.build(name)
    }
}

impl std::fmt::Debug for ShardedIndex {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        write!(
            f,
            "ShardedIndex({} x{} [{}] @ epoch {}: |V|={}, |E|={}, k_max={})",
            self.name,
            self.num_shards,
            self.strategy.name(),
            s.epoch,
            s.num_vertices(),
            s.num_edges,
            s.k_max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::examples;

    fn cfg() -> BatchConfig {
        BatchConfig {
            threads: 1,
            ..BatchConfig::default()
        }
    }

    #[test]
    fn merged_snapshot_matches_single_index_on_g1() {
        let g = examples::g1();
        let single = CoreIndex::new("single", &g);
        for shards in [1, 2, 3, 4, 8] {
            for strategy in [PartitionStrategy::Hash, PartitionStrategy::DegreeRange] {
                let sh = ShardedIndex::new("g1", &g, shards, strategy, cfg());
                let a = sh.snapshot();
                let b = single.snapshot();
                assert_eq!(a.core, b.core, "{shards} shards, {}", strategy.name());
                assert_eq!(a.num_edges, b.num_edges);
                assert_eq!(a.k_max, b.k_max);
                assert_eq!(a.epoch, 0);
            }
        }
    }

    #[test]
    fn routed_queries_agree_with_snapshot() {
        let g = crate::graph::gen::barabasi_albert(200, 3, 9);
        let sh = ShardedIndex::new("ba", &g, 4, PartitionStrategy::Hash, cfg());
        let s = sh.snapshot();
        for v in 0..g.num_vertices() as u32 {
            assert_eq!(sh.coreness(v), s.coreness(v));
        }
        assert_eq!(sh.coreness(g.num_vertices() as u32), None);
        assert_eq!(sh.degeneracy(), s.degeneracy());
        assert_eq!(sh.histogram(), s.histogram());
        for k in 0..=s.k_max {
            assert_eq!(sh.kcore_members(k), s.kcore_members(k));
            assert_eq!(sh.kcore_size(k), s.kcore_size(k));
        }
    }

    #[test]
    fn edits_flow_through_shards_and_stay_exact() {
        let g = examples::g1();
        let sh = ShardedIndex::new("g1", &g, 3, PartitionStrategy::Hash, cfg());
        sh.submit(EdgeEdit::Insert(2, 5));
        sh.submit(EdgeEdit::Insert(2, 5)); // coalesces away
        assert_eq!(sh.pending(), 2);
        let out = sh.flush();
        assert_eq!(out.submitted, 2);
        assert_eq!(out.applied, 1);
        assert_eq!(out.coalesced, 1);
        assert_eq!(out.changed, 1);
        assert_eq!(out.snapshot.epoch, 1);
        assert_eq!(sh.epoch(), 1);
        let (snap, graph) = sh.consistent_view();
        assert_eq!(snap.core, bz_coreness(&graph));
        assert_eq!(snap.k_max, 3);
        // empty flush publishes nothing
        let out = sh.flush();
        assert_eq!(out.submitted, 0);
        assert_eq!(sh.epoch(), 1);
    }

    #[test]
    fn edits_grow_the_vertex_set_like_a_single_index() {
        let g = examples::g1();
        let sh = ShardedIndex::new("g1", &g, 4, PartitionStrategy::Hash, cfg());
        sh.submit(EdgeEdit::Insert(5, 9));
        let out = sh.flush();
        assert_eq!(out.snapshot.num_vertices(), 10);
        assert_eq!(out.snapshot.core[9], 1);
        assert_eq!(out.snapshot.core[7], 0); // intermediate isolated id
        assert_eq!(sh.coreness(7), Some(0));
        let (snap, graph) = sh.consistent_view();
        assert_eq!(graph.num_vertices(), 10);
        assert_eq!(snap.core, bz_coreness(&graph));
    }

    #[test]
    fn boundary_deletion_cascades_across_shards() {
        // complete(6) split across shards: delete edges until the core
        // collapses; refined answers must track the BZ oracle throughout.
        let g = examples::complete(6);
        let sh = ShardedIndex::new("k6", &g, 3, PartitionStrategy::DegreeRange, cfg());
        assert_eq!(sh.snapshot().k_max, 5);
        let deletes = [(0u32, 1u32), (2, 3), (4, 5), (0, 2)];
        for (i, &(u, v)) in deletes.iter().enumerate() {
            sh.submit(EdgeEdit::Delete(u, v));
            let out = sh.flush();
            assert_eq!(out.snapshot.epoch, i as u64 + 1);
            let (snap, graph) = sh.consistent_view();
            assert_eq!(snap.core, bz_coreness(&graph), "after delete ({u},{v})");
        }
    }

    #[test]
    fn merge_stats_are_reported() {
        let g = crate::graph::gen::erdos_renyi(150, 450, 5);
        let sh = ShardedIndex::new("er", &g, 4, PartitionStrategy::Hash, cfg());
        let m = sh.merge_stats();
        assert!(m.rounds >= 1);
        assert!(m.sweeps >= 4, "every shard sweeps at least once");
        assert!(sh.boundary_edges() > 0, "hash partition of ER must cut edges");
        assert_eq!(sh.shard_epochs(), vec![0, 0, 0, 0]);
    }
}
