//! The sharded core index: one epoch-versioned [`CoreIndex`] per shard
//! behind the [`ShardBackend`] interface, a router that fans queries out
//! and merges per-shard answers, and the boundary refinement that makes
//! the merged coreness *exact*.
//!
//! # Why merged answers are exact
//!
//! A shard's local coreness (what its own [`CoreIndex`] maintains) is only
//! a lower bound on global coreness — ghost vertices under-report their
//! degree. The merge therefore runs the distributed h-index fixpoint
//! (Montresor et al., the streaming/partitioned k-core line of work): every
//! owned vertex starts from a *global upper bound* (its degree on a cold
//! pass; its previous exact coreness plus the batch's insert count on a
//! warm pass — each inserted edge raises any coreness by at most one),
//! each shard sweeps `est[v] ← min(est[v], H(est[N(v)]))` to a local
//! fixpoint, and the router exchanges boundary-vertex estimates between
//! rounds ([`crate::shard::router::refine`]; dirty shards sweep
//! concurrently on the batch thread pool). Estimates are always upper
//! bounds and only decrease, so the iteration terminates; at the global
//! fixpoint `est[v] ≤ H(est[N(v)])` for every vertex, which (with the
//! upper-bound invariant) forces `est == coreness` — the same argument as
//! the Index2core paradigm, distributed across shards.
//!
//! The number of exchange rounds and refreshed boundary values is reported
//! per flush ([`MergeStats`]) and measured by `benches/shard_scaling.rs`.
//!
//! # Epochs
//!
//! The sharded index publishes *global* epochs exactly like a single
//! [`CoreIndex`]: epoch 0 is the initial decomposition, one epoch per
//! non-empty flush. Readers grab the published [`CoreSnapshot`] (or the
//! per-shard [`ShardView`]s) and never block on writers. Per-shard
//! `CoreIndex` epochs advance independently (one per flush that touched
//! the shard) and are what [`super::snapshot`] ships to replicas.

use super::backend::{LocalShard, ShardBackend};
use super::partition::{partition, PartitionStrategy};
use super::router::{members_merged, refine, refine_traced, route, MergeStats, RefineOutcome};
use crate::core::maintenance::EdgeEdit;
use crate::graph::{CsrGraph, GraphBuilder, VertexId};
use crate::obs::{self, FlushStages, FlushTrace, Span};
use crate::service::batch::{coalesce, BatchConfig};
use crate::service::index::{CoreIndex, CoreSnapshot};
use crate::util::timer::Timer;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// One shard's published slice of the merged decomposition.
#[derive(Clone, Debug)]
pub struct ShardView {
    pub shard: usize,
    /// The shard-local `CoreIndex` epoch this view was built from.
    pub epoch: u64,
    /// Owned vertices (global ids).
    pub owned: Vec<VertexId>,
    /// Refined *global* coreness, aligned with `owned`.
    pub core: Vec<u32>,
    /// Max refined coreness among owned vertices.
    pub k_max: u32,
}

/// Immutable published state: the merged global snapshot plus the
/// per-shard views the router fans out over.
struct Published {
    global: Arc<CoreSnapshot>,
    views: Vec<Arc<ShardView>>,
    owner: Arc<Vec<u32>>,
    /// `slot[v]` = index of `v` inside its owner's view.
    slot: Vec<u32>,
    merge: MergeStats,
    boundary_edges: u64,
}

/// What one sharded flush did (the sharded analog of
/// [`crate::service::BatchOutcome`], plus merge accounting).
#[derive(Clone, Debug)]
pub struct ShardedOutcome {
    /// Merged global snapshot published by this flush.
    pub snapshot: Arc<CoreSnapshot>,
    pub submitted: usize,
    pub applied: usize,
    pub coalesced: usize,
    /// Edits that changed the global edge set (boundary edits counted
    /// once, by the owner of their lower endpoint).
    pub changed: usize,
    /// Shards whose batch took the full-recompute fallback.
    pub recomputed_shards: usize,
    pub merge: MergeStats,
    /// Time inside the boundary refinement (the merge overhead).
    pub merge_elapsed: Duration,
    pub elapsed: Duration,
}

impl ShardedOutcome {
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed.as_secs_f64() * 1e3
    }

    pub fn merge_ms(&self) -> f64 {
        self.merge_elapsed.as_secs_f64() * 1e3
    }
}

/// A partitioned, epoch-versioned core index with exact merged answers.
/// All shards are in-process [`LocalShard`]s; the multi-host variant
/// with the same merge is [`crate::cluster::ClusterIndex`].
pub struct ShardedIndex {
    name: String,
    strategy: PartitionStrategy,
    num_shards: usize,
    cfg: BatchConfig,
    shards: Vec<Arc<LocalShard>>,
    backends: Vec<Arc<dyn ShardBackend>>,
    /// `owner[v]` = shard owning global vertex `v` (grown per flush).
    owner: Mutex<Vec<u32>>,
    published: RwLock<Arc<Published>>,
    epoch: AtomicU64,
    /// Per-epoch assembled-global-CSR cache (structure queries).
    graph_cache: Mutex<Option<(u64, Arc<CsrGraph>)>>,
    pending: Mutex<Vec<EdgeEdit>>,
    /// When the oldest pending edit arrived (the flush's queue-wait
    /// stage, like `EditQueue`'s).
    queued_since: Mutex<Option<Instant>>,
    /// Serialises whole flushes (same contract as `EditQueue`).
    flush_lock: Mutex<()>,
}

impl ShardedIndex {
    /// Partition `g`, build one `CoreIndex` per shard, refine, and publish
    /// the merged decomposition as epoch 0.
    pub fn new(
        name: impl Into<String>,
        g: &CsrGraph,
        num_shards: usize,
        strategy: PartitionStrategy,
        cfg: BatchConfig,
    ) -> Self {
        let name = name.into();
        let num_shards = num_shards.max(1);
        let plan = partition(g, num_shards, strategy);
        let shards: Vec<Arc<LocalShard>> = plan
            .shards
            .iter()
            .map(|p| Arc::new(LocalShard::from_plan(&name, p, cfg.clone())))
            .collect();
        let backends: Vec<Arc<dyn ShardBackend>> = shards
            .iter()
            .map(|s| s.clone() as Arc<dyn ShardBackend>)
            .collect();
        let refined = refine(&backends, plan.owner.len(), None, 0, cfg.threads)
            .expect("local refinement cannot fail");
        let published = Self::build_published(&plan.owner, &shards, 0, refined);
        Self {
            name,
            strategy,
            num_shards,
            cfg,
            shards,
            backends,
            owner: Mutex::new(plan.owner),
            published: RwLock::new(Arc::new(published)),
            epoch: AtomicU64::new(0),
            graph_cache: Mutex::new(None),
            pending: Mutex::new(Vec::new()),
            queued_since: Mutex::new(None),
            flush_lock: Mutex::new(()),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn num_shards(&self) -> usize {
        self.num_shards
    }

    pub fn strategy(&self) -> PartitionStrategy {
        self.strategy
    }

    /// Last published global epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// The merged global snapshot — identical in shape and content to a
    /// single `CoreIndex`'s snapshot over the same graph.
    pub fn snapshot(&self) -> Arc<CoreSnapshot> {
        self.published.read().unwrap().global.clone()
    }

    fn published(&self) -> Arc<Published> {
        self.published.read().unwrap().clone()
    }

    /// Routed point query: owner shard's view answers.
    pub fn coreness(&self, v: VertexId) -> Option<u32> {
        let p = self.published();
        let owner = *p.owner.get(v as usize)? as usize;
        let i = p.slot[v as usize] as usize;
        Some(p.views[owner].core[i])
    }

    /// Fan-out + merge through the router's per-shard members primitive
    /// ([`members_merged`]): each shard lists its owned members from
    /// committed refined state — no decomposition runs anywhere. Falls
    /// back to the published views should a backend read fail (local
    /// shards never do).
    pub fn kcore_members(&self, k: u32) -> Vec<VertexId> {
        if let Ok((members, _)) = members_merged(&self.backends, k) {
            return members;
        }
        let p = self.published();
        let mut out: Vec<VertexId> = Vec::new();
        for view in &p.views {
            out.extend(
                view.owned
                    .iter()
                    .zip(&view.core)
                    .filter(|&(_, &c)| c >= k)
                    .map(|(&v, _)| v),
            );
        }
        out.sort_unstable();
        out
    }

    /// Fan-out + merge: |k-core| as the sum of per-shard partial counts.
    pub fn kcore_size(&self, k: u32) -> usize {
        let p = self.published();
        p.views
            .iter()
            .map(|view| view.core.iter().filter(|&&c| c >= k).count())
            .sum()
    }

    /// Fan-out + merge: per-shard histograms summed cell-wise.
    pub fn histogram(&self) -> Vec<u64> {
        let p = self.published();
        let mut hist = vec![0u64; p.global.k_max as usize + 1];
        for view in &p.views {
            for &c in &view.core {
                hist[c as usize] += 1;
            }
        }
        hist
    }

    /// Fan-out + merge: global degeneracy = max per-shard refined k_max.
    pub fn degeneracy(&self) -> u32 {
        let p = self.published();
        p.views.iter().map(|v| v.k_max).max().unwrap_or(0)
    }

    /// Merge accounting of the refinement that produced the current epoch.
    pub fn merge_stats(&self) -> MergeStats {
        self.published.read().unwrap().merge
    }

    /// Distinct global boundary edges at the current epoch.
    pub fn boundary_edges(&self) -> u64 {
        self.published.read().unwrap().boundary_edges
    }

    /// Per-shard published views (router inputs).
    pub fn shard_views(&self) -> Vec<Arc<ShardView>> {
        self.published.read().unwrap().views.clone()
    }

    /// Shard-local `CoreIndex` epochs at the current published state.
    pub fn shard_epochs(&self) -> Vec<u64> {
        self.published
            .read()
            .unwrap()
            .views
            .iter()
            .map(|v| v.epoch)
            .collect()
    }

    /// A shard's own epoch-versioned index — what snapshot shipping
    /// serialises for replicas.
    pub fn shard_index(&self, shard: usize) -> Option<Arc<CoreIndex>> {
        self.shards.get(shard).map(|s| s.index())
    }

    /// The shard backends (trait view) — what the router refines over.
    pub fn shard_backends(&self) -> &[Arc<dyn ShardBackend>] {
        &self.backends
    }

    /// Enqueue one edit; returns the pending count after the push.
    pub fn submit(&self, e: EdgeEdit) -> usize {
        let mut p = self.pending.lock().unwrap();
        if p.is_empty() {
            *self.queued_since.lock().unwrap() = Some(Instant::now());
        }
        p.push(e);
        p.len()
    }

    pub fn pending(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// Drain pending edits, route them to their owner shards, apply each
    /// shard's batch through the incremental-vs-recompute pipeline, then
    /// refine boundary estimates (warm-started from the previous epoch)
    /// and publish one merged epoch. Each stage lands in the graph's
    /// `pico_flush_*` histograms and the whole flush in the trace ring
    /// (`TRACES`).
    pub fn flush(&self) -> ShardedOutcome {
        let _in_flight = self.flush_lock.lock().unwrap();
        let (edits, queued_at) = {
            let mut p = self.pending.lock().unwrap();
            let edits: Vec<EdgeEdit> = std::mem::take(&mut *p);
            (edits, self.queued_since.lock().unwrap().take())
        };
        if edits.is_empty() {
            return ShardedOutcome {
                snapshot: self.snapshot(),
                submitted: 0,
                applied: 0,
                coalesced: 0,
                changed: 0,
                recomputed_shards: 0,
                merge: MergeStats::default(),
                merge_elapsed: Duration::ZERO,
                elapsed: Duration::ZERO,
            };
        }
        let ft = FlushTrace::new(obs::next_trace_id());
        let queue_wait = queued_at.map(|t| t.elapsed()).unwrap_or(Duration::ZERO);
        if let Some(t) = queued_at {
            // started before the trace: the offset saturates to 0, which
            // is exactly where the queue-wait stage belongs
            ft.stage("queue", t, queue_wait);
        }
        let timer = Timer::start();
        let batch = coalesce(&edits);
        let applied = batch.len();
        let mut owner = self.owner.lock().unwrap();
        let route_start = Instant::now();
        let plan = route(&mut owner, self.num_shards, &batch);
        let route_elapsed = route_start.elapsed();
        ft.stage("route", route_start, route_elapsed);
        let apply_start = Instant::now();
        let mut changed = 0usize;
        let mut recomputed_shards = 0usize;
        for (s, backend) in self.backends.iter().enumerate() {
            if !plan.touched[s] {
                continue;
            }
            let shard_start = Instant::now();
            let out = backend
                .apply(&plan.per_shard[s])
                .expect("local shard apply cannot fail");
            ft.child(
                "apply",
                Span {
                    name: format!("apply shard={s}"),
                    start_us: shard_start.saturating_duration_since(ft.t0()).as_micros() as u64,
                    dur_us: shard_start.elapsed().as_micros() as u64,
                    remote: None,
                    children: Vec::new(),
                },
            );
            changed += out.changed;
            if out.recomputed {
                recomputed_shards += 1;
            }
        }
        let apply_elapsed = apply_start.elapsed();
        ft.stage("apply", apply_start, apply_elapsed);
        let epoch = self.epoch.load(Ordering::SeqCst) + 1;
        let merge_timer = Timer::start();
        let refined = refine_traced(
            &self.backends,
            owner.len(),
            Some(plan.inserts),
            epoch,
            self.cfg.threads,
            Some(&ft),
        )
        .expect("local refinement cannot fail");
        let merge_elapsed = merge_timer.elapsed();
        let merge = refined.stats;
        let (refine_elapsed, commit_elapsed) = (refined.refine_elapsed, refined.commit_elapsed);
        let publish_start = Instant::now();
        let published = Self::build_published(&owner, &self.shards, epoch, refined);
        let snapshot = published.global.clone();
        *self.published.write().unwrap() = Arc::new(published);
        self.epoch.store(epoch, Ordering::SeqCst);
        let publish_elapsed = publish_start.elapsed();
        ft.stage("publish", publish_start, publish_elapsed);

        let elapsed = timer.elapsed();
        obs::record_flush_stages(
            &self.name,
            &FlushStages {
                queue: queue_wait,
                route: route_elapsed,
                apply: apply_elapsed,
                refine: refine_elapsed,
                commit: commit_elapsed,
                publish: publish_elapsed,
                total: queue_wait + elapsed,
                refine_rounds: merge.rounds as u64,
                boundary_updates: merge.boundary_updates,
                boundary_bytes: merge.boundary_bytes,
                epoch,
            },
        );
        obs::record_trace(ft.finish("flush", &self.name));

        ShardedOutcome {
            snapshot,
            submitted: edits.len(),
            applied,
            coalesced: edits.len() - applied,
            changed,
            recomputed_shards,
            merge,
            merge_elapsed,
            elapsed,
        }
    }

    /// Assemble the published read-side state for `epoch`.
    fn build_published(
        owner: &[u32],
        shards: &[Arc<LocalShard>],
        epoch: u64,
        refined: RefineOutcome,
    ) -> Published {
        let RefineOutcome {
            core,
            stats,
            num_edges,
            boundary_edges,
            // in-process shards have no replicas to journal for
            diffs: _,
            // stage timings were pulled out by the caller already
            refine_elapsed: _,
            commit_elapsed: _,
        } = refined;
        let k_max = core.iter().copied().max().unwrap_or(0);
        // per-shard owned lists in ascending global order — the same
        // order the shards themselves registered them in
        let mut owned_lists: Vec<Vec<VertexId>> = vec![Vec::new(); shards.len()];
        let mut slot = vec![0u32; core.len()];
        for (v, &s) in owner.iter().enumerate() {
            let list = &mut owned_lists[s as usize];
            slot[v] = list.len() as u32;
            list.push(v as VertexId);
        }
        let views: Vec<Arc<ShardView>> = shards
            .iter()
            .enumerate()
            .map(|(s, shard)| {
                let owned = std::mem::take(&mut owned_lists[s]);
                let vcore: Vec<u32> = owned.iter().map(|&v| core[v as usize]).collect();
                Arc::new(ShardView {
                    shard: s,
                    epoch: shard.index().epoch(),
                    k_max: vcore.iter().copied().max().unwrap_or(0),
                    owned,
                    core: vcore,
                })
            })
            .collect();
        Published {
            global: Arc::new(CoreSnapshot {
                epoch,
                core,
                k_max,
                num_edges,
            }),
            views,
            owner: Arc::new(owner.to_vec()),
            slot,
            merge: stats,
            boundary_edges,
        }
    }

    /// Assembled global CSR at the current epoch (per-epoch cached). Like
    /// `CoreIndex::graph`, this is the one heavyweight read: it serialises
    /// with writers.
    pub fn graph(&self) -> Arc<CsrGraph> {
        let owner = self.owner.lock().unwrap();
        self.graph_locked(owner.len())
    }

    fn graph_locked(&self, n: usize) -> Arc<CsrGraph> {
        let epoch = self.epoch.load(Ordering::SeqCst);
        let mut cache = self.graph_cache.lock().unwrap();
        if let Some((e, g)) = cache.as_ref() {
            if *e == epoch {
                return g.clone();
            }
        }
        let g = Arc::new(self.assemble_global(n));
        *cache = Some((epoch, g.clone()));
        g
    }

    /// A mutually consistent (merged snapshot, assembled graph) pair.
    pub fn consistent_view(&self) -> (Arc<CoreSnapshot>, Arc<CsrGraph>) {
        let owner = self.owner.lock().unwrap();
        let g = self.graph_locked(owner.len());
        (self.published.read().unwrap().global.clone(), g)
    }

    /// Union of shard subgraphs mapped back to global ids. Boundary edges
    /// exist in two shards; the builder's dedup collapses them.
    fn assemble_global(&self, n: usize) -> CsrGraph {
        let mut b = GraphBuilder::new(n);
        for shard in &self.shards {
            for (u, v) in shard.owned_edges() {
                b.add_edge(u, v);
            }
        }
        b.build(self.name.as_str())
    }
}

impl std::fmt::Debug for ShardedIndex {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        write!(
            f,
            "ShardedIndex({} x{} [{}] @ epoch {}: |V|={}, |E|={}, k_max={})",
            self.name,
            self.num_shards,
            self.strategy.name(),
            s.epoch,
            s.num_vertices(),
            s.num_edges,
            s.k_max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::examples;

    fn cfg() -> BatchConfig {
        BatchConfig {
            threads: 1,
            ..BatchConfig::default()
        }
    }

    #[test]
    fn merged_snapshot_matches_single_index_on_g1() {
        let g = examples::g1();
        let single = CoreIndex::new("single", &g);
        for shards in [1, 2, 3, 4, 8] {
            for strategy in [PartitionStrategy::Hash, PartitionStrategy::DegreeRange] {
                let sh = ShardedIndex::new("g1", &g, shards, strategy, cfg());
                let a = sh.snapshot();
                let b = single.snapshot();
                assert_eq!(a.core, b.core, "{shards} shards, {}", strategy.name());
                assert_eq!(a.num_edges, b.num_edges);
                assert_eq!(a.k_max, b.k_max);
                assert_eq!(a.epoch, 0);
            }
        }
    }

    #[test]
    fn routed_queries_agree_with_snapshot() {
        let g = crate::graph::gen::barabasi_albert(200, 3, 9);
        let sh = ShardedIndex::new("ba", &g, 4, PartitionStrategy::Hash, cfg());
        let s = sh.snapshot();
        for v in 0..g.num_vertices() as u32 {
            assert_eq!(sh.coreness(v), s.coreness(v));
        }
        assert_eq!(sh.coreness(g.num_vertices() as u32), None);
        assert_eq!(sh.degeneracy(), s.degeneracy());
        assert_eq!(sh.histogram(), s.histogram());
        for k in 0..=s.k_max {
            assert_eq!(sh.kcore_members(k), s.kcore_members(k));
            assert_eq!(sh.kcore_size(k), s.kcore_size(k));
        }
    }

    #[test]
    fn edits_flow_through_shards_and_stay_exact() {
        let g = examples::g1();
        let sh = ShardedIndex::new("g1", &g, 3, PartitionStrategy::Hash, cfg());
        sh.submit(EdgeEdit::Insert(2, 5));
        sh.submit(EdgeEdit::Insert(2, 5)); // coalesces away
        assert_eq!(sh.pending(), 2);
        let out = sh.flush();
        assert_eq!(out.submitted, 2);
        assert_eq!(out.applied, 1);
        assert_eq!(out.coalesced, 1);
        assert_eq!(out.changed, 1);
        assert_eq!(out.snapshot.epoch, 1);
        assert_eq!(sh.epoch(), 1);
        let (snap, graph) = sh.consistent_view();
        assert_eq!(snap.core, bz_coreness(&graph));
        assert_eq!(snap.k_max, 3);
        // empty flush publishes nothing
        let out = sh.flush();
        assert_eq!(out.submitted, 0);
        assert_eq!(sh.epoch(), 1);
    }

    #[test]
    fn edits_grow_the_vertex_set_like_a_single_index() {
        let g = examples::g1();
        let sh = ShardedIndex::new("g1", &g, 4, PartitionStrategy::Hash, cfg());
        sh.submit(EdgeEdit::Insert(5, 9));
        let out = sh.flush();
        assert_eq!(out.snapshot.num_vertices(), 10);
        assert_eq!(out.snapshot.core[9], 1);
        assert_eq!(out.snapshot.core[7], 0); // intermediate isolated id
        assert_eq!(sh.coreness(7), Some(0));
        let (snap, graph) = sh.consistent_view();
        assert_eq!(graph.num_vertices(), 10);
        assert_eq!(snap.core, bz_coreness(&graph));
    }

    #[test]
    fn boundary_deletion_cascades_across_shards() {
        // complete(6) split across shards: delete edges until the core
        // collapses; refined answers must track the BZ oracle throughout.
        let g = examples::complete(6);
        let sh = ShardedIndex::new("k6", &g, 3, PartitionStrategy::DegreeRange, cfg());
        assert_eq!(sh.snapshot().k_max, 5);
        let deletes = [(0u32, 1u32), (2, 3), (4, 5), (0, 2)];
        for (i, &(u, v)) in deletes.iter().enumerate() {
            sh.submit(EdgeEdit::Delete(u, v));
            let out = sh.flush();
            assert_eq!(out.snapshot.epoch, i as u64 + 1);
            let (snap, graph) = sh.consistent_view();
            assert_eq!(snap.core, bz_coreness(&graph), "after delete ({u},{v})");
        }
    }

    #[test]
    fn merge_stats_are_reported() {
        let g = crate::graph::gen::erdos_renyi(150, 450, 5);
        let sh = ShardedIndex::new("er", &g, 4, PartitionStrategy::Hash, cfg());
        let m = sh.merge_stats();
        assert!(m.rounds >= 1);
        assert!(m.sweeps >= 4, "every shard sweeps at least once");
        assert!(sh.boundary_edges() > 0, "hash partition of ER must cut edges");
        assert_eq!(sh.shard_epochs(), vec![0, 0, 0, 0]);
    }

    #[test]
    fn flush_records_stage_trace_and_histograms() {
        // unique graph name: the trace ring and registry are process-wide
        // and other tests in this binary flush concurrently
        let g = examples::g1();
        let sh = ShardedIndex::new("sharded-trace-test", &g, 2, PartitionStrategy::Hash, cfg());
        sh.submit(EdgeEdit::Insert(2, 5));
        sh.flush();
        let t = obs::recent_traces(usize::MAX)
            .into_iter()
            .find(|t| t.graph == "sharded-trace-test")
            .expect("flush trace recorded");
        assert_eq!(t.kind, "flush");
        let stages: Vec<&str> = t.spans.iter().map(|s| s.name.as_str()).collect();
        for want in ["queue", "route", "apply", "refine", "commit", "publish"] {
            assert!(stages.contains(&want), "missing stage {want} in {stages:?}");
        }
        let snap = obs::global().snapshot();
        assert!(snap.iter().any(|s| {
            s.name == obs::names::FLUSH_REFINE_SECONDS
                && s.labels
                    .iter()
                    .any(|(k, v)| k == "graph" && v == "sharded-trace-test")
        }));
    }

    #[test]
    fn warm_started_flushes_stay_exact_and_cheaper() {
        // a long run of small batches: every flush after the first is
        // warm-started; answers must track the oracle and the warm merge
        // should not sweep more than a vertex-count-bounded cold pass
        let g = crate::graph::gen::barabasi_albert(150, 3, 21);
        let sh = ShardedIndex::new("ba", &g, 4, PartitionStrategy::Hash, cfg());
        let mut rng = crate::util::rng::Rng::new(99);
        for _ in 0..6 {
            for _ in 0..4 {
                let u = rng.below(150) as u32;
                let v = rng.below(150) as u32;
                if u != v {
                    sh.submit(if rng.chance(0.5) {
                        EdgeEdit::Insert(u, v)
                    } else {
                        EdgeEdit::Delete(u, v)
                    });
                }
            }
            let out = sh.flush();
            if out.submitted == 0 {
                continue;
            }
            let (snap, graph) = sh.consistent_view();
            assert_eq!(snap.core, bz_coreness(&graph));
            assert!(out.merge.rounds >= 1);
        }
    }
}
