//! Every metric name this crate registers, as constants — one place to
//! rename, and the anchor for the CI lint that keeps the metric-name
//! reference in [`crate::obs`]'s module docs complete (each `pico_*`
//! constant here must appear, backticked, in that table).

// --- counters -----------------------------------------------------------

/// Queries answered by the serving layer, per graph.
pub const SERVE_QUERIES: &str = "pico_serve_queries_total";
/// Edits accepted into a pending batch, per graph.
pub const SERVE_EDITS: &str = "pico_serve_edits_total";
/// Flushed batches, per graph.
pub const SERVE_BATCHES: &str = "pico_serve_batches_total";
/// Batches that took the full-recompute path, per graph.
pub const SERVE_RECOMPUTES: &str = "pico_serve_recomputes_total";
/// Ghost-copy refreshes that changed a value during boundary refinement.
pub const REFINE_BOUNDARY_UPDATES: &str = "pico_refine_boundary_updates_total";
/// Bytes exchanged (both directions) by the boundary-refinement rounds.
pub const REFINE_BOUNDARY_BYTES: &str = "pico_refine_boundary_bytes_total";
/// Delta chains shipped to lagging replicas, per shard.
pub const SYNC_DELTAS: &str = "pico_sync_deltas_total";
/// Full-manifest snapshots shipped to replicas, per shard.
pub const SYNC_SNAPSHOTS: &str = "pico_sync_snapshots_total";
/// Bytes shipped over the delta catch-up path, per shard.
pub const SYNC_DELTA_BYTES: &str = "pico_sync_delta_bytes_total";
/// Bytes shipped over the full-manifest catch-up path, per shard.
pub const SYNC_SNAPSHOT_BYTES: &str = "pico_sync_snapshot_bytes_total";
/// Connections accepted by the transport pool.
pub const NET_ACCEPTED: &str = "pico_net_accepted_total";
/// Connections refused over the connection cap.
pub const NET_REJECTED: &str = "pico_net_rejected_total";
/// Requests cut off mid-read by the slow-loris stall timeout.
pub const NET_TIMED_OUT: &str = "pico_net_timed_out_total";
/// Connections cut off because the peer stopped draining staged
/// replies for a full stall window (write-side slow-loris).
pub const NET_WRITE_STALLED: &str = "pico_net_write_stalled_total";
/// Idle connections reclaimed while the pool sat at its cap.
pub const NET_RECLAIMED: &str = "pico_net_reclaimed_total";
/// Queries slower than the slow-query threshold, per graph.
pub const SLOW_QUERIES: &str = "pico_slow_queries_total";
/// Structured journal events emitted, per severity.
pub const EVENTS_TOTAL: &str = "pico_events_total";
/// Bytes shipped to a migration target (manifest + catch-up chains).
pub const MIGRATE_SHIPPED_BYTES: &str = "pico_migrate_shipped_bytes_total";
/// Completed rebalance moves, per kind (split/merge/migrate).
pub const REBALANCE_MOVES: &str = "pico_rebalance_moves_total";
/// Registry snapshots taken by the tsdb sampler thread.
pub const SAMPLER_SAMPLES: &str = "pico_sampler_samples_total";

// --- gauges -------------------------------------------------------------

/// Live connections right now.
pub const NET_ACTIVE: &str = "pico_net_active";
/// Connections parked on the run queue right now.
pub const NET_QUEUED: &str = "pico_net_queued";
/// Worker threads in the transport pool.
pub const NET_WORKERS: &str = "pico_net_workers";
/// The hard connection cap.
pub const NET_CONN_CAP: &str = "pico_net_conn_cap";
/// Epochs a replica trails the committed head, per shard.
pub const SYNC_LAG_EPOCHS: &str = "pico_sync_lag_epochs";
/// Replicas the last sync pass failed to catch up, per graph.
pub const SYNC_FAILED_REPLICAS: &str = "pico_sync_failed_replicas";
/// The published epoch of a hosted graph.
pub const GRAPH_EPOCH: &str = "pico_graph_epoch";
/// Seconds since the registry (process) started.
pub const UPTIME_SECONDS: &str = "pico_uptime_seconds";

// --- histograms ---------------------------------------------------------

/// Query latency through the serving layer, per graph.
pub const QUERY_SECONDS: &str = "pico_query_seconds";
/// Queue wait: first pending submit until its flush started, per graph.
pub const FLUSH_QUEUE_SECONDS: &str = "pico_flush_queue_seconds";
/// Routing (owner-map growth + per-shard dispatch), per graph.
pub const FLUSH_ROUTE_SECONDS: &str = "pico_flush_route_seconds";
/// Per-shard apply of the routed batches, per graph.
pub const FLUSH_APPLY_SECONDS: &str = "pico_flush_apply_seconds";
/// The whole boundary-refinement exchange loop, per graph.
pub const FLUSH_REFINE_SECONDS: &str = "pico_flush_refine_seconds";
/// The per-shard refine commits, per graph.
pub const FLUSH_COMMIT_SECONDS: &str = "pico_flush_commit_seconds";
/// Snapshot assembly + epoch publish, per graph.
pub const FLUSH_PUBLISH_SECONDS: &str = "pico_flush_publish_seconds";
/// End-to-end flush latency, per graph.
pub const FLUSH_TOTAL_SECONDS: &str = "pico_flush_total_seconds";
/// Exchange rounds per refinement pass, per graph (a count, not time).
pub const FLUSH_REFINE_ROUNDS: &str = "pico_flush_refine_rounds";
/// Host-side `SHARDAPPLY` handler latency, per graph.
pub const SHARD_APPLY_SECONDS: &str = "pico_shard_apply_seconds";
/// Host-side `SHARDREFINE START|ROUND` handler latency, per graph.
pub const SHARD_REFINE_ROUND_SECONDS: &str = "pico_shard_refine_round_seconds";
/// Host-side `SHARDREFINE COMMIT` handler latency, per graph.
pub const SHARD_COMMIT_SECONDS: &str = "pico_shard_commit_seconds";
/// Unfenced migration catch-up (manifest ship + delta chains), per shard.
pub const MIGRATE_CATCHUP_SECONDS: &str = "pico_migrate_catchup_seconds";
/// The fenced migration cutover pause writers observe, per shard.
pub const MIGRATE_CUTOVER_SECONDS: &str = "pico_migrate_cutover_seconds";
