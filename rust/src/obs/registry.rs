//! The metrics registry: named counters, gauges, and histograms with
//! `{label="value"}` label sets, handed out as cheap `Arc` handles so
//! hot paths pay one atomic op per event — never a map lookup.
//!
//! One process-wide registry ([`global`]) absorbs what used to be three
//! disconnected telemetry islands (engine serve slots, transport
//! counters, replica-sync stats); tests that need isolation construct
//! their own [`Registry`].

use super::hist::{HistSnapshot, Histogram};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// A monotonically increasing counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Mirror an externally maintained monotonic total — for publishing
    /// pre-existing atomics (the transport counters) at scrape time
    /// without double-counting.
    pub fn set_total(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins instantaneous value.
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// One registered metric (the registry's storage side).
#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// A point-in-time value in a registry [`Series`].
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Counter(u64),
    Gauge(u64),
    Histogram(HistSnapshot),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Counter(_) => "counter",
            Value::Gauge(_) => "gauge",
            Value::Histogram(_) => "histogram",
        }
    }
}

/// One exported series: a metric name, its label set, and its value.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: Value,
}

struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    metric: Metric,
}

/// The registry proper. Series are keyed by `name{labels}` and kept in
/// a `BTreeMap`, so expositions come out in one deterministic order.
pub struct Registry {
    start: Instant,
    series: Mutex<BTreeMap<String, Entry>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
            series: Mutex::new(BTreeMap::new()),
        }
    }

    /// Seconds since this registry was created (process start for the
    /// global one) — the exposition's `pico_uptime_seconds`.
    pub fn uptime_seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn key(name: &str, labels: &[(&str, &str)]) -> String {
        let mut k = String::with_capacity(name.len() + 16 * labels.len());
        k.push_str(name);
        k.push('{');
        for (i, (lk, lv)) in labels.iter().enumerate() {
            if i > 0 {
                k.push(',');
            }
            k.push_str(lk);
            k.push('=');
            k.push_str(lv);
        }
        k.push('}');
        k
    }

    fn get_or_insert(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Metric,
    ) -> Metric {
        let mut map = self.series.lock().unwrap();
        let entry = map.entry(Self::key(name, labels)).or_insert_with(|| Entry {
            name: name.to_string(),
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            metric: make(),
        });
        entry.metric.clone()
    }

    /// The counter `name{labels}`, created on first use. Re-registering
    /// an existing series with a different kind is a programmer error.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.get_or_insert(name, labels, || Metric::Counter(Arc::new(Counter::default()))) {
            Metric::Counter(c) => c,
            _ => panic!("metric {name} already registered with a different kind"),
        }
    }

    /// The gauge `name{labels}`, created on first use.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.get_or_insert(name, labels, || Metric::Gauge(Arc::new(Gauge::default()))) {
            Metric::Gauge(g) => g,
            _ => panic!("metric {name} already registered with a different kind"),
        }
    }

    /// The histogram `name{labels}`, created on first use.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        let make = || Metric::Histogram(Arc::new(Histogram::default()));
        match self.get_or_insert(name, labels, make) {
            Metric::Histogram(h) => h,
            _ => panic!("metric {name} already registered with a different kind"),
        }
    }

    /// Snapshot every registered series (histograms atomically — see
    /// [`Histogram::snapshot`]), in deterministic key order.
    pub fn snapshot(&self) -> Vec<Series> {
        let map = self.series.lock().unwrap();
        map.values()
            .map(|e| Series {
                name: e.name.clone(),
                labels: e.labels.clone(),
                value: match &e.metric {
                    Metric::Counter(c) => Value::Counter(c.get()),
                    Metric::Gauge(g) => Value::Gauge(g.get()),
                    Metric::Histogram(h) => Value::Histogram(h.snapshot()),
                },
            })
            .collect()
    }
}

/// The process-wide registry every subsystem records into.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_shared_and_series_sorted() {
        let r = Registry::new();
        let a = r.counter("pico_test_total", &[("graph", "g1")]);
        let b = r.counter("pico_test_total", &[("graph", "g1")]);
        a.add(2);
        b.inc();
        assert_eq!(a.get(), 3, "same series, same atomic");
        r.counter("pico_test_total", &[("graph", "g0")]).inc();
        r.gauge("pico_test_gauge", &[]).set(7);
        r.histogram("pico_test_seconds", &[]).record(5);
        let snap = r.snapshot();
        assert_eq!(snap.len(), 4);
        // BTreeMap order: g0 before g1, gauge/histogram names sorted
        assert_eq!(snap[0].labels, vec![("graph".to_string(), "g0".to_string())]);
        assert_eq!(snap[0].value, Value::Counter(1));
        assert_eq!(snap[1].value, Value::Counter(3));
        match &snap[3].value {
            Value::Histogram(h) => assert_eq!(h.count(), 1),
            v => panic!("expected histogram, got {v:?}"),
        }
        assert!(r.uptime_seconds() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        r.counter("pico_x_total", &[]);
        r.gauge("pico_x_total", &[]);
    }
}
