//! Log-bucketed histograms: fixed power-of-two buckets over
//! microseconds (or raw counts), lock-free recording, mergeable
//! snapshots, and conservative quantile readouts.
//!
//! Recording is three relaxed atomic adds plus one release bump of an
//! operation counter; [`Histogram::snapshot`] uses that counter as an
//! optimistic concurrency check so a scan that raced a writer is
//! retried instead of returning a torn `sum`/`buckets` pair.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Bucket `i < NUM_BUCKETS - 1` counts samples with value `<= 2^i`;
/// the last bucket is `+Inf`. `2^25` µs ≈ 33.5 s, so every realistic
/// flush or query latency lands in a finite bucket.
pub const NUM_BUCKETS: usize = 27;

/// Upper bound of bucket `i`; `u64::MAX` encodes `+Inf`.
pub fn bucket_bound(i: usize) -> u64 {
    if i >= NUM_BUCKETS - 1 {
        u64::MAX
    } else {
        1u64 << i
    }
}

fn bucket_index(v: u64) -> usize {
    // first i with 2^i >= v (v=0 and v=1 both land in bucket 0)
    let idx = (64 - v.saturating_sub(1).leading_zeros()) as usize;
    idx.min(NUM_BUCKETS - 1)
}

/// A concurrently-recordable histogram. Values are unitless `u64`s;
/// the `_seconds` series record microseconds and the exposition layer
/// converts bounds on the way out.
pub struct Histogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    sum: AtomicU64,
    /// Bumped (release) after every record; snapshot readers verify it
    /// did not move across their scan (acquire) and retry if it did.
    ops: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            ops: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.ops.fetch_add(1, Ordering::Release);
    }

    /// Record a duration in microseconds.
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// An atomic snapshot: retried while writers race the scan, so the
    /// returned `sum` and `buckets` belong to one consistent prefix of
    /// the recorded samples (no torn reads).
    pub fn snapshot(&self) -> HistSnapshot {
        for _ in 0..64 {
            let before = self.ops.load(Ordering::Acquire);
            let buckets = std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed));
            let sum = self.sum.load(Ordering::Relaxed);
            if self.ops.load(Ordering::Acquire) == before {
                return HistSnapshot { buckets, sum };
            }
        }
        // writers never went quiet; return the last scan (still a valid
        // lower bound on every cell) rather than spinning forever
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of a [`Histogram`], mergeable across shards and
/// hosts by bucket-wise addition.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; NUM_BUCKETS],
    pub sum: u64,
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Bucket-wise merge — associative and commutative, so snapshots
    /// from any number of hosts combine in any order.
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.sum += other.sum;
    }

    /// Conservative quantile: the upper bound of the bucket holding the
    /// rank-`ceil(p·n)` sample. Never below the true quantile and less
    /// than 2x above it (for samples in the finite buckets).
    pub fn quantile(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((p * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return if i == NUM_BUCKETS - 1 {
                    // +Inf bucket: the sum bounds any single sample
                    self.sum
                } else {
                    bucket_bound(i)
                };
            }
        }
        self.sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucketing_is_monotone_and_capped() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
        for i in 0..NUM_BUCKETS - 1 {
            assert_eq!(bucket_index(bucket_bound(i)), i, "bound lands in its own bucket");
        }
    }

    #[test]
    fn quantiles_bound_the_sorted_vector_oracle() {
        let mut rng = Rng::new(42);
        for round in 0..20 {
            let h = Histogram::default();
            let n = 1 + rng.below(400) as usize;
            let mut vals: Vec<u64> = (0..n).map(|_| rng.below(1 << 20)).collect();
            for &v in &vals {
                h.record(v);
            }
            vals.sort_unstable();
            let snap = h.snapshot();
            assert_eq!(snap.count(), n as u64);
            assert_eq!(snap.sum, vals.iter().sum::<u64>());
            for p in [0.5, 0.9, 0.99] {
                let rank = ((p * n as f64).ceil() as usize).clamp(1, n);
                let oracle = vals[rank - 1];
                let got = snap.quantile(p);
                assert!(got >= oracle, "round {round} p{p}: {got} < oracle {oracle}");
                assert!(
                    got <= oracle.saturating_mul(2).max(1),
                    "round {round} p{p}: {got} > 2x oracle {oracle}"
                );
            }
        }
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mut rng = Rng::new(7);
        let snaps: Vec<HistSnapshot> = (0..3)
            .map(|_| {
                let h = Histogram::default();
                for _ in 0..rng.below(100) {
                    h.record(rng.below(1 << 24));
                }
                h.snapshot()
            })
            .collect();
        let (a, b, c) = (&snaps[0], &snaps[1], &snaps[2]);
        // (a + b) + c
        let mut left = a.clone();
        left.merge(b);
        left.merge(c);
        // a + (b + c)
        let mut bc = b.clone();
        bc.merge(c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right, "associativity");
        // b + a == a + b
        let mut ab = a.clone();
        ab.merge(b);
        let mut ba = b.clone();
        ba.merge(a);
        assert_eq!(ab, ba, "commutativity");
        assert_eq!(left.count(), a.count() + b.count() + c.count());
    }

    #[test]
    fn snapshots_are_not_torn_under_concurrent_recording() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        // every sample is exactly 1000, so any internally-consistent
        // snapshot must satisfy sum == 1000 * count — a torn read of
        // sum vs buckets breaks the equality
        let h = Arc::new(Histogram::default());
        let stop = Arc::new(AtomicBool::new(false));
        let writers: Vec<_> = (0..3)
            .map(|_| {
                let h = h.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        h.record(1000);
                    }
                })
            })
            .collect();
        for _ in 0..200 {
            let s = h.snapshot();
            assert_eq!(s.sum, 1000 * s.count(), "torn snapshot");
        }
        stop.store(true, Ordering::Relaxed);
        for w in writers {
            w.join().unwrap();
        }
    }
}
