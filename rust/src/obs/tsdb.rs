//! A zero-dependency, bounded in-process time-series ring over the
//! metrics registry — the "time dimension" the scrape-only exposition
//! lacks.
//!
//! A [`Sampler`] thread in `pico serve` snapshots
//! [`super::registry::global`] every `--sample-interval` (default 1 s)
//! into a fixed-size ring of [`SAMPLE_RING_CAP`] whole-registry
//! samples (~15 min of history at the default cadence). Windowed
//! queries then answer the questions a single scrape cannot:
//!
//! * [`Tsdb::rate`] — counter increase per second over the last
//!   `window_s` seconds (summed across label sets; optionally pinned
//!   to one label via [`Tsdb::rate_with`]).
//! * [`Tsdb::quantile`] — a histogram quantile *over a window*: the
//!   cumulative snapshot at the window start is subtracted bucket-wise
//!   from the newest one, so the readout reflects only samples
//!   recorded inside the window instead of the whole process lifetime.
//! * [`Tsdb::gauge_max`] — the newest value of a gauge (max across
//!   label sets).
//!
//! Everything is exposed over the wire by the `STATS [window_s]
//! [JSON]` verb (rendered by [`render_window_text`] /
//! [`render_window_json`]) and consumed by `obs/health.rs`'s SLO rules
//! and `pico top`. Storage is bounded by construction: one
//! `VecDeque` of samples, oldest evicted first — no allocation growth
//! over a long-lived serve process beyond the ring itself.

use super::hist::{HistSnapshot, NUM_BUCKETS};
use super::registry::{Series, Value};
use super::names;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

/// Samples the ring retains; at the default 1 s cadence this is ~15
/// minutes of history.
pub const SAMPLE_RING_CAP: usize = 900;

struct Sample {
    /// Seconds since the tsdb was created.
    t_s: f64,
    series: Vec<Series>,
}

struct Inner {
    samples: VecDeque<Sample>,
    cap: usize,
}

/// The bounded sample ring. One process-wide instance ([`global`])
/// backs the `STATS`/`HEALTH` verbs; tests construct their own and
/// drive it deterministically through [`Tsdb::record_at`].
pub struct Tsdb {
    start: Instant,
    inner: Mutex<Inner>,
}

impl Default for Tsdb {
    fn default() -> Self {
        Self::new()
    }
}

impl Tsdb {
    pub fn new() -> Self {
        Self::with_cap(SAMPLE_RING_CAP)
    }

    pub fn with_cap(cap: usize) -> Self {
        Self {
            start: Instant::now(),
            inner: Mutex::new(Inner {
                samples: VecDeque::new(),
                cap: cap.max(2),
            }),
        }
    }

    /// Record a registry snapshot now.
    pub fn record(&self, series: Vec<Series>) {
        self.record_at(self.start.elapsed().as_secs_f64(), series);
    }

    /// Record a snapshot at an explicit timestamp (seconds since the
    /// tsdb's creation) — the deterministic entry point tests use.
    pub fn record_at(&self, t_s: f64, series: Vec<Series>) {
        let mut g = self.inner.lock().unwrap();
        while g.samples.len() >= g.cap {
            g.samples.pop_front();
        }
        g.samples.push_back(Sample { t_s, series });
    }

    /// Samples currently retained.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Seconds of history between the oldest and newest retained sample.
    pub fn retention_s(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        match (g.samples.front(), g.samples.back()) {
            (Some(a), Some(b)) => b.t_s - a.t_s,
            _ => 0.0,
        }
    }

    /// Samples whose timestamp falls inside the trailing window.
    pub fn samples_in(&self, window_s: f64) -> usize {
        let g = self.inner.lock().unwrap();
        let Some(newest) = g.samples.back() else {
            return 0;
        };
        let cutoff = newest.t_s - window_s;
        g.samples.iter().filter(|s| s.t_s >= cutoff).count()
    }

    /// Run `f` over (oldest-in-window, newest) — the endpoints every
    /// windowed query differences. `None` with fewer than two samples
    /// in the window (no rate is computable from one point).
    fn with_window<R>(&self, window_s: f64, f: impl FnOnce(&Sample, &Sample) -> R) -> Option<R> {
        let g = self.inner.lock().unwrap();
        let newest = g.samples.back()?;
        let cutoff = newest.t_s - window_s;
        let oldest = g.samples.iter().find(|s| s.t_s >= cutoff)?;
        if oldest.t_s >= newest.t_s {
            return None;
        }
        Some(f(oldest, newest))
    }

    /// Counter increase per second over the trailing window, summed
    /// across every label set of `name`.
    pub fn rate(&self, name: &str, window_s: f64) -> Option<f64> {
        self.rate_with(name, None, window_s)
    }

    /// Like [`Tsdb::rate`], but only label sets carrying `label` (e.g.
    /// `("severity", "error")`) contribute.
    pub fn rate_with(
        &self,
        name: &str,
        label: Option<(&str, &str)>,
        window_s: f64,
    ) -> Option<f64> {
        let sum = |s: &Sample| -> u64 {
            s.series
                .iter()
                .filter(|sr| sr.name == name && label_matches(sr, label))
                .map(|sr| match &sr.value {
                    Value::Counter(v) => *v,
                    _ => 0,
                })
                .sum()
        };
        self.with_window(window_s, |oldest, newest| {
            let dt = newest.t_s - oldest.t_s;
            sum(newest).saturating_sub(sum(oldest)) as f64 / dt
        })
    }

    /// Histogram quantile over the trailing window: merge `name`'s
    /// snapshots across label sets at both window endpoints, subtract
    /// the older cumulative counts bucket-wise, and read the quantile
    /// of what remains. `None` when no samples were recorded inside
    /// the window (the all-time distribution would be misleading).
    pub fn quantile(&self, name: &str, window_s: f64, p: f64) -> Option<u64> {
        let merged = |s: &Sample| -> HistSnapshot {
            let mut acc = HistSnapshot::default();
            for sr in s.series.iter().filter(|sr| sr.name == name) {
                if let Value::Histogram(h) = &sr.value {
                    acc.merge(h);
                }
            }
            acc
        };
        self.with_window(window_s, |oldest, newest| {
            let newer = merged(newest);
            let older = merged(oldest);
            let mut w = HistSnapshot::default();
            for i in 0..NUM_BUCKETS {
                w.buckets[i] = newer.buckets[i].saturating_sub(older.buckets[i]);
            }
            w.sum = newer.sum.saturating_sub(older.sum);
            if w.count() == 0 {
                None
            } else {
                Some(w.quantile(p))
            }
        })
        .flatten()
    }

    /// The newest sample's value of gauge `name`, max across label sets.
    pub fn gauge_max(&self, name: &str) -> Option<u64> {
        let g = self.inner.lock().unwrap();
        let newest = g.samples.back()?;
        newest
            .series
            .iter()
            .filter(|sr| sr.name == name)
            .filter_map(|sr| match &sr.value {
                Value::Gauge(v) => Some(*v),
                _ => None,
            })
            .max()
    }
}

fn label_matches(sr: &Series, label: Option<(&str, &str)>) -> bool {
    match label {
        None => true,
        Some((k, v)) => sr.labels.iter().any(|(lk, lv)| lk == k && lv == v),
    }
}

/// The process-wide sample ring the sampler records into and the
/// `STATS`/`HEALTH` verbs read from.
pub fn global() -> &'static Tsdb {
    static GLOBAL: OnceLock<Tsdb> = OnceLock::new();
    GLOBAL.get_or_init(Tsdb::new)
}

/// The background sampler: snapshots the global registry into the
/// global tsdb every `interval`. Same lifecycle shape as the replica
/// sync daemon — sliced sleeps so `stop` takes effect within ~10 ms,
/// and `Drop` stops and joins.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    ticks: Arc<AtomicU64>,
    join: Option<thread::JoinHandle<()>>,
}

impl Sampler {
    pub fn spawn(interval: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let ticks = Arc::new(AtomicU64::new(0));
        let (t_stop, t_ticks) = (stop.clone(), ticks.clone());
        let join = thread::spawn(move || {
            let slice = Duration::from_millis(10);
            while !t_stop.load(Ordering::Relaxed) {
                global().record(super::registry::global().snapshot());
                super::registry::global()
                    .counter(names::SAMPLER_SAMPLES, &[])
                    .inc();
                t_ticks.fetch_add(1, Ordering::Relaxed);
                let mut slept = Duration::ZERO;
                while slept < interval && !t_stop.load(Ordering::Relaxed) {
                    let d = slice.min(interval - slept);
                    thread::sleep(d);
                    slept += d;
                }
            }
        });
        Self {
            stop,
            ticks,
            join: Some(join),
        }
    }

    /// Samples taken so far.
    pub fn ticks(&self) -> u64 {
        self.ticks.load(Ordering::Relaxed)
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.stop();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// The derived signals `STATS` reports, in display order. Each entry is
/// `(key, value)`; `None` means the window holds too little data.
pub fn window_stats(ts: &Tsdb, window_s: f64) -> Vec<(&'static str, Option<f64>)> {
    let q99 = |name: &str| ts.quantile(name, window_s, 0.99).map(|v| v as f64);
    let cutoffs = [names::NET_TIMED_OUT, names::NET_WRITE_STALLED, names::NET_REJECTED]
        .iter()
        .filter_map(|n| ts.rate(n, window_s))
        .fold(None, |acc: Option<f64>, r| Some(acc.unwrap_or(0.0) + r));
    vec![
        ("qps", ts.rate(names::SERVE_QUERIES, window_s)),
        ("edits_per_s", ts.rate(names::SERVE_EDITS, window_s)),
        ("flushes_per_s", ts.rate(names::SERVE_BATCHES, window_s)),
        ("query_p99_us", q99(names::QUERY_SECONDS)),
        ("flush_total_p99_us", q99(names::FLUSH_TOTAL_SECONDS)),
        ("flush_apply_p99_us", q99(names::FLUSH_APPLY_SECONDS)),
        ("flush_refine_p99_us", q99(names::FLUSH_REFINE_SECONDS)),
        ("replica_lag_epochs", ts.gauge_max(names::SYNC_LAG_EPOCHS).map(|v| v as f64)),
        ("net_cutoffs_per_s", cutoffs),
        ("slow_queries_per_s", ts.rate(names::SLOW_QUERIES, window_s)),
        (
            "error_events_per_s",
            ts.rate_with(names::EVENTS_TOTAL, Some(("severity", "error")), window_s),
        ),
    ]
}

/// `STATS` text body: one `key value` line per signal; `n/a` where the
/// window holds too little data.
pub fn render_window_text(ts: &Tsdb, window_s: f64) -> Vec<String> {
    window_stats(ts, window_s)
        .into_iter()
        .map(|(k, v)| match v {
            Some(v) => format!("{k} {v:.3}"),
            None => format!("{k} n/a"),
        })
        .collect()
}

/// `STATS … JSON` body: one object, `null` where data is missing.
pub fn render_window_json(ts: &Tsdb, window_s: f64) -> String {
    let mut out = String::from("{");
    out.push_str(&format!(
        "\"window_s\":{:.0},\"samples\":{}",
        window_s,
        ts.samples_in(window_s)
    ));
    for (k, v) in window_stats(ts, window_s) {
        match v {
            Some(v) => out.push_str(&format!(",\"{k}\":{v:.3}")),
            None => out.push_str(&format!(",\"{k}\":null")),
        }
    }
    out.push('}');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::Registry;
    use crate::util::rng::Rng;

    fn counter_sample(reg: &Registry, name: &str, v: u64) -> Vec<Series> {
        reg.counter(name, &[("graph", "g")]).set_total(v);
        reg.snapshot()
    }

    #[test]
    fn ring_wraps_and_keeps_the_newest_window() {
        let ts = Tsdb::with_cap(8);
        for i in 0..20u64 {
            ts.record_at(i as f64, vec![]);
        }
        assert_eq!(ts.len(), 8, "ring stays at cap");
        // oldest retained sample is t=12, newest t=19
        assert!((ts.retention_s() - 7.0).abs() < 1e-9);
        assert_eq!(ts.samples_in(3.0), 4, "t in 16..=19");
        assert_eq!(ts.samples_in(1000.0), 8);
    }

    #[test]
    fn rate_is_increase_over_window() {
        let reg = Registry::new();
        let ts = Tsdb::with_cap(64);
        ts.record_at(0.0, counter_sample(&reg, "pico_serve_queries_total", 100));
        ts.record_at(10.0, counter_sample(&reg, "pico_serve_queries_total", 400));
        // full window: (400 - 100) / 10s
        let r = ts.rate("pico_serve_queries_total", 60.0).unwrap();
        assert!((r - 30.0).abs() < 1e-9, "{r}");
        // one sample in window -> no rate
        assert!(ts.rate("pico_serve_queries_total", 5.0).is_none());
        // unknown series: both endpoints sum to 0 -> rate 0
        assert_eq!(ts.rate("pico_nonexistent_total", 60.0), Some(0.0));
    }

    #[test]
    fn rate_sums_label_sets_and_filters_by_label() {
        let reg = Registry::new();
        let ts = Tsdb::with_cap(64);
        reg.counter("pico_events_total", &[("severity", "error")]).set_total(0);
        reg.counter("pico_events_total", &[("severity", "info")]).set_total(0);
        ts.record_at(0.0, reg.snapshot());
        reg.counter("pico_events_total", &[("severity", "error")]).set_total(5);
        reg.counter("pico_events_total", &[("severity", "info")]).set_total(45);
        ts.record_at(10.0, reg.snapshot());
        let all = ts.rate("pico_events_total", 60.0).unwrap();
        assert!((all - 5.0).abs() < 1e-9, "{all}");
        let err = ts
            .rate_with("pico_events_total", Some(("severity", "error")), 60.0)
            .unwrap();
        assert!((err - 0.5).abs() < 1e-9, "{err}");
    }

    #[test]
    fn windowed_rate_matches_a_random_walk_oracle() {
        // property: for any monotone counter walk and any window, the
        // tsdb rate equals (newest - oldest_in_window) / dt computed
        // directly from the walk
        let mut rng = Rng::new(99);
        for round in 0..10 {
            let reg = Registry::new();
            let ts = Tsdb::with_cap(SAMPLE_RING_CAP);
            let n = 20 + rng.below(40) as usize;
            let mut total = 0u64;
            let mut walk = Vec::new(); // (t, total)
            for i in 0..n {
                total += rng.below(50);
                let t = i as f64;
                walk.push((t, total));
                ts.record_at(t, counter_sample(&reg, "pico_walk_total", total));
            }
            for w in [3.0, 7.0, 1000.0] {
                let newest = *walk.last().unwrap();
                let cutoff = newest.0 - w;
                let oldest = walk.iter().find(|(t, _)| *t >= cutoff).unwrap();
                let got = ts.rate("pico_walk_total", w);
                if oldest.0 >= newest.0 {
                    assert!(got.is_none());
                } else {
                    let want = (newest.1 - oldest.1) as f64 / (newest.0 - oldest.0);
                    let got = got.unwrap();
                    assert!((got - want).abs() < 1e-9, "round {round} w={w}: {got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn windowed_quantile_sees_only_the_window() {
        let reg = Registry::new();
        let ts = Tsdb::with_cap(64);
        let h = reg.histogram("pico_q_seconds", &[("graph", "g")]);
        // before the window: a thousand fast samples
        for _ in 0..1000 {
            h.record(10);
        }
        ts.record_at(0.0, reg.snapshot());
        // inside the window: ten slow ones
        for _ in 0..10 {
            h.record(100_000);
        }
        ts.record_at(30.0, reg.snapshot());
        // all-time p99 would be ~10; the windowed one must see only the
        // slow tail
        let p99 = ts.quantile("pico_q_seconds", 60.0, 0.99).unwrap();
        assert!(p99 >= 100_000, "windowed p99 {p99} must reflect the slow samples");
        // a window covering only the newest sample has no pair to diff
        assert!(ts.quantile("pico_q_seconds", 10.0, 0.99).is_none());
        // nothing recorded between the endpoints -> None, not the
        // all-time distribution
        ts.record_at(40.0, reg.snapshot());
        assert!(ts.quantile("pico_q_seconds", 8.0, 0.99).is_none());
    }

    #[test]
    fn gauge_max_reads_the_newest_sample() {
        let reg = Registry::new();
        let ts = Tsdb::with_cap(64);
        reg.gauge("pico_lag", &[("shard", "0")]).set(9);
        reg.gauge("pico_lag", &[("shard", "1")]).set(2);
        ts.record_at(0.0, reg.snapshot());
        assert_eq!(ts.gauge_max("pico_lag"), Some(9));
        reg.gauge("pico_lag", &[("shard", "0")]).set(1);
        ts.record_at(1.0, reg.snapshot());
        assert_eq!(ts.gauge_max("pico_lag"), Some(2), "newest sample wins");
        assert_eq!(ts.gauge_max("pico_other"), None);
    }

    #[test]
    fn render_text_and_json_cover_every_signal() {
        let ts = Tsdb::with_cap(8);
        let lines = render_window_text(&ts, 60.0);
        assert_eq!(lines.len(), window_stats(&ts, 60.0).len());
        assert!(lines.iter().any(|l| l.starts_with("qps ")));
        assert!(lines.iter().all(|l| l.ends_with("n/a")), "empty tsdb -> all n/a");
        let json = render_window_json(&ts, 60.0);
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"qps\":null"));
        assert!(json.contains("\"samples\":0"));
    }

    #[test]
    fn sampler_records_into_the_global_ring_and_stops() {
        let before = global().len();
        let s = Sampler::spawn(Duration::from_millis(5));
        let deadline = Instant::now() + Duration::from_secs(5);
        while s.ticks() < 3 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(5));
        }
        assert!(s.ticks() >= 3, "sampler must tick");
        assert!(global().len() > before);
        drop(s); // stops and joins
    }
}
