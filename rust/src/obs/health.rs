//! SLO rules evaluated into an opinionated verdict: `ok`, `degraded`,
//! or `critical`, plus the reasons — the machine-readable answer
//! behind the `HEALTH [graph]` verb and the non-zero exit of
//! `pico cluster status --health`.
//!
//! Two kinds of rule feed one verdict:
//!
//! * **Windowed rules** read the sample ring ([`super::tsdb`]): the
//!   flush p99 against its budget, and the burn rate (transport
//!   cutoffs + error-severity events per second). They *skip* when the
//!   ring holds too little data — a process with no sampler (or one
//!   that just started) is not thereby unhealthy.
//! * **Instantaneous rules** read the live registry directly: replica
//!   lag in epochs and the count of replicas failing sync. These need
//!   no history, so `HEALTH` is meaningful even without a sampler, and
//!   they honor the optional graph filter (`HEALTH <graph>` judges one
//!   graph's replication instead of the whole process).
//!
//! Thresholds come from [`SloConfig`]; each has an env override
//! (`PICO_SLO_WINDOW_S`, `PICO_SLO_FLUSH_P99_US`,
//! `PICO_SLO_REPLICA_LAG`, `PICO_SLO_BURN_PER_S`) so a deployment can
//! tighten or loosen the budget without a rebuild.

use super::registry::{Registry, Series, Value};
use super::tsdb::Tsdb;
use super::names;
use std::sync::OnceLock;

/// The verdict, ordered so `max` across rules (and across hosts in
/// `pico cluster status --health`) is the aggregate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Verdict {
    Ok,
    Degraded,
    Critical,
}

impl Verdict {
    pub fn as_str(&self) -> &'static str {
        match self {
            Verdict::Ok => "ok",
            Verdict::Degraded => "degraded",
            Verdict::Critical => "critical",
        }
    }

    pub fn parse(s: &str) -> Option<Verdict> {
        match s {
            "ok" => Some(Verdict::Ok),
            "degraded" => Some(Verdict::Degraded),
            "critical" => Some(Verdict::Critical),
            _ => None,
        }
    }
}

/// The SLO thresholds. `degraded` at the base threshold; `critical`
/// at the stated multiple (p99, burn) or the dedicated bound (lag).
#[derive(Clone, Copy, Debug)]
pub struct SloConfig {
    /// Trailing window the tsdb rules evaluate over.
    pub window_s: f64,
    /// End-to-end flush p99 budget in microseconds; 2x is critical.
    pub flush_p99_budget_us: u64,
    /// Replica lag (epochs behind the committed head) that degrades.
    pub replica_lag_warn: u64,
    /// Replica lag that is critical.
    pub replica_lag_crit: u64,
    /// Cutoffs + error events per second that degrade; 10x is critical.
    pub burn_warn_per_s: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        Self {
            window_s: 60.0,
            flush_p99_budget_us: 250_000,
            replica_lag_warn: 3,
            replica_lag_crit: 10,
            burn_warn_per_s: 0.5,
        }
    }
}

impl SloConfig {
    /// Defaults with env overrides applied; parsed once per process.
    pub fn from_env() -> SloConfig {
        static CFG: OnceLock<SloConfig> = OnceLock::new();
        *CFG.get_or_init(|| {
            let mut c = SloConfig::default();
            if let Some(v) = env_parse::<f64>("PICO_SLO_WINDOW_S") {
                if v > 0.0 {
                    c.window_s = v;
                }
            }
            if let Some(v) = env_parse::<u64>("PICO_SLO_FLUSH_P99_US") {
                c.flush_p99_budget_us = v.max(1);
            }
            if let Some(v) = env_parse::<u64>("PICO_SLO_REPLICA_LAG") {
                c.replica_lag_warn = v.max(1);
                c.replica_lag_crit = c.replica_lag_crit.max(c.replica_lag_warn);
            }
            if let Some(v) = env_parse::<f64>("PICO_SLO_BURN_PER_S") {
                if v > 0.0 {
                    c.burn_warn_per_s = v;
                }
            }
            c
        })
    }
}

fn env_parse<T: std::str::FromStr>(key: &str) -> Option<T> {
    std::env::var(key).ok().and_then(|v| v.parse().ok())
}

/// The evaluated verdict plus one reason line per violated rule.
#[derive(Clone, Debug)]
pub struct HealthReport {
    pub verdict: Verdict,
    pub reasons: Vec<String>,
}

impl HealthReport {
    fn note(&mut self, v: Verdict, reason: String) {
        self.verdict = self.verdict.max(v);
        self.reasons.push(reason);
    }
}

/// Max of a gauge across the label sets of `name`, honoring the graph
/// filter (series without a matching `graph` label are excluded when a
/// filter is given).
fn gauge_max(snap: &[Series], name: &str, graph: Option<&str>) -> Option<u64> {
    snap.iter()
        .filter(|s| s.name == name)
        .filter(|s| match graph {
            None => true,
            Some(g) => s.labels.iter().any(|(k, v)| k == "graph" && v == g),
        })
        .filter_map(|s| match &s.value {
            Value::Gauge(v) => Some(*v),
            _ => None,
        })
        .max()
}

/// Evaluate every SLO rule against a tsdb and a registry. `graph`
/// narrows the instantaneous replication rules to one graph's series.
pub fn evaluate(ts: &Tsdb, reg: &Registry, cfg: &SloConfig, graph: Option<&str>) -> HealthReport {
    let mut rep = HealthReport {
        verdict: Verdict::Ok,
        reasons: Vec::new(),
    };
    let w = cfg.window_s;

    // windowed: flush p99 against its budget (skips without samples)
    if let Some(p99) = ts.quantile(names::FLUSH_TOTAL_SECONDS, w, 0.99) {
        if p99 > cfg.flush_p99_budget_us.saturating_mul(2) {
            rep.note(
                Verdict::Critical,
                format!(
                    "flush p99 {p99}us > 2x budget {}us over {w:.0}s",
                    cfg.flush_p99_budget_us
                ),
            );
        } else if p99 > cfg.flush_p99_budget_us {
            rep.note(
                Verdict::Degraded,
                format!(
                    "flush p99 {p99}us > budget {}us over {w:.0}s",
                    cfg.flush_p99_budget_us
                ),
            );
        }
    }

    // windowed: burn rate = transport cutoffs + error-severity events
    let cutoffs = [names::NET_TIMED_OUT, names::NET_WRITE_STALLED, names::NET_REJECTED]
        .iter()
        .filter_map(|n| ts.rate(n, w))
        .sum::<f64>();
    let errors = ts
        .rate_with(names::EVENTS_TOTAL, Some(("severity", "error")), w)
        .unwrap_or(0.0);
    let burn = cutoffs + errors;
    if ts.samples_in(w) >= 2 && burn >= cfg.burn_warn_per_s {
        let v = if burn >= cfg.burn_warn_per_s * 10.0 {
            Verdict::Critical
        } else {
            Verdict::Degraded
        };
        rep.note(
            v,
            format!(
                "burn rate {burn:.2}/s (cutoffs+errors) >= {:.2}/s over {w:.0}s",
                cfg.burn_warn_per_s
            ),
        );
    }

    // instantaneous: replication, straight from the live registry
    let snap = reg.snapshot();
    if let Some(lag) = gauge_max(&snap, names::SYNC_LAG_EPOCHS, graph) {
        if lag >= cfg.replica_lag_crit {
            rep.note(
                Verdict::Critical,
                format!("replica lag {lag} epochs >= {}", cfg.replica_lag_crit),
            );
        } else if lag >= cfg.replica_lag_warn {
            rep.note(
                Verdict::Degraded,
                format!("replica lag {lag} epochs >= {}", cfg.replica_lag_warn),
            );
        }
    }
    if let Some(failed) = gauge_max(&snap, names::SYNC_FAILED_REPLICAS, graph) {
        if failed > 0 {
            rep.note(
                Verdict::Degraded,
                format!("{failed} replica(s) failing sync"),
            );
        }
    }
    rep
}

/// [`evaluate`] against the process-global tsdb and registry with the
/// env-tuned thresholds — what the `HEALTH` verb serves.
pub fn evaluate_global(graph: Option<&str>) -> HealthReport {
    evaluate(
        super::tsdb::global(),
        super::registry::global(),
        &SloConfig::from_env(),
        graph,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SloConfig {
        SloConfig::default()
    }

    #[test]
    fn empty_process_is_ok() {
        let ts = Tsdb::with_cap(8);
        let reg = Registry::new();
        let rep = evaluate(&ts, &reg, &cfg(), None);
        assert_eq!(rep.verdict, Verdict::Ok);
        assert!(rep.reasons.is_empty());
    }

    #[test]
    fn verdict_orders_and_parses() {
        assert!(Verdict::Ok < Verdict::Degraded);
        assert!(Verdict::Degraded < Verdict::Critical);
        assert_eq!(Verdict::parse("degraded"), Some(Verdict::Degraded));
        assert_eq!(Verdict::parse("meh"), None);
        assert_eq!(Verdict::Critical.as_str(), "critical");
    }

    #[test]
    fn slow_flushes_degrade_then_go_critical() {
        let c = cfg();
        let reg = Registry::new();
        let ts = Tsdb::with_cap(8);
        let h = reg.histogram(names::FLUSH_TOTAL_SECONDS, &[("graph", "g")]);
        ts.record_at(0.0, reg.snapshot());
        for _ in 0..50 {
            h.record(c.flush_p99_budget_us + 10_000);
        }
        ts.record_at(30.0, reg.snapshot());
        let rep = evaluate(&ts, &reg, &c, None);
        assert_eq!(rep.verdict, Verdict::Degraded, "{:?}", rep.reasons);
        assert!(rep.reasons[0].contains("flush p99"));

        let reg2 = Registry::new();
        let ts2 = Tsdb::with_cap(8);
        let h2 = reg2.histogram(names::FLUSH_TOTAL_SECONDS, &[("graph", "g")]);
        ts2.record_at(0.0, reg2.snapshot());
        for _ in 0..50 {
            h2.record(c.flush_p99_budget_us * 8);
        }
        ts2.record_at(30.0, reg2.snapshot());
        assert_eq!(evaluate(&ts2, &reg2, &c, None).verdict, Verdict::Critical);
    }

    #[test]
    fn cutoff_burn_rate_degrades() {
        let reg = Registry::new();
        let ts = Tsdb::with_cap(8);
        let t = reg.counter(names::NET_TIMED_OUT, &[]);
        ts.record_at(0.0, reg.snapshot());
        t.add(60); // 2/s over 30s >= 0.5/s
        ts.record_at(30.0, reg.snapshot());
        let rep = evaluate(&ts, &reg, &cfg(), None);
        assert_eq!(rep.verdict, Verdict::Degraded, "{:?}", rep.reasons);
        assert!(rep.reasons[0].contains("burn rate"));
    }

    #[test]
    fn replica_lag_and_failed_sync_need_no_sampler() {
        let reg = Registry::new();
        let ts = Tsdb::with_cap(8); // empty: windowed rules skip
        let c = cfg();
        reg.gauge(names::SYNC_LAG_EPOCHS, &[("graph", "a"), ("shard", "0")])
            .set(c.replica_lag_warn);
        let rep = evaluate(&ts, &reg, &c, None);
        assert_eq!(rep.verdict, Verdict::Degraded);
        assert!(rep.reasons[0].contains("replica lag"));

        reg.gauge(names::SYNC_LAG_EPOCHS, &[("graph", "a"), ("shard", "0")])
            .set(c.replica_lag_crit);
        assert_eq!(evaluate(&ts, &reg, &c, None).verdict, Verdict::Critical);

        // the graph filter isolates verdicts per graph
        assert_eq!(
            evaluate(&ts, &reg, &c, Some("other")).verdict,
            Verdict::Ok,
            "a filtered graph does not inherit another graph's lag"
        );
        reg.gauge(names::SYNC_FAILED_REPLICAS, &[("graph", "other")]).set(1);
        let rep = evaluate(&ts, &reg, &c, Some("other"));
        assert_eq!(rep.verdict, Verdict::Degraded);
        assert!(rep.reasons[0].contains("failing sync"));
        // and recovery flips it back
        reg.gauge(names::SYNC_FAILED_REPLICAS, &[("graph", "other")]).set(0);
        assert_eq!(evaluate(&ts, &reg, &c, Some("other")).verdict, Verdict::Ok);
    }
}
