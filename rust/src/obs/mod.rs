//! The unified observability layer: one zero-dependency metrics
//! registry (counters, gauges, log-bucketed latency histograms with
//! mergeable snapshots), stage-level span tracing with cross-host
//! stitching, and a scrapeable exposition.
//!
//! Before this layer existed the crate's telemetry was three
//! disconnected islands — `engine::Metrics` worker slots, the transport
//! counters behind `METRICS`, and per-group replica `SyncStats` — with
//! no latency distributions outside the offline benches and no view of
//! *where inside a flush* time went. Now every subsystem records into
//! [`registry::global`]:
//!
//! * [`registry`] — named series with label sets, handed out as `Arc`
//!   handles so the hot path pays one atomic op per event.
//! * [`hist`] — power-of-two-bucketed histograms: lock-free recording,
//!   atomic (non-torn) snapshots, bucket-wise merging, conservative
//!   p50/p90/p99 readouts.
//! * [`trace`] — a trace id per flush (and slow query), stage spans
//!   (queue wait → route → apply → refine rounds → commit → publish),
//!   remote child spans stitched from shard-host replies, and the
//!   bounded ring behind the `TRACES` verb.
//! * [`expo`] — Prometheus-text and JSON renderings (`METRICS PROM`,
//!   `METRICS JSON`), plus the parser/merger behind
//!   `pico cluster status --metrics`.
//! * [`tsdb`] — a bounded in-process sample ring over the registry
//!   with windowed rate/quantile queries; the sampler thread in
//!   `pico serve` feeds it and the `STATS [window_s]` verb reads it.
//! * [`events`] — the severity-tagged structured event journal behind
//!   the `EVENTS [n]` verb and `pico cluster status --events`.
//! * [`health`] — SLO rules over the tsdb and registry, folded into
//!   the `ok|degraded|critical` verdict of the `HEALTH` verb.
//!
//! # Metric-name reference
//!
//! Every exported series, its type, and its labels. CI greps the
//! constants in [`names`] against this table, so a new metric cannot
//! land undocumented.
//!
//! | series | type | labels |
//! |---|---|---|
//! | `pico_serve_queries_total` | counter | `graph` |
//! | `pico_serve_edits_total` | counter | `graph` |
//! | `pico_serve_batches_total` | counter | `graph` |
//! | `pico_serve_recomputes_total` | counter | `graph` |
//! | `pico_refine_boundary_updates_total` | counter | `graph` |
//! | `pico_refine_boundary_bytes_total` | counter | `graph` |
//! | `pico_sync_deltas_total` | counter | `graph`, `shard` |
//! | `pico_sync_snapshots_total` | counter | `graph`, `shard` |
//! | `pico_sync_delta_bytes_total` | counter | `graph`, `shard` |
//! | `pico_sync_snapshot_bytes_total` | counter | `graph`, `shard` |
//! | `pico_net_accepted_total` | counter | — |
//! | `pico_net_rejected_total` | counter | — |
//! | `pico_net_timed_out_total` | counter | — |
//! | `pico_net_write_stalled_total` | counter | — |
//! | `pico_net_reclaimed_total` | counter | — |
//! | `pico_slow_queries_total` | counter | `graph` |
//! | `pico_events_total` | counter | `severity` |
//! | `pico_migrate_shipped_bytes_total` | counter | `graph`, `shard` |
//! | `pico_rebalance_moves_total` | counter | `graph`, `kind` |
//! | `pico_sampler_samples_total` | counter | — |
//! | `pico_net_active` | gauge | — |
//! | `pico_net_queued` | gauge | — |
//! | `pico_net_workers` | gauge | — |
//! | `pico_net_conn_cap` | gauge | — |
//! | `pico_sync_lag_epochs` | gauge | `graph`, `shard` |
//! | `pico_sync_failed_replicas` | gauge | `graph` |
//! | `pico_graph_epoch` | gauge | `graph` |
//! | `pico_uptime_seconds` | gauge | — |
//! | `pico_query_seconds` | histogram | `graph` |
//! | `pico_flush_queue_seconds` | histogram | `graph` |
//! | `pico_flush_route_seconds` | histogram | `graph` |
//! | `pico_flush_apply_seconds` | histogram | `graph` |
//! | `pico_flush_refine_seconds` | histogram | `graph` |
//! | `pico_flush_commit_seconds` | histogram | `graph` |
//! | `pico_flush_publish_seconds` | histogram | `graph` |
//! | `pico_flush_total_seconds` | histogram | `graph` |
//! | `pico_flush_refine_rounds` | histogram | `graph` |
//! | `pico_shard_apply_seconds` | histogram | `graph` |
//! | `pico_shard_refine_round_seconds` | histogram | `graph` |
//! | `pico_shard_commit_seconds` | histogram | `graph` |
//! | `pico_migrate_catchup_seconds` | histogram | `graph`, `shard` |
//! | `pico_migrate_cutover_seconds` | histogram | `graph`, `shard` |
//!
//! `_seconds` histograms record microseconds internally and expose
//! second-denominated buckets; `pico_flush_refine_rounds` is a plain
//! count distribution. Single-backend graphs record `queue`, `apply`,
//! `publish`, and `total` flush stages; sharded and cluster graphs add
//! `route`, `refine`, and `commit`. The `pico_shard_*` histograms are
//! recorded host-side under the shard's hosted graph name (e.g.
//! `soc/shard1`), so a coordinator scrape and a shard-host scrape stay
//! distinguishable after a merge.
//!
//! # Event-kind reference
//!
//! Every kind the structured event journal ([`events`]) emits, with
//! its severity and source. CI greps the constants in
//! [`events::kind`] against this table, so a new event kind cannot
//! land undocumented.
//!
//! | kind | severity | emitted by |
//! |---|---|---|
//! | `replica_failover` | warn | `cluster/index.rs` — a replica read failed, next replica took it |
//! | `sync_full_ship` | warn | `cluster/index.rs` — delta catch-up fell back to a full manifest ship |
//! | `sync_failed` | error | `cluster/index.rs` — a replica could not be synced this pass |
//! | `flush_failed` | error | `cluster/index.rs` — a cluster flush died mid-apply |
//! | `crossover_recompute` | info | `service/batch.rs` — batch crossed the incremental threshold, full recompute |
//! | `refine_round_failed` | error | `shard/router.rs` — a refine round lost a shard backend |
//! | `slow_loris_cutoff` | warn | `net/pool.rs` — request stalled mid-read past the stall timeout |
//! | `write_stall_cutoff` | warn | `net/pool.rs` — peer stopped draining staged replies |
//! | `idle_reclaim` | info | `net/pool.rs` — idle connection reclaimed at the cap |
//! | `conn_rejected` | warn | `net/pool.rs` — accept refused over the connection cap |
//! | `auth_reject` | warn | `net/conn.rs` — bad `AUTH` token or gated verb without one |
//! | `drain_start` | info | `net/pool.rs` — graceful shutdown began draining |
//! | `drain_finish` | info | `net/pool.rs` — drain completed (detail says if fully drained) |
//! | `rebalance_move` | info | `cluster/index.rs` — vertex ownership moved between shards (split/merge) |
//! | `primary_migrated` | info | `cluster/index.rs` — a shard's primary cut over to a new host |
//! | `rebalance_aborted` | warn | `cluster/index.rs` — a rebalance step aborted before cutover |

pub mod events;
pub mod expo;
pub mod health;
pub mod hist;
pub mod names;
pub mod registry;
pub mod trace;
pub mod tsdb;

pub use events::{emit, recent_events, Event, Severity};
pub use expo::{merge_prom, parse_prom, render_json, render_prom};
pub use health::{HealthReport, SloConfig, Verdict};
pub use hist::{HistSnapshot, Histogram};
pub use registry::{global, Counter, Gauge, Registry, Series, Value};
pub use trace::{
    next_trace_id, recent_traces, record_slow_query, record_trace, FlushTrace, Span, Trace,
    TraceScope,
};
pub use tsdb::{Sampler, Tsdb};

use std::time::Duration;

/// Stage durations and merge accounting of one routed flush — recorded
/// into the per-graph stage histograms in one call, so the sharded and
/// cluster flush paths cannot drift apart in what they export.
#[derive(Clone, Copy, Debug, Default)]
pub struct FlushStages {
    pub queue: Duration,
    pub route: Duration,
    pub apply: Duration,
    pub refine: Duration,
    pub commit: Duration,
    pub publish: Duration,
    pub total: Duration,
    pub refine_rounds: u64,
    pub boundary_updates: u64,
    pub boundary_bytes: u64,
    /// The epoch this flush published (lands in `pico_graph_epoch`).
    pub epoch: u64,
}

/// Record one flush's stages under `graph`'s label set.
pub fn record_flush_stages(graph: &str, s: &FlushStages) {
    let us = |d: Duration| d.as_micros().min(u64::MAX as u128) as u64;
    let reg = global();
    let l: &[(&str, &str)] = &[("graph", graph)];
    reg.histogram(names::FLUSH_QUEUE_SECONDS, l).record(us(s.queue));
    reg.histogram(names::FLUSH_ROUTE_SECONDS, l).record(us(s.route));
    reg.histogram(names::FLUSH_APPLY_SECONDS, l).record(us(s.apply));
    reg.histogram(names::FLUSH_REFINE_SECONDS, l).record(us(s.refine));
    reg.histogram(names::FLUSH_COMMIT_SECONDS, l).record(us(s.commit));
    reg.histogram(names::FLUSH_PUBLISH_SECONDS, l).record(us(s.publish));
    reg.histogram(names::FLUSH_TOTAL_SECONDS, l).record(us(s.total));
    reg.histogram(names::FLUSH_REFINE_ROUNDS, l).record(s.refine_rounds);
    reg.counter(names::REFINE_BOUNDARY_UPDATES, l).add(s.boundary_updates);
    reg.counter(names::REFINE_BOUNDARY_BYTES, l).add(s.boundary_bytes);
    reg.gauge(names::GRAPH_EPOCH, l).set(s.epoch);
}
