//! The unified observability layer: one zero-dependency metrics
//! registry (counters, gauges, log-bucketed latency histograms with
//! mergeable snapshots), stage-level span tracing with cross-host
//! stitching, and a scrapeable exposition.
//!
//! Before this layer existed the crate's telemetry was three
//! disconnected islands — `engine::Metrics` worker slots, the transport
//! counters behind `METRICS`, and per-group replica `SyncStats` — with
//! no latency distributions outside the offline benches and no view of
//! *where inside a flush* time went. Now every subsystem records into
//! [`registry::global`]:
//!
//! * [`registry`] — named series with label sets, handed out as `Arc`
//!   handles so the hot path pays one atomic op per event.
//! * [`hist`] — power-of-two-bucketed histograms: lock-free recording,
//!   atomic (non-torn) snapshots, bucket-wise merging, conservative
//!   p50/p90/p99 readouts.
//! * [`trace`] — a trace id per flush (and slow query), stage spans
//!   (queue wait → route → apply → refine rounds → commit → publish),
//!   remote child spans stitched from shard-host replies, and the
//!   bounded ring behind the `TRACES` verb.
//! * [`expo`] — Prometheus-text and JSON renderings (`METRICS PROM`,
//!   `METRICS JSON`), plus the parser/merger behind
//!   `pico cluster status --metrics`.
//!
//! # Metric-name reference
//!
//! Every exported series, its type, and its labels. CI greps the
//! constants in [`names`] against this table, so a new metric cannot
//! land undocumented.
//!
//! | series | type | labels |
//! |---|---|---|
//! | `pico_serve_queries_total` | counter | `graph` |
//! | `pico_serve_edits_total` | counter | `graph` |
//! | `pico_serve_batches_total` | counter | `graph` |
//! | `pico_serve_recomputes_total` | counter | `graph` |
//! | `pico_refine_boundary_updates_total` | counter | `graph` |
//! | `pico_refine_boundary_bytes_total` | counter | `graph` |
//! | `pico_sync_deltas_total` | counter | `graph`, `shard` |
//! | `pico_sync_snapshots_total` | counter | `graph`, `shard` |
//! | `pico_sync_delta_bytes_total` | counter | `graph`, `shard` |
//! | `pico_sync_snapshot_bytes_total` | counter | `graph`, `shard` |
//! | `pico_net_accepted_total` | counter | — |
//! | `pico_net_rejected_total` | counter | — |
//! | `pico_net_timed_out_total` | counter | — |
//! | `pico_net_write_stalled_total` | counter | — |
//! | `pico_net_reclaimed_total` | counter | — |
//! | `pico_net_active` | gauge | — |
//! | `pico_net_queued` | gauge | — |
//! | `pico_net_workers` | gauge | — |
//! | `pico_net_conn_cap` | gauge | — |
//! | `pico_sync_lag_epochs` | gauge | `graph`, `shard` |
//! | `pico_graph_epoch` | gauge | `graph` |
//! | `pico_uptime_seconds` | gauge | — |
//! | `pico_query_seconds` | histogram | `graph` |
//! | `pico_flush_queue_seconds` | histogram | `graph` |
//! | `pico_flush_route_seconds` | histogram | `graph` |
//! | `pico_flush_apply_seconds` | histogram | `graph` |
//! | `pico_flush_refine_seconds` | histogram | `graph` |
//! | `pico_flush_commit_seconds` | histogram | `graph` |
//! | `pico_flush_publish_seconds` | histogram | `graph` |
//! | `pico_flush_total_seconds` | histogram | `graph` |
//! | `pico_flush_refine_rounds` | histogram | `graph` |
//! | `pico_shard_apply_seconds` | histogram | `graph` |
//! | `pico_shard_refine_round_seconds` | histogram | `graph` |
//! | `pico_shard_commit_seconds` | histogram | `graph` |
//!
//! `_seconds` histograms record microseconds internally and expose
//! second-denominated buckets; `pico_flush_refine_rounds` is a plain
//! count distribution. Single-backend graphs record `queue`, `apply`,
//! `publish`, and `total` flush stages; sharded and cluster graphs add
//! `route`, `refine`, and `commit`. The `pico_shard_*` histograms are
//! recorded host-side under the shard's hosted graph name (e.g.
//! `soc/shard1`), so a coordinator scrape and a shard-host scrape stay
//! distinguishable after a merge.

pub mod expo;
pub mod hist;
pub mod names;
pub mod registry;
pub mod trace;

pub use expo::{merge_prom, parse_prom, render_json, render_prom};
pub use hist::{HistSnapshot, Histogram};
pub use registry::{global, Counter, Gauge, Registry, Series, Value};
pub use trace::{
    next_trace_id, recent_traces, record_slow_query, record_trace, FlushTrace, Span, Trace,
    TraceScope,
};

use std::time::Duration;

/// Stage durations and merge accounting of one routed flush — recorded
/// into the per-graph stage histograms in one call, so the sharded and
/// cluster flush paths cannot drift apart in what they export.
#[derive(Clone, Copy, Debug, Default)]
pub struct FlushStages {
    pub queue: Duration,
    pub route: Duration,
    pub apply: Duration,
    pub refine: Duration,
    pub commit: Duration,
    pub publish: Duration,
    pub total: Duration,
    pub refine_rounds: u64,
    pub boundary_updates: u64,
    pub boundary_bytes: u64,
    /// The epoch this flush published (lands in `pico_graph_epoch`).
    pub epoch: u64,
}

/// Record one flush's stages under `graph`'s label set.
pub fn record_flush_stages(graph: &str, s: &FlushStages) {
    let us = |d: Duration| d.as_micros().min(u64::MAX as u128) as u64;
    let reg = global();
    let l: &[(&str, &str)] = &[("graph", graph)];
    reg.histogram(names::FLUSH_QUEUE_SECONDS, l).record(us(s.queue));
    reg.histogram(names::FLUSH_ROUTE_SECONDS, l).record(us(s.route));
    reg.histogram(names::FLUSH_APPLY_SECONDS, l).record(us(s.apply));
    reg.histogram(names::FLUSH_REFINE_SECONDS, l).record(us(s.refine));
    reg.histogram(names::FLUSH_COMMIT_SECONDS, l).record(us(s.commit));
    reg.histogram(names::FLUSH_PUBLISH_SECONDS, l).record(us(s.publish));
    reg.histogram(names::FLUSH_TOTAL_SECONDS, l).record(us(s.total));
    reg.histogram(names::FLUSH_REFINE_ROUNDS, l).record(s.refine_rounds);
    reg.counter(names::REFINE_BOUNDARY_UPDATES, l).add(s.boundary_updates);
    reg.counter(names::REFINE_BOUNDARY_BYTES, l).add(s.boundary_bytes);
    reg.gauge(names::GRAPH_EPOCH, l).set(s.epoch);
}
