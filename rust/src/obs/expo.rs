//! Exposition: render a [`Registry`] snapshot as Prometheus text or
//! JSON, and parse/merge Prometheus text from several hosts into one
//! cluster view (`pico cluster status --metrics`).
//!
//! Hand-rolled on both sides — the environment is offline, no serde —
//! and line-based: the parser accepts exactly what the renderer emits
//! (plus whitespace slack), which is all the merger needs.

use super::hist::{bucket_bound, HistSnapshot, NUM_BUCKETS};
use super::names;
use super::registry::{Registry, Series, Value};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Does this histogram record microseconds (rendered as seconds in the
/// Prometheus exposition), or raw counts?
fn is_seconds(name: &str) -> bool {
    name.ends_with("_seconds")
}

fn escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"")
}

fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

fn le_text(name: &str, i: usize) -> String {
    let b = bucket_bound(i);
    if b == u64::MAX {
        "+Inf".to_string()
    } else if is_seconds(name) {
        format!("{}", b as f64 / 1e6)
    } else {
        format!("{b}")
    }
}

fn render_hist_prom(out: &mut String, name: &str, labels: &[(String, String)], h: &HistSnapshot) {
    let mut cum = 0u64;
    for i in 0..NUM_BUCKETS {
        cum += h.buckets[i];
        let lb = label_block(labels, Some(("le", &le_text(name, i))));
        let _ = writeln!(out, "{name}_bucket{lb} {cum}");
    }
    let lb = label_block(labels, None);
    if is_seconds(name) {
        let _ = writeln!(out, "{name}_sum{lb} {}", h.sum as f64 / 1e6);
    } else {
        let _ = writeln!(out, "{name}_sum{lb} {}", h.sum);
    }
    let _ = writeln!(out, "{name}_count{lb} {}", h.count());
}

/// Render the registry as Prometheus exposition text. `_seconds`
/// histograms convert their microsecond buckets to seconds on the way
/// out; `pico_uptime_seconds` is synthesized from the registry clock.
pub fn render_prom(reg: &Registry) -> String {
    let mut out = String::new();
    let mut series = reg.snapshot();
    series.sort_by(|a, b| (a.name.as_str(), &a.labels).cmp(&(b.name.as_str(), &b.labels)));
    let mut typed = std::collections::BTreeSet::new();
    for s in &series {
        if typed.insert(s.name.clone()) {
            let _ = writeln!(out, "# TYPE {} {}", s.name, s.value.type_name());
        }
        match &s.value {
            Value::Counter(v) | Value::Gauge(v) => {
                let _ = writeln!(out, "{}{} {v}", s.name, label_block(&s.labels, None));
            }
            Value::Histogram(h) => render_hist_prom(&mut out, &s.name, &s.labels, h),
        }
    }
    let _ = writeln!(out, "# TYPE {} gauge", names::UPTIME_SECONDS);
    let _ = writeln!(out, "{} {:.3}", names::UPTIME_SECONDS, reg.uptime_seconds());
    out
}

fn json_escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"")
}

fn json_labels(labels: &[(String, String)]) -> String {
    let cells: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("\"{}\": \"{}\"", json_escape(k), json_escape(v)))
        .collect();
    format!("{{{}}}", cells.join(", "))
}

/// Render the registry as JSON. Histogram values stay in their recorded
/// unit (microseconds for `_seconds` series, marked `"unit": "us"`),
/// with p50/p90/p99 readouts precomputed.
pub fn render_json(reg: &Registry) -> String {
    let mut cells = Vec::new();
    for s in reg.snapshot() {
        let head = format!(
            "\"name\": \"{}\", \"labels\": {}, \"type\": \"{}\"",
            json_escape(&s.name),
            json_labels(&s.labels),
            s.value.type_name()
        );
        cells.push(match &s.value {
            Value::Counter(v) | Value::Gauge(v) => format!("{{{head}, \"value\": {v}}}"),
            Value::Histogram(h) => {
                let unit = if is_seconds(&s.name) { "us" } else { "raw" };
                let mut cum = 0u64;
                let buckets: Vec<String> = (0..NUM_BUCKETS)
                    .map(|i| {
                        cum += h.buckets[i];
                        let b = bucket_bound(i);
                        if b == u64::MAX {
                            format!("[null, {cum}]")
                        } else {
                            format!("[{b}, {cum}]")
                        }
                    })
                    .collect();
                format!(
                    "{{{head}, \"unit\": \"{unit}\", \"count\": {}, \"sum\": {}, \
                     \"p50\": {}, \"p90\": {}, \"p99\": {}, \"buckets\": [{}]}}",
                    h.count(),
                    h.sum,
                    h.quantile(0.5),
                    h.quantile(0.9),
                    h.quantile(0.99),
                    buckets.join(", ")
                )
            }
        });
    }
    format!(
        "{{\"uptime_seconds\": {:.3}, \"series\": [{}]}}\n",
        reg.uptime_seconds(),
        cells.join(", ")
    )
}

/// One parsed Prometheus exposition: `# TYPE` declarations plus every
/// sample line, keyed by the full `name{labels}` series string.
#[derive(Debug, Default)]
pub struct PromText {
    /// metric name -> declared type.
    pub types: BTreeMap<String, String>,
    /// `name{labels}` -> value, in first-seen order via BTreeMap.
    pub samples: BTreeMap<String, f64>,
}

/// Parse Prometheus text (what [`render_prom`] emits). Unparseable
/// lines are skipped, not fatal — the merger must survive a host
/// running a newer build with extra series.
pub fn parse_prom(text: &str) -> PromText {
    parse_prom_strict(text).0
}

/// Like [`parse_prom`], but also reports how many non-comment,
/// non-empty lines could NOT be parsed. `pico cluster status
/// --metrics` uses the count to flag a host serving a truncated or
/// corrupt exposition instead of silently merging only its readable
/// part.
pub fn parse_prom_strict(text: &str) -> (PromText, usize) {
    let mut out = PromText::default();
    let mut skipped = 0usize;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            if let (Some(name), Some(kind)) = (it.next(), it.next()) {
                out.types.insert(name.to_string(), kind.to_string());
            } else {
                skipped += 1;
            }
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        // `name{labels} value` or `name value`; labels may hold spaces
        // only inside quotes, which our own renderer never emits
        let Some(split_at) = line.rfind(' ') else {
            skipped += 1;
            continue;
        };
        let (series, value) = line.split_at(split_at);
        let Ok(v) = value.trim().parse::<f64>() else {
            skipped += 1;
            continue;
        };
        out.samples.insert(series.trim().to_string(), v);
    }
    (out, skipped)
}

/// The base metric name of a series key (strips labels and histogram
/// `_bucket`/`_sum`/`_count` suffixes when the base is a declared
/// histogram).
fn base_name<'a>(series: &'a str, types: &BTreeMap<String, String>) -> &'a str {
    let name = series.split('{').next().unwrap_or(series);
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            if types.get(base).map(String::as_str) == Some("histogram") {
                return base;
            }
        }
    }
    name
}

/// Merge expositions from several hosts into one: counters and
/// histogram cells sum; gauges take the max (a merged "epoch" or "lag"
/// is the worst case across hosts, not their sum).
pub fn merge_prom(texts: &[String]) -> String {
    let parsed: Vec<PromText> = texts.iter().map(|t| parse_prom(t)).collect();
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    for p in &parsed {
        for (k, v) in &p.types {
            types.entry(k.clone()).or_insert_with(|| v.clone());
        }
    }
    let mut merged: BTreeMap<String, f64> = BTreeMap::new();
    for p in &parsed {
        for (series, &v) in &p.samples {
            let base = base_name(series, &types);
            let gauge = types.get(base).map(String::as_str) == Some("gauge");
            merged
                .entry(series.clone())
                .and_modify(|cur| {
                    if gauge {
                        *cur = cur.max(v);
                    } else {
                        *cur += v;
                    }
                })
                .or_insert(v);
        }
    }
    let mut out = String::new();
    let mut typed = std::collections::BTreeSet::new();
    for (series, v) in &merged {
        let base = base_name(series, &types).to_string();
        if typed.insert(base.clone()) {
            if let Some(kind) = types.get(&base) {
                let _ = writeln!(out, "# TYPE {base} {kind}");
            }
        }
        let _ = writeln!(out, "{series} {v}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_registry() -> Registry {
        let r = Registry::new();
        r.counter(names::SERVE_QUERIES, &[("graph", "g1")]).add(3);
        r.gauge(names::GRAPH_EPOCH, &[("graph", "g1")]).set(2);
        let h = r.histogram(names::QUERY_SECONDS, &[("graph", "g1")]);
        h.record(1); // -> le 1e-06 bucket
        h.record(3); // -> le 4e-06 bucket
        r
    }

    /// The golden-format pin for `METRICS PROM`: exact lines, exact
    /// order, exact histogram shape. A fresh local registry keeps the
    /// process-global counters out of the assertion.
    #[test]
    fn prom_exposition_golden_format() {
        let text = render_prom(&sample_registry());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "# TYPE pico_graph_epoch gauge");
        assert_eq!(lines[1], "pico_graph_epoch{graph=\"g1\"} 2");
        assert_eq!(lines[2], "# TYPE pico_query_seconds histogram");
        assert_eq!(lines[3], "pico_query_seconds_bucket{graph=\"g1\",le=\"0.000001\"} 1");
        assert_eq!(lines[4], "pico_query_seconds_bucket{graph=\"g1\",le=\"0.000002\"} 1");
        assert_eq!(lines[5], "pico_query_seconds_bucket{graph=\"g1\",le=\"0.000004\"} 2");
        // cumulative counts carry through to +Inf
        assert_eq!(
            lines[2 + NUM_BUCKETS],
            "pico_query_seconds_bucket{graph=\"g1\",le=\"+Inf\"} 2"
        );
        assert_eq!(lines[3 + NUM_BUCKETS], "pico_query_seconds_sum{graph=\"g1\"} 0.000004");
        assert_eq!(lines[4 + NUM_BUCKETS], "pico_query_seconds_count{graph=\"g1\"} 2");
        assert_eq!(lines[5 + NUM_BUCKETS], "# TYPE pico_serve_queries_total counter");
        assert_eq!(lines[6 + NUM_BUCKETS], "pico_serve_queries_total{graph=\"g1\"} 3");
        assert_eq!(lines[7 + NUM_BUCKETS], "# TYPE pico_uptime_seconds gauge");
        assert!(lines[8 + NUM_BUCKETS].starts_with("pico_uptime_seconds "));
        assert_eq!(lines.len(), 9 + NUM_BUCKETS);
    }

    #[test]
    fn strict_parse_counts_malformed_lines() {
        let good = render_prom(&sample_registry());
        let (_, skipped) = parse_prom_strict(&good);
        assert_eq!(skipped, 0, "our own exposition parses clean");
        // a bad value, a line with no value at all; plain comments and
        // blank lines stay free
        let mangled = format!(
            "{good}pico_broken{{graph=\"g1\"}} not-a-number\ntruncated-mid-line\n\n# plain comment\n"
        );
        let (p, skipped) = parse_prom_strict(&mangled);
        assert_eq!(skipped, 2);
        assert!(p.samples.contains_key("pico_serve_queries_total{graph=\"g1\"}"));
    }

    #[test]
    fn json_exposition_is_structured() {
        let text = render_json(&sample_registry());
        assert!(text.starts_with("{\"uptime_seconds\": "));
        assert!(text.contains("\"name\": \"pico_serve_queries_total\""));
        assert!(text.contains("\"type\": \"histogram\""));
        assert!(text.contains("\"p99\": 4"));
        assert!(text.contains("[null, 2]"), "+Inf bucket renders as null: {text}");
    }

    #[test]
    fn parse_round_trips_and_merge_sums_counters_maxes_gauges() {
        let a = render_prom(&sample_registry());
        let p = parse_prom(&a);
        assert_eq!(p.types.get("pico_query_seconds").map(String::as_str), Some("histogram"));
        assert_eq!(p.samples.get("pico_serve_queries_total{graph=\"g1\"}"), Some(&3.0));

        let b = {
            let r = Registry::new();
            r.counter(names::SERVE_QUERIES, &[("graph", "g1")]).add(5);
            r.gauge(names::GRAPH_EPOCH, &[("graph", "g1")]).set(9);
            r.histogram(names::QUERY_SECONDS, &[("graph", "g1")]).record(1);
            render_prom(&r)
        };
        let merged = merge_prom(&[a, b]);
        let m = parse_prom(&merged);
        assert_eq!(
            m.samples.get("pico_serve_queries_total{graph=\"g1\"}"),
            Some(&8.0),
            "counters sum"
        );
        assert_eq!(m.samples.get("pico_graph_epoch{graph=\"g1\"}"), Some(&9.0), "gauges max");
        assert_eq!(
            m.samples
                .get("pico_query_seconds_bucket{graph=\"g1\",le=\"0.000001\"}"),
            Some(&2.0),
            "histogram buckets sum"
        );
        assert_eq!(m.samples.get("pico_query_seconds_count{graph=\"g1\"}"), Some(&3.0));
        assert!(merged.contains("# TYPE pico_query_seconds histogram"));
    }
}
