//! Stage-level span tracing: a trace id minted per flush (and per slow
//! query), stage spans recorded as the flush progresses, remote child
//! spans stitched in from shard-host replies, and a bounded ring of
//! recent span trees behind the `TRACES` verb.
//!
//! Two builder shapes:
//!
//! * [`FlushTrace`] — owned by one flush on the coordinator: top-level
//!   stage spans (`queue`, `route`, `apply`, `refine`, `commit`,
//!   `publish`) plus children nested under a named stage (per-round
//!   spans, remote sub-spans).
//! * [`TraceScope`] — the shared mailbox a [`crate::cluster::RemoteShard`]
//!   records into while a flush is active: the active trace id travels
//!   out on the shard verbs as a `trace=<hex>` head-line token, the
//!   remote handler answers with its own `us=<micros>`, and the scope
//!   turns that into a child span under the right stage. The flush lock
//!   serializes flushes, so one scope per cluster is race-free.

use super::names;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// How many recent traces the ring keeps by default — tunable at
/// startup with `pico serve --trace-ring N` (see [`set_trace_ring_cap`]).
pub const TRACE_RING_CAP: usize = 64;

/// Queries at or above this (µs) land in the trace ring; faster ones
/// only feed the latency histograms (the ring would otherwise be all
/// point queries and no flushes). `PICO_SLOW_QUERY_US` overrides it.
pub const SLOW_QUERY_US: u64 = 10_000;

static RING_CAP: AtomicUsize = AtomicUsize::new(TRACE_RING_CAP);

/// The effective trace-ring capacity.
pub fn trace_ring_cap() -> usize {
    RING_CAP.load(Ordering::Relaxed)
}

/// Resize the trace ring (`pico serve --trace-ring N`). Takes effect on
/// the next [`record_trace`]; shrinking evicts oldest-first.
pub fn set_trace_ring_cap(n: usize) {
    RING_CAP.store(n.max(1), Ordering::Relaxed);
}

/// The effective slow-query threshold in µs: `PICO_SLOW_QUERY_US` when
/// set and parseable, else [`SLOW_QUERY_US`]. Parsed once per process.
pub fn slow_query_threshold_us() -> u64 {
    static CACHE: OnceLock<u64> = OnceLock::new();
    *CACHE.get_or_init(|| {
        std::env::var("PICO_SLOW_QUERY_US")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(SLOW_QUERY_US)
    })
}

/// Mint a fresh trace id: a counter seeded from the wall clock at first
/// use, so ids from different hosts almost never collide.
pub fn next_trace_id() -> u64 {
    static NEXT: OnceLock<AtomicU64> = OnceLock::new();
    let next = NEXT.get_or_init(|| {
        let seed = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(1);
        AtomicU64::new(seed | 1)
    });
    next.fetch_add(1, Ordering::Relaxed)
}

/// One timed stage, with offsets relative to its trace's start.
#[derive(Clone, Debug)]
pub struct Span {
    pub name: String,
    pub start_us: u64,
    pub dur_us: u64,
    /// The remote host that executed this span, when it crossed the wire.
    pub remote: Option<String>,
    pub children: Vec<Span>,
}

/// A finished span tree.
#[derive(Clone, Debug)]
pub struct Trace {
    pub id: u64,
    pub kind: &'static str,
    pub graph: String,
    pub total_us: u64,
    pub spans: Vec<Span>,
}

impl Trace {
    /// Render as indented text lines (the `TRACES` reply body).
    pub fn render(&self) -> Vec<String> {
        let mut lines = vec![format!(
            "trace=0x{:x} kind={} graph={} total_us={}",
            self.id, self.kind, self.graph, self.total_us
        )];
        for s in &self.spans {
            render_span(s, 1, &mut lines);
        }
        lines
    }
}

fn render_span(s: &Span, depth: usize, out: &mut Vec<String>) {
    let indent = "  ".repeat(depth);
    let remote = match &s.remote {
        Some(addr) => format!(" remote={addr}"),
        None => String::new(),
    };
    out.push(format!(
        "{indent}{} start_us={} dur_us={}{remote}",
        s.name, s.start_us, s.dur_us
    ));
    for c in &s.children {
        render_span(c, depth + 1, out);
    }
}

fn ring() -> &'static Mutex<VecDeque<Trace>> {
    static RING: OnceLock<Mutex<VecDeque<Trace>>> = OnceLock::new();
    RING.get_or_init(|| Mutex::new(VecDeque::with_capacity(TRACE_RING_CAP)))
}

/// Push a finished trace into the bounded ring (oldest evicted).
pub fn record_trace(t: Trace) {
    let cap = trace_ring_cap();
    let mut r = ring().lock().unwrap();
    while r.len() >= cap {
        r.pop_front();
    }
    r.push_back(t);
}

/// The `n` most recent traces, newest first.
pub fn recent_traces(n: usize) -> Vec<Trace> {
    let r = ring().lock().unwrap();
    r.iter().rev().take(n).cloned().collect()
}

/// Record a single-span query trace — only when it was slow enough to
/// be worth a ring slot (see [`slow_query_threshold_us`]). Every slow
/// query also bumps `pico_slow_queries_total{graph}`.
pub fn record_slow_query(graph: &str, verb: &str, dur: Duration) {
    let dur_us = dur.as_micros().min(u64::MAX as u128) as u64;
    if dur_us < slow_query_threshold_us() {
        return;
    }
    super::global()
        .counter(names::SLOW_QUERIES, &[("graph", graph)])
        .inc();
    record_trace(Trace {
        id: next_trace_id(),
        kind: "query",
        graph: graph.to_string(),
        total_us: dur_us,
        spans: vec![Span {
            name: verb.to_string(),
            start_us: 0,
            dur_us,
            remote: None,
            children: Vec::new(),
        }],
    });
}

/// The span-tree builder one flush owns.
pub struct FlushTrace {
    id: u64,
    t0: Instant,
    /// `(parent stage name, span)` — `None` parents are top-level
    /// stages; named parents nest under the stage of that name at
    /// [`FlushTrace::finish`] time.
    entries: Mutex<Vec<(Option<String>, Span)>>,
}

impl FlushTrace {
    pub fn new(id: u64) -> Self {
        Self {
            id,
            t0: Instant::now(),
            entries: Mutex::new(Vec::new()),
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn t0(&self) -> Instant {
        self.t0
    }

    fn offset(&self, at: Instant) -> u64 {
        at.saturating_duration_since(self.t0).as_micros() as u64
    }

    /// Record a top-level stage span.
    pub fn stage(&self, name: &str, start: Instant, dur: Duration) {
        let span = Span {
            name: name.to_string(),
            start_us: self.offset(start),
            dur_us: dur.as_micros().min(u64::MAX as u128) as u64,
            remote: None,
            children: Vec::new(),
        };
        self.entries.lock().unwrap().push((None, span));
    }

    /// Record a child span to be nested under the stage named `stage`.
    pub fn child(&self, stage: &str, span: Span) {
        self.entries.lock().unwrap().push((Some(stage.to_string()), span));
    }

    /// Assemble the tree: children attach to their named stage (falling
    /// back to top level if the stage never materialized), everything
    /// sorts by start offset.
    pub fn finish(self, kind: &'static str, graph: &str) -> Trace {
        let total_us = self.t0.elapsed().as_micros().min(u64::MAX as u128) as u64;
        let entries = self.entries.into_inner().unwrap();
        let mut spans: Vec<Span> = Vec::new();
        let mut nested: Vec<(String, Span)> = Vec::new();
        for (parent, span) in entries {
            match parent {
                None => spans.push(span),
                Some(p) => nested.push((p, span)),
            }
        }
        for (p, span) in nested {
            match spans.iter_mut().find(|s| s.name == p) {
                Some(stage) => stage.children.push(span),
                None => spans.push(span),
            }
        }
        spans.sort_by_key(|s| s.start_us);
        for s in &mut spans {
            s.children.sort_by_key(|c| c.start_us);
        }
        Trace {
            id: self.id,
            kind,
            graph: graph.to_string(),
            total_us,
            spans,
        }
    }
}

/// The shared mailbox remote-shard backends record spans into while a
/// flush is active (see the module docs for the wire protocol).
#[derive(Default)]
pub struct TraceScope {
    /// The active trace id (0 = no flush in progress).
    active: AtomicU64,
    inner: Mutex<ScopeInner>,
}

#[derive(Default)]
struct ScopeInner {
    t0: Option<Instant>,
    spans: Vec<(String, Span)>,
}

impl TraceScope {
    /// Arm the scope for one flush.
    pub fn begin(&self, id: u64, t0: Instant) {
        let mut inner = self.inner.lock().unwrap();
        inner.t0 = Some(t0);
        inner.spans.clear();
        self.active.store(id, Ordering::Release);
    }

    /// The active trace id, if a flush is in progress.
    pub fn active(&self) -> Option<u64> {
        match self.active.load(Ordering::Acquire) {
            0 => None,
            id => Some(id),
        }
    }

    /// Record a remote child span under `stage`. `dur_us` came back on
    /// the wire; the start offset is reconstructed as now − duration.
    pub fn record_remote(&self, stage: &str, name: String, addr: &str, dur_us: u64) {
        let mut inner = self.inner.lock().unwrap();
        let Some(t0) = inner.t0 else { return };
        let end_us = Instant::now().saturating_duration_since(t0).as_micros() as u64;
        let span = Span {
            name,
            start_us: end_us.saturating_sub(dur_us),
            dur_us,
            remote: Some(addr.to_string()),
            children: Vec::new(),
        };
        inner.spans.push((stage.to_string(), span));
    }

    /// Disarm and drain: the collected `(stage, span)` pairs, ready for
    /// [`FlushTrace::child`].
    pub fn end(&self) -> Vec<(String, Span)> {
        self.active.store(0, Ordering::Release);
        let mut inner = self.inner.lock().unwrap();
        inner.t0 = None;
        std::mem::take(&mut inner.spans)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slow_query_threshold_defaults_without_env() {
        if std::env::var("PICO_SLOW_QUERY_US").is_err() {
            assert_eq!(slow_query_threshold_us(), SLOW_QUERY_US);
        }
        // the runtime cap starts at the compiled default and clamps to 1
        assert!(trace_ring_cap() >= 1);
    }

    #[test]
    fn trace_ids_are_distinct() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn finish_nests_children_under_their_stage() {
        let ft = FlushTrace::new(7);
        let t0 = ft.t0();
        ft.stage("route", t0, Duration::from_micros(10));
        ft.stage("apply", t0, Duration::from_micros(50));
        ft.child(
            "apply",
            Span {
                name: "apply shard=1".into(),
                start_us: 5,
                dur_us: 40,
                remote: Some("10.0.0.7:7571".into()),
                children: Vec::new(),
            },
        );
        ft.child(
            "missing-stage",
            Span {
                name: "orphan".into(),
                start_us: 1,
                dur_us: 1,
                remote: None,
                children: Vec::new(),
            },
        );
        let t = ft.finish("flush", "g1");
        assert_eq!(t.id, 7);
        assert_eq!(t.spans.len(), 3, "two stages + the orphan fallback");
        let apply = t.spans.iter().find(|s| s.name == "apply").unwrap();
        assert_eq!(apply.children.len(), 1);
        assert_eq!(apply.children[0].remote.as_deref(), Some("10.0.0.7:7571"));
        let lines = t.render();
        assert!(lines[0].starts_with("trace=0x7 kind=flush graph=g1"), "{}", lines[0]);
        assert!(lines.iter().any(|l| l.contains("remote=10.0.0.7:7571")));
    }

    #[test]
    fn ring_is_bounded_and_newest_first() {
        for i in 0..TRACE_RING_CAP + 5 {
            record_trace(Trace {
                id: 1_000_000 + i as u64,
                kind: "flush",
                graph: "ring-test".into(),
                total_us: i as u64,
                spans: Vec::new(),
            });
        }
        // other tests in this binary may be recording concurrently:
        // assert only on this test's own traces
        let all = recent_traces(usize::MAX);
        assert!(all.len() <= TRACE_RING_CAP);
        let mine: Vec<&Trace> = all.iter().filter(|t| t.graph == "ring-test").collect();
        assert!(mine.len() >= 2, "ring must retain recent traces");
        assert!(mine[0].total_us > mine[1].total_us, "newest first");
    }

    #[test]
    fn scope_collects_remote_spans_only_while_armed() {
        let scope = TraceScope::default();
        assert_eq!(scope.active(), None);
        scope.record_remote("apply", "early".into(), "h:1", 5);
        scope.begin(42, Instant::now());
        assert_eq!(scope.active(), Some(42));
        scope.record_remote("apply", "apply shard=1".into(), "h:1", 500);
        let spans = scope.end();
        assert_eq!(scope.active(), None);
        assert_eq!(spans.len(), 1, "pre-arm span dropped at begin()");
        assert_eq!(spans[0].0, "apply");
        assert_eq!(spans[0].1.dur_us, 500);
    }
}
