//! The structured event journal: a bounded, severity-tagged ring of
//! the *exceptional* things a serving process did — replica failovers,
//! delta-sync fallbacks to a full manifest ship, slow-loris and
//! write-stall cutoffs, flush-crossover recomputes, auth rejects,
//! drain start/finish — so "what happened overnight" has an answer
//! that counters alone cannot give.
//!
//! Metrics say *how often*; the journal says *what, when, and to
//! which graph*. Every [`emit`] also bumps the
//! `pico_events_total{severity=...}` registry counter, so the tsdb's
//! windowed event rate and the journal's readable tail stay two views
//! of one stream. The ring is bounded ([`EVENT_JOURNAL_CAP`]) and
//! process-global, mirroring the trace ring ([`super::trace`]):
//! emission is one mutex push on paths that are already exceptional,
//! never on the per-query hot path.
//!
//! Read it with the `EVENTS [n] [min-severity]` verb (any session,
//! any backend) or merged across hosts by `pico cluster status
//! --events`. Event kinds are constants in [`kind`] — CI lints that
//! table against the reference table in [`super`] (obs/mod.rs), so a
//! new kind cannot land undocumented.

use super::names;
use std::collections::VecDeque;
use std::sync::{Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

/// Events the journal retains; older ones are evicted. At the default
/// emission rates (events are exceptional) this covers hours.
pub const EVENT_JOURNAL_CAP: usize = 256;

/// Every event kind the journal carries — the single definition site,
/// CI-linted against the reference table in obs/mod.rs.
pub mod kind {
    /// A replica read failed and the group fell over to the next one.
    pub const REPLICA_FAILOVER: &str = "replica_failover";
    /// Replica catch-up could not use the delta chain and re-shipped
    /// the full manifest instead.
    pub const SYNC_FULL_SHIP: &str = "sync_full_ship";
    /// A replica could not be synced at all this pass.
    pub const SYNC_FAILED: &str = "sync_failed";
    /// A cluster flush died mid-apply; the group is poisoned until a
    /// full re-ship.
    pub const FLUSH_FAILED: &str = "flush_failed";
    /// A batch crossed the incremental-vs-recompute threshold and fell
    /// back to a full recompute.
    pub const CROSSOVER_RECOMPUTE: &str = "crossover_recompute";
    /// A distributed refine round lost a shard backend mid-merge.
    pub const REFINE_ROUND_FAILED: &str = "refine_round_failed";
    /// A request stalled mid-read past the stall timeout (slow-loris)
    /// and the connection was cut off.
    pub const SLOW_LORIS_CUTOFF: &str = "slow_loris_cutoff";
    /// A peer stopped draining staged replies for a full stall window
    /// and was cut off.
    pub const WRITE_STALL_CUTOFF: &str = "write_stall_cutoff";
    /// An idle connection gave its slot back while the pool sat at its
    /// connection cap.
    pub const IDLE_RECLAIM: &str = "idle_reclaim";
    /// An accept was refused because the pool was at its connection cap.
    pub const CONN_REJECTED: &str = "conn_rejected";
    /// An `AUTH` preamble carried the wrong token, or a gated shard
    /// verb arrived without one.
    pub const AUTH_REJECT: &str = "auth_reject";
    /// Graceful shutdown began draining connections.
    pub const DRAIN_START: &str = "drain_start";
    /// The drain finished (detail says whether every connection made it).
    pub const DRAIN_FINISH: &str = "drain_finish";
    /// A rebalance moved vertex ownership between shards (split/merge).
    pub const REBALANCE_MOVE: &str = "rebalance_move";
    /// A live migration swapped a shard's primary to a new host.
    pub const PRIMARY_MIGRATED: &str = "primary_migrated";
    /// A rebalance step aborted before cutover; prior state intact.
    pub const REBALANCE_ABORTED: &str = "rebalance_aborted";
}

/// Event severity, ordered: `Info < Warn < Error`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }

    /// Parse a severity name (case-insensitive); `None` for noise.
    pub fn parse(s: &str) -> Option<Severity> {
        match s.to_ascii_lowercase().as_str() {
            "info" => Some(Severity::Info),
            "warn" | "warning" => Some(Severity::Warn),
            "error" | "err" => Some(Severity::Error),
            _ => None,
        }
    }
}

/// One journal entry.
#[derive(Clone, Debug)]
pub struct Event {
    /// Monotonic per-process sequence number (total ordering within
    /// one host even when two events share a millisecond).
    pub seq: u64,
    /// Wall-clock milliseconds since the unix epoch — comparable
    /// across hosts, which is what `pico cluster status --events`
    /// sorts the merged tail by.
    pub unix_ms: u64,
    pub severity: Severity,
    /// One of the [`kind`] constants.
    pub kind: &'static str,
    /// The graph the event concerns; empty for transport-level events.
    pub graph: String,
    /// Free-form `key=value`-style context.
    pub detail: String,
}

impl Event {
    /// The one-line wire/CLI rendering:
    /// `<unix_ms> <severity> <kind> graph=<g|-> <detail>`.
    pub fn render(&self) -> String {
        format!(
            "{} {} {} graph={} {}",
            self.unix_ms,
            self.severity.as_str(),
            self.kind,
            if self.graph.is_empty() { "-" } else { &self.graph },
            self.detail
        )
    }
}

struct Journal {
    events: VecDeque<Event>,
    next_seq: u64,
}

fn journal() -> &'static Mutex<Journal> {
    static JOURNAL: OnceLock<Mutex<Journal>> = OnceLock::new();
    JOURNAL.get_or_init(|| {
        Mutex::new(Journal {
            events: VecDeque::with_capacity(EVENT_JOURNAL_CAP),
            next_seq: 0,
        })
    })
}

fn unix_ms_now() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis().min(u64::MAX as u128) as u64)
        .unwrap_or(0)
}

/// Append one event to the process journal (evicting the oldest past
/// [`EVENT_JOURNAL_CAP`]) and bump `pico_events_total{severity=...}`.
pub fn emit(severity: Severity, kind: &'static str, graph: &str, detail: impl Into<String>) {
    super::global()
        .counter(names::EVENTS_TOTAL, &[("severity", severity.as_str())])
        .inc();
    let mut j = journal().lock().unwrap();
    let seq = j.next_seq;
    j.next_seq += 1;
    while j.events.len() >= EVENT_JOURNAL_CAP {
        j.events.pop_front();
    }
    j.events.push_back(Event {
        seq,
        unix_ms: unix_ms_now(),
        severity,
        kind,
        graph: graph.to_string(),
        detail: detail.into(),
    });
}

/// The newest `n` events (newest first), optionally keeping only
/// severities at or above `min`.
pub fn recent_events(n: usize, min: Option<Severity>) -> Vec<Event> {
    let j = journal().lock().unwrap();
    j.events
        .iter()
        .rev()
        .filter(|e| min.map_or(true, |m| e.severity >= m))
        .take(n)
        .cloned()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_orders_and_parses() {
        assert!(Severity::Info < Severity::Warn);
        assert!(Severity::Warn < Severity::Error);
        assert_eq!(Severity::parse("WARN"), Some(Severity::Warn));
        assert_eq!(Severity::parse("error"), Some(Severity::Error));
        assert_eq!(Severity::parse("bogus"), None);
        assert_eq!(Severity::Error.as_str(), "error");
    }

    #[test]
    fn journal_is_bounded_and_keeps_the_newest() {
        // the journal is process-global and other tests may emit
        // concurrently, so assert on our own uniquely-tagged events
        let tag = "bounded-test";
        for i in 0..EVENT_JOURNAL_CAP + 16 {
            emit(Severity::Info, kind::DRAIN_START, "gj", format!("{tag} i={i}"));
        }
        let all = recent_events(usize::MAX, None);
        assert!(all.len() <= EVENT_JOURNAL_CAP, "ring must stay bounded");
        let newest_tag = format!("{tag} i={}", EVENT_JOURNAL_CAP + 15);
        assert!(
            all.iter().any(|e| e.detail == newest_tag),
            "newest event must survive"
        );
        assert!(
            !all.iter().any(|e| e.detail == format!("{tag} i=0")),
            "oldest overflow event must be evicted"
        );
        // newest-first ordering by sequence number
        let seqs: Vec<u64> = all.iter().map(|e| e.seq).collect();
        assert!(seqs.windows(2).all(|w| w[0] > w[1]), "newest first");
    }

    #[test]
    fn severity_filter_keeps_at_or_above() {
        emit(Severity::Info, kind::DRAIN_START, "gs", "sev-filter info");
        emit(Severity::Warn, kind::REPLICA_FAILOVER, "gs", "sev-filter warn");
        emit(Severity::Error, kind::FLUSH_FAILED, "gs", "sev-filter error");
        let warn_up = recent_events(usize::MAX, Some(Severity::Warn));
        assert!(warn_up.iter().any(|e| e.detail == "sev-filter warn"));
        assert!(warn_up.iter().any(|e| e.detail == "sev-filter error"));
        assert!(!warn_up.iter().any(|e| e.detail == "sev-filter info"));
        assert!(warn_up.iter().all(|e| e.severity >= Severity::Warn));
    }

    #[test]
    fn render_is_one_structured_line() {
        let e = Event {
            seq: 7,
            unix_ms: 1754000000123,
            severity: Severity::Warn,
            kind: kind::REPLICA_FAILOVER,
            graph: "soc".into(),
            detail: "replica=10.0.0.7:7571 err=dial".into(),
        };
        assert_eq!(
            e.render(),
            "1754000000123 warn replica_failover graph=soc replica=10.0.0.7:7571 err=dial"
        );
        let t = Event { graph: String::new(), ..e };
        assert!(t.render().contains(" graph=- "), "{}", t.render());
    }
}
