//! Algorithm registry: name → [`Decomposer`] instance, covering every
//! algorithm the paper's tables reference plus the XLA vectorised engines.

use crate::core::{bz::Bz, index2core, peel, Decomposer};
use crate::vc::VcPeel;
use anyhow::{bail, Result};

/// All registry names, in the order the tables print them. The XLA engines
/// appear only when the crate is built with the `xla` feature.
pub fn algorithm_names() -> Vec<&'static str> {
    let mut names = vec![
        "BZ",
        "GPP",
        "PeelOne",
        "PP-dyn",
        "PO-dyn",
        "BucketPeel",
        "VC-Peel(Gunrock)",
        "NbrCore",
        "CntCore",
        "HistoCore",
        "Hybrid",
    ];
    if cfg!(feature = "xla") {
        names.push("VecPeel(XLA)");
        names.push("VecHindex(XLA)");
    }
    names
}

/// Instantiate an algorithm by name. The XLA engines require built
/// artifacts; their construction error propagates here.
pub fn algorithm_by_name(name: &str) -> Result<Box<dyn Decomposer>> {
    Ok(match name {
        "BZ" => Box::new(Bz),
        "GPP" => Box::new(peel::Gpp),
        "PeelOne" => Box::new(peel::PeelOne),
        "PP-dyn" => Box::new(peel::PpDyn),
        "PO-dyn" => Box::new(peel::PoDyn),
        "BucketPeel" => Box::new(peel::BucketPeel),
        "VC-Peel(Gunrock)" => Box::new(VcPeel),
        "NbrCore" => Box::new(index2core::NbrCore),
        "CntCore" => Box::new(index2core::CntCore),
        "HistoCore" => Box::new(index2core::HistoCore),
        "Hybrid" => Box::new(crate::core::Hybrid::default()),
        #[cfg(feature = "xla")]
        "VecPeel(XLA)" => Box::new(crate::runtime::VecPeel::open_default()?),
        #[cfg(feature = "xla")]
        "VecHindex(XLA)" => Box::new(crate::runtime::VecHindex::open_default()?),
        #[cfg(not(feature = "xla"))]
        "VecPeel(XLA)" | "VecHindex(XLA)" => bail!(
            "algorithm '{name}' needs the XLA backend; rebuild with `--features xla`"
        ),
        other => bail!(
            "unknown algorithm '{other}' (known: {})",
            algorithm_names().join(", ")
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;

    #[test]
    fn native_algorithms_resolve_and_run() {
        for name in [
            "BZ",
            "GPP",
            "PeelOne",
            "PP-dyn",
            "PO-dyn",
            "BucketPeel",
            "NbrCore",
            "CntCore",
            "HistoCore",
            "VC-Peel(Gunrock)",
        ] {
            let algo = algorithm_by_name(name).unwrap();
            assert_eq!(algo.name(), name);
            let r = algo.decompose_with(&examples::g1(), 2, false);
            assert_eq!(r.core, examples::g1_coreness(), "{name}");
        }
    }

    #[test]
    fn unknown_name_is_error() {
        match algorithm_by_name("NopeCore") {
            Ok(_) => panic!("should have failed"),
            Err(err) => assert!(err.to_string().contains("unknown algorithm")),
        }
    }

    #[test]
    fn names_list_is_complete() {
        for n in algorithm_names() {
            match algorithm_by_name(n) {
                Ok(_) => {}
                // The XLA engines resolve only once artifacts are built;
                // every native name must always resolve.
                Err(e) => assert!(n.contains("XLA"), "{n} unresolvable: {e}"),
            }
        }
    }
}
