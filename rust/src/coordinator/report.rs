//! Plain-text table rendering for job results — the same row shapes the
//! paper's tables use (time in ms, iteration counts in parentheses,
//! speedup columns).

use super::job::{JobOutcome, JobResult};
use crate::util::fmt;

/// Simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>width$}", cell, width = widths[c]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Status glyph for a result.
pub fn status(r: &JobResult) -> String {
    match &r.outcome {
        JobOutcome::Ok => "ok".into(),
        JobOutcome::ValidationFailed(_) => "BAD".into(),
        JobOutcome::Rejected(m) => format!("rejected: {m}"),
        JobOutcome::Panicked(m) => format!("panic: {m}"),
    }
}

/// Render a batch of results grouped as one table.
pub fn render_results(results: &[JobResult]) -> String {
    let mut t = Table::new(&[
        "dataset", "|V|", "|E|", "algorithm", "time(ms)", "iters", "k_max", "status",
    ]);
    for r in results {
        t.row(vec![
            r.dataset.clone(),
            fmt::si(r.vertices),
            fmt::si(r.edges),
            r.algorithm.clone(),
            fmt::ms(r.elapsed_ms()),
            r.iterations.to_string(),
            r.k_max.to_string(),
            status(r),
        ]);
    }
    t.render()
}

/// Geometric mean of pairwise speedups (baseline time / candidate time),
/// the aggregate the paper quotes ("average speedup of 1.9x").
pub fn geomean_speedup(pairs: &[(f64, f64)]) -> f64 {
    if pairs.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = pairs
        .iter()
        .map(|&(base, cand)| (base / cand).ln())
        .sum();
    (log_sum / pairs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bbbb"]);
        t.row(vec!["xxx".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("a  bbbb") || s.contains("  a  bbbb"));
        assert!(s.lines().count() == 3);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        Table::new(&["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean_speedup(&[(2.0, 1.0)]) - 2.0).abs() < 1e-12);
        // 4x and 1x -> 2x geometric mean
        assert!((geomean_speedup(&[(4.0, 1.0), (1.0, 1.0)]) - 2.0).abs() < 1e-12);
        assert!(geomean_speedup(&[]).is_nan());
    }
}
