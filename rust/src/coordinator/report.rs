//! Plain-text table rendering for job results — the same row shapes the
//! paper's tables use (time in ms, iteration counts in parentheses,
//! speedup columns).

use super::job::{JobOutcome, JobResult};
use crate::util::fmt;

/// Simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>width$}", cell, width = widths[c]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Status glyph for a result.
pub fn status(r: &JobResult) -> String {
    match &r.outcome {
        JobOutcome::Ok => "ok".into(),
        JobOutcome::ValidationFailed(_) => "BAD".into(),
        JobOutcome::Rejected(m) => format!("rejected: {m}"),
        JobOutcome::Panicked(m) => format!("panic: {m}"),
    }
}

/// Render a batch of results grouped as one table.
pub fn render_results(results: &[JobResult]) -> String {
    let mut t = Table::new(&[
        "dataset", "|V|", "|E|", "algorithm", "time(ms)", "iters", "k_max", "status",
    ]);
    for r in results {
        t.row(vec![
            r.dataset.clone(),
            fmt::si(r.vertices),
            fmt::si(r.edges),
            r.algorithm.clone(),
            fmt::ms(r.elapsed_ms()),
            r.iterations.to_string(),
            r.k_max.to_string(),
            status(r),
        ]);
    }
    t.render()
}

/// Minimal JSON string escaping (the environment carries no serde; the
/// emitted values are ASCII identifiers and error messages).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One result as a JSON object.
fn result_json(r: &JobResult) -> String {
    format!(
        concat!(
            "{{\"dataset\":\"{}\",\"algorithm\":\"{}\",\"status\":\"{}\",\"ok\":{},",
            "\"time_ms\":{:.3},\"iterations\":{},\"launches\":{},\"k_max\":{},",
            "\"vertices\":{},\"edges\":{},",
            "\"metrics\":{{\"atomic_subs\":{},\"atomic_adds\":{},\"cas_retries\":{},",
            "\"edge_accesses\":{},\"hindex_evals\":{},\"frontier_pushes\":{}}}}}"
        ),
        json_escape(&r.dataset),
        json_escape(&r.algorithm),
        json_escape(&status(r)),
        r.ok(),
        r.elapsed_ms(),
        r.iterations,
        r.launches,
        r.k_max,
        r.vertices,
        r.edges,
        r.metrics.atomic_subs,
        r.metrics.atomic_adds,
        r.metrics.cas_retries,
        r.metrics.edge_accesses,
        r.metrics.hindex_evals,
        r.metrics.frontier_pushes,
    )
}

/// Machine-readable run/suite report (`pico run --json`,
/// `pico suite --json`) — one stable document per invocation so the perf
/// trajectory can be tracked across PRs.
pub fn render_results_json(results: &[JobResult]) -> String {
    let rows: Vec<String> = results.iter().map(result_json).collect();
    format!("{{\"results\":[{}]}}\n", rows.join(","))
}

/// Geometric mean of pairwise speedups (baseline time / candidate time),
/// the aggregate the paper quotes ("average speedup of 1.9x").
pub fn geomean_speedup(pairs: &[(f64, f64)]) -> f64 {
    if pairs.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = pairs
        .iter()
        .map(|&(base, cand)| (base / cand).ln())
        .sum();
    (log_sum / pairs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bbbb"]);
        t.row(vec!["xxx".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("a  bbbb") || s.contains("  a  bbbb"));
        assert!(s.lines().count() == 3);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        Table::new(&["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn json_report_shape() {
        use crate::engine::metrics::MetricsSnapshot;
        let r = JobResult {
            dataset: "g\"1".into(),
            algorithm: "PO-dyn".into(),
            outcome: JobOutcome::Ok,
            elapsed: std::time::Duration::from_millis(12),
            iterations: 3,
            launches: 9,
            k_max: 2,
            vertices: 6,
            edges: 7,
            metrics: MetricsSnapshot::default(),
        };
        let s = render_results_json(std::slice::from_ref(&r));
        assert!(s.starts_with("{\"results\":[{"), "{s}");
        assert!(s.contains("\"dataset\":\"g\\\"1\""), "{s}");
        assert!(s.contains("\"algorithm\":\"PO-dyn\""), "{s}");
        assert!(s.contains("\"ok\":true"), "{s}");
        assert!(s.contains("\"k_max\":2"), "{s}");
        assert!(s.contains("\"time_ms\":12."), "{s}");
        assert!(s.trim_end().ends_with("]}"), "{s}");
        // two results join with a comma
        let two = render_results_json(&[r.clone(), r]);
        assert!(two.contains("},{"), "{two}");
    }

    #[test]
    fn json_escape_control_chars() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean_speedup(&[(2.0, 1.0)]) - 2.0).abs() < 1e-12);
        // 4x and 1x -> 2x geometric mean
        assert!((geomean_speedup(&[(4.0, 1.0), (1.0, 1.0)]) - 2.0).abs() < 1e-12);
        assert!(geomean_speedup(&[]).is_nan());
    }
}
