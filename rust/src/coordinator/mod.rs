//! Layer-3 coordinator: the job scheduler that turns decomposition
//! requests (dataset × algorithm × options) into validated, instrumented
//! results.
//!
//! * [`job`] — job specs and results;
//! * [`registry`] — algorithm lookup by name (all eight native algorithms,
//!   the VC framework baseline, and the XLA vectorised engines);
//! * [`scheduler`] — admission (memory budget), dispatch, failure
//!   containment (a panicking job is reported, not fatal), aggregation;
//! * [`report`] — plain-text table rendering for results.

pub mod job;
pub mod registry;
pub mod report;
pub mod scheduler;

pub use job::{DatasetSpec, Job, JobOutcome, JobResult};
pub use registry::{algorithm_by_name, algorithm_names};
pub use scheduler::{Scheduler, SchedulerConfig};
