//! The scheduler: admission control, dispatch, failure containment.
//!
//! Jobs flow through three stages:
//! 1. **Admission** — resolve the algorithm, materialise the dataset,
//!    check it against the memory budget; failures become
//!    [`JobOutcome::Rejected`] results, never panics.
//! 2. **Dispatch** — jobs run on a pool of scheduler workers; each
//!    decomposition itself fans out over its own SPMD threads, so the
//!    scheduler default is one job at a time (`job_slots = 1`) and the
//!    knob exists for multi-tenant hosts.
//! 3. **Containment** — a panicking algorithm is caught
//!    (`catch_unwind`) and reported as [`JobOutcome::Panicked`]; the
//!    suite keeps running.

use super::job::{Job, JobOutcome, JobResult};
use super::registry::algorithm_by_name;
use crate::core::verify::check_against_oracle;
use crate::engine::metrics::MetricsSnapshot;
use crate::util::timer::Timer;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Scheduler tuning.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Concurrent job slots (decompositions already use all cores; keep 1
    /// unless jobs are tiny).
    pub job_slots: usize,
    /// Reject datasets whose resident CSR exceeds this (bytes).
    pub memory_budget: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            job_slots: 1,
            memory_budget: 8 << 30, // 8 GiB
        }
    }
}

/// Batch scheduler.
pub struct Scheduler {
    cfg: SchedulerConfig,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Self { cfg }
    }

    /// Run all jobs; results come back in submission order.
    pub fn run(&self, jobs: Vec<Job>) -> Vec<JobResult> {
        let n = jobs.len();
        let results: Vec<Mutex<Option<JobResult>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let cursor = AtomicUsize::new(0);
        let slots = self.cfg.job_slots.max(1).min(n.max(1));

        crossbeam_utils::thread::scope(|scope| {
            for _ in 0..slots {
                scope.spawn(|_| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let result = self.run_one(&jobs[i]);
                    *results[i].lock().unwrap() = Some(result);
                });
            }
        })
        .expect("scheduler worker panicked outside containment");

        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("job skipped"))
            .collect()
    }

    /// Run a single job through admission, dispatch, containment.
    pub fn run_one(&self, job: &Job) -> JobResult {
        let dataset_name = job.dataset.name();
        let rejected = |msg: String| JobResult {
            dataset: dataset_name.clone(),
            algorithm: job.algorithm.clone(),
            outcome: JobOutcome::Rejected(msg),
            elapsed: std::time::Duration::ZERO,
            iterations: 0,
            launches: 0,
            k_max: 0,
            vertices: 0,
            edges: 0,
            metrics: MetricsSnapshot::default(),
        };

        // --- admission ---
        let algo = match algorithm_by_name(&job.algorithm) {
            Ok(a) => a,
            Err(e) => return rejected(e.to_string()),
        };
        let g = match job.dataset.load() {
            Ok(g) => g,
            Err(e) => return rejected(format!("dataset load failed: {e}")),
        };
        if g.resident_bytes() > self.cfg.memory_budget {
            return rejected(format!(
                "graph needs {} bytes, budget is {}",
                g.resident_bytes(),
                self.cfg.memory_budget
            ));
        }

        // --- dispatch with containment ---
        let timer = Timer::start();
        let run = std::panic::catch_unwind(AssertUnwindSafe(|| {
            algo.decompose_with(&g, job.threads, job.metrics)
        }));
        let elapsed = timer.elapsed();

        match run {
            Err(panic) => {
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "opaque panic".into());
                JobResult {
                    dataset: dataset_name,
                    algorithm: job.algorithm.clone(),
                    outcome: JobOutcome::Panicked(msg),
                    elapsed,
                    iterations: 0,
                    launches: 0,
                    k_max: 0,
                    vertices: g.num_vertices() as u64,
                    edges: g.num_edges(),
                    metrics: MetricsSnapshot::default(),
                }
            }
            Ok(r) => {
                let outcome = if job.validate {
                    match check_against_oracle(&g, &r.core) {
                        Ok(()) => JobOutcome::Ok,
                        Err(e) => JobOutcome::ValidationFailed(e),
                    }
                } else {
                    JobOutcome::Ok
                };
                JobResult {
                    dataset: dataset_name,
                    algorithm: job.algorithm.clone(),
                    outcome,
                    elapsed,
                    iterations: r.iterations,
                    launches: r.launches,
                    k_max: r.k_max(),
                    vertices: g.num_vertices() as u64,
                    edges: g.num_edges(),
                    metrics: r.metrics,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::DatasetSpec;
    use crate::graph::examples;
    use std::sync::Arc;

    fn g1_job(algo: &str) -> Job {
        Job::new(DatasetSpec::InMemory(Arc::new(examples::g1())), algo).with_threads(2)
    }

    #[test]
    fn runs_and_validates() {
        let s = Scheduler::new(SchedulerConfig::default());
        let r = s.run_one(&g1_job("PO-dyn"));
        assert!(r.ok(), "{:?}", r.outcome);
        assert_eq!(r.k_max, 2);
        assert_eq!(r.vertices, 6);
    }

    #[test]
    fn unknown_algorithm_rejected() {
        let s = Scheduler::new(SchedulerConfig::default());
        let r = s.run_one(&g1_job("NopeCore"));
        assert!(matches!(r.outcome, JobOutcome::Rejected(_)));
    }

    #[test]
    fn memory_budget_enforced() {
        let s = Scheduler::new(SchedulerConfig {
            memory_budget: 8, // 8 bytes: nothing fits
            ..Default::default()
        });
        let r = s.run_one(&g1_job("PeelOne"));
        assert!(matches!(r.outcome, JobOutcome::Rejected(ref m) if m.contains("budget")));
    }

    #[test]
    fn batch_preserves_order() {
        let s = Scheduler::new(SchedulerConfig::default());
        let jobs = vec![g1_job("BZ"), g1_job("PeelOne"), g1_job("HistoCore")];
        let rs = s.run(jobs);
        assert_eq!(rs.len(), 3);
        assert_eq!(rs[0].algorithm, "BZ");
        assert_eq!(rs[1].algorithm, "PeelOne");
        assert_eq!(rs[2].algorithm, "HistoCore");
        assert!(rs.iter().all(|r| r.ok()));
    }

    #[test]
    fn dataset_load_failure_is_rejection() {
        let s = Scheduler::new(SchedulerConfig::default());
        let j = Job::new(DatasetSpec::Path("/nonexistent/x.el".into()), "BZ");
        let r = s.run_one(&j);
        assert!(matches!(r.outcome, JobOutcome::Rejected(ref m) if m.contains("load failed")));
    }
}
