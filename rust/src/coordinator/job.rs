//! Job model: what to run, on what, and what came back.

use crate::engine::metrics::MetricsSnapshot;
use crate::graph::CsrGraph;
use std::sync::Arc;
use std::time::Duration;

/// Where a job's graph comes from.
#[derive(Clone)]
pub enum DatasetSpec {
    /// Already materialised (generated suites, tests).
    InMemory(Arc<CsrGraph>),
    /// Load from a file at admission time.
    Path(std::path::PathBuf),
    /// Generate lazily from a named generator closure.
    Lazy {
        name: String,
        build: Arc<dyn Fn() -> CsrGraph + Send + Sync>,
    },
}

impl DatasetSpec {
    /// Resolve a user-supplied dataset argument — suite name first, then
    /// filesystem path — shared by `pico run`/`serve` and the protocol's
    /// OPEN verb so the two surfaces can't drift.
    pub fn resolve(name: &str) -> anyhow::Result<DatasetSpec> {
        if let Some(entry) = crate::bench::suite::by_name(name) {
            return Ok(DatasetSpec::Lazy {
                name: entry.name.to_string(),
                build: Arc::new(move || entry.build()),
            });
        }
        let path = std::path::Path::new(name);
        if path.exists() {
            return Ok(DatasetSpec::Path(path.to_path_buf()));
        }
        anyhow::bail!("'{name}' is neither a suite dataset (see `pico list`) nor a file")
    }

    pub fn name(&self) -> String {
        match self {
            DatasetSpec::InMemory(g) => g.name.clone(),
            DatasetSpec::Path(p) => p
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| p.display().to_string()),
            DatasetSpec::Lazy { name, .. } => name.clone(),
        }
    }

    /// Materialise the graph.
    pub fn load(&self) -> anyhow::Result<Arc<CsrGraph>> {
        match self {
            DatasetSpec::InMemory(g) => Ok(g.clone()),
            DatasetSpec::Path(p) => Ok(Arc::new(crate::graph::io::load(p)?)),
            DatasetSpec::Lazy { build, .. } => Ok(Arc::new(build())),
        }
    }
}

impl std::fmt::Debug for DatasetSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "DatasetSpec({})", self.name())
    }
}

/// One decomposition request.
#[derive(Clone, Debug)]
pub struct Job {
    pub dataset: DatasetSpec,
    /// Registry name (`PeelOne`, `HistoCore`, `VecPeel(XLA)`, …).
    pub algorithm: String,
    pub threads: usize,
    pub metrics: bool,
    /// Validate the output against the BZ oracle.
    pub validate: bool,
}

impl Job {
    pub fn new(dataset: DatasetSpec, algorithm: impl Into<String>) -> Self {
        Self {
            dataset,
            algorithm: algorithm.into(),
            threads: crate::util::default_threads(),
            metrics: false,
            validate: true,
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_metrics(mut self, metrics: bool) -> Self {
        self.metrics = metrics;
        self
    }

    pub fn with_validation(mut self, validate: bool) -> Self {
        self.validate = validate;
        self
    }
}

/// Terminal state of a job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobOutcome {
    /// Completed; coreness validated if requested.
    Ok,
    /// Completed but the oracle check failed (message).
    ValidationFailed(String),
    /// Rejected at admission (unknown algorithm, load failure, budget).
    Rejected(String),
    /// The algorithm panicked (contained; message).
    Panicked(String),
}

/// What came back.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub dataset: String,
    pub algorithm: String,
    pub outcome: JobOutcome,
    pub elapsed: Duration,
    pub iterations: usize,
    pub launches: usize,
    pub k_max: u32,
    pub vertices: u64,
    pub edges: u64,
    pub metrics: MetricsSnapshot,
}

impl JobResult {
    pub fn ok(&self) -> bool {
        self.outcome == JobOutcome::Ok
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed.as_secs_f64() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;

    #[test]
    fn dataset_names() {
        let g = Arc::new(examples::g1());
        assert_eq!(DatasetSpec::InMemory(g).name(), "G1");
        assert_eq!(
            DatasetSpec::Path("/tmp/foo.el".into()).name(),
            "foo"
        );
        let lazy = DatasetSpec::Lazy {
            name: "lz".into(),
            build: Arc::new(|| examples::g1()),
        };
        assert_eq!(lazy.name(), "lz");
        assert_eq!(lazy.load().unwrap().num_vertices(), 6);
    }

    #[test]
    fn job_builder() {
        let j = Job::new(DatasetSpec::InMemory(Arc::new(examples::g1())), "PeelOne")
            .with_threads(3)
            .with_metrics(true)
            .with_validation(false);
        assert_eq!(j.threads, 3);
        assert!(j.metrics);
        assert!(!j.validate);
    }
}
