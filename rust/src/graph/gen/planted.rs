//! Planted-coreness generators: graphs with a *known, controllable* core
//! hierarchy. These are the deep-hierarchy web-graph analogs (paper's
//! indochina-2004 with k_max = 6869, hollywood-2009 with k_max = 2208) and
//! double as exact-answer oracles for tests — [`nested_cliques`] returns
//! the expected coreness alongside the graph.

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::{CsrGraph, VertexId};
use crate::util::rng::Rng;

/// Clique chain: `levels` cliques of sizes `base, base+step, …`, adjacent
/// cliques joined by a single bridge edge.
///
/// Exact coreness: every member of the clique of size `s` has coreness
/// `s − 1`. A bridge raises one endpoint's *degree* but not its coreness —
/// an s-core containing a K_s member would need all members at degree ≥ s
/// inside the subgraph, which the other K_s members (degree s−1) cannot
/// supply, and the bridge leads to a clique that cannot sustain it either
/// (its own members cap out at their clique bound). This yields a
/// staircase hierarchy with k_max = base + (levels−1)·step − 1 and a
/// *fixed* peel depth, the regime where the paper's Table VII shows
/// HistoCore beating PO-dyn (l2 ≪ l1 = k_max).
///
/// Returns (graph, expected coreness).
pub fn nested_cliques(levels: usize, base: usize, step: usize) -> (CsrGraph, Vec<u32>) {
    assert!(levels >= 1 && base >= 2);
    let sizes: Vec<usize> = (0..levels).map(|i| base + i * step).collect();
    let n: usize = sizes.iter().sum();
    let m: usize = sizes.iter().map(|s| s * (s - 1) / 2).sum::<usize>() + levels - 1;
    let mut b = GraphBuilder::with_capacity(n, m);
    let mut expected = vec![0u32; n];
    let mut offset = 0usize;
    let mut prev_first: Option<VertexId> = None;
    for &s in &sizes {
        for i in 0..s {
            expected[offset + i] = (s - 1) as u32;
            for j in (i + 1)..s {
                b.add_edge((offset + i) as VertexId, (offset + j) as VertexId);
            }
        }
        if let Some(p) = prev_first {
            // single bridge between consecutive cliques (coreness-neutral)
            b.add_edge(p, offset as VertexId);
        }
        prev_first = Some(offset as VertexId);
        offset += s;
    }
    let g = b.build(format!("cliques_l{levels}_b{base}_s{step}"));
    (g, expected)
}

/// Planted-core graph: a random power-law background with an embedded
/// dense core ladder. `ladder` entries are `(member_count, internal_degree)`:
/// each rung adds a random near-regular subgraph over the vertex prefix
/// `[0, member_count)`, so inner prefixes accumulate density — a controlled
/// deep hierarchy without the O(n²) edges of [`nested_cliques`]. Coreness
/// is not closed-form here; use the BZ oracle for ground truth.
pub fn planted_core(
    n: usize,
    background_edges: usize,
    ladder: &[(usize, usize)],
    seed: u64,
) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let cap = background_edges + ladder.iter().map(|&(c, d)| c * d / 2).sum::<usize>();
    let mut b = GraphBuilder::with_capacity(n, cap);
    // sparse background
    for _ in 0..background_edges {
        let u = rng.below_usize(n) as VertexId;
        let v = rng.below_usize(n) as VertexId;
        b.add_edge(u, v);
    }
    // dense rungs over vertex prefixes (rung i over vertices [0, count_i))
    for &(count, internal_degree) in ladder {
        let count = count.min(n);
        if count < 2 {
            continue;
        }
        let target_edges = count * internal_degree / 2;
        for _ in 0..target_edges {
            let u = rng.below_usize(count) as VertexId;
            let v = rng.below_usize(count) as VertexId;
            b.add_edge(u, v);
        }
    }
    b.build(format!("planted_n{n}_l{}", ladder.len()))
}

/// Core–periphery graph: a small deep core (clique of `core_size`) inside
/// a large sparse periphery (random tree + a few extra edges), connected
/// by a handful of bridges.
///
/// This is the structural regime of the paper's HistoCore-winning
/// datasets (indochina-2004, webbase-2001, it-2004): k_max is set by the
/// small core while |V| is set by the periphery, so the Peel paradigm's
/// l1 = k_max levels each pay an O(|V|) scan — l1·|V| ≫ |E| — while
/// Index2core converges in a handful of sweeps over mostly-settled
/// estimates.
pub fn core_periphery(periphery: usize, core_size: usize, seed: u64) -> CsrGraph {
    assert!(core_size >= 2);
    let n = periphery + core_size;
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, periphery * 2 + core_size * core_size / 2);
    // periphery: random recursive tree + sprinkle of extra edges
    for v in 1..periphery {
        let parent = rng.below_usize(v) as VertexId;
        b.add_edge(v as VertexId, parent);
    }
    for _ in 0..periphery / 4 {
        let u = rng.below_usize(periphery) as VertexId;
        let v = rng.below_usize(periphery) as VertexId;
        b.add_edge(u, v);
    }
    // the deep core
    let base = periphery as VertexId;
    for i in 0..core_size as VertexId {
        for j in (i + 1)..core_size as VertexId {
            b.add_edge(base + i, base + j);
        }
    }
    // bridges
    for i in 0..8.min(core_size) {
        let p = rng.below_usize(periphery.max(1)) as VertexId;
        b.add_edge(base + i as VertexId, p);
    }
    b.build(format!("coreperiph_p{periphery}_c{core_size}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clique_chain_structure() {
        let (g, expected) = nested_cliques(3, 4, 2); // sizes 4, 6, 8
        assert_eq!(g.validate(), Ok(()));
        assert_eq!(g.num_vertices(), 18);
        // K4: coreness 3, K6: 5, K8: 7
        assert_eq!(expected[0], 3);
        assert_eq!(expected[4], 5);
        assert_eq!(expected[10], 7);
        // 2 bridges
        let clique_edges = 4 * 3 / 2 + 6 * 5 / 2 + 8 * 7 / 2;
        assert_eq!(g.num_edges() as usize, clique_edges + 2);
    }

    #[test]
    fn clique_chain_kmax() {
        let (_, expected) = nested_cliques(5, 3, 3); // biggest clique 15
        assert_eq!(*expected.iter().max().unwrap(), 14);
    }

    #[test]
    fn planted_core_valid() {
        let g = planted_core(5000, 10_000, &[(1000, 8), (200, 24), (50, 40)], 11);
        assert_eq!(g.validate(), Ok(()));
        assert!(g.num_edges() > 10_000);
    }

    #[test]
    fn core_periphery_structure() {
        let g = core_periphery(10_000, 60, 5);
        assert_eq!(g.validate(), Ok(()));
        let core = crate::core::bz::bz_coreness(&g);
        let k_max = *core.iter().max().unwrap();
        assert!(k_max >= 59, "core sets k_max, got {k_max}");
        // periphery is shallow
        let shallow = core.iter().filter(|&&c| c <= 3).count();
        assert!(shallow > 9_000);
    }

    #[test]
    fn planted_deterministic() {
        let a = planted_core(1000, 2000, &[(100, 10)], 3);
        let b = planted_core(1000, 2000, &[(100, 10)], 3);
        assert_eq!(a, b);
    }
}
