//! Synthetic graph generators — the workload substitute for the paper's 24
//! public datasets (Table II). Each generator is deterministic in its seed
//! and targets one of the paper's dataset categories:
//!
//! | Generator | Paper category analog | Key property |
//! |---|---|---|
//! | [`erdos_renyi`] | baseline | homogeneous degrees, tiny k_max |
//! | [`barabasi_albert`] | social / collaboration | power-law, k_max = m |
//! | [`rmat`] | social (twitter/sinaweibo) | skewed power-law, hubs |
//! | [`power_law_cluster`] | collaboration (hollywood) | power-law + triangles |
//! | [`planted_core`] | web graphs (deep hierarchy) | controlled large k_max |
//! | [`star_burst`] | communication (wiki-Talk) | extreme hub skew, small k_max |
//! | [`grid2d`] | mesh/road-like | uniform, k_max = 2..3 |
//! | [`caveman`] | community structure | clique hierarchy |

pub mod models;
pub mod planted;

pub use models::{barabasi_albert, caveman, erdos_renyi, grid2d, power_law_cluster, rmat, star_burst};
pub use planted::{core_periphery, nested_cliques, planted_core};
