//! Classical random-graph models (ER, BA, RMAT, power-law-cluster,
//! star-burst, grid, caveman). All deterministic in the seed.

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::{CsrGraph, VertexId};
use crate::util::rng::Rng;

/// Erdős–Rényi G(n, m): `m` uniform random distinct edges.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, m);
    // Oversample: dedup in the builder removes collisions; for the sparse
    // regimes we use (m << n^2/2) the loss is small, so top up in rounds.
    let mut added = 0usize;
    while added < m {
        let u = rng.below_usize(n) as VertexId;
        let v = rng.below_usize(n) as VertexId;
        if u != v {
            b.add_edge(u, v);
            added += 1;
        }
    }
    b.build(format!("er_n{n}_m{m}"))
}

/// Barabási–Albert preferential attachment: each new vertex attaches `m`
/// edges to existing vertices proportionally to degree. Classic power-law
/// social-network analog; coreness is m for the bulk of vertices.
pub fn barabasi_albert(n: usize, m: usize, seed: u64) -> CsrGraph {
    assert!(n > m && m >= 1);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, n * m);
    // Repeated-endpoint list: sampling uniformly from it = degree-biased.
    let mut endpoints: Vec<VertexId> = Vec::with_capacity(2 * n * m);
    // Seed clique over the first m+1 vertices.
    for u in 0..=(m as VertexId) {
        for v in (u + 1)..=(m as VertexId) {
            b.add_edge(u, v);
            endpoints.push(u);
            endpoints.push(v);
        }
    }
    for v in (m + 1)..n {
        let v = v as VertexId;
        let mut targets = Vec::with_capacity(m);
        while targets.len() < m {
            let t = endpoints[rng.below_usize(endpoints.len())];
            if t != v && !targets.contains(&t) {
                targets.push(t);
            }
        }
        for &t in &targets {
            b.add_edge(v, t);
            endpoints.push(v);
            endpoints.push(t);
        }
    }
    b.build(format!("ba_n{n}_m{m}"))
}

/// R-MAT recursive matrix model (Chakrabarti et al.) — the standard
/// twitter-scale power-law analog. `scale` ⇒ n = 2^scale vertices;
/// `edge_factor` edges per vertex; (a,b,c,d) the quadrant probabilities.
pub fn rmat(scale: u32, edge_factor: usize, a: f64, b: f64, c: f64, seed: u64) -> CsrGraph {
    let n = 1usize << scale;
    let m = n * edge_factor;
    let d = 1.0 - a - b - c;
    assert!(d >= 0.0, "rmat probabilities must sum <= 1");
    let mut rng = Rng::new(seed);
    let mut builder = GraphBuilder::with_capacity(n, m);
    for _ in 0..m {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            u <<= 1;
            v <<= 1;
            let r = rng.f64();
            if r < a {
                // top-left
            } else if r < a + b {
                v |= 1;
            } else if r < a + b + c {
                u |= 1;
            } else {
                u |= 1;
                v |= 1;
            }
        }
        if u != v {
            builder.add_edge(u as VertexId, v as VertexId);
        }
    }
    builder.build(format!("rmat_s{scale}_e{edge_factor}"))
}

/// Holme–Kim power-law-cluster model: BA attachment where each of the `m`
/// links is followed (w.p. `p_triad`) by a triad-closing edge to a random
/// neighbor of the new target — collaboration-network analog (many
/// triangles, higher coreness than plain BA).
pub fn power_law_cluster(n: usize, m: usize, p_triad: f64, seed: u64) -> CsrGraph {
    assert!(n > m && m >= 1);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, n * m * 2);
    let mut endpoints: Vec<VertexId> = Vec::new();
    let mut adj: Vec<Vec<VertexId>> = vec![Vec::new(); n];
    let connect = |b: &mut GraphBuilder,
                       adj: &mut Vec<Vec<VertexId>>,
                       endpoints: &mut Vec<VertexId>,
                       u: VertexId,
                       v: VertexId| {
        b.add_edge(u, v);
        adj[u as usize].push(v);
        adj[v as usize].push(u);
        endpoints.push(u);
        endpoints.push(v);
    };
    for u in 0..=(m as VertexId) {
        for v in (u + 1)..=(m as VertexId) {
            connect(&mut b, &mut adj, &mut endpoints, u, v);
        }
    }
    for v in (m + 1)..n {
        let v = v as VertexId;
        let mut last_target: Option<VertexId> = None;
        let mut added = 0usize;
        let mut guard = 0usize;
        while added < m && guard < 50 * m {
            guard += 1;
            let t = if let Some(lt) = last_target.filter(|_| rng.chance(p_triad)) {
                // triad closure: a random neighbor of the last target
                let nbrs = &adj[lt as usize];
                nbrs[rng.below_usize(nbrs.len())]
            } else {
                endpoints[rng.below_usize(endpoints.len())]
            };
            if t != v && !adj[v as usize].contains(&t) {
                connect(&mut b, &mut adj, &mut endpoints, v, t);
                last_target = Some(t);
                added += 1;
            }
        }
    }
    b.build(format!("plc_n{n}_m{m}"))
}

/// Star-burst: `hubs` mega-hubs each with `leaves_per_hub` leaves, plus a
/// sparse ER background. Communication-graph analog (wiki-Talk: huge
/// d_max, large frontier churn, small k_max).
pub fn star_burst(hubs: usize, leaves_per_hub: usize, background_edges: usize, seed: u64) -> CsrGraph {
    let n = hubs * (1 + leaves_per_hub);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, hubs * leaves_per_hub + background_edges);
    for h in 0..hubs {
        let hub = (h * (1 + leaves_per_hub)) as VertexId;
        for l in 1..=leaves_per_hub {
            b.add_edge(hub, hub + l as VertexId);
        }
        // ring among hubs so the graph is connected-ish
        if h > 0 {
            b.add_edge(hub, ((h - 1) * (1 + leaves_per_hub)) as VertexId);
        }
    }
    for _ in 0..background_edges {
        let u = rng.below_usize(n) as VertexId;
        let v = rng.below_usize(n) as VertexId;
        b.add_edge(u, v);
    }
    b.build(format!("starburst_h{hubs}"))
}

/// 2-D grid (rows × cols) — mesh/road analog; k_max = 2.
pub fn grid2d(rows: usize, cols: usize) -> CsrGraph {
    let mut b = GraphBuilder::with_capacity(rows * cols, 2 * rows * cols);
    let id = |r: usize, c: usize| (r * cols + c) as VertexId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                b.add_edge(id(r, c), id(r, c + 1));
            }
            if r + 1 < rows {
                b.add_edge(id(r, c), id(r + 1, c));
            }
        }
    }
    b.build(format!("grid_{rows}x{cols}"))
}

/// Connected caveman: `cliques` cliques of size `size`, neighbouring
/// cliques joined by one rewired edge. Community-structure analog;
/// coreness ≈ size−1 in the bulk.
pub fn caveman(cliques: usize, size: usize, seed: u64) -> CsrGraph {
    assert!(size >= 2 && cliques >= 1);
    let n = cliques * size;
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(n, cliques * size * size / 2);
    for c in 0..cliques {
        let base = (c * size) as VertexId;
        for i in 0..size as VertexId {
            for j in (i + 1)..size as VertexId {
                b.add_edge(base + i, base + j);
            }
        }
        // bridge to the next clique
        let next = (((c + 1) % cliques) * size) as VertexId;
        let from = base + rng.below_usize(size) as VertexId;
        let to = next + rng.below_usize(size) as VertexId;
        b.add_edge(from, to);
    }
    b.build(format!("caveman_{cliques}x{size}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_deterministic() {
        let a = erdos_renyi(100, 300, 7);
        let b = erdos_renyi(100, 300, 7);
        assert_eq!(a, b);
        let c = erdos_renyi(100, 300, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn er_valid_and_sized() {
        let g = erdos_renyi(200, 800, 1);
        assert_eq!(g.validate(), Ok(()));
        assert_eq!(g.num_vertices(), 200);
        // dedup can only lose a few edges at this density
        assert!(g.num_edges() > 700);
    }

    #[test]
    fn ba_power_law_hubs() {
        let g = barabasi_albert(2000, 4, 42);
        assert_eq!(g.validate(), Ok(()));
        // min degree is m (attachment count) for non-seed vertices
        let degs = g.degrees();
        assert!(degs.iter().filter(|&&d| d >= 4).count() > 1900);
        // power law: the max degree should be far above the mean
        let mean = degs.iter().map(|&d| d as f64).sum::<f64>() / degs.len() as f64;
        assert!(g.max_degree() as f64 > 5.0 * mean);
    }

    #[test]
    fn rmat_skew() {
        let g = rmat(10, 8, 0.57, 0.19, 0.19, 3);
        assert_eq!(g.validate(), Ok(()));
        assert_eq!(g.num_vertices(), 1024);
        let mean = g.degrees().iter().map(|&d| d as f64).sum::<f64>() / 1024.0;
        assert!(g.max_degree() as f64 > 4.0 * mean, "rmat should be skewed");
    }

    #[test]
    fn plc_has_more_triangles_than_ba() {
        // Proxy: coreness bulk should be >= m thanks to triad closure —
        // here we just check structural validity and size.
        let g = power_law_cluster(1000, 3, 0.8, 5);
        assert_eq!(g.validate(), Ok(()));
        assert!(g.num_edges() >= 2900);
    }

    #[test]
    fn starburst_hub_skew() {
        let g = star_burst(4, 500, 100, 9);
        assert_eq!(g.validate(), Ok(()));
        assert!(g.max_degree() >= 500);
    }

    #[test]
    fn grid_degrees() {
        let g = grid2d(10, 10);
        assert_eq!(g.validate(), Ok(()));
        assert_eq!(g.num_edges(), 180);
        assert_eq!(g.max_degree(), 4);
    }

    #[test]
    fn caveman_cliques() {
        let g = caveman(10, 6, 2);
        assert_eq!(g.validate(), Ok(()));
        assert_eq!(g.num_vertices(), 60);
        // every clique member has degree >= size-1
        assert!(g.degrees().iter().all(|&d| d >= 5));
    }
}
