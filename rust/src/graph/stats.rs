//! Graph statistics — the columns of the paper's Table II
//! (|V|, |E|, d_avg, std, d_max, and k_max via the BZ oracle).

use super::csr::CsrGraph;

/// Statistical properties of a dataset (Table II row).
#[derive(Clone, Debug, PartialEq)]
pub struct GraphStats {
    pub name: String,
    pub vertices: u64,
    pub edges: u64,
    pub d_avg: f64,
    pub d_std: f64,
    pub d_max: u32,
    /// Max coreness; computed lazily (needs a decomposition) — `None`
    /// until [`GraphStats::with_kmax`] fills it.
    pub k_max: Option<u32>,
}

impl GraphStats {
    /// Degree-level statistics (cheap, no decomposition).
    pub fn measure(g: &CsrGraph) -> Self {
        let n = g.num_vertices() as u64;
        let degs = g.degrees();
        let sum: f64 = degs.iter().map(|&d| d as f64).sum();
        let d_avg = if n == 0 { 0.0 } else { sum / n as f64 };
        let var: f64 = if n == 0 {
            0.0
        } else {
            degs.iter().map(|&d| (d as f64 - d_avg).powi(2)).sum::<f64>() / n as f64
        };
        Self {
            name: g.name.clone(),
            vertices: n,
            edges: g.num_edges(),
            d_avg,
            d_std: var.sqrt(),
            d_max: g.max_degree(),
            k_max: None,
        }
    }

    /// Attach the max coreness from a computed decomposition.
    pub fn with_kmax(mut self, core: &[u32]) -> Self {
        self.k_max = core.iter().copied().max();
        self
    }

    /// Degree skew: d_max / d_avg — the property (paper §V-A2,
    /// `trackers`) that predicts dynamic-frontier pathologies.
    pub fn skew(&self) -> f64 {
        if self.d_avg == 0.0 {
            0.0
        } else {
            self.d_max as f64 / self.d_avg
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;

    #[test]
    fn g1_stats() {
        let s = GraphStats::measure(&examples::g1());
        assert_eq!(s.vertices, 6);
        assert_eq!(s.edges, 7);
        assert_eq!(s.d_max, 4);
        // degrees 1,1,2,3,3,4 -> mean 14/6
        assert!((s.d_avg - 14.0 / 6.0).abs() < 1e-9);
        assert!(s.k_max.is_none());
    }

    #[test]
    fn kmax_attach() {
        let s = GraphStats::measure(&examples::g1()).with_kmax(&examples::g1_coreness());
        assert_eq!(s.k_max, Some(2));
    }

    #[test]
    fn empty_graph_stats() {
        let g = crate::graph::CsrGraph::from_parts(vec![0], vec![], "empty");
        let s = GraphStats::measure(&g);
        assert_eq!(s.vertices, 0);
        assert_eq!(s.d_avg, 0.0);
        assert_eq!(s.skew(), 0.0);
    }

    #[test]
    fn star_skew_is_high() {
        let s = GraphStats::measure(&examples::star(100));
        assert!(s.skew() > 25.0);
    }
}
