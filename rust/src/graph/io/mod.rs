//! Graph loaders and the binary cache.
//!
//! Supported input formats (auto-detected by extension in [`load`]):
//! * `.txt` / `.el` — whitespace edge list, `#`/`%` comments (SNAP style)
//! * `.mtx` — MatrixMarket coordinate (1-based, header skipped)
//! * `.gz` suffix on any of the above — gzip-compressed
//! * `.pico` — this crate's binary CSR cache (fast reload)

pub mod binfmt;
pub mod edgelist;
pub mod mtx;

use crate::graph::csr::CsrGraph;
use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

/// Load a graph, dispatching on the file extension.
pub fn load(path: impl AsRef<Path>) -> Result<CsrGraph> {
    let path = path.as_ref();
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "unnamed".into());
    let lower = path.to_string_lossy().to_lowercase();

    if lower.ends_with(".pico") {
        return binfmt::read_file(path);
    }

    let text = read_maybe_gz(path)?;
    if lower.ends_with(".mtx") || lower.ends_with(".mtx.gz") {
        mtx::parse(&text, &name)
    } else if lower.ends_with(".txt")
        || lower.ends_with(".el")
        || lower.ends_with(".txt.gz")
        || lower.ends_with(".el.gz")
        || lower.ends_with(".edges")
        || lower.ends_with(".edges.gz")
    {
        edgelist::parse(&text, &name)
    } else {
        bail!("unrecognised graph format: {}", path.display())
    }
}

/// Read a file into a string, transparently decompressing `.gz`.
pub fn read_maybe_gz(path: &Path) -> Result<String> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if path.to_string_lossy().ends_with(".gz") {
        let mut decoder = flate2::read::GzDecoder::new(&bytes[..]);
        let mut out = String::new();
        decoder
            .read_to_string(&mut out)
            .with_context(|| format!("gunzip {}", path.display()))?;
        Ok(out)
    } else {
        Ok(String::from_utf8(bytes).context("graph file is not UTF-8")?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn dispatch_edgelist() {
        let dir = std::env::temp_dir().join("pico_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tiny.el");
        std::fs::write(&p, "# comment\n0 1\n1 2\n").unwrap();
        let g = load(&p).unwrap();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.name, "tiny");
    }

    #[test]
    fn dispatch_gz() {
        let dir = std::env::temp_dir().join("pico_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tiny2.el.gz");
        let f = std::fs::File::create(&p).unwrap();
        let mut enc = flate2::write::GzEncoder::new(f, flate2::Compression::default());
        enc.write_all(b"0 1\n0 2\n1 2\n").unwrap();
        enc.finish().unwrap();
        let g = load(&p).unwrap();
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn unknown_extension_errors() {
        assert!(load("/tmp/does_not_exist.xyz").is_err());
    }
}
