//! `.pico` binary CSR cache: magic, version, name, offsets, adjacency —
//! all little-endian. Reloading a cached multi-million-edge graph is ~100×
//! faster than re-parsing text, which keeps the bench suite iterable.

use crate::graph::csr::{CsrGraph, VertexId};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"PICOCSR1";

/// Write `g` to `path` in binary form.
pub fn write_file(g: &CsrGraph, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    let name = g.name.as_bytes();
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name)?;
    w.write_all(&(g.offsets().len() as u64).to_le_bytes())?;
    w.write_all(&(g.adjacency().len() as u64).to_le_bytes())?;
    for &o in g.offsets() {
        w.write_all(&o.to_le_bytes())?;
    }
    for &a in g.adjacency() {
        w.write_all(&a.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read a graph previously written by [`write_file`].
pub fn read_file(path: impl AsRef<Path>) -> Result<CsrGraph> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut r = BufReader::new(f);

    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a .pico file (bad magic)");
    }
    let name_len = read_u32(&mut r)? as usize;
    if name_len > 4096 {
        bail!("unreasonable name length {name_len}");
    }
    let mut name_bytes = vec![0u8; name_len];
    r.read_exact(&mut name_bytes)?;
    let name = String::from_utf8(name_bytes).context("name not UTF-8")?;

    let offsets_len = read_u64(&mut r)? as usize;
    let adjacency_len = read_u64(&mut r)? as usize;
    if offsets_len == 0 {
        bail!("offsets array empty");
    }
    let mut offsets = vec![0u64; offsets_len];
    for o in offsets.iter_mut() {
        *o = read_u64(&mut r)?;
    }
    let mut adjacency = vec![0 as VertexId; adjacency_len];
    for a in adjacency.iter_mut() {
        *a = read_u32(&mut r)?;
    }

    // try_from_parts: a corrupt file must come back as an error, not a
    // debug assertion, whatever the build profile
    CsrGraph::try_from_parts(offsets, adjacency, name)
        .map_err(|e| anyhow::anyhow!("corrupt .pico file: {e}"))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("pico_binfmt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g1.pico");
        let g = examples::g1();
        write_file(&g, &p).unwrap();
        let g2 = read_file(&p).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("pico_binfmt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.pico");
        std::fs::write(&p, b"NOTPICO!xxxxxxxxxxxx").unwrap();
        assert!(read_file(&p).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let dir = std::env::temp_dir().join("pico_binfmt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trunc.pico");
        let g = examples::complete(10);
        write_file(&g, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(read_file(&p).is_err());
    }
}
