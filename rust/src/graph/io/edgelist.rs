//! Whitespace edge-list parser (SNAP `.txt` style): one `u v` pair per
//! line, `#` or `%` comment lines, arbitrary (possibly sparse) vertex ids
//! remapped densely in order of first appearance when they exceed a
//! density threshold, kept as-is otherwise.

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::CsrGraph;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Parse edge-list text into a graph named `name`.
pub fn parse(text: &str, name: &str) -> Result<CsrGraph> {
    // First pass: collect raw pairs and the max id.
    let mut raw: Vec<(u64, u64)> = Vec::new();
    let mut max_id = 0u64;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let u: u64 = it
            .next()
            .context("missing source id")?
            .parse()
            .with_context(|| format!("line {}: bad source id", lineno + 1))?;
        let v: u64 = match it.next() {
            Some(tok) => tok
                .parse()
                .with_context(|| format!("line {}: bad target id", lineno + 1))?,
            None => bail!("line {}: missing target id", lineno + 1),
        };
        max_id = max_id.max(u).max(v);
        raw.push((u, v));
    }

    if raw.is_empty() {
        return Ok(GraphBuilder::new(0).build(name));
    }

    // Dense ids: keep as-is when the id space is reasonably filled,
    // otherwise remap (avoids 2^32-sized offset arrays for sparse ids).
    let dense_enough = (max_id as usize) < raw.len().saturating_mul(4).max(1024);
    let mut b = GraphBuilder::with_capacity(0, raw.len());
    if dense_enough && max_id < u32::MAX as u64 {
        for (u, v) in raw {
            b.add_edge(u as u32, v as u32);
        }
    } else {
        let mut remap: HashMap<u64, u32> = HashMap::new();
        let mut next = 0u32;
        let mut id = |x: u64, remap: &mut HashMap<u64, u32>| -> Result<u32> {
            if let Some(&i) = remap.get(&x) {
                return Ok(i);
            }
            if next == u32::MAX {
                bail!("more than 2^32 distinct vertex ids");
            }
            remap.insert(x, next);
            next += 1;
            Ok(next - 1)
        };
        for (u, v) in raw {
            let iu = id(u, &mut remap)?;
            let iv = id(v, &mut remap)?;
            b.add_edge(iu, iv);
        }
    }
    Ok(b.build(name))
}

/// Serialise a graph to edge-list text (round-trip / export).
pub fn serialize(g: &CsrGraph) -> String {
    let mut out = String::with_capacity(g.num_edges() as usize * 8);
    out.push_str(&format!("# pico edge list: {} ({} vertices, {} edges)\n", g.name, g.num_vertices(), g.num_edges()));
    for u in 0..g.num_vertices() as u32 {
        for &v in g.neighbors(u) {
            if u < v {
                out.push_str(&format!("{u} {v}\n"));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_with_comments() {
        let g = parse("# header\n% alt comment\n0 1\n1 2\n2 0\n", "t").unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn sparse_ids_remapped() {
        let g = parse("1000000000 2000000000\n2000000000 3000000000\n", "t").unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn bad_line_errors() {
        assert!(parse("0\n", "t").is_err());
        assert!(parse("a b\n", "t").is_err());
    }

    #[test]
    fn empty_text_gives_empty_graph() {
        let g = parse("# nothing\n", "t").unwrap();
        assert_eq!(g.num_vertices(), 0);
    }

    #[test]
    fn round_trip() {
        let g = crate::graph::examples::g1();
        let text = serialize(&g);
        let g2 = parse(&text, "G1").unwrap();
        assert_eq!(g.offsets(), g2.offsets());
        assert_eq!(g.adjacency(), g2.adjacency());
    }

    #[test]
    fn tabs_and_extra_whitespace() {
        let g = parse("0\t1\n1   2\n", "t").unwrap();
        assert_eq!(g.num_edges(), 2);
    }
}
