//! MatrixMarket coordinate-format parser (the SuiteSparse distribution
//! format for several of the paper's datasets). Only the structure is
//! used: `%%MatrixMarket matrix coordinate <field> <symmetry>`, a
//! dimensions line, then 1-based `i j [value]` entries.

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::CsrGraph;
use anyhow::{bail, Context, Result};

/// Parse MatrixMarket text into an undirected simple graph.
pub fn parse(text: &str, name: &str) -> Result<CsrGraph> {
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());

    // Header (optional but usual).
    let mut first = lines.next().context("empty mtx file")?;
    if first.1.starts_with("%%MatrixMarket") {
        let header = first.1.to_lowercase();
        if !header.contains("coordinate") {
            bail!("only coordinate-format MatrixMarket supported");
        }
        // skip remaining comments
        loop {
            first = lines.next().context("mtx missing dimensions line")?;
            if !first.1.trim_start().starts_with('%') {
                break;
            }
        }
    } else if first.1.trim_start().starts_with('%') {
        loop {
            first = lines.next().context("mtx missing dimensions line")?;
            if !first.1.trim_start().starts_with('%') {
                break;
            }
        }
    }

    // Dimensions: rows cols nnz
    let dims: Vec<u64> = first
        .1
        .split_whitespace()
        .map(|t| t.parse::<u64>())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("line {}: bad dimensions", first.0 + 1))?;
    if dims.len() != 3 {
        bail!("mtx dimensions line must have 3 fields, got {}", dims.len());
    }
    let n = dims[0].max(dims[1]) as usize;
    let nnz = dims[2] as usize;

    let mut b = GraphBuilder::with_capacity(n, nnz);
    let mut seen = 0usize;
    for (lineno, line) in lines {
        let line = line.trim();
        if line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let i: u64 = it
            .next()
            .context("missing row")?
            .parse()
            .with_context(|| format!("line {}: bad row", lineno + 1))?;
        let j: u64 = it
            .next()
            .with_context(|| format!("line {}: missing col", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad col", lineno + 1))?;
        if i == 0 || j == 0 {
            bail!("line {}: MatrixMarket indices are 1-based", lineno + 1);
        }
        b.add_edge((i - 1) as u32, (j - 1) as u32);
        seen += 1;
    }
    if seen != nnz {
        // tolerated (some files count symmetric pairs differently) but
        // grossly wrong counts indicate truncation
        if seen * 2 < nnz {
            bail!("mtx truncated: header says {nnz} entries, found {seen}");
        }
    }
    Ok(b.build(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "%%MatrixMarket matrix coordinate pattern symmetric\n\
% a comment\n\
4 4 4\n\
1 2\n\
2 3\n\
3 4\n\
4 1\n";

    #[test]
    fn parses_sample() {
        let g = parse(SAMPLE, "c4").unwrap();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4); // a 4-cycle
        assert!(g.degrees().iter().all(|&d| d == 2));
    }

    #[test]
    fn zero_based_rejected() {
        assert!(parse("2 2 1\n0 1\n", "t").is_err());
    }

    #[test]
    fn headerless_ok() {
        let g = parse("3 3 2\n1 2\n2 3\n", "t").unwrap();
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn truncated_detected() {
        assert!(parse("5 5 100\n1 2\n", "t").is_err());
    }
}
