//! Graph substrate: CSR storage, construction, loaders, synthetic
//! generators, statistics, and the paper's running example `G1`.
//!
//! All decomposition algorithms in [`crate::core`] consume an immutable
//! [`CsrGraph`]; mutation happens only in [`GraphBuilder`].

pub mod builder;
pub mod csr;
pub mod examples;
pub mod gen;
pub mod io;
pub mod stats;

pub use builder::GraphBuilder;
pub use csr::{CsrGraph, VertexId};
pub use stats::GraphStats;
