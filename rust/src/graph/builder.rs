//! Mutable edge-set accumulator that canonicalises into [`CsrGraph`]:
//! undirected closure, self-loop stripping, duplicate removal, sorted
//! adjacency. All loaders and generators funnel through here so the CSR
//! invariants hold by construction.

use super::csr::{CsrGraph, VertexId};

/// Accumulates edges, then `build()`s a canonical CSR.
#[derive(Clone, Debug, Default)]
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(VertexId, VertexId)>,
}

impl GraphBuilder {
    /// Builder for a graph with (at least) `n` vertices. Adding an edge
    /// with a larger endpoint grows the vertex count.
    pub fn new(n: usize) -> Self {
        Self { n, edges: Vec::new() }
    }

    pub fn with_capacity(n: usize, m: usize) -> Self {
        Self {
            n,
            edges: Vec::with_capacity(m),
        }
    }

    /// Current vertex count.
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Number of (pre-dedup) edges added so far.
    pub fn num_pending_edges(&self) -> usize {
        self.edges.len()
    }

    /// Add an undirected edge. Self-loops are silently dropped (the k-core
    /// literature works on simple graphs); duplicates are removed at build.
    pub fn add_edge(&mut self, u: VertexId, v: VertexId) {
        if u == v {
            return;
        }
        let hi = u.max(v) as usize + 1;
        if hi > self.n {
            self.n = hi;
        }
        // store canonical (min, max): undirected dedup key
        self.edges.push((u.min(v), u.max(v)));
    }

    /// Bulk add.
    pub fn add_edges(&mut self, it: impl IntoIterator<Item = (VertexId, VertexId)>) {
        for (u, v) in it {
            self.add_edge(u, v);
        }
    }

    /// Canonicalise into CSR. O(E log E).
    pub fn build(mut self, name: impl Into<String>) -> CsrGraph {
        self.edges.sort_unstable();
        self.edges.dedup();
        let n = self.n;

        // Count degrees over both directions.
        let mut offsets = vec![0u64; n + 1];
        for &(u, v) in &self.edges {
            offsets[u as usize + 1] += 1;
            offsets[v as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }

        // Fill adjacency; edges are sorted by (u, v) so u-lists fill in
        // order, v-lists need a second sorted pass — easiest is cursor fill
        // then per-list sort, but since (u,v) sorted gives sorted u-lists
        // and v-entries arrive sorted by u too, cursor fill keeps every
        // list sorted already.
        let mut cursor: Vec<u64> = offsets[..n].to_vec();
        let mut adjacency = vec![0 as VertexId; *offsets.last().unwrap() as usize];
        for &(u, v) in &self.edges {
            adjacency[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
        }
        // v-direction: iterate again; (u,v) sorted by u then v means for a
        // fixed v the u's arrive ascending, so v-lists stay sorted only if
        // we interleave correctly — but u-entries (written above) for a
        // list all precede... they do not. Simplest correct approach:
        // write both directions then sort each list. Lists are short on
        // average; total cost O(E log d_max).
        for &(u, v) in &self.edges {
            adjacency[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
        for v in 0..n {
            let lo = offsets[v] as usize;
            let hi = offsets[v + 1] as usize;
            adjacency[lo..hi].sort_unstable();
        }

        CsrGraph::from_parts(offsets, adjacency, name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_and_selfloops() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 0); // duplicate, reversed
        b.add_edge(0, 1); // duplicate
        b.add_edge(2, 2); // self-loop dropped
        let g = b.build("t");
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.degree(2), 0);
        assert_eq!(g.validate(), Ok(()));
    }

    #[test]
    fn grows_vertex_count() {
        let mut b = GraphBuilder::new(0);
        b.add_edge(5, 9);
        let g = b.build("t");
        assert_eq!(g.num_vertices(), 10);
        assert!(g.has_edge(9, 5));
    }

    #[test]
    fn adjacency_sorted() {
        let mut b = GraphBuilder::new(6);
        b.add_edge(3, 5);
        b.add_edge(3, 1);
        b.add_edge(3, 4);
        b.add_edge(3, 0);
        let g = b.build("t");
        assert_eq!(g.neighbors(3), &[0, 1, 4, 5]);
        assert_eq!(g.validate(), Ok(()));
    }

    #[test]
    fn star_degrees() {
        let mut b = GraphBuilder::new(5);
        for i in 1..5 {
            b.add_edge(0, i);
        }
        let g = b.build("star");
        assert_eq!(g.degree(0), 4);
        for i in 1..5 {
            assert_eq!(g.degree(i), 1);
        }
    }
}
