//! Compressed Sparse Row graph storage — the layout every GPU k-core work
//! (and this reproduction) operates on: one array of concatenated
//! adjacency lists plus one offsets array (paper §II-B1).

/// Vertex identifier. 32-bit: the suite tops out well under 2^32 vertices,
/// and halving the index width doubles effective memory bandwidth on the
/// scatter-heavy hot path (same reasoning as the CUDA original).
pub type VertexId = u32;

/// An immutable undirected graph in CSR form.
///
/// Invariants (validated by [`CsrGraph::validate`] and enforced by
/// [`crate::graph::GraphBuilder`]):
/// * `offsets.len() == n + 1`, `offsets[0] == 0`, non-decreasing.
/// * `adjacency.len() == offsets[n]` = 2·|E| (each undirected edge stored
///   in both endpoint lists).
/// * No self-loops, no duplicate edges; each adjacency list is sorted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CsrGraph {
    offsets: Vec<u64>,
    adjacency: Vec<VertexId>,
    /// Optional human-readable name (dataset id in tables).
    pub name: String,
}

impl CsrGraph {
    /// Construct from raw parts. Prefer [`crate::graph::GraphBuilder`];
    /// this is for loaders that already produce canonical CSR.
    pub fn from_parts(offsets: Vec<u64>, adjacency: Vec<VertexId>, name: impl Into<String>) -> Self {
        let g = Self {
            offsets,
            adjacency,
            name: name.into(),
        };
        debug_assert!(g.validate().is_ok(), "invalid CSR: {:?}", g.validate());
        g
    }

    /// Fallible counterpart of [`Self::from_parts`] for *untrusted* input
    /// (wire bytes, cache files): runs full validation up front and
    /// returns the error instead of tripping a debug assertion.
    pub fn try_from_parts(
        offsets: Vec<u64>,
        adjacency: Vec<VertexId>,
        name: impl Into<String>,
    ) -> Result<Self, String> {
        let g = Self {
            offsets,
            adjacency,
            name: name.into(),
        };
        g.validate()?;
        Ok(g)
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn num_edges(&self) -> u64 {
        self.adjacency.len() as u64 / 2
    }

    /// Number of directed arcs (2·|E|) — the length of the adjacency array,
    /// which is what kernel workloads scale with.
    #[inline]
    pub fn num_arcs(&self) -> u64 {
        self.adjacency.len() as u64
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: VertexId) -> u32 {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as u32
    }

    /// Neighbors of `v`, sorted ascending.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        &self.adjacency[lo..hi]
    }

    /// The raw offsets array (length n+1).
    #[inline]
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// The raw adjacency array (length 2·|E|).
    #[inline]
    pub fn adjacency(&self) -> &[VertexId] {
        &self.adjacency
    }

    /// Degree vector.
    pub fn degrees(&self) -> Vec<u32> {
        (0..self.num_vertices() as VertexId).map(|v| self.degree(v)).collect()
    }

    /// Maximum degree (0 for the empty graph).
    pub fn max_degree(&self) -> u32 {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Whether the undirected edge (u, v) exists. O(log deg(u)).
    pub fn has_edge(&self, u: VertexId, v: VertexId) -> bool {
        self.neighbors(u).binary_search(&v).is_ok()
    }

    /// Full structural validation (used by loader tests & property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.offsets.is_empty() {
            return Err("offsets empty".into());
        }
        if self.offsets[0] != 0 {
            return Err("offsets[0] != 0".into());
        }
        let n = self.num_vertices();
        for i in 0..n {
            if self.offsets[i] > self.offsets[i + 1] {
                return Err(format!("offsets decrease at {i}"));
            }
        }
        if *self.offsets.last().unwrap() != self.adjacency.len() as u64 {
            return Err("offsets[n] != adjacency.len()".into());
        }
        if self.adjacency.len() % 2 != 0 {
            return Err("odd arc count (must be 2|E|)".into());
        }
        for v in 0..n as VertexId {
            let nbrs = self.neighbors(v);
            for w in nbrs.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("adjacency of {v} not strictly sorted"));
                }
            }
            for &u in nbrs {
                if u as usize >= n {
                    return Err(format!("neighbor {u} of {v} out of range"));
                }
                if u == v {
                    return Err(format!("self-loop at {v}"));
                }
                if !self.has_edge(u, v) {
                    return Err(format!("edge ({v},{u}) not symmetric"));
                }
            }
        }
        Ok(())
    }

    /// Approximate resident bytes (memory-budget checks in the coordinator).
    pub fn resident_bytes(&self) -> u64 {
        (self.offsets.len() * std::mem::size_of::<u64>()
            + self.adjacency.len() * std::mem::size_of::<VertexId>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn triangle() -> CsrGraph {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(0, 2);
        b.build("triangle")
    }

    #[test]
    fn basic_accessors() {
        let g = triangle();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.num_arcs(), 6);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn has_edge_symmetric() {
        let g = triangle();
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 0));
        assert!(!g.has_edge(0, 0));
    }

    #[test]
    fn validate_accepts_canonical() {
        assert_eq!(triangle().validate(), Ok(()));
    }

    #[test]
    fn validate_rejects_asymmetry() {
        // 0 -> 1 present but 1 -> 0 missing.
        let g = CsrGraph {
            offsets: vec![0, 1, 1],
            adjacency: vec![1, 1].into_iter().take(1).collect(),
            name: "bad".into(),
        };
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_self_loop() {
        let g = CsrGraph {
            offsets: vec![0, 1, 2],
            adjacency: vec![0, 0],
            name: "loop".into(),
        };
        assert!(g.validate().is_err());
    }

    #[test]
    fn empty_graph_ok() {
        let g = CsrGraph::from_parts(vec![0], vec![], "empty");
        assert_eq!(g.num_vertices(), 0);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.validate(), Ok(()));
    }

    #[test]
    fn isolated_vertices_ok() {
        let b = GraphBuilder::new(5);
        let g = b.build("isolated");
        assert_eq!(g.num_vertices(), 5);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.degree(3), 0);
    }
}
