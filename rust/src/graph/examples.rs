//! Hand-constructed example graphs, including the paper's running example
//! `G1` (Fig. 1): six vertices where {v0, v1} have coreness 1 and
//! {v2, v3, v4, v5} have coreness 2.

use super::builder::GraphBuilder;
use super::csr::CsrGraph;

/// The paper's Fig. 1 graph `G1`.
///
/// Edges: v0–v5, v1–v5, v2–v3, v2–v4, v3–v4, v3–v5, v4–v5.
/// Degrees: v0=1, v1=1, v2=2, v3=3, v4=3, v5=4.
/// Coreness: v0=v1=1, v2..v5=2 (the 2-core is {v2,v3,v4,v5}; no 3-core).
/// The peel walkthrough of Fig. 2 takes 3 iterations and yields the
/// under-core set {v3, v5} in the third.
pub fn g1() -> CsrGraph {
    let mut b = GraphBuilder::new(6);
    b.add_edges([(0, 5), (1, 5), (2, 3), (2, 4), (3, 4), (3, 5), (4, 5)]);
    b.build("G1")
}

/// Expected coreness of [`g1`].
pub fn g1_coreness() -> Vec<u32> {
    vec![1, 1, 2, 2, 2, 2]
}

/// Complete graph K_n — coreness n−1 everywhere.
pub fn complete(n: usize) -> CsrGraph {
    let mut b = GraphBuilder::new(n);
    for u in 0..n as u32 {
        for v in (u + 1)..n as u32 {
            b.add_edge(u, v);
        }
    }
    b.build(format!("K{n}"))
}

/// Path P_n — coreness 1 everywhere (n ≥ 2).
pub fn path(n: usize) -> CsrGraph {
    let mut b = GraphBuilder::new(n);
    for v in 1..n as u32 {
        b.add_edge(v - 1, v);
    }
    b.build(format!("P{n}"))
}

/// Cycle C_n — coreness 2 everywhere (n ≥ 3).
pub fn cycle(n: usize) -> CsrGraph {
    let mut b = GraphBuilder::new(n);
    for v in 1..n as u32 {
        b.add_edge(v - 1, v);
    }
    b.add_edge(n as u32 - 1, 0);
    b.build(format!("C{n}"))
}

/// Star S_n (one hub, n leaves) — coreness 1 everywhere.
pub fn star(leaves: usize) -> CsrGraph {
    let mut b = GraphBuilder::new(leaves + 1);
    for v in 1..=leaves as u32 {
        b.add_edge(0, v);
    }
    b.build(format!("S{leaves}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn g1_shape_matches_paper() {
        let g = g1();
        assert_eq!(g.num_vertices(), 6);
        assert_eq!(g.num_edges(), 7);
        assert_eq!(g.degrees(), vec![1, 1, 2, 3, 3, 4]);
    }

    #[test]
    fn complete_graph() {
        let g = complete(5);
        assert_eq!(g.num_edges(), 10);
        assert!(g.degrees().iter().all(|&d| d == 4));
    }

    #[test]
    fn path_and_cycle() {
        assert_eq!(path(10).num_edges(), 9);
        assert_eq!(cycle(10).num_edges(), 10);
        assert!(cycle(10).degrees().iter().all(|&d| d == 2));
    }

    #[test]
    fn star_shape() {
        let g = star(7);
        assert_eq!(g.degree(0), 7);
        assert_eq!(g.num_edges(), 7);
    }
}
