//! The read API over published snapshots — the §I application queries
//! (engagement cohorts, degeneracy ordering, dense-community location)
//! served concurrently with updates.
//!
//! Everything here except [`densest_core`] runs against one immutable
//! [`CoreSnapshot`] and therefore never blocks on writers. Densest-core
//! extraction needs the adjacency; it takes a consistent (snapshot,
//! graph) pair from the index and scans by counting (suffix sums over
//! per-coreness vertex and edge tallies), materialising only the
//! winning core.

use super::index::{CoreIndex, CoreSnapshot};
use crate::graph::{CsrGraph, VertexId};

impl CoreSnapshot {
    /// Coreness of `v`; `None` for out-of-range ids.
    pub fn coreness(&self, v: VertexId) -> Option<u32> {
        self.core.get(v as usize).copied()
    }

    /// The graph's degeneracy (max coreness) at this epoch.
    pub fn degeneracy(&self) -> u32 {
        self.k_max
    }

    /// Vertices of the k-core (coreness >= k), ascending.
    pub fn kcore_members(&self, k: u32) -> Vec<VertexId> {
        (0..self.core.len() as VertexId)
            .filter(|&v| self.core[v as usize] >= k)
            .collect()
    }

    /// First `cap` members of the k-core, ascending — the reply-listing
    /// path, which never needs more than the protocol's cap. Size-only
    /// callers should use [`Self::kcore_size`] instead; neither walks
    /// the full membership into a |V|-sized list.
    pub fn kcore_members_capped(&self, k: u32, cap: usize) -> Vec<VertexId> {
        (0..self.core.len() as VertexId)
            .filter(|&v| self.core[v as usize] >= k)
            .take(cap)
            .collect()
    }

    /// |k-core| without materialising the members.
    pub fn kcore_size(&self, k: u32) -> usize {
        self.core.iter().filter(|&&c| c >= k).count()
    }

    /// Core-number histogram: `hist[k]` = vertices with coreness exactly
    /// k, for k in `0..=k_max`.
    pub fn histogram(&self) -> Vec<u64> {
        let mut hist = vec![0u64; self.k_max as usize + 1];
        for &c in &self.core {
            hist[c as usize] += 1;
        }
        hist
    }
}

/// The densest k-core of a graph (max edges-per-vertex over all k).
#[derive(Clone, Debug)]
pub struct DensestCore {
    /// Epoch the extraction ran against.
    pub epoch: u64,
    pub k: u32,
    pub vertices: usize,
    pub edges: u64,
    /// |E| / |V| of the extracted core (0 for the empty graph).
    pub density: f64,
    pub members: Vec<VertexId>,
}

/// Extract the densest core by counting, not materialising: every
/// k-core's size and edge count fall out of two suffix sums (vertices
/// with coreness ≥ k; edges whose endpoint-coreness minimum is ≥ k), so
/// the whole k = 0..=k_max scan is O(|V| + |E| + k_max) and only the
/// winning core's members are ever listed. Serialises with writers
/// (needs the adjacency for the edge counts).
pub fn densest_core(index: &CoreIndex) -> DensestCore {
    let (snap, g) = index.consistent_view();
    densest_core_view(&snap, &g)
}

/// The same extraction over an explicit (snapshot, graph) pair — the
/// entry point for backends that assemble their view differently (e.g. a
/// [`crate::shard::ShardedIndex`]'s merged snapshot + assembled graph).
pub fn densest_core_view(snap: &CoreSnapshot, g: &CsrGraph) -> DensestCore {
    let k_max = snap.k_max as usize;
    // vcnt[j] = vertices with coreness exactly j; ecnt[j] = edges whose
    // smaller endpoint-coreness is exactly j. An edge survives in the
    // k-core iff min(core(u), core(v)) >= k, so suffix sums over both
    // arrays give every k-core's |V| and |E| in one pass each.
    let mut vcnt = vec![0u64; k_max + 1];
    for &c in &snap.core {
        vcnt[c as usize] += 1;
    }
    let mut ecnt = vec![0u64; k_max + 1];
    for u in 0..g.num_vertices() as VertexId {
        let cu = snap.core[u as usize];
        for &v in g.neighbors(u) {
            if u < v {
                ecnt[cu.min(snap.core[v as usize]) as usize] += 1;
            }
        }
    }
    let mut best = DensestCore {
        epoch: snap.epoch,
        k: 0,
        vertices: g.num_vertices(),
        edges: g.num_edges(),
        density: if g.num_vertices() == 0 {
            0.0
        } else {
            g.num_edges() as f64 / g.num_vertices() as f64
        },
        members: Vec::new(),
    };
    // walk k ascending, peeling the suffix sums down as k rises; ties
    // promote the deeper core — a k-core and (k+1)-core can be the same
    // vertex set, and the larger k is the sharper label
    let mut vertices: u64 = snap.core.len() as u64;
    let mut edges: u64 = g.num_edges();
    for k in 1..=snap.k_max {
        vertices -= vcnt[k as usize - 1];
        edges -= ecnt[k as usize - 1];
        if vertices == 0 {
            continue;
        }
        let density = edges as f64 / vertices as f64;
        if density >= best.density {
            best = DensestCore {
                epoch: snap.epoch,
                k,
                vertices: vertices as usize,
                edges,
                density,
                members: Vec::new(),
            };
        }
    }
    // materialise members once, for the winner only (k = 0 lists the
    // whole vertex set so the fields stay mutually consistent)
    best.members = snap.kcore_members(best.k);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{examples, GraphBuilder};

    #[test]
    fn snapshot_queries_on_g1() {
        let idx = CoreIndex::new("g1", &examples::g1());
        let s = idx.snapshot();
        assert_eq!(s.coreness(0), Some(1));
        assert_eq!(s.coreness(3), Some(2));
        assert_eq!(s.coreness(6), None);
        assert_eq!(s.degeneracy(), 2);
        assert_eq!(s.kcore_members(2), vec![2, 3, 4, 5]);
        assert_eq!(s.kcore_size(2), 4);
        assert_eq!(s.kcore_size(3), 0);
        assert_eq!(s.histogram(), vec![0, 2, 4]);
    }

    #[test]
    fn densest_core_finds_planted_clique() {
        // a K5 (density 2.0) hanging off a long path (density ~1)
        let mut b = GraphBuilder::new(0);
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                b.add_edge(u, v);
            }
        }
        for v in 5..30u32 {
            b.add_edge(v - 1, v);
        }
        let g = b.build("k5+path");
        let idx = CoreIndex::new("k5+path", &g);
        let d = densest_core(&idx);
        assert_eq!(d.k, 4);
        assert_eq!(d.vertices, 5);
        assert_eq!(d.edges, 10);
        assert!((d.density - 2.0).abs() < 1e-9);
        assert_eq!(d.members, vec![0, 1, 2, 3, 4]);
        assert_eq!(d.epoch, 0);
    }

    #[test]
    fn densest_core_tracks_updates() {
        let idx = CoreIndex::new("g1", &examples::g1());
        let before = densest_core(&idx);
        assert_eq!(before.k, 2);
        // close (2,5): {2,3,4,5} becomes K4 — density jumps to 1.5
        idx.update(|dc| dc.insert_edge(2, 5));
        let after = densest_core(&idx);
        assert_eq!(after.k, 3);
        assert_eq!(after.vertices, 4);
        assert_eq!(after.edges, 6);
        assert_eq!(after.epoch, 1);
    }

    #[test]
    fn densest_core_of_empty_graph() {
        let g = GraphBuilder::new(3).build("edgeless");
        let idx = CoreIndex::new("edgeless", &g);
        let d = densest_core(&idx);
        assert_eq!(d.k, 0);
        assert_eq!(d.vertices, 3);
        assert_eq!(d.density, 0.0);
        // the base case lists its members too (fields stay consistent)
        assert_eq!(d.members, vec![0, 1, 2]);
        assert_eq!(d.members.len(), d.vertices);
    }
}
