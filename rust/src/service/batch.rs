//! The batched update pipeline: coalesce queued edits, apply them to a
//! [`CoreIndex`], and pick incremental maintenance vs full recompute.
//!
//! **Coalescing.** Edits are keyed by their canonical endpoint pair; an
//! edge's final membership after a batch equals the *last* edit's target
//! state (Insert ⇒ present, Delete ⇒ absent), so last-wins coalescing is
//! exact: an insert+delete pair on the same edge collapses to the delete,
//! duplicate inserts collapse to one, and intermediate flip-flops vanish.
//! Self-loop edits are dropped outright (simple graphs only).
//!
//! **Crossover.** Incremental maintenance pays a subcore-cascade per edit;
//! a full recompute pays one kernel run regardless of batch size. The
//! incremental path wins for small batches and loses once the batch is a
//! few percent of |E| — the same shape as the paper's Table VII
//! peel-vs-index2core crossover, and like it, host-dependent. The
//! decision is made against *measured* costs: every flush feeds
//! [`CrossoverCosts`] (per-edit ns on the incremental path, per-edge ns
//! on the recompute path, EWMA-smoothed), and once both sides are warm
//! the threshold sits at their break-even point. Until then the static
//! [`BatchConfig::recompute_fraction`] calibration from
//! `benches/serve_throughput.rs` applies. The recompute itself runs the
//! hierarchical-bucket peel ([`crate::core::peel::BucketPeel`]) against
//! the index's persistent [`crate::core::peel::BucketScratch`], so a
//! steady flush load allocates nothing per recompute.

use super::index::{CoreIndex, CoreSnapshot};
use crate::core::maintenance::EdgeEdit;
use crate::obs::{self, names};
use crate::util::timer::Timer;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Tuning for the batch pipeline.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Fall back to full recompute when the coalesced batch exceeds this
    /// fraction of the current edge count. Calibrated by
    /// `benches/serve_throughput.rs` on this testbed.
    pub recompute_fraction: f64,
    /// Floor for the recompute trigger, so tiny graphs / tiny batches
    /// always take the incremental path.
    pub min_recompute_edits: usize,
    /// SPMD threads for the recompute decomposer.
    pub threads: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self {
            recompute_fraction: default_recompute_fraction(),
            min_recompute_edits: 64,
            threads: crate::util::default_threads(),
        }
    }
}

/// The crossover default: the compiled-in fallback (0.02), or the
/// `PICO_RECOMPUTE_FRACTION` env override so a deployment can pin the
/// value its own `serve_throughput` crossover table measured without
/// rebuilding. ROADMAP's tuning item records the reference-host number.
pub fn default_recompute_fraction() -> f64 {
    static CACHED: std::sync::OnceLock<f64> = std::sync::OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("PICO_RECOMPUTE_FRACTION")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .filter(|f| (0.0..=1.0).contains(f))
            .unwrap_or(0.02)
    })
}

impl BatchConfig {
    /// Coalesced-batch size at which recompute takes over, for a graph
    /// with `num_edges` edges — the *static* (cold-start) calibration.
    pub fn recompute_threshold(&self, num_edges: u64) -> usize {
        let frac = (self.recompute_fraction * num_edges as f64).ceil() as usize;
        frac.max(self.min_recompute_edits)
    }
}

/// Measured crossover costs for one index: EWMA of the incremental
/// path's cost per applied edit and the bucket recompute's cost per
/// edge, fed by every flush this index runs. Once both sides are warm,
/// recompute wins when `edits · ns_per_edit ≥ |E| · ns_per_edge`; the
/// break-even batch size replaces the static fraction. Values are f64
/// bit patterns in atomics — readers never lock, and a lost racing
/// update only drops one EWMA sample.
#[derive(Debug, Default)]
pub struct CrossoverCosts {
    incr_ns_per_edit: AtomicU64,
    rec_ns_per_edge: AtomicU64,
}

impl CrossoverCosts {
    /// EWMA smoothing weight for new samples.
    const ALPHA: f64 = 0.25;

    fn fold(cell: &AtomicU64, sample: f64) {
        if !sample.is_finite() || sample <= 0.0 {
            return;
        }
        let old = f64::from_bits(cell.load(Ordering::Relaxed));
        let new = if old > 0.0 {
            old + Self::ALPHA * (sample - old)
        } else {
            sample
        };
        cell.store(new.to_bits(), Ordering::Relaxed);
    }

    /// Record an incremental batch: `applied` edits took `elapsed`.
    pub fn observe_incremental(&self, applied: usize, elapsed: Duration) {
        if applied > 0 {
            Self::fold(
                &self.incr_ns_per_edit,
                elapsed.as_nanos() as f64 / applied as f64,
            );
        }
    }

    /// Record a bucket recompute over a graph of `num_edges` edges.
    pub fn observe_recompute(&self, num_edges: u64, elapsed: Duration) {
        if num_edges > 0 {
            Self::fold(
                &self.rec_ns_per_edge,
                elapsed.as_nanos() as f64 / num_edges as f64,
            );
        }
    }

    /// Break-even batch size for a graph with `num_edges` edges, or
    /// `None` while either side is still unmeasured (cold start — the
    /// static calibration applies).
    pub fn measured_threshold(&self, num_edges: u64) -> Option<usize> {
        let e = f64::from_bits(self.incr_ns_per_edit.load(Ordering::Relaxed));
        let r = f64::from_bits(self.rec_ns_per_edge.load(Ordering::Relaxed));
        (e > 0.0 && r > 0.0).then(|| (num_edges as f64 * r / e).ceil() as usize)
    }

    /// The effective crossover expressed as a fraction of |E| — what the
    /// bench tables report next to the static calibration.
    pub fn effective_fraction(&self, num_edges: u64) -> Option<f64> {
        self.measured_threshold(num_edges)
            .filter(|_| num_edges > 0)
            .map(|t| t as f64 / num_edges as f64)
    }
}

/// What one applied batch did.
#[derive(Clone, Debug)]
pub struct BatchOutcome {
    /// Snapshot published by this batch.
    pub snapshot: Arc<CoreSnapshot>,
    /// Edits handed in (pre-coalescing).
    pub submitted: usize,
    /// Edits applied after coalescing.
    pub applied: usize,
    /// Edits removed by coalescing (duplicates, cancelling pairs, loops).
    pub coalesced: usize,
    /// Applied edits that actually changed the edge set.
    pub changed: usize,
    /// Whether the full-recompute fallback ran instead of per-edit
    /// maintenance.
    pub recomputed: bool,
    pub elapsed: Duration,
}

impl BatchOutcome {
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed.as_secs_f64() * 1e3
    }
}

/// Last-wins coalescing over canonical endpoint pairs; drops self-loops.
/// Output preserves the order in which pairs first appeared.
pub fn coalesce(edits: &[EdgeEdit]) -> Vec<EdgeEdit> {
    let mut last: HashMap<(u32, u32), (usize, EdgeEdit)> = HashMap::with_capacity(edits.len());
    for &e in edits {
        let (u, v) = e.endpoints();
        if u == v {
            continue;
        }
        let next_slot = last.len();
        last.entry((u, v))
            .and_modify(|slot| slot.1 = e)
            .or_insert((next_slot, e));
    }
    let mut out: Vec<(usize, EdgeEdit)> = last.into_values().collect();
    out.sort_by_key(|&(slot, _)| slot);
    out.into_iter().map(|(_, e)| e).collect()
}

/// Coalesce and apply `edits` to `index`, publishing one new epoch.
/// Readers observe the pre-batch snapshot until the publish.
pub fn apply_batch(index: &CoreIndex, edits: &[EdgeEdit], cfg: &BatchConfig) -> BatchOutcome {
    let timer = Timer::start();
    let batch = coalesce(edits);
    let applied = batch.len();
    let costs = index.crossover_costs();
    let ((changed, recomputed), snapshot) = index.update(|dc| {
        for e in &batch {
            let (_, hi) = e.endpoints();
            dc.ensure_vertex(hi);
        }
        // Measured break-even when warm, static calibration when cold;
        // the floor always applies.
        let num_edges = dc.num_edges();
        let threshold = costs
            .measured_threshold(num_edges)
            .map(|t| t.max(cfg.min_recompute_edits))
            .unwrap_or_else(|| cfg.recompute_threshold(num_edges));
        if applied >= threshold {
            // Structural edits + one from-scratch bucket-peel run against
            // the index's persistent scratch — the flush-time recompute
            // hot path.
            let mut changed = 0usize;
            for &e in &batch {
                let did = match e {
                    EdgeEdit::Insert(u, v) => dc.insert_edge_structural(u, v),
                    EdgeEdit::Delete(u, v) => dc.delete_edge_structural(u, v),
                };
                if did {
                    changed += 1;
                }
            }
            let t0 = Instant::now();
            dc.recompute_bucket(cfg.threads, &mut index.recompute_scratch());
            costs.observe_recompute(dc.num_edges(), t0.elapsed());
            (changed, true)
        } else {
            let t0 = Instant::now();
            let changed = dc.apply_batch(&batch);
            costs.observe_incremental(applied, t0.elapsed());
            (changed, false)
        }
    });
    if recomputed {
        crate::obs::events::emit(
            crate::obs::Severity::Info,
            crate::obs::events::kind::CROSSOVER_RECOMPUTE,
            index.name(),
            format!("applied={applied} crossed the incremental threshold; full recompute"),
        );
    }
    BatchOutcome {
        snapshot,
        submitted: edits.len(),
        applied,
        coalesced: edits.len() - applied,
        changed,
        recomputed,
        elapsed: timer.elapsed(),
    }
}

/// A thread-safe pending-edit queue in front of one [`CoreIndex`] —
/// producers `submit`, a flusher (timer, size trigger, or the protocol's
/// FLUSH verb) drains and applies.
pub struct EditQueue {
    index: Arc<CoreIndex>,
    cfg: BatchConfig,
    pending: Mutex<Vec<EdgeEdit>>,
    /// When the oldest pending edit arrived — the flush's queue-wait
    /// stage (`pico_flush_queue_seconds`) measures from here.
    queued_since: Mutex<Option<Instant>>,
    /// Serialises whole flushes (drain *and* apply). Without it, a flush
    /// arriving while another one is mid-apply would find the queue empty
    /// and return the pre-batch snapshot — breaking the protocol's
    /// read-your-writes promise ("my edits are visible after my FLUSH").
    flush_lock: Mutex<()>,
}

impl EditQueue {
    pub fn new(index: Arc<CoreIndex>, cfg: BatchConfig) -> Self {
        Self {
            index,
            cfg,
            pending: Mutex::new(Vec::new()),
            queued_since: Mutex::new(None),
            flush_lock: Mutex::new(()),
        }
    }

    pub fn index(&self) -> &Arc<CoreIndex> {
        &self.index
    }

    pub fn config(&self) -> &BatchConfig {
        &self.cfg
    }

    /// Enqueue one edit; returns the pending count after the push.
    pub fn submit(&self, e: EdgeEdit) -> usize {
        let mut p = self.pending.lock().unwrap();
        if p.is_empty() {
            *self.queued_since.lock().unwrap() = Some(Instant::now());
        }
        p.push(e);
        p.len()
    }

    pub fn pending(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// Clone the queued edits in submission order — the `MEMBERS` fast
    /// path overlays them on the live structure to answer single-k
    /// queries mid-batch without forcing a flush.
    pub fn pending_edits(&self) -> Vec<EdgeEdit> {
        self.pending.lock().unwrap().clone()
    }

    /// Drain the queue and apply it as one batch (publishes one epoch).
    /// An empty queue publishes nothing and reports zeros. Concurrent
    /// flushes serialise: a flush that finds the queue empty still waits
    /// for any in-flight flush, so its returned snapshot includes every
    /// edit submitted before this call.
    pub fn flush(&self) -> BatchOutcome {
        let _in_flight = self.flush_lock.lock().unwrap();
        let (edits, queued_at) = {
            let mut p = self.pending.lock().unwrap();
            let edits: Vec<EdgeEdit> = std::mem::take(&mut *p);
            (edits, self.queued_since.lock().unwrap().take())
        };
        if edits.is_empty() {
            return BatchOutcome {
                snapshot: self.index.snapshot(),
                submitted: 0,
                applied: 0,
                coalesced: 0,
                changed: 0,
                recomputed: false,
                elapsed: Duration::ZERO,
            };
        }
        let queue_wait = queued_at.map(|t| t.elapsed()).unwrap_or(Duration::ZERO);
        let out = apply_batch(&self.index, &edits, &self.cfg);
        record_flush_obs(self.index.name(), queue_wait, &out);
        out
    }
}

/// Land one applied single-index batch in the observability registry:
/// queue-wait / apply / total stage histograms plus the published-epoch
/// gauge, all under the graph's label. The sharded and cluster flush
/// paths record their richer stage set (route, refine, commit) in
/// [`crate::shard`] and [`crate::cluster`].
fn record_flush_obs(graph: &str, queue_wait: Duration, out: &BatchOutcome) {
    let us = |d: Duration| d.as_micros().min(u64::MAX as u128) as u64;
    let reg = obs::global();
    let l: &[(&str, &str)] = &[("graph", graph)];
    reg.histogram(names::FLUSH_QUEUE_SECONDS, l).record(us(queue_wait));
    reg.histogram(names::FLUSH_APPLY_SECONDS, l).record(us(out.elapsed));
    reg.histogram(names::FLUSH_TOTAL_SECONDS, l)
        .record(us(queue_wait + out.elapsed));
    reg.gauge(names::GRAPH_EPOCH, l).set(out.snapshot.epoch);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::examples;

    #[test]
    fn coalesce_is_last_wins_per_pair() {
        let edits = [
            EdgeEdit::Insert(1, 2),
            EdgeEdit::Insert(3, 4),
            EdgeEdit::Delete(2, 1), // same pair as (1,2), reversed: wins
            EdgeEdit::Insert(5, 5), // self-loop dropped
            EdgeEdit::Insert(3, 4), // duplicate collapses
        ];
        let c = coalesce(&edits);
        assert_eq!(c, vec![EdgeEdit::Delete(2, 1), EdgeEdit::Insert(3, 4)]);
    }

    #[test]
    fn coalesce_empty_and_loops_only() {
        assert!(coalesce(&[]).is_empty());
        assert!(coalesce(&[EdgeEdit::Insert(7, 7)]).is_empty());
    }

    #[test]
    fn incremental_batch_matches_oracle() {
        let idx = CoreIndex::new("g1", &examples::g1());
        let out = apply_batch(
            &idx,
            &[
                EdgeEdit::Insert(2, 5),
                EdgeEdit::Delete(0, 5),
                EdgeEdit::Insert(0, 5), // cancels the delete -> no-op insert
            ],
            &BatchConfig::default(),
        );
        assert!(!out.recomputed);
        assert_eq!(out.submitted, 3);
        assert_eq!(out.applied, 2);
        assert_eq!(out.coalesced, 1);
        assert_eq!(out.changed, 1); // (0,5) already present
        assert_eq!(out.snapshot.epoch, 1);
        assert_eq!(out.snapshot.core, bz_coreness(&idx.graph()));
    }

    #[test]
    fn big_batch_takes_recompute_path_and_matches_oracle() {
        let idx = CoreIndex::new("g1", &examples::g1());
        let cfg = BatchConfig {
            recompute_fraction: 0.01,
            min_recompute_edits: 2,
            threads: 1,
        };
        let out = apply_batch(
            &idx,
            &[
                EdgeEdit::Insert(2, 5),
                EdgeEdit::Insert(0, 1),
                EdgeEdit::Delete(3, 4),
                EdgeEdit::Insert(0, 2),
            ],
            &cfg,
        );
        assert!(out.recomputed);
        assert_eq!(out.changed, 4);
        assert_eq!(out.snapshot.core, bz_coreness(&idx.graph()));
    }

    #[test]
    fn batch_grows_vertex_set() {
        let idx = CoreIndex::new("g1", &examples::g1());
        let out = apply_batch(
            &idx,
            &[EdgeEdit::Insert(5, 9)],
            &BatchConfig::default(),
        );
        assert_eq!(out.snapshot.num_vertices(), 10);
        assert_eq!(out.snapshot.core[9], 1);
        assert_eq!(out.snapshot.core, bz_coreness(&idx.graph()));
    }

    #[test]
    fn queue_accumulates_and_flushes_once() {
        let idx = Arc::new(CoreIndex::new("g1", &examples::g1()));
        let q = EditQueue::new(idx.clone(), BatchConfig::default());
        assert_eq!(q.submit(EdgeEdit::Insert(2, 5)), 1);
        assert_eq!(q.submit(EdgeEdit::Insert(2, 5)), 2);
        assert_eq!(q.pending(), 2);
        let out = q.flush();
        assert_eq!(out.submitted, 2);
        assert_eq!(out.applied, 1);
        assert_eq!(q.pending(), 0);
        assert_eq!(idx.epoch(), 1);
        // empty flush publishes nothing
        let out2 = q.flush();
        assert_eq!(out2.submitted, 0);
        assert_eq!(out2.snapshot.epoch, 1);
        assert_eq!(idx.epoch(), 1);
    }

    #[test]
    fn threshold_floor_respected() {
        let cfg = BatchConfig {
            recompute_fraction: 0.5,
            min_recompute_edits: 10,
            threads: 1,
        };
        assert_eq!(cfg.recompute_threshold(4), 10);
        assert_eq!(cfg.recompute_threshold(1000), 500);
    }
}
