//! Layer 3.5 — the serving layer: a long-running, concurrent
//! query/update surface over maintained k-core indices.
//!
//! The paper's engines answer "decompose this graph once"; a production
//! deployment answers "what is v's coreness *right now*" while the graph
//! keeps changing. This subsystem provides that:
//!
//! * [`index`] — [`index::CoreIndex`]: epoch-versioned snapshots over
//!   [`crate::core::DynamicCore`]; readers never block on writers.
//! * [`batch`] — the update pipeline: last-wins edit coalescing and the
//!   incremental-maintenance vs full-recompute crossover (the serving
//!   analog of the paper's Peel vs Index2core crossover, Table VII).
//! * [`queries`] — the read API: coreness, k-core membership,
//!   degeneracy, core histograms, densest-core extraction.
//! * [`server`] — the application protocol: the multi-graph
//!   [`server::CoreService`] behind `pico serve` — hosting single
//!   indices, sharded ones ([`crate::shard::ShardedIndex`],
//!   `pico serve --shards N`), shard hosts, and whole clusters — served
//!   over the [`crate::net`] transport layer ([`server::serve`] /
//!   [`server::serve_with`]; framing, worker pool, auth, and transport
//!   metrics live in `net`, which drives `CoreService` through
//!   [`crate::net::conn::Handler`]).
//!
//! Throughput/latency characteristics are measured by
//! `benches/serve_throughput.rs`; the crossover default in
//! [`batch::BatchConfig`] comes from that bench.

pub mod batch;
pub mod index;
pub mod queries;
pub mod server;

pub use batch::{
    apply_batch, coalesce, default_recompute_fraction, BatchConfig, BatchOutcome, EditQueue,
};
pub use index::{CoreIndex, CoreSnapshot, CoreStore};
pub use queries::{densest_core, DensestCore};
pub use server::{serve, serve_with, CoreService, ReplicaSyncDaemon, ServerHandle, Session};
