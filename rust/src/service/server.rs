//! The serving surface: a multi-graph [`CoreService`] and a line-protocol
//! TCP front end (`pico serve` / `pico query`).
//!
//! # Line protocol
//!
//! One UTF-8 command per line, one reply line per command. Replies start
//! with `OK` or `ERR`. Verbs are case-insensitive; vertex ids are decimal
//! `u32`. A session has a *current graph* (the server's default graph
//! until `USE` switches it).
//!
//! | command | reply |
//! |---|---|
//! | `PING` | `OK pong` |
//! | `GRAPHS` | `OK n=<count> <name>...` |
//! | `USE <name>` | `OK use=<name>` |
//! | `OPEN <name> <dataset>` | `OK open=<name> vertices=<n> edges=<m>` — index a suite dataset or graph file |
//! | `EPOCH` | `OK epoch=<e>` |
//! | `CORENESS <v>` | `OK core=<c> epoch=<e>` |
//! | `DEGENERACY` | `OK degeneracy=<k> epoch=<e>` |
//! | `MEMBERS <k>` | `OK count=<n> epoch=<e> members=<v,v,...>` (capped) |
//! | `HISTO` | `OK epoch=<e> histo=<k>:<count>,...` |
//! | `DENSEST` | `OK k=<k> vertices=<n> edges=<m> density=<d> epoch=<e>` |
//! | `INSERT <u> <v>` | `OK pending=<n>` — queued, not yet visible |
//! | `DELETE <u> <v>` | `OK pending=<n>` |
//! | `FLUSH` | `OK epoch=<e> submitted=<s> applied=<a> coalesced=<c> changed=<g> recomputed=<0|1> ms=<t>` |
//! | `STATS` | `OK queries=<q> edits=<e> batches=<b> recomputes=<r> graphs=<g>` |
//! | `QUIT` | `OK bye` (connection closes) |
//!
//! Edits become visible only at `FLUSH` (one published epoch per flush),
//! so a client controls its own read-your-writes boundary. Readers on
//! other connections keep being served the previous epoch while a flush
//! is applying — the epoch-snapshot guarantee from [`super::index`].
//!
//! The TCP layer is thread-per-connection with the scheduler's
//! containment idiom: a panicking handler poisons nothing — the
//! connection reports `ERR internal` and closes, the server keeps
//! accepting. Abuse bounds: [`MAX_LINE_BYTES`], [`MAX_VERTEX_ID`],
//! [`MAX_PENDING_EDITS`], [`MAX_HOSTED_GRAPHS`].
//!
//! **Trust model:** the protocol is unauthenticated, and `OPEN` resolves
//! suite names *and server-local file paths* (CLI parity). The default
//! bind is loopback; expose a non-loopback `--addr` only to clients you
//! would let run `pico` on the host.

use super::batch::{BatchConfig, EditQueue};
use super::index::CoreIndex;
use super::queries::densest_core;
use crate::core::maintenance::EdgeEdit;
use crate::engine::metrics::{Metrics, MetricsSnapshot};
use crate::graph::CsrGraph;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Metric slots shared by connection threads (round-robin assignment).
const METRIC_SLOTS: usize = 8;

/// Reply cap for `MEMBERS` (a serving system never streams a million ids
/// down one reply line; `count=` always carries the true size).
pub const MAX_REPLY_MEMBERS: usize = 64;

/// Longest protocol line accepted from the wire. A client streaming
/// bytes with no newline must not grow the server's line buffer without
/// bound (same memory-exhaustion class as [`MAX_VERTEX_ID`]).
pub const MAX_LINE_BYTES: usize = 4096;

/// Most queued-but-unflushed edits per graph accepted from the wire. A
/// client that streams INSERTs without ever flushing must not grow the
/// pending queue without bound; past the cap, edits are rejected until a
/// FLUSH drains it.
pub const MAX_PENDING_EDITS: usize = 1 << 20;

/// Most graphs one server will host (OPEN of an *existing* name always
/// works — it is a reset). Keeps a chatty client from growing the hosted
/// map, each entry of which owns a full index.
pub const MAX_HOSTED_GRAPHS: usize = 16;

/// Largest vertex id accepted from the wire. Edits grow the vertex set
/// (`DynamicCore::ensure_vertex`), so without a bound one
/// `INSERT 0 4294967295` would make the server allocate tens of GB and
/// die. 2^24 vertices ≈ 200 MB of adjacency headroom — far above every
/// suite graph; raise it here when hosting genuinely larger graphs.
pub const MAX_VERTEX_ID: u32 = (1 << 24) - 1;

/// One hosted graph: its index and edit queue, always installed (and
/// replaced) together so a flush can never reach an orphaned index.
#[derive(Clone)]
struct Hosted {
    index: Arc<CoreIndex>,
    queue: Arc<EditQueue>,
}

/// The serving core: named indices, their edit queues, request counters.
pub struct CoreService {
    hosted: RwLock<HashMap<String, Hosted>>,
    batch_cfg: BatchConfig,
    metrics: Metrics,
    default_graph: Mutex<String>,
}

impl CoreService {
    pub fn new(batch_cfg: BatchConfig) -> Self {
        Self {
            hosted: RwLock::new(HashMap::new()),
            batch_cfg,
            metrics: Metrics::new(METRIC_SLOTS, true),
            default_graph: Mutex::new(String::new()),
        }
    }

    /// Host `g` under `name` (first hosted graph becomes the default).
    /// Re-opening an existing name atomically replaces both the index
    /// and its queue — any unflushed edits on the old queue are
    /// discarded by design (OPEN is a reset).
    pub fn open(&self, name: &str, g: &CsrGraph) -> Arc<CoreIndex> {
        let idx = Arc::new(CoreIndex::new(name, g));
        let q = Arc::new(EditQueue::new(idx.clone(), self.batch_cfg.clone()));
        self.hosted.write().unwrap().insert(
            name.to_string(),
            Hosted {
                index: idx.clone(),
                queue: q,
            },
        );
        let mut d = self.default_graph.lock().unwrap();
        if d.is_empty() {
            *d = name.to_string();
        }
        idx
    }

    pub fn default_graph(&self) -> String {
        self.default_graph.lock().unwrap().clone()
    }

    pub fn index(&self, name: &str) -> Option<Arc<CoreIndex>> {
        self.hosted.read().unwrap().get(name).map(|h| h.index.clone())
    }

    pub fn queue(&self, name: &str) -> Option<Arc<EditQueue>> {
        self.hosted.read().unwrap().get(name).map(|h| h.queue.clone())
    }

    pub fn graph_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.hosted.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    fn num_graphs(&self) -> usize {
        self.hosted.read().unwrap().len()
    }

    /// Aggregated serve-path counters.
    pub fn stats(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Execute one protocol line for a session on `graph`; returns the
    /// reply line (without newline). `slot` picks the metrics slot.
    pub fn handle_command(&self, session: &mut Session, line: &str, slot: usize) -> String {
        let view = self.metrics.view(slot % METRIC_SLOTS);
        let mut parts = line.split_whitespace();
        let Some(raw_verb) = parts.next() else {
            return "ERR empty command".into();
        };
        let verb = raw_verb.to_ascii_uppercase();
        let args: Vec<&str> = parts.collect();
        match verb.as_str() {
            "PING" => "OK pong".into(),
            "GRAPHS" => {
                let names = self.graph_names();
                format!("OK n={} {}", names.len(), names.join(" "))
            }
            "USE" => match args.first() {
                Some(&name) if self.index(name).is_some() => {
                    session.graph = name.to_string();
                    format!("OK use={name}")
                }
                Some(&name) => format!("ERR unknown graph '{name}'"),
                None => "ERR usage: USE <name>".into(),
            },
            "OPEN" => {
                let (Some(&name), Some(&dataset)) = (args.first(), args.get(1)) else {
                    return "ERR usage: OPEN <name> <dataset>".into();
                };
                if self.index(name).is_none() && self.num_graphs() >= MAX_HOSTED_GRAPHS {
                    return format!("ERR graph limit reached ({MAX_HOSTED_GRAPHS} hosted)");
                }
                match load_dataset(dataset) {
                    Ok(g) => {
                        let idx = self.open(name, &g);
                        let s = idx.snapshot();
                        session.graph = name.to_string();
                        format!(
                            "OK open={name} vertices={} edges={}",
                            s.num_vertices(),
                            s.num_edges
                        )
                    }
                    Err(e) => format!("ERR {e:#}"),
                }
            }
            "STATS" => {
                let s = self.stats();
                format!(
                    "OK queries={} edits={} batches={} recomputes={} graphs={}",
                    s.serve_queries,
                    s.serve_edits,
                    s.serve_batches,
                    s.serve_recomputes,
                    self.num_graphs()
                )
            }
            "QUIT" => "OK bye".into(),
            // everything below operates on the session's current graph
            _ => {
                let Some(idx) = self.index(&session.graph) else {
                    return format!("ERR no graph selected (have: {})", self.graph_names().join(" "));
                };
                match verb.as_str() {
                    "EPOCH" => {
                        view.serve_queries(1);
                        // the snapshot's epoch, not the writer counter:
                        // the reply must name an epoch readers can get
                        format!("OK epoch={}", idx.snapshot().epoch)
                    }
                    "CORENESS" => {
                        view.serve_queries(1);
                        let Some(Ok(v)) = args.first().map(|a| a.parse::<u32>()) else {
                            return "ERR usage: CORENESS <v>".into();
                        };
                        let s = idx.snapshot();
                        match s.coreness(v) {
                            Some(c) => format!("OK core={c} epoch={}", s.epoch),
                            None => format!("ERR vertex {v} out of range (|V|={})", s.num_vertices()),
                        }
                    }
                    "DEGENERACY" => {
                        view.serve_queries(1);
                        let s = idx.snapshot();
                        format!("OK degeneracy={} epoch={}", s.degeneracy(), s.epoch)
                    }
                    "MEMBERS" => {
                        view.serve_queries(1);
                        let Some(Ok(k)) = args.first().map(|a| a.parse::<u32>()) else {
                            return "ERR usage: MEMBERS <k>".into();
                        };
                        let s = idx.snapshot();
                        // count + capped listing without materialising the
                        // full membership (|V|-sized per request otherwise)
                        let count = s.kcore_size(k);
                        let listed: Vec<String> = s
                            .core
                            .iter()
                            .enumerate()
                            .filter(|&(_, &c)| c >= k)
                            .take(MAX_REPLY_MEMBERS)
                            .map(|(v, _)| v.to_string())
                            .collect();
                        format!(
                            "OK count={} epoch={} members={}",
                            count,
                            s.epoch,
                            listed.join(",")
                        )
                    }
                    "HISTO" => {
                        view.serve_queries(1);
                        let s = idx.snapshot();
                        let cells: Vec<String> = s
                            .histogram()
                            .iter()
                            .enumerate()
                            .map(|(k, n)| format!("{k}:{n}"))
                            .collect();
                        format!("OK epoch={} histo={}", s.epoch, cells.join(","))
                    }
                    "DENSEST" => {
                        view.serve_queries(1);
                        let d = densest_core(&idx);
                        format!(
                            "OK k={} vertices={} edges={} density={:.4} epoch={}",
                            d.k, d.vertices, d.edges, d.density, d.epoch
                        )
                    }
                    "INSERT" | "DELETE" => {
                        let (Some(Ok(u)), Some(Ok(v))) = (
                            args.first().map(|a| a.parse::<u32>()),
                            args.get(1).map(|a| a.parse::<u32>()),
                        ) else {
                            return format!("ERR usage: {verb} <u> <v>");
                        };
                        if u == v {
                            return format!("ERR self-loop ({u},{u}) rejected");
                        }
                        if u > MAX_VERTEX_ID || v > MAX_VERTEX_ID {
                            return format!(
                                "ERR vertex id above limit {MAX_VERTEX_ID} (see server::MAX_VERTEX_ID)"
                            );
                        }
                        let Some(q) = self.queue(&session.graph) else {
                            return format!("ERR no edit queue for '{}'", session.graph);
                        };
                        if q.pending() >= MAX_PENDING_EDITS {
                            return format!(
                                "ERR edit queue full ({MAX_PENDING_EDITS} pending); FLUSH first"
                            );
                        }
                        view.serve_edits(1);
                        let edit = if verb == "INSERT" {
                            EdgeEdit::Insert(u, v)
                        } else {
                            EdgeEdit::Delete(u, v)
                        };
                        format!("OK pending={}", q.submit(edit))
                    }
                    "FLUSH" => {
                        let Some(q) = self.queue(&session.graph) else {
                            return format!("ERR no edit queue for '{}'", session.graph);
                        };
                        let out = q.flush();
                        view.serve_batches(1);
                        if out.recomputed {
                            view.serve_recomputes(1);
                        }
                        format!(
                            "OK epoch={} submitted={} applied={} coalesced={} changed={} recomputed={} ms={:.3}",
                            out.snapshot.epoch,
                            out.submitted,
                            out.applied,
                            out.coalesced,
                            out.changed,
                            out.recomputed as u8,
                            out.elapsed_ms()
                        )
                    }
                    other => format!("ERR unknown command '{other}'"),
                }
            }
        }
    }
}

/// Per-connection state.
#[derive(Clone, Debug)]
pub struct Session {
    /// Current graph name.
    pub graph: String,
}

/// Resolve a dataset argument — the same suite-name-then-path rules as
/// the CLI ([`crate::coordinator::DatasetSpec::resolve`]).
fn load_dataset(name: &str) -> Result<Arc<CsrGraph>> {
    crate::coordinator::DatasetSpec::resolve(name)?.load()
}

/// A running TCP server. Dropping the handle stops the accept loop.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the accept loop to exit.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Block until the accept loop exits (`stop()` from another thread,
    /// or process teardown).
    pub fn join(mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Bind `addr` and serve `service` until the handle is stopped.
/// The accept loop runs on a background thread; connections get a thread
/// each, wrapped in `catch_unwind` containment.
pub fn serve(service: Arc<CoreService>, addr: &str) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let local = listener.local_addr().context("reading bound address")?;
    listener
        .set_nonblocking(true)
        .context("setting the listener non-blocking")?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let conn_counter = Arc::new(AtomicUsize::new(0));
    let join = std::thread::Builder::new()
        .name("pico-serve-accept".into())
        .spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let service = service.clone();
                        let slot = conn_counter.fetch_add(1, Ordering::Relaxed);
                        let _ = std::thread::Builder::new()
                            .name(format!("pico-serve-conn-{slot}"))
                            .spawn(move || handle_connection(service, stream, slot));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(_) => {
                        // transient accept error; keep serving
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                }
            }
        })
        .context("spawning the accept thread")?;
    Ok(ServerHandle {
        addr: local,
        stop,
        join: Some(join),
    })
}

fn handle_connection(service: Arc<CoreService>, stream: TcpStream, slot: usize) {
    // the listener is non-blocking (stoppable accept loop); make sure the
    // per-connection socket blocks — inheritance is platform-dependent
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut session = Session {
        graph: service.default_graph(),
    };
    loop {
        let line = match read_line_capped(&mut reader, MAX_LINE_BYTES) {
            Ok(Some(l)) => l,
            Ok(None) => break, // EOF
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                let _ = writeln!(writer, "ERR line exceeds {MAX_LINE_BYTES} bytes");
                break;
            }
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        // containment: a panicking handler must not take the server down
        let reply = std::panic::catch_unwind(AssertUnwindSafe(|| {
            service.handle_command(&mut session, &line, slot)
        }))
        .unwrap_or_else(|_| "ERR internal handler panic (contained)".into());
        let quit = reply == "OK bye";
        if writeln!(writer, "{reply}").and_then(|_| writer.flush()).is_err() {
            break;
        }
        if quit {
            break;
        }
    }
}

/// `read_line` with a byte cap: returns `Ok(None)` at EOF and
/// `ErrorKind::InvalidData` once a line exceeds `max` bytes.
fn read_line_capped(
    reader: &mut BufReader<TcpStream>,
    max: usize,
) -> std::io::Result<Option<String>> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let buf = reader.fill_buf()?;
        if buf.is_empty() {
            // EOF: hand back any trailing unterminated line
            return Ok(if line.is_empty() {
                None
            } else {
                Some(String::from_utf8_lossy(&line).into_owned())
            });
        }
        let newline = buf.iter().position(|&b| b == b'\n');
        let upto = newline.unwrap_or(buf.len());
        if line.len() + upto > max {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "protocol line too long",
            ));
        }
        line.extend_from_slice(&buf[..upto]);
        let consumed = if newline.is_some() { upto + 1 } else { upto };
        reader.consume(consumed);
        if newline.is_some() {
            return Ok(Some(String::from_utf8_lossy(&line).into_owned()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;

    fn service_with_g1() -> (CoreService, Session) {
        let svc = CoreService::new(BatchConfig {
            threads: 1,
            ..BatchConfig::default()
        });
        svc.open("g1", &examples::g1());
        let session = Session {
            graph: svc.default_graph(),
        };
        (svc, session)
    }

    #[test]
    fn read_commands_round_trip() {
        let (svc, mut s) = service_with_g1();
        assert_eq!(svc.handle_command(&mut s, "PING", 0), "OK pong");
        assert_eq!(svc.handle_command(&mut s, "GRAPHS", 0), "OK n=1 g1");
        assert_eq!(svc.handle_command(&mut s, "EPOCH", 0), "OK epoch=0");
        assert_eq!(svc.handle_command(&mut s, "coreness 3", 0), "OK core=2 epoch=0");
        assert_eq!(
            svc.handle_command(&mut s, "DEGENERACY", 0),
            "OK degeneracy=2 epoch=0"
        );
        assert_eq!(
            svc.handle_command(&mut s, "MEMBERS 2", 0),
            "OK count=4 epoch=0 members=2,3,4,5"
        );
        assert_eq!(
            svc.handle_command(&mut s, "HISTO", 0),
            "OK epoch=0 histo=0:0,1:2,2:4"
        );
    }

    #[test]
    fn edit_flush_cycle_bumps_epoch() {
        let (svc, mut s) = service_with_g1();
        assert_eq!(svc.handle_command(&mut s, "INSERT 2 5", 0), "OK pending=1");
        // queued, not visible yet
        assert_eq!(svc.handle_command(&mut s, "coreness 2", 0), "OK core=2 epoch=0");
        let flush = svc.handle_command(&mut s, "FLUSH", 0);
        assert!(
            flush.starts_with("OK epoch=1 submitted=1 applied=1 coalesced=0 changed=1 recomputed=0"),
            "{flush}"
        );
        assert_eq!(svc.handle_command(&mut s, "coreness 2", 0), "OK core=3 epoch=1");
        let stats = svc.handle_command(&mut s, "STATS", 0);
        assert!(stats.contains("edits=1"), "{stats}");
        assert!(stats.contains("batches=1"), "{stats}");
    }

    #[test]
    fn error_paths_are_structured() {
        let (svc, mut s) = service_with_g1();
        assert!(svc.handle_command(&mut s, "CORENESS 99", 0).starts_with("ERR vertex 99"));
        assert!(svc.handle_command(&mut s, "CORENESS", 0).starts_with("ERR usage"));
        assert!(svc.handle_command(&mut s, "INSERT 3 3", 0).starts_with("ERR self-loop"));
        // unbounded ids would let one command allocate gigabytes
        assert!(svc
            .handle_command(&mut s, "INSERT 0 4294967295", 0)
            .starts_with("ERR vertex id above limit"));
        assert!(svc
            .handle_command(&mut s, &format!("DELETE 0 {}", MAX_VERTEX_ID + 1), 0)
            .starts_with("ERR vertex id above limit"));
        assert!(svc.handle_command(&mut s, "NOPE", 0).starts_with("ERR unknown command"));
        assert!(svc.handle_command(&mut s, "USE ghost", 0).starts_with("ERR unknown graph"));
        assert!(svc.handle_command(&mut s, "", 0).starts_with("ERR empty"));
    }

    #[test]
    fn multi_graph_sessions_are_independent() {
        let (svc, mut s) = service_with_g1();
        let open = svc.handle_command(&mut s, "OPEN k5 g1", 0);
        // 'g1' resolves through the suite; the new index is independent
        assert_eq!(open, "OK open=k5 vertices=6 edges=7");
        assert_eq!(s.graph, "k5");
        svc.handle_command(&mut s, "INSERT 2 5", 0);
        svc.handle_command(&mut s, "FLUSH", 0);
        assert_eq!(svc.handle_command(&mut s, "EPOCH", 0), "OK epoch=1");
        // the original graph is untouched
        assert_eq!(svc.handle_command(&mut s, "USE g1", 0), "OK use=g1");
        assert_eq!(svc.handle_command(&mut s, "EPOCH", 0), "OK epoch=0");
        assert_eq!(svc.handle_command(&mut s, "GRAPHS", 0), "OK n=2 g1 k5");
    }

    #[test]
    fn members_reply_is_capped() {
        let svc = CoreService::new(BatchConfig::default());
        svc.open("star", &examples::star(200));
        let mut s = Session { graph: "star".into() };
        let reply = svc.handle_command(&mut s, "MEMBERS 1", 0);
        assert!(reply.starts_with("OK count=201 "), "{reply}");
        let members = reply.split("members=").nth(1).unwrap();
        assert_eq!(members.split(',').count(), MAX_REPLY_MEMBERS);
    }

    #[test]
    fn tcp_round_trip() {
        let svc = Arc::new(CoreService::new(BatchConfig {
            threads: 1,
            ..BatchConfig::default()
        }));
        svc.open("g1", &examples::g1());
        let handle = serve(svc, "127.0.0.1:0").expect("bind");
        let addr = handle.addr();

        let stream = TcpStream::connect(addr).expect("connect");
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let mut send = |cmd: &str, r: &mut BufReader<TcpStream>| -> String {
            writeln!(w, "{cmd}").unwrap();
            w.flush().unwrap();
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            line.trim_end().to_string()
        };
        assert_eq!(send("PING", &mut r), "OK pong");
        assert_eq!(send("CORENESS 4", &mut r), "OK core=2 epoch=0");
        assert_eq!(send("INSERT 2 5", &mut r), "OK pending=1");
        assert!(send("FLUSH", &mut r).starts_with("OK epoch=1"));
        assert_eq!(send("CORENESS 4", &mut r), "OK core=3 epoch=1");
        assert_eq!(send("QUIT", &mut r), "OK bye");
        handle.stop();
    }
}
