//! The serving surface: a multi-graph [`CoreService`] hosting single or
//! sharded backends behind the [`crate::net`] transport layer — a
//! line-protocol front end plus a length-prefixed binary protocol for
//! snapshot shipping (`pico serve` / `pico query`).
//!
//! This module owns the *application* protocol only: verb semantics and
//! the backends they act on. Framing, connection scheduling, `AUTH`,
//! `METRICS`, and the read-abuse bounds belong to [`crate::net`]
//! ([`crate::net::codec`] / [`crate::net::conn`] / [`crate::net::pool`]),
//! which drives [`CoreService`] through the [`crate::net::conn::Handler`]
//! trait.
//!
//! # Line protocol
//!
//! One UTF-8 command per line, one reply line per command. Replies start
//! with `OK` or `ERR` (or `REDIRECT`, below). Verbs are case-insensitive;
//! vertex ids are decimal `u32`. A session has a *current graph* (the
//! server's default graph until `USE` switches it).
//!
//! | command | reply |
//! |---|---|
//! | `PING` | `OK pong` |
//! | `GRAPHS` | `OK n=<count> <name>...` |
//! | `USE <name>` | `OK use=<name>` |
//! | `OPEN <name> <dataset> [shards]` | `OK open=<name> vertices=<n> edges=<m>[ shards=<k>]` — index a suite dataset or graph file, optionally partitioned across `shards` |
//! | `EPOCH` | `OK epoch=<e>` |
//! | `CORENESS <v>` | `OK core=<c> epoch=<e>` |
//! | `DEGENERACY` | `OK degeneracy=<k> epoch=<e>` |
//! | `MEMBERS <k>` | `OK count=<n> epoch=<e> members=<v,v,...>` (capped). With edits pending on a single-index graph, answered from the live structure + pending overlay via the sort-free single-k extractor ([`crate::core::peel::single_k`]) — one `O(n+m)` pass, no decomposition, no flush; the reply then reflects the queued edits before any epoch publishes them |
//! | `HISTO` | `OK epoch=<e> histo=<k>:<count>,...` |
//! | `DENSEST` | `OK k=<k> vertices=<n> edges=<m> density=<d> epoch=<e>` |
//! | `SHARDS` | deprecated alias for `CLUSTER TOPOLOGY` (byte-identical reply; kept for old tooling, see [`crate::net::conn::CLUSTER_ALIASES`]) |
//! | `CLUSTER TOPOLOGY` | `OK shards=<n> strategy=<s> ...` — partition + merge stats; on a cluster front end, one `<id>:<kind>:<addr>+<n>r:fo<f>:st<s>:lag<l>` cell per replica group |
//! | `CLUSTER REBALANCE PLAN` | `OK rebalance plan moves=<m> lines=<l>` + one `load shard=...` line per shard (state bytes, routed-edit heat, boundary arcs, replica lag, reachability) and one `move <kind> from=... reason: ...` line per planned move — a dry run, touches nothing ([`crate::cluster::rebalance`]) |
//! | `CLUSTER REBALANCE APPLY` | plan and execute in one latched step: `OK rebalance applied moves=<m> lines=<l>` + one line per completed move; `ERR MIGRATING ...` when another structural change is in flight |
//! | `CLUSTER REBALANCE MIGRATE <shard> <host:port>` | live primary migration: unfenced manifest + delta-chain catch-up, then an epoch-verified fenced cutover — `OK migrate shard=<s> addr=<a> bytes=<b> cutover_us=<c> epoch=<e>` |
//! | `CLUSTER MOVES [JSON]` | `OK moves n=<n> lines=<l>` + one line per completed move (kind, endpoints, vertices, bytes shipped, cutover pause, epoch, wall-clock), oldest first; with `JSON`, one JSON array instead |
//! | `INSERT <u> <v>` | `OK pending=<n>` — queued, not yet visible |
//! | `DELETE <u> <v>` | `OK pending=<n>` |
//! | `FLUSH` | `OK epoch=<e> submitted=<s> applied=<a> coalesced=<c> changed=<g> recomputed=<r> [shards=<n> rounds=<r> boundary=<b>] ms=<t>` |
//! | `STATS` | `OK queries=<q> edits=<e> batches=<b> recomputes=<r> graphs=<g>` |
//! | `STATS <window_s> [JSON]` | `OK stats window=<w>s samples=<n> lines=<l>` + one `key value` line per windowed signal (qps, edits/flushes per second, query/flush-stage p99s in µs, replica lag, cutoff/error rates) computed from the [`crate::obs::tsdb`] sample ring over the trailing `window_s` seconds; `n/a` where the ring holds too little data (no sampler, or just started). With `JSON`, one JSON object instead (`null` for missing). The ring is fed by the `pico serve --sample-interval` sampler |
//! | `METRICS` | `OK workers=<w> conn_cap=<c> accepted=<a> active=<n> queued=<q> rejected=<r> timed_out=<t> write_stalled=<s> reclaimed=<i>` — transport counters, answered by [`crate::net::conn`] (`write_stalled` = peers cut off for not draining their replies, `reclaimed` = idle connections closed while the pool sat at its cap) |
//! | `METRICS PROM` / `METRICS JSON` | `OK metrics format=<f> lines=<n> bytes=<b>` + `\n`-joined exposition of the whole [`crate::obs`] registry (serve counters, flush-stage histograms, transport + sync series); `PROM` is the Prometheus text format `pico cluster status --metrics` scrapes and merges |
//! | `TRACES [n]` | `OK traces n=<t> lines=<l>` + the `l` rendered span-tree lines of the `n` most recent flush/slow-query traces from the [`crate::obs::trace`] ring (default 5; ring size set by `pico serve --trace-ring`) |
//! | `EVENTS [n] [min-severity]` | `OK events n=<e> lines=<l>` + one line per journal entry, newest first: `<unix_ms> <severity> <kind> graph=<g> <detail>` from the [`crate::obs::events`] ring (default 10; `min-severity` of `info`/`warn`/`error` filters), answered by [`crate::net::conn`]; merged across hosts by `pico cluster status --events` |
//! | `HEALTH [graph]` | `OK health=<ok\|degraded\|critical> reasons=<r> lines=<l>` + one reason line per violated SLO rule, evaluated by [`crate::obs::health`] against the tsdb window and the live registry (optionally narrowed to one graph's replication state); `pico cluster status --health` exits non-zero below `ok` |
//! | `AUTH <token>` | `OK auth` / `ERR AUTH bad auth token` — unlocks the gated shard verbs when the server has a token configured (answered by [`crate::net::conn`], constant-time compare) |
//! | `BINARY` | `OK binary proto=<id>` — switch this connection to binary framing (the id names the framing codec, [`crate::net::codec::FRAME_PROTO`]) |
//! | `QUIT` | `OK bye` (connection closes) |
//!
//! `SHARDINFO`, `SHARDCORE <v>`, and `SHARDHISTO` are the line-mode
//! shard probes (documented under *Cluster verbs* below). On a server
//! *fronting a cluster*, `SHARDCORE <v>` for a vertex whose shard lives
//! on another host answers `REDIRECT shard=<s> addr=<host:port>
//! graph=<name>` — a hint the shared client (`pico query`) follows for
//! one hop instead of erroring; locally-owned shards answer inline.
//!
//! Edits become visible only at `FLUSH` (one published epoch per flush),
//! so a client controls its own read-your-writes boundary. Readers on
//! other connections keep being served the previous epoch while a flush
//! is applying — the epoch-snapshot guarantee from [`super::index`]. On a
//! sharded graph the flush routes edits to their owner shards and runs
//! the boundary-refinement merge before publishing (see [`crate::shard`]).
//!
//! # Structured errors
//!
//! Every refusal a client may want to branch on carries a
//! machine-readable code as the first token after `ERR` — `ERR <CODE>
//! <message>` — minted by one helper ([`crate::net::conn::err_reply`])
//! and parsed back by the shared client
//! ([`crate::net::client::ErrCode`]), so retry/failover logic never
//! string-matches message text:
//!
//! | code | meaning |
//! |---|---|
//! | `AUTH` | missing or wrong `AUTH <token>` preamble on a gated verb |
//! | `NOGRAPH` | the session has no (usable) graph selected |
//! | `STALE_EPOCH` | an epoch-fenced request does not match the replica's base epoch (delta chain after a rebalance, read of a stale replica) — catch up or re-route, never retry verbatim |
//! | `REDIRECT` | the addressed state lives on another host; the message names it |
//! | `CAPACITY` | a server-side cap refused the request (hosted-graph limit, connection cap, pending-edit queue) |
//! | `BADREQ` | malformed or oversized request — a client bug, never retried |
//! | `MIGRATING` | a structural change (rebalance / migration) holds the latch; retry shortly |
//!
//! Errors without a recognized code are legacy message-only refusals;
//! the client surfaces them with `code=None`.
//!
//! # Binary protocol
//!
//! After `BINARY`, every subsequent request and reply is one frame:
//! a little-endian `u32` byte length followed by that many payload bytes
//! (capped at [`MAX_FRAME_BYTES`]; framing lives in
//! [`crate::net::codec`]). A request frame's payload is a UTF-8
//! command line — any line-protocol verb works — optionally followed by
//! `\n` and raw bytes. Two verbs use the raw-byte side:
//!
//! | frame | payload |
//! |---|---|
//! | `SNAPSHOT` (single) / `SNAPSHOT <shard>` (sharded) | reply `OK snapshot name=<n> epoch=<e> bytes=<b>` + `\n` + snapshot bytes |
//! | `RESTORE <name>` + `\n` + snapshot bytes | reply `OK restore=<name> epoch=<e> vertices=<v> edges=<m>` — hydrates a replica **without recomputing** |
//!
//! Snapshot bytes are the [`crate::shard::snapshot`] format; `RESTORE`
//! validates them fully (CSR structure + coreness invariants) before
//! installing, so corrupt payloads are rejected without leaving a
//! half-installed graph slot behind.
//!
//! A *single-index* snapshot restores a full replica: identical answers
//! at the identical epoch. `SNAPSHOT <shard>` of a sharded graph ships
//! that shard's **local** index — its subgraph (owned + ghost vertices,
//! local ids) and shard-local coreness at the shard's own epoch. That is
//! the unit a shard replica hydrates; it does not answer global queries
//! by itself (global answers come from the router's merge).
//!
//! # Cluster verbs
//!
//! The multi-host layer ([`crate::cluster`]) adds verbs in two roles.
//! On a server *hosting a shard* (installed by `SHARDHOST`), the shard
//! interface — payload formats in [`crate::cluster::wire`]:
//!
//! | frame | payload / reply |
//! |---|---|
//! | `SHARDHOST <name>` + manifest | install/overwrite a hosted shard (hydrates, never recomputes) |
//! | `SHARDSNAP` | reply head + manifest bytes — the full-catch-up source |
//! | `SHARDDELTA <from> <to>` + delta chain | replay epochs `(from, to]` on a lagging replica: per epoch, the routed batch + the refined-coreness diff ([`crate::cluster::journal`]); validated in full (base epoch must match) and never recomputes — `OK sharddelta=<name> epochs=<k> cluster=<to>`, or `ERR` and the router falls back to `SHARDHOST` |
//! | `SHARDAPPLY` + routed batch | `OK changed=<c> recomputed=<r> epoch=<e>` |
//! | `SHARDREFINE START <slack\|->` | `OK refine-init ...` + estimates/ghosts/arcs payload |
//! | `SHARDREFINE ROUND` + updates | `OK sweeps=<s> ghosts=<g>` + changed-estimates payload |
//! | `SHARDREFINE COMMIT <epoch>` | `OK commit=<epoch> changed=<n>` + refined-diff payload (the journal entry's diff half) |
//! | `SHARDMEMBERS <k>` | `OK count=<n> cluster=<ce>` + member-id payload |
//! | `SHARDHAND EXPORT <count>` | `OK handoff shard=<id> bytes=<n>` + handoff payload — the `count` boundary-heaviest owned vertices with their full adjacency and committed coreness ([`crate::cluster::wire`] handoff codec), the elastic-resharding export half |
//! | `SHARDHAND ADOPT` + handoff payload | `OK adopted=<n> shard=<id>` + adopted-id payload — splice the shipped vertices into this shard's owned set; a vertex already owned is refused wholesale (the double-apply fence) |
//! | `SHARDHAND RELEASE` + id payload | `OK released=<n>` — drop ownership of vertices that landed elsewhere (they stay as ghosts where referenced) |
//!
//! plus line-mode probes `SHARDINFO` (health/epoch/state bytes),
//! `SHARDCORE <v>`, and `SHARDHISTO`, each stamped with the committed
//! cluster epoch so readers can reject stale replicas. On a server
//! *fronting a cluster* (`pico serve --cluster`), the ordinary verbs
//! serve merged answers: `CORENESS` routes to the owner shard's replica
//! group (epoch-checked failover); `FLUSH` routes edits to primaries,
//! runs the boundary exchange, journals the epoch's per-shard deltas,
//! and publishes — it does **not** touch replicas, so flush latency is
//! independent of replica health. Replica convergence belongs to the
//! background [`ReplicaSyncDaemon`] (`pico serve --sync-interval`,
//! jittered probing), which ships delta chains to lagging replicas and
//! full manifests when the journal cannot cover the gap.
//!
//! The TCP layer is [`crate::net::pool`] + [`crate::net::poller`]: one
//! accept thread and a bounded worker pool (`pico serve --workers N`,
//! default `min(cores, 16)`) over a connection run queue, with every
//! idle connection parked in a single `poll(2)` readiness set — an
//! idle connection costs one fd and its buffers, never a worker wakeup,
//! so a mostly-idle fleet of tens of thousands of clients leaves
//! request latency untouched. There is a hard connection cap
//! (`--max-conns`; accept #cap+1 gets one best-effort bounded `ERR`
//! line and a close — a rejected client that never reads cannot block
//! the accept thread), per-request slow-loris timeouts, and write
//! backpressure: replies are staged on a bounded per-connection buffer
//! and flushed on writability, a connection over its high-water mark
//! stops being read, and a peer that stops draining replies for a full
//! stall window is cut off (`write_stalled` on `METRICS`) — so a
//! non-reading client can never pin a worker or the accept thread.
//! Plus the scheduler's containment idiom: a panicking handler poisons
//! nothing — the connection reports `ERR internal` and closes, the
//! pool keeps serving. The transport counters surface on `METRICS`.
//! Abuse bounds: [`MAX_LINE_BYTES`], [`MAX_FRAME_BYTES`],
//! [`MAX_VERTEX_ID`], [`MAX_PENDING_EDITS`], [`MAX_HOSTED_GRAPHS`].
//!
//! # Graceful shutdown
//!
//! [`ServerHandle::drain`] stops the accept loop and asks every
//! connection to wind down at its next *command boundary*: an in-flight
//! request is parsed, executed, and answered in full (a half-read frame
//! is never dropped), parked idle connections are woken and closed
//! immediately, staged replies keep flushing (bounded by the stall
//! timeout, so a write-stalled peer cannot hold the drain open), and
//! [`CoreService::flush_all`] then applies any pending edits so nothing
//! queued is lost. `pico serve` drives this on SIGTERM / ctrl-c.
//!
//! **Trust model:** when an auth token is configured (`auth_token` in
//! the cluster topology, or the `PICO_AUTH_TOKEN` env var for any
//! `pico serve`), the state-mutating shard verbs
//! ([`crate::net::conn::AUTH_VERBS`]) require the `AUTH <token>`
//! preamble on the connection; everything else — reads, and `OPEN`,
//! which resolves suite names *and server-local file paths* (CLI
//! parity) — stays open. The default bind is loopback; expose a
//! non-loopback `--addr` only to clients you would let run `pico` on
//! the host.

use super::batch::{BatchConfig, EditQueue};
use super::index::{CoreIndex, CoreSnapshot};
use super::queries::densest_core_view;
use crate::cluster::{ClusterIndex, ShardHost};
use crate::core::maintenance::EdgeEdit;
use crate::core::peel::live_kcore;
use crate::graph::CsrGraph;
use crate::net::conn::{code, err_reply, Handler, CLUSTER_SUBVERBS};
use crate::net::{codec, NetConfig};
use crate::obs::{self, names};
use crate::shard::{snapshot as shard_snapshot, PartitionStrategy, ShardedIndex};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

// The transport surface moved to `crate::net`; these re-exports keep
// the long-standing `service::server::{...}` import paths working for
// tests, benches, and downstream code.
pub use crate::net::codec::{read_frame, write_frame, MAX_FRAME_BYTES, MAX_LINE_BYTES};
pub use crate::net::conn::Session;
pub use crate::net::pool::ServerHandle;

/// The read verbs whose latency lands in `pico_query_seconds` (and
/// whose count feeds the query counters — serve-path accounting lives
/// in the observability registry, [`crate::obs`], one series per
/// graph).
const QUERY_VERBS: &[&str] = &[
    "EPOCH",
    "CORENESS",
    "DEGENERACY",
    "MEMBERS",
    "HISTO",
    "DENSEST",
    "SHARDS",
    "SHARDINFO",
    "SHARDCORE",
    "SHARDHISTO",
];

/// Host-side stage histogram for a timed shard-mutation frame, if the
/// verb is one of the flush stages a coordinator traces: `SHARDAPPLY`
/// lands in `pico_shard_apply_seconds`, `SHARDREFINE COMMIT` in
/// `pico_shard_commit_seconds`, and the other `SHARDREFINE` phases in
/// `pico_shard_refine_round_seconds`. Read/ship verbs return `None`.
fn shard_stage_histogram(verb: &str, first_arg: Option<&str>) -> Option<&'static str> {
    match verb {
        "SHARDAPPLY" => Some(names::SHARD_APPLY_SECONDS),
        "SHARDREFINE" => {
            if first_arg.is_some_and(|a| a.eq_ignore_ascii_case("COMMIT")) {
                Some(names::SHARD_COMMIT_SECONDS)
            } else {
                Some(names::SHARD_REFINE_ROUND_SECONDS)
            }
        }
        _ => None,
    }
}

/// Reply cap for `MEMBERS` (a serving system never streams a million ids
/// down one reply line; `count=` always carries the true size).
pub const MAX_REPLY_MEMBERS: usize = 64;

/// Most queued-but-unflushed edits per graph accepted from the wire. A
/// client that streams INSERTs without ever flushing must not grow the
/// pending queue without bound; past the cap, edits are rejected until a
/// FLUSH drains it.
pub const MAX_PENDING_EDITS: usize = 1 << 20;

/// Most graphs one server will host (OPEN of an *existing* name always
/// works — it is a reset). Keeps a chatty client from growing the hosted
/// map, each entry of which owns a full index.
pub const MAX_HOSTED_GRAPHS: usize = 16;

/// Most shards one OPEN may request (each shard owns a full index).
pub const MAX_SHARDS: usize = 64;

/// Largest vertex id accepted from the wire. Edits grow the vertex set
/// (`DynamicCore::ensure_vertex`), so without a bound one
/// `INSERT 0 4294967295` would make the server allocate tens of GB and
/// die. 2^24 vertices ≈ 200 MB of adjacency headroom — far above every
/// suite graph; raise it here when hosting genuinely larger graphs.
pub const MAX_VERTEX_ID: u32 = (1 << 24) - 1;

/// One hosted graph: a single index + its edit queue, or a sharded index
/// (which owns its shards' queues internally). Installed and replaced as
/// a unit so a flush can never reach an orphaned index.
#[derive(Clone)]
enum Backend {
    Single {
        index: Arc<CoreIndex>,
        queue: Arc<EditQueue>,
    },
    Sharded(Arc<ShardedIndex>),
    /// One shard of some cluster, installed via `SHARDHOST`. Serves the
    /// shard interface; ordinary read verbs see the shard-local view.
    ShardHost(Arc<ShardHost>),
    /// A whole cluster fronted by this server (`pico serve --cluster`).
    Cluster(Arc<ClusterIndex>),
}

impl Backend {
    fn snapshot(&self) -> Arc<CoreSnapshot> {
        match self {
            Backend::Single { index, .. } => index.snapshot(),
            Backend::Sharded(sh) => sh.snapshot(),
            Backend::ShardHost(h) => h.index().snapshot(),
            Backend::Cluster(c) => c.snapshot(),
        }
    }

    fn consistent_view(&self) -> Result<(Arc<CoreSnapshot>, Arc<CsrGraph>)> {
        match self {
            Backend::Single { index, .. } => Ok(index.consistent_view()),
            Backend::Sharded(sh) => Ok(sh.consistent_view()),
            Backend::ShardHost(h) => Ok(h.index().consistent_view()),
            Backend::Cluster(c) => c.consistent_view(),
        }
    }

    fn pending(&self) -> usize {
        match self {
            Backend::Single { queue, .. } => queue.pending(),
            Backend::Sharded(sh) => sh.pending(),
            Backend::ShardHost(_) => 0,
            Backend::Cluster(c) => c.pending(),
        }
    }

    /// Shard hosts take writes only through their cluster router
    /// (`SHARDAPPLY`) — local INSERTs would silently diverge from it.
    fn writable(&self) -> bool {
        !matches!(self, Backend::ShardHost(_))
    }

    fn submit(&self, e: EdgeEdit) -> usize {
        match self {
            Backend::Single { queue, .. } => queue.submit(e),
            Backend::Sharded(sh) => sh.submit(e),
            Backend::ShardHost(_) => 0,
            Backend::Cluster(c) => c.submit(e),
        }
    }
}

/// Per-graph observability handles ([`crate::obs`]), resolved once at
/// install and carried alongside the backend in the hosted map — the
/// request path pays atomic bumps, never a registry lookup.
struct GraphObs {
    queries: Arc<obs::Counter>,
    edits: Arc<obs::Counter>,
    batches: Arc<obs::Counter>,
    recomputes: Arc<obs::Counter>,
    query_seconds: Arc<obs::Histogram>,
}

impl GraphObs {
    fn register(graph: &str) -> Arc<Self> {
        let reg = obs::global();
        let l: &[(&str, &str)] = &[("graph", graph)];
        Arc::new(Self {
            queries: reg.counter(names::SERVE_QUERIES, l),
            edits: reg.counter(names::SERVE_EDITS, l),
            batches: reg.counter(names::SERVE_BATCHES, l),
            recomputes: reg.counter(names::SERVE_RECOMPUTES, l),
            query_seconds: reg.histogram(names::QUERY_SECONDS, l),
        })
    }
}

/// A hosted graph slot: the backend plus its registry handles.
#[derive(Clone)]
struct Hosted {
    backend: Backend,
    obs: Arc<GraphObs>,
}

/// Service-local `STATS` totals. The canonical per-graph series live in
/// the process-global observability registry; these four stay on the
/// service so embedded services (tests host several per process) keep
/// independent `STATS` readouts.
#[derive(Default)]
struct Totals {
    queries: AtomicU64,
    edits: AtomicU64,
    batches: AtomicU64,
    recomputes: AtomicU64,
}

/// Aggregated serve-path counters, as the `STATS` verb reports them.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub serve_queries: u64,
    pub serve_edits: u64,
    pub serve_batches: u64,
    pub serve_recomputes: u64,
}

/// The serving core: named backends, request counters, batch policy.
pub struct CoreService {
    hosted: RwLock<HashMap<String, Hosted>>,
    batch_cfg: BatchConfig,
    totals: Totals,
    default_graph: Mutex<String>,
}

impl CoreService {
    pub fn new(batch_cfg: BatchConfig) -> Self {
        Self {
            hosted: RwLock::new(HashMap::new()),
            batch_cfg,
            totals: Totals::default(),
            default_graph: Mutex::new(String::new()),
        }
    }

    fn install(&self, name: &str, backend: Backend) {
        let slot = Hosted {
            backend,
            obs: GraphObs::register(name),
        };
        self.hosted.write().unwrap().insert(name.to_string(), slot);
        let mut d = self.default_graph.lock().unwrap();
        if d.is_empty() {
            *d = name.to_string();
        }
    }

    /// Wire-path install: enforce [`MAX_HOSTED_GRAPHS`] *under the map's
    /// write lock*, so concurrent OPEN/RESTORE connections cannot race
    /// past the cap between a check and the insert.
    fn install_checked(&self, name: &str, backend: Backend) -> Result<(), String> {
        let slot = Hosted {
            backend,
            obs: GraphObs::register(name),
        };
        {
            let mut hosted = self.hosted.write().unwrap();
            if !hosted.contains_key(name) && hosted.len() >= MAX_HOSTED_GRAPHS {
                return Err(format!("graph limit reached ({MAX_HOSTED_GRAPHS} hosted)"));
            }
            hosted.insert(name.to_string(), slot);
        }
        let mut d = self.default_graph.lock().unwrap();
        if d.is_empty() {
            *d = name.to_string();
        }
        Ok(())
    }

    /// Host `g` under `name` (first hosted graph becomes the default).
    /// Re-opening an existing name atomically replaces the whole backend
    /// — any unflushed edits on the old queue are discarded by design
    /// (OPEN is a reset).
    pub fn open(&self, name: &str, g: &CsrGraph) -> Arc<CoreIndex> {
        let idx = Arc::new(CoreIndex::new(name, g));
        let queue = Arc::new(EditQueue::new(idx.clone(), self.batch_cfg.clone()));
        self.install(
            name,
            Backend::Single {
                index: idx.clone(),
                queue,
            },
        );
        idx
    }

    /// Host `g` partitioned across `shards` under `name`.
    pub fn open_sharded(
        &self,
        name: &str,
        g: &CsrGraph,
        shards: usize,
        strategy: PartitionStrategy,
    ) -> Arc<ShardedIndex> {
        let idx = Arc::new(ShardedIndex::new(
            name,
            g,
            shards,
            strategy,
            self.batch_cfg.clone(),
        ));
        self.install(name, Backend::Sharded(idx.clone()));
        idx
    }

    /// Front a cluster index under `name` — the `pico serve --cluster`
    /// install path (the index was built and its shards placed already).
    pub fn open_cluster(&self, name: &str, idx: Arc<ClusterIndex>) {
        self.install(name, Backend::Cluster(idx));
    }

    /// Flush every backend with pending edits — the drain path, so
    /// nothing a client queued before shutdown is lost. Per graph that
    /// flushed something (or failed to): `Ok((published epoch, applied
    /// edits))`, or the error text — a cluster whose remote primary is
    /// unreachable at shutdown must be reported, not silently skipped.
    #[allow(clippy::type_complexity)]
    pub fn flush_all(&self) -> Vec<(String, Result<(u64, usize), String>)> {
        let hosted: Vec<(String, Backend)> = self
            .hosted
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.backend.clone()))
            .collect();
        let mut out = Vec::new();
        for (name, backend) in hosted {
            let flushed = match &backend {
                Backend::Single { queue, .. } => {
                    let o = queue.flush();
                    Ok((o.snapshot.epoch, o.applied))
                }
                Backend::Sharded(sh) => {
                    let o = sh.flush();
                    Ok((o.snapshot.epoch, o.applied))
                }
                Backend::Cluster(c) => match c.flush() {
                    Ok(o) => {
                        // drain-time convergence: the daemon is stopping,
                        // so give replicas one last best-effort sync (the
                        // flush result stands either way)
                        match c.sync_replicas() {
                            Ok(r) if r.failed > 0 => eprintln!(
                                "warning: {} replica(s) of '{name}' not synced during drain: {}",
                                r.failed,
                                r.first_error.as_deref().unwrap_or("unknown error")
                            ),
                            Ok(_) => {}
                            Err(e) => eprintln!(
                                "warning: replica sync for '{name}' failed during drain: {e:#}"
                            ),
                        }
                        Ok((o.snapshot.epoch, o.applied))
                    }
                    Err(e) => Err(format!("{e:#}")),
                },
                // a shard host has no local queue; its router drains it
                Backend::ShardHost(_) => continue,
            };
            match flushed {
                Ok((_, 0)) => {} // nothing was pending
                other => out.push((name, other)),
            }
        }
        out
    }

    pub fn default_graph(&self) -> String {
        self.default_graph.lock().unwrap().clone()
    }

    fn backend(&self, name: &str) -> Option<Backend> {
        self.hosted.read().unwrap().get(name).map(|h| h.backend.clone())
    }

    fn hosted_of(&self, name: &str) -> Option<Hosted> {
        self.hosted.read().unwrap().get(name).cloned()
    }

    /// Count one served query against `graph` (frame-path verbs; the
    /// line path counts in [`Self::handle_command`]).
    fn count_query(&self, graph: &str) {
        self.totals.queries.fetch_add(1, Ordering::Relaxed);
        if let Some(h) = self.hosted_of(graph) {
            h.obs.queries.inc();
        }
    }

    /// The single-index backend of `name`, if it is one.
    pub fn index(&self, name: &str) -> Option<Arc<CoreIndex>> {
        match self.backend(name)? {
            Backend::Single { index, .. } => Some(index),
            _ => None,
        }
    }

    /// The sharded backend of `name`, if it is one.
    pub fn sharded(&self, name: &str) -> Option<Arc<ShardedIndex>> {
        match self.backend(name)? {
            Backend::Sharded(sh) => Some(sh),
            _ => None,
        }
    }

    /// The edit queue of a single-index graph.
    pub fn queue(&self, name: &str) -> Option<Arc<EditQueue>> {
        match self.backend(name)? {
            Backend::Single { queue, .. } => Some(queue),
            _ => None,
        }
    }

    pub fn graph_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.hosted.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    fn num_graphs(&self) -> usize {
        self.hosted.read().unwrap().len()
    }

    /// Aggregated serve-path counters (this service only; the per-graph
    /// series live in [`crate::obs::global`]).
    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            serve_queries: self.totals.queries.load(Ordering::Relaxed),
            serve_edits: self.totals.edits.load(Ordering::Relaxed),
            serve_batches: self.totals.batches.load(Ordering::Relaxed),
            serve_recomputes: self.totals.recomputes.load(Ordering::Relaxed),
        }
    }

    /// Execute one protocol line for a session on `graph`; returns the
    /// reply line (without newline). Read verbs are timed into the
    /// graph's `pico_query_seconds` histogram here (slow ones also land
    /// in the trace ring), wrapping [`Self::dispatch_command`] so the
    /// early returns inside the verb arms stay simple.
    pub fn handle_command(&self, session: &mut Session, line: &str, slot: usize) -> String {
        let verb = line.split_whitespace().next().unwrap_or("");
        if !QUERY_VERBS.iter().any(|q| verb.eq_ignore_ascii_case(q)) {
            return self.dispatch_command(session, line, slot);
        }
        let t0 = Instant::now();
        let reply = self.dispatch_command(session, line, slot);
        if let Some(h) = self.hosted_of(&session.graph) {
            let dur = t0.elapsed();
            self.totals.queries.fetch_add(1, Ordering::Relaxed);
            h.obs.queries.inc();
            h.obs
                .query_seconds
                .record(dur.as_micros().min(u64::MAX as u128) as u64);
            obs::record_slow_query(&session.graph, &verb.to_ascii_uppercase(), dur);
        }
        reply
    }

    fn dispatch_command(&self, session: &mut Session, line: &str, _slot: usize) -> String {
        let mut parts = line.split_whitespace();
        let Some(raw_verb) = parts.next() else {
            return "ERR empty command".into();
        };
        let verb = raw_verb.to_ascii_uppercase();
        let args: Vec<&str> = parts.collect();
        match verb.as_str() {
            "PING" => "OK pong".into(),
            "GRAPHS" => {
                let names = self.graph_names();
                format!("OK n={} {}", names.len(), names.join(" "))
            }
            "USE" => match args.first() {
                Some(&name) if self.backend(name).is_some() => {
                    session.graph = name.to_string();
                    format!("OK use={name}")
                }
                Some(&name) => format!("ERR unknown graph '{name}'"),
                None => "ERR usage: USE <name>".into(),
            },
            "OPEN" => {
                let (Some(&name), Some(&dataset)) = (args.first(), args.get(1)) else {
                    return "ERR usage: OPEN <name> <dataset> [shards]".into();
                };
                let shards = match args.get(2) {
                    None => 1usize,
                    Some(s) => match s.parse::<usize>() {
                        Ok(k) if (1..=MAX_SHARDS).contains(&k) => k,
                        _ => return format!("ERR shards must be 1..={MAX_SHARDS}, got '{s}'"),
                    },
                };
                // cheap fast-fail; install_checked below is authoritative
                if self.backend(name).is_none() && self.num_graphs() >= MAX_HOSTED_GRAPHS {
                    return err_reply(
                        code::CAPACITY,
                        format!("graph limit reached ({MAX_HOSTED_GRAPHS} hosted)"),
                    );
                }
                match load_dataset(dataset) {
                    Ok(g) => {
                        let (backend, vertices, edges, suffix) = if shards > 1 {
                            let idx = Arc::new(ShardedIndex::new(
                                name,
                                &g,
                                shards,
                                PartitionStrategy::Hash,
                                self.batch_cfg.clone(),
                            ));
                            let s = idx.snapshot();
                            (
                                Backend::Sharded(idx),
                                s.num_vertices(),
                                s.num_edges,
                                format!(" shards={shards}"),
                            )
                        } else {
                            let idx = Arc::new(CoreIndex::new(name, &g));
                            let queue =
                                Arc::new(EditQueue::new(idx.clone(), self.batch_cfg.clone()));
                            let s = idx.snapshot();
                            (
                                Backend::Single { index: idx, queue },
                                s.num_vertices(),
                                s.num_edges,
                                String::new(),
                            )
                        };
                        if let Err(e) = self.install_checked(name, backend) {
                            return err_reply(code::CAPACITY, e);
                        }
                        session.graph = name.to_string();
                        format!("OK open={name} vertices={vertices} edges={edges}{suffix}")
                    }
                    Err(e) => format!("ERR {e:#}"),
                }
            }
            "STATS" => match args.first() {
                // the bare reply line predates the tsdb and stays
                // byte-for-byte stable for existing tooling
                None => {
                    let s = self.stats();
                    format!(
                        "OK queries={} edits={} batches={} recomputes={} graphs={}",
                        s.serve_queries,
                        s.serve_edits,
                        s.serve_batches,
                        s.serve_recomputes,
                        self.num_graphs()
                    )
                }
                Some(w) => match w.parse::<f64>() {
                    Ok(window_s) if window_s > 0.0 => {
                        let ts = obs::tsdb::global();
                        let json = args
                            .get(1)
                            .map(|f| f.eq_ignore_ascii_case("json"))
                            .unwrap_or(false);
                        if json {
                            let body = obs::tsdb::render_window_json(ts, window_s);
                            format!(
                                "OK stats window={window_s:.0}s samples={} format=json lines=1\n{body}",
                                ts.samples_in(window_s)
                            )
                        } else {
                            let lines = obs::tsdb::render_window_text(ts, window_s);
                            let mut reply = format!(
                                "OK stats window={window_s:.0}s samples={} lines={}",
                                ts.samples_in(window_s),
                                lines.len()
                            );
                            for l in &lines {
                                reply.push('\n');
                                reply.push_str(l);
                            }
                            reply
                        }
                    }
                    _ => format!("ERR bad STATS window '{w}' (want seconds > 0)"),
                },
            },
            "CLUSTER" => self.cluster_command(session, &args, _slot),
            "BINARY" => {
                session.binary = true;
                format!("OK binary proto={}", codec::FRAME_PROTO)
            }
            "SNAPSHOT" | "RESTORE" | "SHARDHOST" | "SHARDSNAP" | "SHARDAPPLY" | "SHARDREFINE"
            | "SHARDMEMBERS" | "SHARDDELTA" | "SHARDHAND"
                if !session.binary =>
            {
                format!("ERR {verb} needs the binary protocol (send BINARY first)")
            }
            "QUIT" => "OK bye".into(),
            // everything below operates on the session's current graph
            _ => {
                let Some(Hosted { backend, obs: gobs }) = self.hosted_of(&session.graph) else {
                    return err_reply(
                        code::NOGRAPH,
                        format!("no graph selected (have: {})", self.graph_names().join(" ")),
                    );
                };
                match verb.as_str() {
                    "EPOCH" => {
                        // the snapshot's epoch, not the writer counter:
                        // the reply must name an epoch readers can get
                        format!("OK epoch={}", backend.snapshot().epoch)
                    }
                    "CORENESS" => {
                        let Some(Ok(v)) = args.first().map(|a| a.parse::<u32>()) else {
                            return "ERR usage: CORENESS <v>".into();
                        };
                        // a cluster answers from the owner shard's
                        // replica group (epoch-checked failover) — the
                        // read path the replicas exist for
                        if let Backend::Cluster(c) = &backend {
                            return match c.coreness_routed(v) {
                                Ok(Some(core)) => {
                                    format!("OK core={core} epoch={}", c.epoch())
                                }
                                Ok(None) => format!(
                                    "ERR vertex {v} out of range (|V|={})",
                                    c.snapshot().num_vertices()
                                ),
                                Err(e) => format!("ERR cluster read: {e:#}"),
                            };
                        }
                        let s = backend.snapshot();
                        match s.coreness(v) {
                            Some(c) => format!("OK core={c} epoch={}", s.epoch),
                            None => {
                                format!("ERR vertex {v} out of range (|V|={})", s.num_vertices())
                            }
                        }
                    }
                    "DEGENERACY" => {
                        let s = backend.snapshot();
                        format!("OK degeneracy={} epoch={}", s.degeneracy(), s.epoch)
                    }
                    "MEMBERS" => {
                        let Some(Ok(k)) = args.first().map(|a| a.parse::<u32>()) else {
                            return "ERR usage: MEMBERS <k>".into();
                        };
                        // Mid-batch fast path: with edits queued the
                        // committed snapshot is stale, so answer from the
                        // live structure + pending overlay via the
                        // sort-free single-k extractor — one O(n+m) pass,
                        // no decomposition, no flush. Racing a concurrent
                        // flush is benign: already-applied overlay edits
                        // classify as no-ops against the base adjacency.
                        if let Backend::Single { index, queue } = &backend {
                            let edits = queue.pending_edits();
                            if !edits.is_empty() {
                                let set =
                                    index.with_dynamic(|dc| live_kcore(dc, &edits, k));
                                let listed: Vec<String> = set
                                    .members_capped(MAX_REPLY_MEMBERS)
                                    .into_iter()
                                    .map(|v| v.to_string())
                                    .collect();
                                return format!(
                                    "OK count={} epoch={} members={}",
                                    set.size(),
                                    index.epoch(),
                                    listed.join(",")
                                );
                            }
                        }
                        let s = backend.snapshot();
                        // count + capped listing without materialising the
                        // full membership (|V|-sized per request otherwise)
                        let count = s.kcore_size(k);
                        let listed: Vec<String> = s
                            .kcore_members_capped(k, MAX_REPLY_MEMBERS)
                            .into_iter()
                            .map(|v| v.to_string())
                            .collect();
                        format!(
                            "OK count={} epoch={} members={}",
                            count,
                            s.epoch,
                            listed.join(",")
                        )
                    }
                    "HISTO" => {
                        let s = backend.snapshot();
                        let cells: Vec<String> = s
                            .histogram()
                            .iter()
                            .enumerate()
                            .map(|(k, n)| format!("{k}:{n}"))
                            .collect();
                        format!("OK epoch={} histo={}", s.epoch, cells.join(","))
                    }
                    "DENSEST" => {
                        match backend.consistent_view() {
                            Ok((snap, g)) => {
                                let d = densest_core_view(&snap, &g);
                                format!(
                                    "OK k={} vertices={} edges={} density={:.4} epoch={}",
                                    d.k, d.vertices, d.edges, d.density, d.epoch
                                )
                            }
                            Err(e) => format!("ERR densest: {e:#}"),
                        }
                    }
                    "SHARDS" => {
                        match &backend {
                            Backend::Single { .. } => "OK shards=1 strategy=single".into(),
                            Backend::ShardHost(h) => h.info(),
                            Backend::Cluster(c) => {
                                // topology + counters from local state
                                // only — a serving verb must not probe
                                // every endpoint over the network (that
                                // is `pico cluster status`'s job)
                                let m = c.merge_stats();
                                let mut sync = crate::cluster::SyncStats::default();
                                let groups: Vec<String> = c
                                    .groups()
                                    .iter()
                                    .map(|g| {
                                        let s = g.sync_stats();
                                        sync.deltas_shipped += s.deltas_shipped;
                                        sync.snapshots_shipped += s.snapshots_shipped;
                                        sync.delta_bytes += s.delta_bytes;
                                        sync.snapshot_bytes += s.snapshot_bytes;
                                        sync.lag_epochs = sync.lag_epochs.max(s.lag_epochs);
                                        format!(
                                            "{}:{}:{}+{}r:fo{}:st{}:lag{}",
                                            g.backend().id(),
                                            g.kind(),
                                            g.primary_addr(),
                                            g.replicas().len(),
                                            g.failovers(),
                                            g.stale_reads(),
                                            s.lag_epochs
                                        )
                                    })
                                    .collect();
                                format!(
                                    "OK shards={} strategy=cluster boundary_edges={} rounds={} boundary_updates={} deltas={} snapshots={} delta_bytes={} snapshot_bytes={} lag={} groups={}",
                                    c.num_shards(),
                                    c.boundary_edges(),
                                    m.rounds,
                                    m.boundary_updates,
                                    sync.deltas_shipped,
                                    sync.snapshots_shipped,
                                    sync.delta_bytes,
                                    sync.snapshot_bytes,
                                    sync.lag_epochs,
                                    groups.join(",")
                                )
                            }
                            Backend::Sharded(sh) => {
                                let epochs: Vec<String> =
                                    sh.shard_epochs().iter().map(|e| e.to_string()).collect();
                                let m = sh.merge_stats();
                                format!(
                                    "OK shards={} strategy={} boundary_edges={} rounds={} boundary_updates={} epochs={}",
                                    sh.num_shards(),
                                    sh.strategy().name(),
                                    sh.boundary_edges(),
                                    m.rounds,
                                    m.boundary_updates,
                                    epochs.join(",")
                                )
                            }
                        }
                    }
                    "INSERT" | "DELETE" => {
                        if !backend.writable() {
                            return format!(
                                "ERR '{}' hosts a cluster shard; writes go through its cluster router",
                                session.graph
                            );
                        }
                        let (Some(Ok(u)), Some(Ok(v))) = (
                            args.first().map(|a| a.parse::<u32>()),
                            args.get(1).map(|a| a.parse::<u32>()),
                        ) else {
                            return format!("ERR usage: {verb} <u> <v>");
                        };
                        if u == v {
                            return format!("ERR self-loop ({u},{u}) rejected");
                        }
                        if u > MAX_VERTEX_ID || v > MAX_VERTEX_ID {
                            return format!(
                                "ERR vertex id above limit {MAX_VERTEX_ID} (see server::MAX_VERTEX_ID)"
                            );
                        }
                        if backend.pending() >= MAX_PENDING_EDITS {
                            return err_reply(
                                code::CAPACITY,
                                format!("edit queue full ({MAX_PENDING_EDITS} pending); FLUSH first"),
                            );
                        }
                        self.totals.edits.fetch_add(1, Ordering::Relaxed);
                        gobs.edits.inc();
                        let edit = if verb == "INSERT" {
                            EdgeEdit::Insert(u, v)
                        } else {
                            EdgeEdit::Delete(u, v)
                        };
                        format!("OK pending={}", backend.submit(edit))
                    }
                    "SHARDINFO" => match &backend {
                        Backend::ShardHost(h) => h.info(),
                        _ => format!("ERR '{}' is not a hosted shard", session.graph),
                    },
                    "SHARDCORE" => match &backend {
                        Backend::ShardHost(h) => h.core_line(&args),
                        // a cluster coordinator knows the owner shard:
                        // redirect the probe to its host (the shared
                        // client follows one hop), or answer inline for
                        // in-coordinator shards
                        Backend::Cluster(c) => {
                            let Some(Ok(v)) = args.first().map(|a| a.parse::<u32>()) else {
                                return "ERR usage: SHARDCORE <v>".into();
                            };
                            let Some(s) = c.owner_of(v) else {
                                return format!(
                                    "ERR vertex {v} out of range (|V|={})",
                                    c.snapshot().num_vertices()
                                );
                            };
                            match c.groups()[s].remote_primary() {
                                Some((addr, graph)) => {
                                    format!("REDIRECT shard={s} addr={addr} graph={graph}")
                                }
                                None => match c.groups()[s].backend().refined_coreness(v) {
                                    Ok((Some(core), ce)) => {
                                        format!("OK core={core} cluster={ce}")
                                    }
                                    Ok((None, ce)) => format!("OK core=none cluster={ce}"),
                                    Err(e) => format!("ERR shard read: {e:#}"),
                                },
                            }
                        }
                        _ => format!("ERR '{}' is not a hosted shard", session.graph),
                    },
                    "SHARDHISTO" => match &backend {
                        Backend::ShardHost(h) => h.histo_line(),
                        _ => format!("ERR '{}' is not a hosted shard", session.graph),
                    },
                    "FLUSH" => match &backend {
                        Backend::ShardHost(_) => format!(
                            "ERR '{}' hosts a cluster shard; its router flushes it",
                            session.graph
                        ),
                        Backend::Cluster(c) => match c.flush() {
                            Ok(out) => {
                                self.totals.batches.fetch_add(1, Ordering::Relaxed);
                                gobs.batches.inc();
                                if out.recomputed_shards > 0 {
                                    let n = out.recomputed_shards as u64;
                                    self.totals.recomputes.fetch_add(n, Ordering::Relaxed);
                                    gobs.recomputes.add(n);
                                }
                                // replicas are NOT synced here: the flush
                                // only journals the epoch's deltas and
                                // publishes, so its latency never depends
                                // on replica health — the background sync
                                // daemon (or an explicit sync) converges
                                // the replicas afterwards
                                format!(
                                    "OK epoch={} submitted={} applied={} coalesced={} changed={} recomputed={} shards={} rounds={} boundary={} ms={:.3}",
                                    out.snapshot.epoch,
                                    out.submitted,
                                    out.applied,
                                    out.coalesced,
                                    out.changed,
                                    out.recomputed_shards,
                                    c.num_shards(),
                                    out.merge.rounds,
                                    out.merge.boundary_updates,
                                    out.elapsed_ms()
                                )
                            }
                            Err(e) => format!("ERR cluster flush: {e:#}"),
                        },
                        Backend::Single { queue, .. } => {
                            let out = queue.flush();
                            self.totals.batches.fetch_add(1, Ordering::Relaxed);
                            gobs.batches.inc();
                            if out.recomputed {
                                self.totals.recomputes.fetch_add(1, Ordering::Relaxed);
                                gobs.recomputes.inc();
                            }
                            format!(
                                "OK epoch={} submitted={} applied={} coalesced={} changed={} recomputed={} ms={:.3}",
                                out.snapshot.epoch,
                                out.submitted,
                                out.applied,
                                out.coalesced,
                                out.changed,
                                out.recomputed as u8,
                                out.elapsed_ms()
                            )
                        }
                        Backend::Sharded(sh) => {
                            let out = sh.flush();
                            self.totals.batches.fetch_add(1, Ordering::Relaxed);
                            gobs.batches.inc();
                            if out.recomputed_shards > 0 {
                                let n = out.recomputed_shards as u64;
                                self.totals.recomputes.fetch_add(n, Ordering::Relaxed);
                                gobs.recomputes.add(n);
                            }
                            format!(
                                "OK epoch={} submitted={} applied={} coalesced={} changed={} recomputed={} shards={} rounds={} boundary={} ms={:.3}",
                                out.snapshot.epoch,
                                out.submitted,
                                out.applied,
                                out.coalesced,
                                out.changed,
                                out.recomputed_shards,
                                sh.num_shards(),
                                out.merge.rounds,
                                out.merge.boundary_updates,
                                out.elapsed_ms()
                            )
                        }
                    },
                    other => format!("ERR unknown command '{other}'"),
                }
            }
        }
    }

    /// The `CLUSTER <SUBVERB>` admin namespace — the one entry point for
    /// the cluster control plane, resolved against
    /// [`crate::net::conn::CLUSTER_SUBVERBS`]. `CLUSTER TOPOLOGY`
    /// re-dispatches the legacy `SHARDS` arm so the alias
    /// ([`crate::net::conn::CLUSTER_ALIASES`]) can never drift from it.
    fn cluster_command(&self, session: &mut Session, args: &[&str], slot: usize) -> String {
        let Some(sub) = args.first().map(|s| s.to_ascii_uppercase()) else {
            return err_reply(
                code::BADREQ,
                format!("usage: CLUSTER <{}>", CLUSTER_SUBVERBS.join("|")),
            );
        };
        if !CLUSTER_SUBVERBS.contains(&sub.as_str()) {
            return err_reply(
                code::BADREQ,
                format!(
                    "unknown CLUSTER subverb '{sub}' (have: {})",
                    CLUSTER_SUBVERBS.join(" ")
                ),
            );
        }
        if sub == "TOPOLOGY" {
            return self.dispatch_command(session, "SHARDS", slot);
        }
        // the structural sub-verbs act on a cluster front end only
        let Some(Hosted { backend, .. }) = self.hosted_of(&session.graph) else {
            return err_reply(
                code::NOGRAPH,
                format!("no graph selected (have: {})", self.graph_names().join(" ")),
            );
        };
        let Backend::Cluster(c) = &backend else {
            return err_reply(
                code::BADREQ,
                format!("'{}' does not front a cluster", session.graph),
            );
        };
        let move_line = |m: &crate::cluster::MoveRecord| {
            format!(
                "{} from=shard{} to={} vertices={} bytes={} cutover_us={} epoch={} unix_ms={}",
                m.kind, m.from, m.to, m.vertices, m.bytes, m.cutover_us, m.epoch, m.unix_ms
            )
        };
        match sub.as_str() {
            "MOVES" => {
                let moves = c.moves();
                let json = args
                    .get(1)
                    .map(|f| f.eq_ignore_ascii_case("json"))
                    .unwrap_or(false);
                if json {
                    let items: Vec<String> = moves
                        .iter()
                        .map(|m| {
                            format!(
                                "{{\"kind\":\"{}\",\"from\":{},\"to\":\"{}\",\"vertices\":{},\"bytes\":{},\"cutover_us\":{},\"epoch\":{},\"unix_ms\":{}}}",
                                m.kind, m.from, m.to, m.vertices, m.bytes, m.cutover_us, m.epoch, m.unix_ms
                            )
                        })
                        .collect();
                    format!(
                        "OK moves n={} format=json lines=1\n[{}]",
                        moves.len(),
                        items.join(",")
                    )
                } else {
                    let mut reply = format!("OK moves n={0} lines={0}", moves.len());
                    for m in &moves {
                        reply.push('\n');
                        reply.push_str(&move_line(m));
                    }
                    reply
                }
            }
            "REBALANCE" => {
                let Some(action) = args.get(1).map(|s| s.to_ascii_uppercase()) else {
                    return err_reply(
                        code::BADREQ,
                        "usage: CLUSTER REBALANCE PLAN|APPLY|MIGRATE <shard> <host:port>",
                    );
                };
                match action.as_str() {
                    "PLAN" => {
                        let plan = c.rebalance_plan();
                        let mut lines = Vec::new();
                        for l in &plan.loads {
                            lines.push(format!(
                                "load shard={} owned={} bytes={} edits={} boundary={} lag={} reachable={}",
                                l.shard,
                                l.owned,
                                l.state_bytes,
                                l.edits_routed,
                                l.boundary_arcs,
                                l.lag_epochs,
                                l.reachable
                            ));
                        }
                        for m in &plan.moves {
                            lines.push(format!(
                                "move {} from=shard{} to=shard{} count={} reason: {}",
                                m.kind, m.from, m.to, m.count, m.reason
                            ));
                        }
                        let mut reply = format!(
                            "OK rebalance plan moves={} lines={}",
                            plan.moves.len(),
                            lines.len()
                        );
                        for l in &lines {
                            reply.push('\n');
                            reply.push_str(l);
                        }
                        reply
                    }
                    "APPLY" => match c.rebalance_apply() {
                        Ok((_, records)) => {
                            let mut reply = format!(
                                "OK rebalance applied moves={0} lines={0}",
                                records.len()
                            );
                            for r in &records {
                                reply.push('\n');
                                reply.push_str(&move_line(r));
                            }
                            reply
                        }
                        Err(e) => structural_err(e),
                    },
                    "MIGRATE" => {
                        let (Some(Ok(shard)), Some(&addr)) =
                            (args.get(2).map(|a| a.parse::<usize>()), args.get(3))
                        else {
                            return err_reply(
                                code::BADREQ,
                                "usage: CLUSTER REBALANCE MIGRATE <shard> <host:port>",
                            );
                        };
                        match c.migrate_primary(shard, addr) {
                            Ok(r) => format!(
                                "OK migrate shard={} addr={} bytes={} cutover_us={} epoch={}",
                                r.from, r.to, r.bytes, r.cutover_us, r.epoch
                            ),
                            Err(e) => structural_err(e),
                        }
                    }
                    other => err_reply(
                        code::BADREQ,
                        format!(
                            "unknown CLUSTER REBALANCE action '{other}' (have: PLAN APPLY MIGRATE)"
                        ),
                    ),
                }
            }
            // unreachable while CLUSTER_SUBVERBS = TOPOLOGY|REBALANCE|MOVES;
            // a new table entry lands here until its arm exists
            other => err_reply(code::BADREQ, format!("CLUSTER {other} not implemented")),
        }
    }

    /// Execute one binary-protocol frame; returns the reply frame body.
    /// `SNAPSHOT`/`RESTORE` carry raw bytes after the first line; every
    /// other verb delegates to [`Self::handle_command`].
    ///
    /// A trailing `trace=<hex>` head-line token (attached by a cluster
    /// coordinator's flush — see [`crate::obs::trace`]) is stripped
    /// before dispatch; the handler is timed, the mutation-path shard
    /// verbs land in the host-side `pico_shard_*_seconds` histograms
    /// under the hosted shard's graph name, and `OK` replies are tagged
    /// `trace=<hex> us=<micros>` so the coordinator can stitch this
    /// host's time into its flush span tree.
    pub fn handle_frame(&self, session: &mut Session, body: &[u8], slot: usize) -> Vec<u8> {
        let (head, payload) = match body.iter().position(|&b| b == b'\n') {
            Some(i) => (&body[..i], &body[i + 1..]),
            None => (body, &[][..]),
        };
        let Ok(raw_line) = std::str::from_utf8(head) else {
            return b"ERR command line not UTF-8".to_vec();
        };
        let (line, trace) = codec::extract_trace(raw_line);
        let mut parts = line.split_whitespace();
        let verb = parts.next().unwrap_or("").to_ascii_uppercase();
        let args: Vec<&str> = parts.collect();
        let t0 = Instant::now();
        let mut reply = match verb.as_str() {
            "SNAPSHOT" => self.frame_snapshot(session, &args, slot),
            "RESTORE" => self.frame_restore(session, &args, payload, slot),
            "SHARDHOST" => self.frame_shardhost(session, &args, payload, slot),
            "SHARDSNAP" => self.frame_shard(session, slot, |h| h.snap_frame()),
            "SHARDAPPLY" => self.frame_shard(session, slot, |h| h.apply_frame(payload)),
            "SHARDREFINE" => self.frame_shard(session, slot, |h| h.refine_frame(&args, payload)),
            "SHARDDELTA" => self.frame_shard(session, slot, |h| h.delta_frame(&args, payload)),
            "SHARDHAND" => self.frame_shard(session, slot, |h| h.hand_frame(&args, payload)),
            "SHARDMEMBERS" => self.frame_shard(session, slot, |h| h.members_frame(&args)),
            _ => self.handle_command(session, line, slot).into_bytes(),
        };
        let dur_us = t0.elapsed().as_micros().min(u64::MAX as u128) as u64;
        if let Some(hist) = shard_stage_histogram(&verb, args.first().copied()) {
            obs::global()
                .histogram(hist, &[("graph", &session.graph)])
                .record(dur_us);
        }
        if let Some(id) = trace {
            if reply.starts_with(b"OK") {
                codec::tag_reply_trace(&mut reply, id, dur_us);
            }
        }
        reply
    }

    /// Dispatch a shard-interface frame to the session's hosted shard.
    fn frame_shard(
        &self,
        session: &Session,
        _slot: usize,
        f: impl FnOnce(&ShardHost) -> Vec<u8>,
    ) -> Vec<u8> {
        self.count_query(&session.graph);
        match self.backend(&session.graph) {
            Some(Backend::ShardHost(h)) => f(&h),
            Some(_) => format!("ERR '{}' is not a hosted shard", session.graph).into_bytes(),
            None => err_reply(
                code::NOGRAPH,
                format!("no graph selected (have: {})", self.graph_names().join(" ")),
            )
            .into_bytes(),
        }
    }

    /// `SHARDHOST <name>` + manifest payload: validate, hydrate, install
    /// — initial shard shipping and replica catch-up both land here.
    fn frame_shardhost(
        &self,
        session: &mut Session,
        args: &[&str],
        payload: &[u8],
        _slot: usize,
    ) -> Vec<u8> {
        self.count_query(&session.graph);
        let Some(&name) = args.first() else {
            return b"ERR usage: SHARDHOST <name> (manifest bytes follow the command line)"
                .to_vec();
        };
        if payload.is_empty() {
            return b"ERR SHARDHOST carries no manifest payload".to_vec();
        }
        // cheap fast-fail; install_checked below re-checks under the lock
        if self.backend(name).is_none() && self.num_graphs() >= MAX_HOSTED_GRAPHS {
            return err_reply(
                code::CAPACITY,
                format!("graph limit reached ({MAX_HOSTED_GRAPHS} hosted)"),
            )
            .into_bytes();
        }
        match ShardHost::from_manifest_bytes(name, payload, self.batch_cfg.clone()) {
            Ok(h) => {
                let reply = format!(
                    "OK shardhost={name} shard={} shards={} vertices={} cluster={}",
                    h.shard_id(),
                    h.num_shards(),
                    h.index().snapshot().num_vertices(),
                    h.cluster_epoch()
                );
                if let Err(e) = self.install_checked(name, Backend::ShardHost(Arc::new(h))) {
                    return err_reply(code::CAPACITY, e).into_bytes();
                }
                session.graph = name.to_string();
                reply.into_bytes()
            }
            Err(e) => format!("ERR shardhost: {e:#}").into_bytes(),
        }
    }

    fn frame_snapshot(&self, session: &mut Session, args: &[&str], _slot: usize) -> Vec<u8> {
        self.count_query(&session.graph);
        let Some(backend) = self.backend(&session.graph) else {
            return err_reply(
                code::NOGRAPH,
                format!("no graph selected (have: {})", self.graph_names().join(" ")),
            )
            .into_bytes();
        };
        let index: Arc<CoreIndex> = match &backend {
            Backend::Single { index, .. } => {
                if !args.is_empty() {
                    return b"ERR SNAPSHOT takes a shard argument only on sharded graphs".to_vec();
                }
                index.clone()
            }
            Backend::ShardHost(h) => {
                if !args.is_empty() {
                    return b"ERR SNAPSHOT takes a shard argument only on sharded graphs".to_vec();
                }
                h.index()
            }
            Backend::Cluster(_) => {
                return b"ERR SNAPSHOT of a cluster: ship its shard hosts' manifests (SHARDSNAP) instead"
                    .to_vec();
            }
            Backend::Sharded(sh) => {
                let Some(Ok(k)) = args.first().map(|a| a.parse::<usize>()) else {
                    return format!(
                        "ERR usage: SNAPSHOT <shard> ('{}' has {} shards)",
                        session.graph,
                        sh.num_shards()
                    )
                    .into_bytes();
                };
                match sh.shard_index(k) {
                    Some(idx) => idx,
                    None => {
                        return format!("ERR shard {k} out of range (0..{})", sh.num_shards())
                            .into_bytes()
                    }
                }
            }
        };
        let (snap, g) = index.consistent_view();
        let bytes = shard_snapshot::encode(index.name(), snap.epoch, &snap.core, &g);
        let mut out = format!(
            "OK snapshot name={} epoch={} bytes={}\n",
            index.name(),
            snap.epoch,
            bytes.len()
        )
        .into_bytes();
        out.extend_from_slice(&bytes);
        // the frame cap applies to replies too ("accepted or sent"): a
        // snapshot no peer could RESTORE must not be shipped at all
        if out.len() > MAX_FRAME_BYTES {
            return format!(
                "ERR snapshot is {} bytes, above the frame cap ({MAX_FRAME_BYTES}); raise server::MAX_FRAME_BYTES on both ends or ship it out-of-band",
                out.len()
            )
            .into_bytes();
        }
        out
    }

    fn frame_restore(
        &self,
        session: &mut Session,
        args: &[&str],
        payload: &[u8],
        _slot: usize,
    ) -> Vec<u8> {
        self.count_query(&session.graph);
        let Some(&name) = args.first() else {
            return b"ERR usage: RESTORE <name> (snapshot bytes follow the command line)".to_vec();
        };
        if payload.is_empty() {
            return b"ERR RESTORE carries no snapshot payload".to_vec();
        }
        // cheap fast-fail before the (potentially large) decode; the
        // install_checked below re-checks the cap under the write lock
        if self.backend(name).is_none() && self.num_graphs() >= MAX_HOSTED_GRAPHS {
            return err_reply(
                code::CAPACITY,
                format!("graph limit reached ({MAX_HOSTED_GRAPHS} hosted)"),
            )
            .into_bytes();
        }
        // decode validates everything before anything is installed: a
        // rejected payload leaves the hosted map untouched
        match shard_snapshot::decode(payload) {
            Ok(snap) => {
                let epoch = snap.epoch;
                let vertices = snap.graph.num_vertices();
                let edges = snap.graph.num_edges();
                let idx = Arc::new(CoreIndex::hydrate(name, &snap.graph, snap.core, epoch));
                let queue = Arc::new(EditQueue::new(idx.clone(), self.batch_cfg.clone()));
                if let Err(e) = self.install_checked(name, Backend::Single { index: idx, queue }) {
                    return err_reply(code::CAPACITY, e).into_bytes();
                }
                session.graph = name.to_string();
                format!("OK restore={name} epoch={epoch} vertices={vertices} edges={edges}")
                    .into_bytes()
            }
            Err(e) => format!("ERR restore: {e:#}").into_bytes(),
        }
    }
}

/// The application half of the transport contract: the worker pool
/// drives [`CoreService`] through this.
impl Handler for CoreService {
    fn default_graph(&self) -> String {
        CoreService::default_graph(self)
    }

    fn handle_line(&self, session: &mut Session, line: &str, slot: usize) -> String {
        self.handle_command(session, line, slot)
    }

    fn handle_frame(&self, session: &mut Session, body: &[u8], slot: usize) -> Vec<u8> {
        CoreService::handle_frame(self, session, body, slot)
    }
}

/// Resolve a dataset argument — the same suite-name-then-path rules as
/// the CLI ([`crate::coordinator::DatasetSpec::resolve`]).
fn load_dataset(name: &str) -> Result<Arc<CsrGraph>> {
    crate::coordinator::DatasetSpec::resolve(name)?.load()
}

/// Render a structural-change failure: the one-at-a-time latch refusal
/// gets the machine-readable `MIGRATING` code (a client retries it);
/// everything else stays a message-only error with its full chain.
fn structural_err(e: anyhow::Error) -> String {
    if e.downcast_ref::<crate::cluster::RebalanceBusy>().is_some() {
        err_reply(code::MIGRATING, e)
    } else {
        format!("ERR rebalance: {e:#}")
    }
}

/// The background replica-sync daemon: probes replica epochs on a
/// jittered interval and runs [`ClusterIndex::sync_replicas`] (delta
/// chains first, full manifests as the fallback), so served `FLUSH`es
/// never block on replica health. Jitter (±25% of the interval) keeps a
/// fleet of coordinators from probing their shard hosts in lockstep.
///
/// Dropping (or [`ReplicaSyncDaemon::stop`]-ping) the handle stops the
/// loop at its next poll tick; `pico serve --sync-interval` owns one per
/// cluster backend and stops it before the final drain-time sync.
pub struct ReplicaSyncDaemon {
    stop: Arc<AtomicBool>,
    syncs: Arc<AtomicUsize>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ReplicaSyncDaemon {
    /// Spawn the daemon for `cluster`, probing every ~`interval`.
    pub fn spawn(cluster: Arc<ClusterIndex>, interval: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let syncs = Arc::new(AtomicUsize::new(0));
        let stop2 = stop.clone();
        let syncs2 = syncs.clone();
        let join = std::thread::Builder::new()
            .name("pico-replica-sync".into())
            .spawn(move || {
                let seed = std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .map(|d| d.subsec_nanos() as u64 ^ d.as_secs())
                    .unwrap_or(0x5EED);
                let mut rng = crate::util::rng::Rng::new(seed | 1);
                while !stop2.load(Ordering::SeqCst) {
                    // jittered sleep, polled in short slices so stop()
                    // takes effect promptly even with long intervals
                    let target = interval.mul_f64(0.75 + 0.5 * rng.f64());
                    let deadline = std::time::Instant::now() + target;
                    while std::time::Instant::now() < deadline {
                        if stop2.load(Ordering::SeqCst) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(
                            10u64.min(target.as_millis().max(1) as u64),
                        ));
                    }
                    match cluster.sync_replicas() {
                        Ok(r) => {
                            syncs2.fetch_add(1, Ordering::SeqCst);
                            // quiet when idle; one line whenever the pass
                            // actually moved (or failed to move) a replica
                            if r.shipped() > 0 || r.failed > 0 {
                                println!(
                                    "replica-sync '{}': synced={} (deltas={} snapshots={}) bytes={}+{} lag={} failed={}",
                                    cluster.name(),
                                    r.shipped(),
                                    r.deltas,
                                    r.snapshots,
                                    r.delta_bytes,
                                    r.snapshot_bytes,
                                    r.max_lag_epochs,
                                    r.failed
                                );
                            }
                        }
                        Err(e) => {
                            println!("replica-sync '{}': pass failed: {e:#}", cluster.name())
                        }
                    }
                }
            })
            .expect("spawning the replica-sync daemon");
        Self {
            stop,
            syncs,
            join: Some(join),
        }
    }

    /// Completed sync passes (successful probe rounds, shipped or not).
    pub fn syncs(&self) -> usize {
        self.syncs.load(Ordering::SeqCst)
    }

    /// Ask the loop to exit at its next poll tick.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

impl Drop for ReplicaSyncDaemon {
    fn drop(&mut self) {
        self.stop();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Bind `addr` and serve `service` with the default transport
/// configuration (see [`NetConfig`]): a bounded worker pool, a hard
/// connection cap, and slow-loris timeouts — the accept loop and
/// workers run on background threads ([`crate::net::pool`]).
pub fn serve(service: Arc<CoreService>, addr: &str) -> Result<ServerHandle> {
    serve_with(service, addr, NetConfig::default())
}

/// [`serve`] with explicit transport knobs (`pico serve --workers /
/// --max-conns`, auth token, timeouts).
pub fn serve_with(service: Arc<CoreService>, addr: &str, cfg: NetConfig) -> Result<ServerHandle> {
    crate::net::pool::serve_handler(service, addr, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    fn service_with_g1() -> (CoreService, Session) {
        let svc = CoreService::new(BatchConfig {
            threads: 1,
            ..BatchConfig::default()
        });
        svc.open("g1", &examples::g1());
        let session = Session::new(svc.default_graph());
        (svc, session)
    }

    #[test]
    fn read_commands_round_trip() {
        let (svc, mut s) = service_with_g1();
        assert_eq!(svc.handle_command(&mut s, "PING", 0), "OK pong");
        assert_eq!(svc.handle_command(&mut s, "GRAPHS", 0), "OK n=1 g1");
        assert_eq!(svc.handle_command(&mut s, "EPOCH", 0), "OK epoch=0");
        assert_eq!(svc.handle_command(&mut s, "coreness 3", 0), "OK core=2 epoch=0");
        assert_eq!(
            svc.handle_command(&mut s, "DEGENERACY", 0),
            "OK degeneracy=2 epoch=0"
        );
        assert_eq!(
            svc.handle_command(&mut s, "MEMBERS 2", 0),
            "OK count=4 epoch=0 members=2,3,4,5"
        );
        assert_eq!(
            svc.handle_command(&mut s, "HISTO", 0),
            "OK epoch=0 histo=0:0,1:2,2:4"
        );
        assert_eq!(
            svc.handle_command(&mut s, "SHARDS", 0),
            "OK shards=1 strategy=single"
        );
    }

    #[test]
    fn edit_flush_cycle_bumps_epoch() {
        let (svc, mut s) = service_with_g1();
        assert_eq!(svc.handle_command(&mut s, "INSERT 2 5", 0), "OK pending=1");
        // queued, not visible yet
        assert_eq!(svc.handle_command(&mut s, "coreness 2", 0), "OK core=2 epoch=0");
        let flush = svc.handle_command(&mut s, "FLUSH", 0);
        assert!(
            flush.starts_with("OK epoch=1 submitted=1 applied=1 coalesced=0 changed=1 recomputed=0"),
            "{flush}"
        );
        assert_eq!(svc.handle_command(&mut s, "coreness 2", 0), "OK core=3 epoch=1");
        let stats = svc.handle_command(&mut s, "STATS", 0);
        assert!(stats.contains("edits=1"), "{stats}");
        assert!(stats.contains("batches=1"), "{stats}");
    }

    #[test]
    fn members_fast_path_lockstep_with_flush() {
        // MEMBERS answered mid-batch (edits queued, epoch not yet
        // advanced) must agree with the post-flush answer on count and
        // member list — only the epoch may differ
        let (svc, mut s) = service_with_g1();
        // closing (2,5) turns {2,3,4,5} into a K4: a 3-core appears
        assert_eq!(svc.handle_command(&mut s, "INSERT 2 5", 0), "OK pending=1");
        assert_eq!(
            svc.handle_command(&mut s, "MEMBERS 3", 0),
            "OK count=4 epoch=0 members=2,3,4,5",
            "mid-batch fast path must see the pending insert"
        );
        svc.handle_command(&mut s, "FLUSH", 0);
        assert_eq!(
            svc.handle_command(&mut s, "MEMBERS 3", 0),
            "OK count=4 epoch=1 members=2,3,4,5"
        );
        // and the other direction: a pending delete empties the 3-core
        assert_eq!(svc.handle_command(&mut s, "DELETE 2 3", 0), "OK pending=1");
        assert_eq!(
            svc.handle_command(&mut s, "MEMBERS 3", 0),
            "OK count=0 epoch=1 members=",
            "mid-batch fast path must see the pending delete"
        );
        svc.handle_command(&mut s, "FLUSH", 0);
        assert_eq!(
            svc.handle_command(&mut s, "MEMBERS 3", 0),
            "OK count=0 epoch=2 members="
        );
    }

    #[test]
    fn error_paths_are_structured() {
        let (svc, mut s) = service_with_g1();
        assert!(svc.handle_command(&mut s, "CORENESS 99", 0).starts_with("ERR vertex 99"));
        assert!(svc.handle_command(&mut s, "CORENESS", 0).starts_with("ERR usage"));
        assert!(svc.handle_command(&mut s, "INSERT 3 3", 0).starts_with("ERR self-loop"));
        // unbounded ids would let one command allocate gigabytes
        assert!(svc
            .handle_command(&mut s, "INSERT 0 4294967295", 0)
            .starts_with("ERR vertex id above limit"));
        assert!(svc
            .handle_command(&mut s, &format!("DELETE 0 {}", MAX_VERTEX_ID + 1), 0)
            .starts_with("ERR vertex id above limit"));
        assert!(svc.handle_command(&mut s, "NOPE", 0).starts_with("ERR unknown command"));
        assert!(svc.handle_command(&mut s, "USE ghost", 0).starts_with("ERR unknown graph"));
        assert!(svc.handle_command(&mut s, "", 0).starts_with("ERR empty"));
        // snapshot verbs are binary-only
        assert!(svc
            .handle_command(&mut s, "SNAPSHOT", 0)
            .starts_with("ERR SNAPSHOT needs the binary protocol"));
        assert!(svc
            .handle_command(&mut s, "RESTORE r", 0)
            .starts_with("ERR RESTORE needs the binary protocol"));
        assert!(svc
            .handle_command(&mut s, "OPEN x g1 0", 0)
            .starts_with("ERR shards must be"));
    }

    #[test]
    fn multi_graph_sessions_are_independent() {
        let (svc, mut s) = service_with_g1();
        let open = svc.handle_command(&mut s, "OPEN k5 g1", 0);
        // 'g1' resolves through the suite; the new index is independent
        assert_eq!(open, "OK open=k5 vertices=6 edges=7");
        assert_eq!(s.graph, "k5");
        svc.handle_command(&mut s, "INSERT 2 5", 0);
        svc.handle_command(&mut s, "FLUSH", 0);
        assert_eq!(svc.handle_command(&mut s, "EPOCH", 0), "OK epoch=1");
        // the original graph is untouched
        assert_eq!(svc.handle_command(&mut s, "USE g1", 0), "OK use=g1");
        assert_eq!(svc.handle_command(&mut s, "EPOCH", 0), "OK epoch=0");
        assert_eq!(svc.handle_command(&mut s, "GRAPHS", 0), "OK n=2 g1 k5");
    }

    #[test]
    fn sharded_graph_over_the_protocol() {
        let (svc, mut s) = service_with_g1();
        let open = svc.handle_command(&mut s, "OPEN shg g1 4", 0);
        assert_eq!(open, "OK open=shg vertices=6 edges=7 shards=4");
        assert_eq!(s.graph, "shg");
        let shards = svc.handle_command(&mut s, "SHARDS", 0);
        assert!(shards.starts_with("OK shards=4 strategy=hash"), "{shards}");
        // same answers as the single-index backend
        assert_eq!(svc.handle_command(&mut s, "CORENESS 3", 0), "OK core=2 epoch=0");
        assert_eq!(
            svc.handle_command(&mut s, "HISTO", 0),
            "OK epoch=0 histo=0:0,1:2,2:4"
        );
        // edits route through shards; FLUSH reports the merge
        svc.handle_command(&mut s, "INSERT 2 5", 0);
        let flush = svc.handle_command(&mut s, "FLUSH", 0);
        assert!(
            flush.starts_with("OK epoch=1 submitted=1 applied=1 coalesced=0 changed=1"),
            "{flush}"
        );
        assert!(flush.contains(" shards=4 rounds="), "{flush}");
        assert_eq!(svc.handle_command(&mut s, "CORENESS 2", 0), "OK core=3 epoch=1");
        let densest = svc.handle_command(&mut s, "DENSEST", 0);
        assert!(densest.starts_with("OK k=3 vertices=4 edges=6"), "{densest}");
    }

    #[test]
    fn snapshot_restore_frames_round_trip_in_process() {
        let (svc, mut s) = service_with_g1();
        let upgrade = svc.handle_command(&mut s, "BINARY", 0);
        assert!(upgrade.starts_with("OK binary proto="), "{upgrade}");
        assert!(s.binary);
        // SNAPSHOT: header line + payload bytes
        let frame = svc.handle_frame(&mut s, b"SNAPSHOT", 0);
        let nl = frame.iter().position(|&b| b == b'\n').expect("header line");
        let head = std::str::from_utf8(&frame[..nl]).unwrap();
        assert!(head.starts_with("OK snapshot name=g1 epoch=0 bytes="), "{head}");
        let payload = frame[nl + 1..].to_vec();
        assert_eq!(
            head.rsplit('=').next().unwrap().parse::<usize>().unwrap(),
            payload.len()
        );
        // RESTORE installs a replica serving identical answers
        let mut req = b"RESTORE replica\n".to_vec();
        req.extend_from_slice(&payload);
        let reply = svc.handle_frame(&mut s, &req, 0);
        assert_eq!(
            std::str::from_utf8(&reply).unwrap(),
            "OK restore=replica epoch=0 vertices=6 edges=7"
        );
        assert_eq!(s.graph, "replica");
        assert_eq!(svc.handle_command(&mut s, "CORENESS 3", 0), "OK core=2 epoch=0");
        assert_eq!(svc.handle_command(&mut s, "GRAPHS", 0), "OK n=2 g1 replica");
        // corrupt payloads are rejected and leak no slot
        let reply = svc.handle_frame(&mut s, b"RESTORE evil\nnot-a-snapshot", 0);
        assert!(std::str::from_utf8(&reply).unwrap().starts_with("ERR restore:"));
        assert_eq!(svc.handle_command(&mut s, "GRAPHS", 0), "OK n=2 g1 replica");
    }

    #[test]
    fn sharded_snapshot_ships_one_shard() {
        let (svc, mut s) = service_with_g1();
        svc.handle_command(&mut s, "OPEN shg g1 2", 0);
        svc.handle_command(&mut s, "BINARY", 0);
        let err = svc.handle_frame(&mut s, b"SNAPSHOT", 0);
        assert!(std::str::from_utf8(&err).unwrap().starts_with("ERR usage: SNAPSHOT <shard>"));
        let frame = svc.handle_frame(&mut s, b"SNAPSHOT 1", 0);
        let nl = frame.iter().position(|&b| b == b'\n').unwrap();
        let head = std::str::from_utf8(&frame[..nl]).unwrap();
        assert!(head.starts_with("OK snapshot name=shg/shard1 epoch=0"), "{head}");
        let snap = crate::shard::snapshot::decode(&frame[nl + 1..]).unwrap();
        assert_eq!(snap.name, "shg/shard1");
        let oob = svc.handle_frame(&mut s, b"SNAPSHOT 9", 0);
        assert!(std::str::from_utf8(&oob).unwrap().starts_with("ERR shard 9 out of range"));
    }

    #[test]
    fn cluster_namespace_resolves_subverbs_and_aliases() {
        let (svc, mut s) = service_with_g1();
        // TOPOLOGY answers byte-identically to the legacy SHARDS alias
        let shards = svc.handle_command(&mut s, "SHARDS", 0);
        assert_eq!(shards, "OK shards=1 strategy=single");
        assert_eq!(svc.handle_command(&mut s, "CLUSTER TOPOLOGY", 0), shards);
        assert_eq!(svc.handle_command(&mut s, "cluster topology", 0), shards);
        // refusals carry machine-readable codes
        assert!(svc
            .handle_command(&mut s, "CLUSTER", 0)
            .starts_with("ERR BADREQ usage: CLUSTER"));
        assert!(svc
            .handle_command(&mut s, "CLUSTER NOPE", 0)
            .starts_with("ERR BADREQ unknown CLUSTER subverb 'NOPE'"));
        // the structural sub-verbs need a cluster front end
        for cmd in ["CLUSTER MOVES", "CLUSTER REBALANCE PLAN", "CLUSTER REBALANCE APPLY"] {
            assert!(
                svc.handle_command(&mut s, cmd, 0)
                    .starts_with("ERR BADREQ 'g1' does not front a cluster"),
                "{cmd}"
            );
        }
    }

    #[test]
    fn members_reply_is_capped() {
        let svc = CoreService::new(BatchConfig::default());
        svc.open("star", &examples::star(200));
        let mut s = Session::new("star");
        let reply = svc.handle_command(&mut s, "MEMBERS 1", 0);
        assert!(reply.starts_with("OK count=201 "), "{reply}");
        let members = reply.split("members=").nth(1).unwrap();
        assert_eq!(members.split(',').count(), MAX_REPLY_MEMBERS);
    }

    #[test]
    fn tcp_round_trip() {
        let svc = Arc::new(CoreService::new(BatchConfig {
            threads: 1,
            ..BatchConfig::default()
        }));
        svc.open("g1", &examples::g1());
        let handle = serve(svc, "127.0.0.1:0").expect("bind");
        let addr = handle.addr();

        let stream = TcpStream::connect(addr).expect("connect");
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        let mut send = |cmd: &str, r: &mut BufReader<TcpStream>| -> String {
            writeln!(w, "{cmd}").unwrap();
            w.flush().unwrap();
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            line.trim_end().to_string()
        };
        assert_eq!(send("PING", &mut r), "OK pong");
        assert_eq!(send("CORENESS 4", &mut r), "OK core=2 epoch=0");
        assert_eq!(send("INSERT 2 5", &mut r), "OK pending=1");
        assert!(send("FLUSH", &mut r).starts_with("OK epoch=1"));
        assert_eq!(send("CORENESS 4", &mut r), "OK core=3 epoch=1");
        assert_eq!(send("QUIT", &mut r), "OK bye");
        handle.stop();
    }

    #[test]
    fn shard_host_frames_round_trip_in_process() {
        use crate::shard::backend::LocalShard;
        use crate::shard::partition::{partition, PartitionStrategy};

        let (svc, mut s) = service_with_g1();
        svc.handle_command(&mut s, "BINARY", 0);
        // build a shard manifest the way a cluster coordinator would
        let g = examples::g1();
        let plan = partition(&g, 2, PartitionStrategy::Hash);
        let shard = LocalShard::from_plan(
            "c",
            &plan.shards[0],
            BatchConfig {
                threads: 1,
                ..BatchConfig::default()
            },
        );
        let manifest = crate::cluster::manifest_for(&shard, 2);
        let mut req = b"SHARDHOST c/shard0\n".to_vec();
        req.extend_from_slice(&manifest);
        let reply = svc.handle_frame(&mut s, &req, 0);
        let head = String::from_utf8(reply).unwrap();
        assert!(head.starts_with("OK shardhost=c/shard0 shard=0 shards=2"), "{head}");
        assert_eq!(s.graph, "c/shard0");
        // line-mode probes answer on the hosted shard
        let info = svc.handle_command(&mut s, "SHARDINFO", 0);
        assert!(info.starts_with("OK shard=0 shards=2 epoch=0"), "{info}");
        // fresh shards have no committed refined state yet: the sentinel
        // epoch keeps epoch-checked readers from trusting them
        let histo = svc.handle_command(&mut s, "SHARDHISTO", 0);
        assert!(histo.starts_with(&format!("OK cluster={}", u64::MAX)), "{histo}");
        // direct writes are refused — the cluster router owns this shard
        assert!(svc
            .handle_command(&mut s, "INSERT 0 1", 0)
            .starts_with("ERR 'c/shard0' hosts a cluster shard"));
        assert!(svc
            .handle_command(&mut s, "FLUSH", 0)
            .starts_with("ERR 'c/shard0' hosts a cluster shard"));
        // the shard interface works over frames
        let refine = svc.handle_frame(&mut s, b"SHARDREFINE START -", 0);
        let nl = refine.iter().position(|&b| b == b'\n').unwrap();
        assert!(std::str::from_utf8(&refine[..nl]).unwrap().starts_with("OK refine-init"));
        let snap = svc.handle_frame(&mut s, b"SHARDSNAP", 0);
        let nl = snap.iter().position(|&b| b == b'\n').unwrap();
        assert!(std::str::from_utf8(&snap[..nl]).unwrap().starts_with("OK shardsnap"));
        crate::cluster::wire::decode_manifest(&snap[nl + 1..]).unwrap();
        // corrupt manifests are rejected and leak no graph slot
        let before = svc.handle_command(&mut s, "GRAPHS", 0);
        let evil = svc.handle_frame(&mut s, b"SHARDHOST evil\nnot-a-manifest", 0);
        assert!(String::from_utf8(evil).unwrap().starts_with("ERR shardhost:"));
        assert_eq!(svc.handle_command(&mut s, "GRAPHS", 0), before);
        // shard verbs on a non-shard graph are structured errors
        svc.handle_command(&mut s, "USE g1", 0);
        assert!(svc.handle_command(&mut s, "SHARDINFO", 0).starts_with("ERR 'g1' is not"));
        assert!(String::from_utf8(svc.handle_frame(&mut s, b"SHARDSNAP", 0))
            .unwrap()
            .starts_with("ERR 'g1' is not"));
    }

    #[test]
    fn frame_trace_token_is_echoed_with_host_time() {
        let (svc, mut s) = service_with_g1();
        svc.handle_command(&mut s, "BINARY", 0);
        // a traced frame answers with the same id plus the host's time
        let reply = svc.handle_frame(&mut s, b"PING trace=ab12", 0);
        let head = std::str::from_utf8(&reply).unwrap();
        assert!(head.starts_with("OK pong trace=ab12 us="), "{head}");
        assert!(codec::reply_us(head).is_some(), "{head}");
        // untraced frames answer byte-identically to before
        assert_eq!(svc.handle_frame(&mut s, b"PING", 0), b"OK pong");
        // ERR replies are never tagged — the coordinator only stitches
        // successful stages
        let err = svc.handle_frame(&mut s, b"NOPE trace=ab12", 0);
        assert!(!String::from_utf8(err).unwrap().contains("us="));
    }

    #[test]
    fn shard_verbs_need_binary_in_line_mode() {
        let (svc, mut s) = service_with_g1();
        for verb in ["SHARDHOST x", "SHARDSNAP", "SHARDAPPLY", "SHARDREFINE START -"] {
            let reply = svc.handle_command(&mut s, verb, 0);
            assert!(reply.contains("needs the binary protocol"), "{verb}: {reply}");
        }
    }

    #[test]
    fn drain_finishes_connections_and_flush_all_applies_pending() {
        let svc = Arc::new(CoreService::new(BatchConfig {
            threads: 1,
            ..BatchConfig::default()
        }));
        svc.open("g1", &examples::g1());
        let handle = serve(svc.clone(), "127.0.0.1:0").expect("bind");
        let stream = TcpStream::connect(handle.addr()).expect("connect");
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        writeln!(w, "INSERT 2 5").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "OK pending=1");
        // drain: the idle connection closes at its next read timeout
        assert!(handle.drain(Duration::from_secs(5)), "connections did not drain");
        assert_eq!(handle.active_connections(), 0);
        line.clear();
        // server closed our connection (EOF), not mid-reply
        assert_eq!(r.read_line(&mut line).unwrap(), 0);
        // pending edits survive the drain and land in flush_all
        let flushed = svc.flush_all();
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].0, "g1");
        assert_eq!(flushed[0].1, Ok((1, 1))); // (epoch, applied edits)
        assert_eq!(svc.index("g1").unwrap().snapshot().epoch, 1);
    }

    #[test]
    fn tcp_binary_upgrade_round_trip() {
        let svc = Arc::new(CoreService::new(BatchConfig {
            threads: 1,
            ..BatchConfig::default()
        }));
        svc.open("g1", &examples::g1());
        let handle = serve(svc, "127.0.0.1:0").expect("bind");

        let stream = TcpStream::connect(handle.addr()).expect("connect");
        let mut w = stream.try_clone().unwrap();
        let mut r = BufReader::new(stream);
        writeln!(w, "BINARY").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.trim_end().starts_with("OK binary"), "{line}");

        let mut send_frame = |body: &[u8], r: &mut BufReader<TcpStream>| -> Vec<u8> {
            write_frame(&mut w, body).unwrap();
            read_frame(r, MAX_FRAME_BYTES).unwrap().expect("reply frame")
        };
        assert_eq!(send_frame(b"PING", &mut r), b"OK pong");
        assert_eq!(send_frame(b"CORENESS 3", &mut r), b"OK core=2 epoch=0");
        let snap = send_frame(b"SNAPSHOT", &mut r);
        assert!(snap.starts_with(b"OK snapshot name=g1 "));
        assert_eq!(send_frame(b"QUIT", &mut r), b"OK bye");
        handle.stop();
    }
}
