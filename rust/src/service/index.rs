//! Epoch-versioned core index — the read side of the serving layer.
//!
//! A [`CoreIndex`] wraps a [`DynamicCore`] (the §VI-C1 maintenance
//! structure) behind an epoch-snapshot protocol:
//!
//! * **Readers** call [`CoreIndex::snapshot`] and get an
//!   `Arc<CoreSnapshot>` — the last *published* immutable view. The only
//!   synchronisation on the read path is one `RwLock` read acquisition to
//!   clone the `Arc`; readers never wait for a writer's maintenance
//!   cascades and can hold a snapshot for as long as they like.
//! * **Writers** go through [`CoreIndex::update`]: mutate the writer
//!   state under the writer mutex, then publish a fresh snapshot with the
//!   epoch bumped. A reader therefore observes either the pre-batch or
//!   the post-batch world, never a half-applied batch.
//!
//! Publishing costs O(|V|) (one coreness copy) — independent of the edit
//! batch's cascade size and of |E|. Structure-dependent queries (densest
//! core) need the adjacency too; [`CoreIndex::graph`] rebuilds a CSR view
//! lazily and caches it per epoch, serialising with writers (documented
//! as the one heavyweight read).

use super::batch::CrossoverCosts;
use crate::core::maintenance::DynamicCore;
use crate::core::peel::BucketScratch;
use crate::graph::CsrGraph;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock};

/// An immutable, epoch-stamped view of one graph's core decomposition.
#[derive(Clone, Debug)]
pub struct CoreSnapshot {
    /// Publication counter; epoch 0 is the initial full decomposition.
    pub epoch: u64,
    /// `core[v]` = coreness of vertex `v` at this epoch.
    pub core: Vec<u32>,
    /// Max coreness (the graph's degeneracy) at this epoch.
    pub k_max: u32,
    /// Undirected edge count at this epoch.
    pub num_edges: u64,
}

impl CoreSnapshot {
    fn capture(epoch: u64, dc: &DynamicCore) -> Self {
        let core = dc.coreness().to_vec();
        let k_max = core.iter().copied().max().unwrap_or(0);
        Self {
            epoch,
            core,
            k_max,
            num_edges: dc.num_edges(),
        }
    }

    pub fn num_vertices(&self) -> usize {
        self.core.len()
    }
}

/// A served graph: writer state + published snapshot + epoch counter.
pub struct CoreIndex {
    name: String,
    writer: Mutex<DynamicCore>,
    published: RwLock<Arc<CoreSnapshot>>,
    epoch: AtomicU64,
    /// Per-epoch CSR rebuild cache for structure queries.
    graph_cache: Mutex<Option<(u64, Arc<CsrGraph>)>>,
    /// Flush-time recompute working set (bucket-peel scratch), persistent
    /// across epochs so steady flush load allocates nothing per recompute.
    recompute_scratch: Mutex<BucketScratch>,
    /// Measured per-edit / per-edge flush costs feeding the crossover
    /// decision (`service::batch`).
    costs: CrossoverCosts,
}

impl CoreIndex {
    /// Index a static graph (one full decomposition, published as epoch 0).
    pub fn new(name: impl Into<String>, g: &CsrGraph) -> Self {
        Self::from_dynamic(name, DynamicCore::new(g))
    }

    /// Wrap an existing maintained structure.
    pub fn from_dynamic(name: impl Into<String>, dc: DynamicCore) -> Self {
        Self::from_dynamic_at(name, dc, 0)
    }

    /// Wrap an existing maintained structure, publishing it as `epoch` —
    /// the restore path for shipped snapshots, where the replica must
    /// resume at the primary's epoch rather than 0.
    pub fn from_dynamic_at(name: impl Into<String>, dc: DynamicCore, epoch: u64) -> Self {
        let snap = Arc::new(CoreSnapshot::capture(epoch, &dc));
        Self {
            name: name.into(),
            writer: Mutex::new(dc),
            published: RwLock::new(snap),
            epoch: AtomicU64::new(epoch),
            graph_cache: Mutex::new(None),
            recompute_scratch: Mutex::new(BucketScratch::with_capacity(0)),
            costs: CrossoverCosts::default(),
        }
    }

    /// Hydrate from shipped state (`shard::snapshot`) without running any
    /// decomposition: the given coreness is installed as-is at `epoch`.
    pub fn hydrate(name: impl Into<String>, g: &CsrGraph, core: Vec<u32>, epoch: u64) -> Self {
        Self::from_dynamic_at(name, DynamicCore::from_parts(g, core), epoch)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The last *published* epoch (the counter is stored only after the
    /// snapshot swap, so this never names an epoch a reader can't get).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// The current published snapshot. Readers clone the `Arc` and are
    /// then completely decoupled from writers.
    pub fn snapshot(&self) -> Arc<CoreSnapshot> {
        self.published.read().unwrap().clone()
    }

    /// Run `f` against the writer state, then publish a new epoch.
    /// Readers keep serving the previous snapshot until the swap.
    pub fn update<R>(&self, f: impl FnOnce(&mut DynamicCore) -> R) -> (R, Arc<CoreSnapshot>) {
        let mut dc = self.writer.lock().unwrap();
        let out = f(&mut dc);
        // writers are serialised by the writer lock, so load+store is
        // race-free; the counter is advanced only *after* the publish so
        // `epoch()` never runs ahead of what readers can observe
        let epoch = self.epoch.load(Ordering::SeqCst) + 1;
        let snap = Arc::new(CoreSnapshot::capture(epoch, &dc));
        *self.published.write().unwrap() = snap.clone();
        self.epoch.store(epoch, Ordering::SeqCst);
        (out, snap)
    }

    fn graph_locked(&self, dc: &DynamicCore) -> Arc<CsrGraph> {
        let epoch = self.epoch.load(Ordering::SeqCst);
        let mut cache = self.graph_cache.lock().unwrap();
        if let Some((e, g)) = cache.as_ref() {
            if *e == epoch {
                return g.clone();
            }
        }
        let g = Arc::new(dc.snapshot());
        *cache = Some((epoch, g.clone()));
        g
    }

    /// CSR view of the current structure (per-epoch cached rebuild).
    /// Heavier than [`Self::snapshot`]: serialises with writers.
    pub fn graph(&self) -> Arc<CsrGraph> {
        let dc = self.writer.lock().unwrap();
        self.graph_locked(&dc)
    }

    /// The recompute scratch, locked. Held only around a
    /// [`DynamicCore::recompute_bucket`] call inside [`Self::update`];
    /// its own mutex (not the writer lock) so a bench or test can warm
    /// it without publishing an epoch.
    pub fn recompute_scratch(&self) -> MutexGuard<'_, BucketScratch> {
        self.recompute_scratch.lock().unwrap()
    }

    /// Measured flush-path costs for this index (crossover input).
    pub fn crossover_costs(&self) -> &CrossoverCosts {
        &self.costs
    }

    /// Run a read-only closure against the writer structure — for O(1)
    /// structural probes (degrees, edge membership) where a full
    /// [`Self::graph`] CSR rebuild would dominate the caller's cost.
    /// Briefly serialises with writers; do not do heavy work inside.
    pub fn with_dynamic<R>(&self, f: impl FnOnce(&DynamicCore) -> R) -> R {
        let dc = self.writer.lock().unwrap();
        f(&dc)
    }

    /// A mutually consistent (snapshot, graph) pair from one epoch —
    /// what structure queries like densest-core extraction need.
    pub fn consistent_view(&self) -> (Arc<CoreSnapshot>, Arc<CsrGraph>) {
        let dc = self.writer.lock().unwrap();
        let g = self.graph_locked(&dc);
        // The published snapshot always matches the writer state while
        // the writer lock is held (update() publishes under it).
        (self.published.read().unwrap().clone(), g)
    }
}

impl std::fmt::Debug for CoreIndex {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        write!(
            f,
            "CoreIndex({} @ epoch {}: |V|={}, |E|={}, k_max={})",
            self.name,
            s.epoch,
            s.num_vertices(),
            s.num_edges,
            s.k_max
        )
    }
}

/// The multi-graph store: named [`CoreIndex`]es behind one handle — what
/// a serving deployment hosts (one index per tenant graph).
#[derive(Default)]
pub struct CoreStore {
    map: RwLock<HashMap<String, Arc<CoreIndex>>>,
}

impl CoreStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Index `g` under `name`, replacing any previous index of that name.
    pub fn open(&self, name: &str, g: &CsrGraph) -> Arc<CoreIndex> {
        let idx = Arc::new(CoreIndex::new(name, g));
        self.map.write().unwrap().insert(name.to_string(), idx.clone());
        idx
    }

    /// Insert a pre-built index under its own name.
    pub fn insert(&self, idx: CoreIndex) -> Arc<CoreIndex> {
        let idx = Arc::new(idx);
        self.map
            .write()
            .unwrap()
            .insert(idx.name().to_string(), idx.clone());
        idx
    }

    pub fn get(&self, name: &str) -> Option<Arc<CoreIndex>> {
        self.map.read().unwrap().get(name).cloned()
    }

    pub fn remove(&self, name: &str) -> bool {
        self.map.write().unwrap().remove(name).is_some()
    }

    /// Hosted graph names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.map.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::examples;

    #[test]
    fn snapshot_is_immutable_across_updates() {
        let idx = CoreIndex::new("g1", &examples::g1());
        let before = idx.snapshot();
        assert_eq!(before.epoch, 0);
        assert_eq!(before.core, examples::g1_coreness());
        assert_eq!(before.k_max, 2);
        assert_eq!(before.num_edges, 7);

        let (changed, after) = idx.update(|dc| dc.insert_edge(2, 5));
        assert!(changed);
        assert_eq!(after.epoch, 1);
        assert_eq!(after.k_max, 3);
        // the old snapshot is untouched — readers holding it see epoch 0
        assert_eq!(before.epoch, 0);
        assert_eq!(before.k_max, 2);
        assert_eq!(idx.epoch(), 1);
    }

    #[test]
    fn graph_view_is_cached_per_epoch() {
        let idx = CoreIndex::new("g1", &examples::g1());
        let a = idx.graph();
        let b = idx.graph();
        assert!(Arc::ptr_eq(&a, &b), "same epoch must reuse the cache");
        idx.update(|dc| dc.insert_edge(0, 1));
        let c = idx.graph();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(c.num_edges(), 8);
    }

    #[test]
    fn consistent_view_pairs_epochs() {
        let idx = CoreIndex::new("g1", &examples::g1());
        idx.update(|dc| dc.insert_edge(2, 5));
        let (snap, g) = idx.consistent_view();
        assert_eq!(snap.num_edges, g.num_edges());
        assert_eq!(snap.core, bz_coreness(&g));
    }

    #[test]
    fn store_hosts_named_graphs() {
        let store = CoreStore::new();
        assert!(store.is_empty());
        store.open("a", &examples::g1());
        store.open("b", &examples::complete(4));
        assert_eq!(store.names(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(store.get("b").unwrap().snapshot().k_max, 3);
        assert!(store.get("c").is_none());
        assert!(store.remove("a"));
        assert!(!store.remove("a"));
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn concurrent_readers_never_see_torn_state() {
        use std::sync::atomic::AtomicBool;
        let idx = Arc::new(CoreIndex::new("k6", &examples::complete(6)));
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let idx = idx.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || {
                let mut seen = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let s = idx.snapshot();
                    // every published coreness vector is internally
                    // consistent: uniform on a clique-or-clique-minus-edge
                    let kmax = s.core.iter().copied().max().unwrap();
                    assert_eq!(s.k_max, kmax, "stale k_max at epoch {}", s.epoch);
                    assert!(
                        s.core.iter().all(|&c| c == 5) || s.core.iter().all(|&c| c == 4),
                        "torn snapshot at epoch {}: {:?}",
                        s.epoch,
                        s.core
                    );
                    seen = seen.max(s.epoch);
                }
                seen
            }));
        }
        for i in 0..50 {
            if i % 2 == 0 {
                idx.update(|dc| dc.delete_edge(0, 1));
            } else {
                idx.update(|dc| dc.insert_edge(0, 1));
            }
        }
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(idx.epoch(), 50);
    }
}
