//! The BSP kernel-launch engine — the CUDA-substitute substrate.
//!
//! The paper's algorithms are synchronous sequences of GPU kernel launches
//! (`scan`, `scatter`, `SumHisto`, `UpdateHisto`). We model each launch as
//! a bulk-synchronous data-parallel pass executed by a fixed pool of OS
//! threads in SPMD style: every algorithm is written as *one* function all
//! workers execute, with [`SpmdCtx::barrier`] marking kernel boundaries —
//! exactly the shape of a CUDA cooperative-groups program. Atomic
//! operations (including the paper's novel `atomicSub_{>=k}`) are CAS loops
//! over `std::sync::atomic` with optional instrumentation, so every table
//! can report the paper's atomic-op and launch counts alongside time.

pub mod atomics;
pub mod frontier;
pub mod metrics;
pub mod spmd;

pub use atomics::{atomic_sub_floor, AtomicCoreArray};
pub use frontier::{NextFrontier, WorkList};
pub use metrics::{Metrics, MetricsSnapshot, MetricsView};
pub use spmd::{run_spmd, SpmdCtx};
