//! Instrumentation counters for the quantities the paper reports:
//! atomic-op counts (Fig. 4's `2n−m` vs `n−m` claim), edge accesses
//! (Fig. 3), h-index summations, and kernel launches. The serving
//! layer's request-path counters used to ride along in these slots;
//! they now live in the observability registry ([`crate::obs`]) so the
//! algorithm-cost counters here stay exactly the paper's quantities.
//!
//! Counters are per-worker, cache-line padded, and relaxed — a worker only
//! ever touches its own slot on the hot path, so enabling metrics costs a
//! predictable branch + one uncontended add. Disabled metrics cost only
//! the branch.

use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Default)]
struct Slot {
    atomic_subs: AtomicU64,
    atomic_adds: AtomicU64,
    cas_retries: AtomicU64,
    edge_accesses: AtomicU64,
    hindex_evals: AtomicU64,
    frontier_pushes: AtomicU64,
}

/// Shared metrics sink, one padded slot per worker.
pub struct Metrics {
    enabled: bool,
    slots: Vec<CachePadded<Slot>>,
}

impl Metrics {
    pub fn new(num_threads: usize, enabled: bool) -> Self {
        Self {
            enabled,
            slots: (0..num_threads.max(1))
                .map(|_| CachePadded::new(Slot::default()))
                .collect(),
        }
    }

    /// Disabled sink (timing runs).
    pub fn disabled(num_threads: usize) -> Self {
        Self::new(num_threads, false)
    }

    /// Per-worker view for the hot path.
    pub fn view(&self, tid: usize) -> MetricsView<'_> {
        MetricsView {
            slot: &self.slots[tid],
            enabled: self.enabled,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Aggregate all worker slots.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut s = MetricsSnapshot::default();
        for slot in &self.slots {
            s.atomic_subs += slot.atomic_subs.load(Ordering::Relaxed);
            s.atomic_adds += slot.atomic_adds.load(Ordering::Relaxed);
            s.cas_retries += slot.cas_retries.load(Ordering::Relaxed);
            s.edge_accesses += slot.edge_accesses.load(Ordering::Relaxed);
            s.hindex_evals += slot.hindex_evals.load(Ordering::Relaxed);
            s.frontier_pushes += slot.frontier_pushes.load(Ordering::Relaxed);
        }
        s
    }
}

/// Per-worker handle; all methods are no-ops when metrics are disabled.
#[derive(Clone, Copy)]
pub struct MetricsView<'a> {
    slot: &'a Slot,
    enabled: bool,
}

macro_rules! bump {
    ($name:ident) => {
        #[inline(always)]
        pub fn $name(&self, n: u64) {
            if self.enabled {
                self.slot.$name.fetch_add(n, Ordering::Relaxed);
            }
        }
    };
}

impl MetricsView<'_> {
    bump!(atomic_subs);
    bump!(atomic_adds);
    bump!(cas_retries);
    bump!(edge_accesses);
    bump!(hindex_evals);
    bump!(frontier_pushes);
}

/// Aggregated counter values.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub atomic_subs: u64,
    pub atomic_adds: u64,
    pub cas_retries: u64,
    pub edge_accesses: u64,
    pub hindex_evals: u64,
    pub frontier_pushes: u64,
}

impl MetricsSnapshot {
    /// Total atomic RMW operations (the Fig. 4 quantity).
    pub fn total_atomics(&self) -> u64 {
        self.atomic_subs + self.atomic_adds
    }
}

/// Process-wide count of scratch-buffer reuses on the maintenance and
/// recompute hot paths — each tick is a batch/edit that found its work
/// queues pre-warmed instead of allocating fresh ones. Global rather than
/// per-run: the buffers live across flushes (that is the point), so the
/// saving is a process-lifetime quantity like the obs counters.
static SCRATCH_REUSES: AtomicU64 = AtomicU64::new(0);

/// Record `n` avoided allocations (a warm scratch buffer served a batch).
#[inline]
pub fn note_scratch_reuses(n: u64) {
    SCRATCH_REUSES.fetch_add(n, Ordering::Relaxed);
}

/// Total scratch-buffer reuses since process start.
pub fn scratch_reuses() -> u64 {
    SCRATCH_REUSES.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_when_enabled() {
        let m = Metrics::new(2, true);
        m.view(0).atomic_subs(3);
        m.view(1).atomic_subs(4);
        m.view(1).edge_accesses(10);
        let s = m.snapshot();
        assert_eq!(s.atomic_subs, 7);
        assert_eq!(s.edge_accesses, 10);
        assert_eq!(s.total_atomics(), 7);
    }

    #[test]
    fn noop_when_disabled() {
        let m = Metrics::disabled(2);
        m.view(0).atomic_subs(3);
        assert_eq!(m.snapshot(), MetricsSnapshot::default());
    }

    #[test]
    fn scratch_reuse_counter_is_monotone() {
        // global counter: other tests may bump it concurrently, so only
        // the delta from our own notes is asserted
        let before = scratch_reuses();
        note_scratch_reuses(3);
        note_scratch_reuses(2);
        assert!(scratch_reuses() >= before + 5);
    }
}
