//! Atomic primitives of the paper.
//!
//! * [`atomic_sub_floor`] — the paper's novel `atomicSub_{>=k}(addr, 1, k)`
//!   (§III.B): atomically compute `old > k ? old − 1 : k` — i.e. decrement
//!   but never below the floor `k`. CUDA exposes this as a single atomic
//!   transaction built from `atomicCAS`; we use the identical CAS loop.
//! * [`AtomicCoreArray`] — the shared `core[]` / `deg[]` property array all
//!   kernels operate on.

use super::metrics::MetricsView;
use std::sync::atomic::{AtomicU32, Ordering};

/// Shared u32 property array with relaxed-atomic element access.
pub struct AtomicCoreArray {
    cells: Vec<AtomicU32>,
}

impl AtomicCoreArray {
    pub fn from_vec(init: Vec<u32>) -> Self {
        Self {
            cells: init.into_iter().map(AtomicU32::new).collect(),
        }
    }

    pub fn zeros(n: usize) -> Self {
        Self::from_vec(vec![0; n])
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    #[inline]
    pub fn load(&self, i: usize) -> u32 {
        self.cells[i].load(Ordering::Relaxed)
    }

    #[inline]
    pub fn store(&self, i: usize, v: u32) {
        self.cells[i].store(v, Ordering::Relaxed)
    }

    #[inline]
    pub fn cell(&self, i: usize) -> &AtomicU32 {
        &self.cells[i]
    }

    /// Copy out the plain values (end of a run).
    pub fn to_vec(&self) -> Vec<u32> {
        self.cells.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }
}

/// Result of [`atomic_sub_floor`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubFloor {
    /// This thread performed the decrement; holds the *new* value.
    Written(u32),
    /// Value was already at or below the floor; holds the observed value.
    AtFloor(u32),
}

/// The paper's `atomicSub_{>=k}`: decrement `cell` by one but never below
/// `k`. Returns whether *this* call performed a write and the resulting
/// value — the caller uses `Written(k)` as the unique "vertex just hit the
/// floor" signal for dynamic-frontier insertion (§III.C step 3).
#[inline]
pub fn atomic_sub_floor(cell: &AtomicU32, k: u32, mv: &MetricsView) -> SubFloor {
    let mut old = cell.load(Ordering::Relaxed);
    loop {
        if old <= k {
            return SubFloor::AtFloor(old);
        }
        match cell.compare_exchange_weak(old, old - 1, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => {
                mv.atomic_subs(1);
                return SubFloor::Written(old - 1);
            }
            Err(actual) => {
                mv.cas_retries(1);
                old = actual;
            }
        }
    }
}

/// Single-worker fast path of [`atomic_sub_floor`]: plain load/store
/// (no LOCK prefix). Semantically identical when exactly one thread
/// mutates the array — the SPMD programs select it for `num_threads == 1`,
/// where CAS traffic would be pure overhead (a ~15x per-op difference on
/// x86 that otherwise drowns the algorithmic comparisons the benches
/// make).
#[inline]
pub fn sub_floor_seq(cell: &AtomicU32, k: u32, mv: &MetricsView) -> SubFloor {
    let old = cell.load(Ordering::Relaxed);
    if old <= k {
        SubFloor::AtFloor(old)
    } else {
        cell.store(old - 1, Ordering::Relaxed);
        mv.atomic_subs(1);
        SubFloor::Written(old - 1)
    }
}

/// Allocate a zeroed atomic array via the `u32`→`AtomicU32` layout
/// guarantee ("same size and bit validity"): `vec![0u32]` is a memset,
/// element-wise `AtomicU32::new` is not — this matters for HistoCore's
/// O(2|E|) histogram rows.
pub fn atomic_u32_zeroed(len: usize) -> Vec<AtomicU32> {
    let v = vec![0u32; len];
    let mut v = std::mem::ManuallyDrop::new(v);
    // SAFETY: AtomicU32 has the same size, alignment, and bit validity as
    // u32 (std documented guarantee); length/capacity are preserved.
    unsafe { Vec::from_raw_parts(v.as_mut_ptr() as *mut AtomicU32, v.len(), v.capacity()) }
}

/// Plain instrumented `atomicSub(cell, 1)` returning the new value —
/// the baseline GPP / PP-dyn operation (may go below any floor).
#[inline]
pub fn atomic_sub_one(cell: &AtomicU32, mv: &MetricsView) -> u32 {
    mv.atomic_subs(1);
    cell.fetch_sub(1, Ordering::Relaxed).wrapping_sub(1)
}

/// Plain instrumented `atomicAdd(cell, 1)` returning the new value —
/// PP-dyn's under-core correction (Fig. 4a).
#[inline]
pub fn atomic_add_one(cell: &AtomicU32, mv: &MetricsView) -> u32 {
    mv.atomic_adds(1);
    cell.fetch_add(1, Ordering::Relaxed).wrapping_add(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::metrics::Metrics;
    use crate::engine::spmd::run_spmd;

    #[test]
    fn sub_floor_decrements_above_floor() {
        let m = Metrics::new(1, true);
        let c = AtomicU32::new(10);
        assert_eq!(atomic_sub_floor(&c, 5, &m.view(0)), SubFloor::Written(9));
        assert_eq!(c.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn sub_floor_stops_at_floor() {
        let m = Metrics::new(1, true);
        let c = AtomicU32::new(5);
        assert_eq!(atomic_sub_floor(&c, 5, &m.view(0)), SubFloor::AtFloor(5));
        assert_eq!(c.load(Ordering::Relaxed), 5);
        // below floor (removed vertex from an earlier level): untouched
        let c = AtomicU32::new(3);
        assert_eq!(atomic_sub_floor(&c, 5, &m.view(0)), SubFloor::AtFloor(3));
        assert_eq!(c.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn sub_floor_exactly_one_writer_hits_floor() {
        // n concurrent decrements on a cell of value k+m: exactly m writes
        // succeed, exactly one of them produces Written(k) — the paper's
        // unique frontier-insertion signal.
        let k = 8u32;
        let m_extra = 5u32; // value = k + m_extra
        let n_threads = 8usize;
        let reps = 200;
        for rep in 0..reps {
            let cell = AtomicU32::new(k + m_extra);
            let metrics = Metrics::new(n_threads, true);
            let hit_floor = std::sync::atomic::AtomicU32::new(0);
            run_spmd(n_threads, |ctx| {
                // every thread tries 3 decrements: 24 attempts on 5 slack
                for _ in 0..3 {
                    if let SubFloor::Written(nv) =
                        atomic_sub_floor(&cell, k, &metrics.view(ctx.tid))
                    {
                        if nv == k {
                            hit_floor.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
            assert_eq!(cell.load(Ordering::Relaxed), k, "rep {rep}");
            assert_eq!(hit_floor.load(Ordering::Relaxed), 1, "rep {rep}");
            // exactly m_extra successful subs
            assert_eq!(metrics.snapshot().atomic_subs, m_extra as u64);
        }
    }

    #[test]
    fn sub_floor_seq_matches_concurrent_semantics() {
        let m = Metrics::new(1, true);
        for (init, k) in [(10u32, 5u32), (5, 5), (3, 5), (6, 5)] {
            let a = AtomicU32::new(init);
            let b = AtomicU32::new(init);
            let ra = atomic_sub_floor(&a, k, &m.view(0));
            let rb = sub_floor_seq(&b, k, &m.view(0));
            assert_eq!(ra, rb, "init={init} k={k}");
            assert_eq!(a.load(Ordering::Relaxed), b.load(Ordering::Relaxed));
        }
    }

    #[test]
    fn zeroed_atomic_vec() {
        let v = atomic_u32_zeroed(1000);
        assert_eq!(v.len(), 1000);
        assert!(v.iter().all(|c| c.load(Ordering::Relaxed) == 0));
        v[5].store(7, Ordering::Relaxed);
        assert_eq!(v[5].load(Ordering::Relaxed), 7);
    }

    #[test]
    fn core_array_round_trip() {
        let a = AtomicCoreArray::from_vec(vec![1, 2, 3]);
        a.store(1, 9);
        assert_eq!(a.to_vec(), vec![1, 9, 3]);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn sub_add_one_instrumented() {
        let m = Metrics::new(1, true);
        let c = AtomicU32::new(10);
        assert_eq!(atomic_sub_one(&c, &m.view(0)), 9);
        assert_eq!(atomic_add_one(&c, &m.view(0)), 10);
        let s = m.snapshot();
        assert_eq!(s.atomic_subs, 1);
        assert_eq!(s.atomic_adds, 1);
    }
}
