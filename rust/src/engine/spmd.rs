//! SPMD execution: spawn `T` workers that all run the same kernel program,
//! synchronising at explicit barriers (= GPU kernel-launch boundaries).
//!
//! Workers are spawned once per decomposition run (not per launch), so a
//! run with thousands of launches pays thousands of *barriers* (~µs), not
//! thousands of thread spawns — mirroring the persistent-threads style of
//! the paper's CUDA kernels while keeping launch counts meaningful.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Barrier;

/// Per-worker execution context.
pub struct SpmdCtx<'a> {
    /// Worker id in `0..num_threads`.
    pub tid: usize,
    /// Total workers.
    pub num_threads: usize,
    barrier: &'a Barrier,
    launches: &'a AtomicUsize,
}

impl<'a> SpmdCtx<'a> {
    /// Synchronise all workers — the kernel-launch boundary.
    #[inline]
    pub fn barrier(&self) {
        self.barrier.wait();
    }

    /// Barrier that also counts a kernel launch (thread 0 accounts it).
    #[inline]
    pub fn launch_boundary(&self) {
        if self.tid == 0 {
            self.launches.fetch_add(1, Ordering::Relaxed);
        }
        self.barrier.wait();
    }

    /// The static contiguous chunk of `domain` assigned to this worker —
    /// the analog of `blockIdx`-based index partitioning.
    #[inline]
    pub fn static_chunk(&self, domain: usize) -> std::ops::Range<usize> {
        let per = domain.div_ceil(self.num_threads);
        let lo = (self.tid * per).min(domain);
        let hi = ((self.tid + 1) * per).min(domain);
        lo..hi
    }

    /// Dynamically load-balanced chunks over `domain` via a shared cursor —
    /// the analog of a grid-stride persistent-threads loop. `cursor` must
    /// be reset (to 0) before the launch and be the same for all workers.
    #[inline]
    pub fn dynamic_chunks<'c>(
        &self,
        domain: usize,
        chunk: usize,
        cursor: &'c AtomicUsize,
    ) -> DynamicChunks<'c> {
        DynamicChunks {
            domain,
            chunk: chunk.max(1),
            cursor,
        }
    }
}

/// Iterator over dynamically grabbed chunks.
pub struct DynamicChunks<'c> {
    domain: usize,
    chunk: usize,
    cursor: &'c AtomicUsize,
}

impl Iterator for DynamicChunks<'_> {
    type Item = std::ops::Range<usize>;

    #[inline]
    fn next(&mut self) -> Option<Self::Item> {
        let lo = self.cursor.fetch_add(self.chunk, Ordering::Relaxed);
        if lo >= self.domain {
            return None;
        }
        Some(lo..(lo + self.chunk).min(self.domain))
    }
}

/// Run `kernel_program` on `num_threads` workers; returns the number of
/// `launch_boundary` crossings (kernel launches) observed.
pub fn run_spmd<F>(num_threads: usize, kernel_program: F) -> usize
where
    F: Fn(&SpmdCtx) + Sync,
{
    assert!(num_threads >= 1);
    let barrier = Barrier::new(num_threads);
    let launches = AtomicUsize::new(0);
    if num_threads == 1 {
        // Fast path (also used by tests to get deterministic scheduling).
        let ctx = SpmdCtx {
            tid: 0,
            num_threads: 1,
            barrier: &barrier,
            launches: &launches,
        };
        kernel_program(&ctx);
        return launches.load(Ordering::Relaxed);
    }
    crossbeam_utils::thread::scope(|scope| {
        for tid in 0..num_threads {
            let barrier = &barrier;
            let launches = &launches;
            let kernel_program = &kernel_program;
            scope.spawn(move |_| {
                let ctx = SpmdCtx {
                    tid,
                    num_threads,
                    barrier,
                    launches,
                };
                kernel_program(&ctx);
            });
        }
    })
    .expect("SPMD worker panicked");
    launches.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn static_chunks_cover_domain() {
        let barrier = Barrier::new(1);
        let launches = AtomicUsize::new(0);
        let mk = |tid, nt| SpmdCtx {
            tid,
            num_threads: nt,
            barrier: &barrier,
            launches: &launches,
        };
        for nt in [1, 3, 4, 7] {
            for domain in [0usize, 1, 5, 100] {
                let mut covered = vec![false; domain];
                for tid in 0..nt {
                    for i in mk(tid, nt).static_chunk(domain) {
                        assert!(!covered[i], "overlap at {i}");
                        covered[i] = true;
                    }
                }
                assert!(covered.iter().all(|&c| c), "nt={nt} domain={domain}");
            }
        }
    }

    #[test]
    fn spmd_parallel_sum() {
        let total = AtomicU64::new(0);
        let n = 100_000usize;
        run_spmd(4, |ctx| {
            let mut local = 0u64;
            for i in ctx.static_chunk(n) {
                local += i as u64;
            }
            total.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn dynamic_chunks_cover_exactly_once() {
        let n = 10_000usize;
        let cursor = AtomicUsize::new(0);
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        run_spmd(4, |ctx| {
            for range in ctx.dynamic_chunks(n, 64, &cursor) {
                for i in range {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn launch_boundary_counts_once_per_crossing() {
        let launches = run_spmd(4, |ctx| {
            for _ in 0..5 {
                ctx.launch_boundary();
            }
        });
        assert_eq!(launches, 5);
    }

    #[test]
    fn barriers_order_phases() {
        // Phase 1 writes, phase 2 reads — barrier must make writes visible.
        let n = 1000usize;
        let data: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let sum = AtomicU64::new(0);
        run_spmd(4, |ctx| {
            for i in ctx.static_chunk(n) {
                data[i].store(i as u64 + 1, Ordering::Relaxed);
            }
            ctx.barrier();
            let mut local = 0;
            for i in ctx.static_chunk(n) {
                local += data[i].load(Ordering::Relaxed);
            }
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (1..=n as u64).sum::<u64>());
    }
}
