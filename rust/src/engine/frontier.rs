//! Frontier machinery.
//!
//! * [`WorkList`] — the *dynamic* global frontier queue of PP-dyn/PO-dyn
//!   (§III.C step 3): vertices discovered mid-launch (under-core vertices
//!   asserted to the floor k) are pushed and drained within the same
//!   launch, collapsing the per-level sub-iterations so l1 = k_max.
//!   Modeled after a GPU global work-list: one reservation cursor for
//!   pops, one publish cursor for pushes, live termination detection.
//! * [`NextFrontier`] — the double-buffered, deduplicated frontier the
//!   Index2core algorithms use for `V_active` / `V_cnt` (one epoch per
//!   BSP launch; dedup via an epoch-stamp array instead of clearing).

use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};

const SENTINEL: u32 = u32::MAX;

/// Dynamic global work-list over vertex ids `< capacity`.
///
/// Usage per level: seed with [`WorkList::push`] (scan kernel), then all
/// workers call [`WorkList::drain`] concurrently; `drain` returns when the
/// list is globally exhausted and no worker can produce more items.
/// [`WorkList::reset`] (single-threaded, between levels) clears only the
/// used range, so a full decomposition pays O(total pushes) reset cost.
pub struct WorkList {
    buf: Vec<AtomicU32>,
    /// Pop reservation cursor.
    head: CachePadded<AtomicUsize>,
    /// Publish cursor.
    tail: CachePadded<AtomicUsize>,
    /// Workers currently processing an item (termination detection).
    busy: CachePadded<AtomicUsize>,
}

impl WorkList {
    /// Capacity must bound the number of pushes between two resets —
    /// for peel algorithms each vertex is enqueued at most once, so `n`.
    pub fn new(capacity: usize) -> Self {
        Self {
            buf: (0..capacity).map(|_| AtomicU32::new(SENTINEL)).collect(),
            head: CachePadded::new(AtomicUsize::new(0)),
            tail: CachePadded::new(AtomicUsize::new(0)),
            busy: CachePadded::new(AtomicUsize::new(0)),
        }
    }

    /// Publish an item (safe to call concurrently with draining).
    #[inline]
    pub fn push(&self, v: u32) {
        debug_assert_ne!(v, SENTINEL);
        let i = self.tail.fetch_add(1, Ordering::AcqRel);
        assert!(i < self.buf.len(), "WorkList overflow (capacity {})", self.buf.len());
        self.buf[i].store(v, Ordering::Release);
    }

    /// Number of items published since the last reset.
    pub fn pushed(&self) -> usize {
        self.tail.load(Ordering::Acquire)
    }

    /// Maximum pushes between resets (fixed at construction). Lets a
    /// scratch holder decide whether an old list can serve a new graph.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Read a published item by index (BSP use: `i < pushed()` and a
    /// barrier separates the pushing launch from the reading launch).
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        let v = self.buf[i].load(Ordering::Acquire);
        debug_assert_ne!(v, SENTINEL, "read of unpublished WorkList slot {i}");
        v
    }

    /// Cooperatively drain: repeatedly pop an item and call `f(item, self)`
    /// (f may push). Returns the number of items this worker processed.
    /// All workers of the launch must call this; it returns only when the
    /// list is globally empty and no worker is mid-item.
    pub fn drain(&self, mut f: impl FnMut(u32, &WorkList)) -> usize {
        let mut processed = 0usize;
        let mut spins = 0u32;
        loop {
            // Optimistically mark ourselves busy before attempting a pop so
            // no peer can observe (empty ∧ nobody busy) while we hold an
            // unprocessed item.
            self.busy.fetch_add(1, Ordering::SeqCst);
            let h = self.head.load(Ordering::Acquire);
            let t = self.tail.load(Ordering::Acquire);
            if h < t
                && self
                    .head
                    .compare_exchange(h, h + 1, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                // Wait for the slot to be published (push reserves index
                // before storing the value). Yield after a short spin —
                // on low-core hosts the publisher may need the CPU.
                let v = {
                    let mut wait = 0u32;
                    loop {
                        let v = self.buf[h].load(Ordering::Acquire);
                        if v != SENTINEL {
                            break v;
                        }
                        wait += 1;
                        if wait > 16 {
                            std::thread::yield_now();
                        } else {
                            std::hint::spin_loop();
                        }
                    }
                };
                f(v, self);
                processed += 1;
                self.busy.fetch_sub(1, Ordering::SeqCst);
                spins = 0;
                continue;
            }
            self.busy.fetch_sub(1, Ordering::SeqCst);
            // Exhausted? Only if nothing pending and nobody mid-item.
            if self.busy.load(Ordering::SeqCst) == 0
                && self.head.load(Ordering::SeqCst) >= self.tail.load(Ordering::SeqCst)
            {
                return processed;
            }
            spins += 1;
            if spins > 64 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }

    /// Sequential drain — the single-worker fast path (no busy
    /// accounting, no CAS): used when the SPMD pool has one thread, where
    /// the concurrent protocol's SeqCst traffic would be pure overhead.
    pub fn drain_seq(&self, mut f: impl FnMut(u32, &WorkList)) -> usize {
        let mut processed = 0usize;
        loop {
            let h = self.head.load(Ordering::Relaxed);
            let t = self.tail.load(Ordering::Relaxed);
            if h >= t {
                return processed;
            }
            self.head.store(h + 1, Ordering::Relaxed);
            let v = self.buf[h].load(Ordering::Relaxed);
            debug_assert_ne!(v, SENTINEL);
            f(v, self);
            processed += 1;
        }
    }

    /// Clear for the next level. Single-threaded (between BSP launches).
    pub fn reset(&self) {
        let used = self.tail.load(Ordering::Acquire).min(self.buf.len());
        for slot in &self.buf[..used] {
            slot.store(SENTINEL, Ordering::Relaxed);
        }
        self.head.store(0, Ordering::Release);
        self.tail.store(0, Ordering::Release);
    }
}

/// Double-buffered deduplicated next-frontier set (BSP epochs).
///
/// During launch `e`, workers [`NextFrontier::push`] candidate vertices;
/// duplicates within the epoch are dropped via an epoch-stamp array.
/// Between launches (single-threaded), [`NextFrontier::take`] yields the
/// collected set and opens the next epoch. Visibility is provided by the
/// BSP barrier, so all atomics are relaxed.
pub struct NextFrontier {
    epoch: AtomicU32,
    stamp: Vec<AtomicU32>,
    buf: Vec<AtomicU32>,
    len: CachePadded<AtomicUsize>,
}

impl NextFrontier {
    pub fn new(n: usize) -> Self {
        Self {
            epoch: AtomicU32::new(1),
            stamp: (0..n).map(|_| AtomicU32::new(0)).collect(),
            buf: (0..n).map(|_| AtomicU32::new(0)).collect(),
            len: CachePadded::new(AtomicUsize::new(0)),
        }
    }

    /// Add `v` to the next frontier (idempotent within an epoch).
    #[inline]
    pub fn push(&self, v: u32) {
        let e = self.epoch.load(Ordering::Relaxed);
        if self.stamp[v as usize].swap(e, Ordering::Relaxed) != e {
            let i = self.len.fetch_add(1, Ordering::Relaxed);
            self.buf[i].store(v, Ordering::Relaxed);
        }
    }

    /// Whether `v` is already queued this epoch.
    #[inline]
    pub fn contains(&self, v: u32) -> bool {
        self.stamp[v as usize].load(Ordering::Relaxed) == self.epoch.load(Ordering::Relaxed)
    }

    /// Current number of queued vertices.
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Collect the queued set and open the next epoch. Call from a single
    /// thread between barriers.
    pub fn take(&self) -> Vec<u32> {
        let n = self.len.load(Ordering::Relaxed);
        let out: Vec<u32> = self.buf[..n]
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        self.len.store(0, Ordering::Relaxed);
        self.epoch.fetch_add(1, Ordering::Relaxed);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::spmd::run_spmd;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn worklist_single_thread_fifo_drain() {
        let wl = WorkList::new(10);
        wl.push(3);
        wl.push(7);
        let mut seen = Vec::new();
        let n = wl.drain(|v, _| seen.push(v));
        assert_eq!(n, 2);
        assert_eq!(seen, vec![3, 7]);
    }

    #[test]
    fn worklist_recursive_pushes_processed_same_launch() {
        // Seed one item; each processed item v pushes v-1 down to 0:
        // the whole chain must drain within a single `drain` call.
        let wl = WorkList::new(101);
        wl.push(100);
        let count = AtomicU64::new(0);
        run_spmd(4, |_| {
            wl.drain(|v, wl| {
                count.fetch_add(1, Ordering::Relaxed);
                if v > 0 {
                    wl.push(v - 1);
                }
            });
        });
        assert_eq!(count.load(Ordering::Relaxed), 101);
    }

    #[test]
    fn worklist_parallel_exactly_once() {
        let n = 10_000u32;
        let wl = WorkList::new(n as usize);
        for v in 0..n {
            wl.push(v);
        }
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        run_spmd(8, |_| {
            wl.drain(|v, _| {
                hits[v as usize].fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn worklist_reset_reusable() {
        let wl = WorkList::new(8);
        wl.push(1);
        wl.drain(|_, _| {});
        wl.reset();
        assert_eq!(wl.pushed(), 0);
        wl.push(2);
        let mut seen = Vec::new();
        wl.drain(|v, _| seen.push(v));
        assert_eq!(seen, vec![2]);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn worklist_overflow_panics() {
        let wl = WorkList::new(1);
        wl.push(0);
        wl.push(1);
    }

    #[test]
    fn next_frontier_dedups_within_epoch() {
        let nf = NextFrontier::new(10);
        nf.push(4);
        nf.push(4);
        nf.push(2);
        assert_eq!(nf.len(), 2);
        let mut got = nf.take();
        got.sort_unstable();
        assert_eq!(got, vec![2, 4]);
        // next epoch: same vertex can be queued again
        nf.push(4);
        assert_eq!(nf.take(), vec![4]);
    }

    #[test]
    fn next_frontier_parallel_dedup() {
        let n = 1000usize;
        let nf = NextFrontier::new(n);
        run_spmd(8, |_| {
            for v in 0..n as u32 {
                nf.push(v % 100); // heavy duplication across threads
            }
        });
        let mut got = nf.take();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 100);
        assert_eq!(got, (0..100u32).collect::<Vec<_>>());
    }
}
