//! # PICO-RS — all k-core decomposition paradigms
//!
//! A production-shaped reproduction of *PICO: Accelerating All k-Core
//! Paradigms on GPU* (CS.DC 2024) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: a bulk-synchronous
//!   kernel-launch engine ([`engine`]), the eight decomposition algorithms
//!   of the paper ([`core`]), a vertex-centric framework baseline ([`vc`]),
//!   the job scheduler ([`coordinator`]), and the benchmark harness
//!   ([`bench`]) that regenerates every table and figure.
//! * **Layer 3.5 ([`service`])** — the serving layer: epoch-versioned
//!   core indices with non-blocking concurrent reads, a coalescing
//!   batched-update pipeline with an incremental-vs-recompute crossover,
//!   and a line-protocol TCP server (`pico serve` / `pico query`).
//! * **Layer 3.6 ([`shard`])** — sharded serving: vertex partitioners,
//!   a `ShardedIndex` whose router fans queries out and merges per-shard
//!   answers exactly (boundary refinement), and binary snapshot shipping
//!   (`SNAPSHOT`/`RESTORE` over the length-prefixed binary protocol).
//! * **Layer 3.7 ([`cluster`])** — multi-host cluster serving: a
//!   `ClusterIndex` routing over local and remote shards (the shard
//!   interface spoken over the binary protocol), replica groups with
//!   epoch-checked reads + failover, snapshot-ship catch-up, and the
//!   `pico serve --cluster` / `pico cluster status` topology tooling.
//! * **Transport ([`net`])** — the unified wire layer under all of the
//!   above: one frame/line codec owning every protocol magic, a
//!   bounded worker-pool server (connections are queue entries, not
//!   threads), the per-connection session state machine with `AUTH`
//!   gating and transport `METRICS`, and the one reconnecting client
//!   shared by the cluster router and the CLI.
//! * **Observability ([`obs`])** — the unified metrics registry
//!   (counters, gauges, latency histograms), stage-level flush tracing
//!   with cross-host span stitching, and the `METRICS PROM|JSON` /
//!   `TRACES` expositions scraped by `pico cluster status --metrics`.
//! * **Layer 2 (build-time JAX)** — vectorised peel / h-index step
//!   functions, AOT-lowered to HLO text and executed from [`runtime`] via
//!   the PJRT C API.
//! * **Layer 1 (build-time Pallas)** — the threshold-matrix h-index tile
//!   kernel; see `python/compile/kernels/hindex.py`.
//!
//! Quickstart (`no_run` here only because rustdoc's test binary lacks the
//! xla rpath; `cargo run --example quickstart` executes the same code):
//!
//! ```no_run
//! use pico::graph::{examples, CsrGraph};
//! use pico::core::{Decomposer, peel::PoDyn};
//!
//! let g = examples::g1();
//! let result = PoDyn::default().decompose(&g);
//! assert_eq!(result.core, vec![1, 1, 2, 2, 2, 2]);
//! ```

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod engine;
pub mod graph;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod service;
pub mod shard;
pub mod util;
pub mod vc;
