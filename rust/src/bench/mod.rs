//! Benchmark harness shared by `rust/benches/*` and the CLI: the dataset
//! suite (the Table II substitute), the measurement loop, and helpers to
//! print paper-shaped tables.

pub mod runner;
pub mod suite;

pub use runner::{measure, BenchOptions, Measurement};
pub use suite::{suite, SuiteEntry, Tier};

/// Standard preamble all bench binaries print, so recorded outputs carry
/// their run conditions.
pub fn print_preamble(title: &str, opts: &BenchOptions) {
    println!("== {title} ==");
    println!(
        "host: {} hw threads | spmd threads: {} | reps: {} (min reported) | tier: {:?}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        opts.threads,
        opts.reps,
        Tier::from_env(),
    );
    println!();
}
