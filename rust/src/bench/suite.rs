//! The synthetic dataset suite — the laptop-scale substitute for the
//! paper's 24 public datasets (Table II). Entries mirror the paper's
//! categories and the structural regimes its analysis depends on:
//! power-law skew (under-core pressure, multi-changed frontiers), deep
//! core hierarchies (l1 = k_max large), hub-dominated communication
//! graphs, and regular meshes.

use crate::graph::{gen, CsrGraph};

/// Which benchmark tier an entry belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Seconds-fast sanity graphs.
    Small,
    /// The default bench suite.
    Standard,
    /// Larger stress graphs (opt-in: `PICO_SUITE=large`).
    Large,
    /// Graphs that fit the XLA buckets (|V| <= 4096, d_max <= 64).
    Xla,
}

impl Tier {
    pub fn from_env() -> Tier {
        match std::env::var("PICO_SUITE").as_deref() {
            Ok("small") => Tier::Small,
            Ok("large") => Tier::Large,
            Ok("xla") => Tier::Xla,
            _ => Tier::Standard,
        }
    }
}

/// `PICO_BENCH_QUICK=1` — CI smoke mode for the bench binaries: tiny
/// graphs and iteration counts, full output shape (the ROADMAP
/// crossover paste line still prints). Shared here so every bench
/// agrees on what counts as "on".
pub fn quick_bench() -> bool {
    std::env::var("PICO_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

/// Machine-readable bench results for CI's perf trail: in quick mode
/// (or when `PICO_BENCH_JSON=1` forces it), write `BENCH_<name>.json`
/// — `{bench, dataset, quick, metrics: {key: value}}` — into
/// `PICO_BENCH_JSON_DIR` (default: the working directory). The CI
/// `bench-smoke` job uploads these as artifacts, so the per-commit
/// numbers are recorded instead of scrolling away in a log. Handwritten
/// JSON: the environment is offline, no serde. Failures are reported,
/// never fatal — a bench must not die on a read-only filesystem.
pub fn write_bench_json(name: &str, dataset: &str, metrics: &[(&str, f64)]) {
    let forced = std::env::var("PICO_BENCH_JSON")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if !quick_bench() && !forced {
        return;
    }
    let dir = std::env::var("PICO_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&dir).join(format!("BENCH_{name}.json"));
    let cells: Vec<String> = metrics
        .iter()
        .map(|(k, v)| {
            // JSON has no NaN/Inf; clamp to null so consumers stay happy
            if v.is_finite() {
                format!("\"{k}\": {v:.6}")
            } else {
                format!("\"{k}\": null")
            }
        })
        .collect();
    let body = format!(
        "{{\"bench\": \"{name}\", \"dataset\": \"{dataset}\", \"quick\": {}, \"metrics\": {{{}}}}}\n",
        quick_bench(),
        cells.join(", ")
    );
    match std::fs::write(&path, body) {
        Ok(()) => println!("bench json -> {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }
}

/// One dataset definition (generated deterministically on demand).
pub struct SuiteEntry {
    pub name: &'static str,
    /// Paper-category analog (Table II's last column).
    pub category: &'static str,
    pub tier: Tier,
    build: fn() -> CsrGraph,
}

impl SuiteEntry {
    pub fn build(&self) -> CsrGraph {
        let mut g = (self.build)();
        g.name = self.name.to_string();
        g
    }
}

/// The full suite; filter by tier.
pub fn suite(tier: Tier) -> Vec<&'static SuiteEntry> {
    ALL.iter().filter(|e| e.tier == tier).collect()
}

/// Every entry regardless of tier.
pub fn all_entries() -> &'static [SuiteEntry] {
    &ALL
}

/// Find one entry by name.
pub fn by_name(name: &str) -> Option<&'static SuiteEntry> {
    ALL.iter().find(|e| e.name == name)
}

static ALL: [SuiteEntry; 19] = [
    // ---- Small tier (smoke / CI) ----
    SuiteEntry {
        name: "g1",
        category: "paper example",
        tier: Tier::Small,
        build: || crate::graph::examples::g1(),
    },
    SuiteEntry {
        name: "ba-small",
        category: "Social Network",
        tier: Tier::Small,
        build: || gen::barabasi_albert(2_000, 4, 101),
    },
    SuiteEntry {
        name: "cliques-small",
        category: "Web Graph (deep)",
        tier: Tier::Small,
        build: || gen::nested_cliques(8, 6, 6).0,
    },
    // ---- Standard tier (the paper-table suite) ----
    SuiteEntry {
        name: "social-ba",
        category: "Social Network",
        tier: Tier::Standard,
        build: || gen::barabasi_albert(20_000, 8, 42),
    },
    SuiteEntry {
        name: "social-rmat",
        category: "Social Network",
        tier: Tier::Standard,
        build: || gen::rmat(15, 12, 0.57, 0.19, 0.19, 7),
    },
    SuiteEntry {
        name: "comm-starburst",
        category: "Communication",
        tier: Tier::Standard,
        build: || gen::star_burst(8, 15_000, 60_000, 11),
    },
    SuiteEntry {
        name: "cite-er",
        category: "Citation",
        tier: Tier::Standard,
        build: || gen::erdos_renyi(40_000, 320_000, 13),
    },
    SuiteEntry {
        name: "collab-plc",
        category: "Collaboration",
        tier: Tier::Standard,
        build: || gen::power_law_cluster(20_000, 8, 0.7, 17),
    },
    SuiteEntry {
        name: "collab-caveman",
        category: "Collaboration",
        tier: Tier::Standard,
        build: || gen::caveman(1_500, 12, 19),
    },
    SuiteEntry {
        name: "web-planted",
        category: "Web Graph",
        tier: Tier::Standard,
        build: || {
            gen::planted_core(
                30_000,
                150_000,
                &[(6_000, 24), (1_500, 60), (300, 120), (60, 200)],
                23,
            )
        },
    },
    SuiteEntry {
        name: "web-cliques",
        category: "Web Graph (deep)",
        tier: Tier::Standard,
        build: || gen::nested_cliques(30, 12, 6).0,
    },
    SuiteEntry {
        name: "web-coreperiph",
        category: "Web Graph (deep)",
        tier: Tier::Standard,
        build: || gen::core_periphery(150_000, 120, 3),
    },
    SuiteEntry {
        name: "road-grid",
        category: "Mesh/Road",
        tier: Tier::Standard,
        build: || gen::grid2d(260, 260),
    },
    SuiteEntry {
        name: "ba-dense",
        category: "Social Network",
        tier: Tier::Standard,
        build: || gen::barabasi_albert(8_000, 16, 29),
    },
    // ---- Large tier ----
    SuiteEntry {
        name: "rmat-large",
        category: "Social Network",
        tier: Tier::Large,
        build: || gen::rmat(17, 12, 0.57, 0.19, 0.19, 31),
    },
    SuiteEntry {
        name: "ba-large",
        category: "Social Network",
        tier: Tier::Large,
        build: || gen::barabasi_albert(150_000, 10, 37),
    },
    // ---- XLA tier (fits the (4096, 64) bucket) ----
    SuiteEntry {
        name: "xla-grid",
        category: "Mesh/Road",
        tier: Tier::Xla,
        build: || gen::grid2d(64, 64),
    },
    SuiteEntry {
        name: "xla-caveman",
        category: "Collaboration",
        tier: Tier::Xla,
        build: || gen::caveman(512, 8, 41),
    },
    SuiteEntry {
        name: "xla-er",
        category: "Citation",
        tier: Tier::Xla,
        build: || gen::erdos_renyi(4_000, 12_000, 43),
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_partitions_by_tier() {
        assert!(!suite(Tier::Small).is_empty());
        assert!(suite(Tier::Standard).len() >= 8);
        assert!(!suite(Tier::Xla).is_empty());
    }

    #[test]
    fn small_entries_build_and_validate() {
        for e in suite(Tier::Small) {
            let g = e.build();
            assert_eq!(g.validate(), Ok(()), "{}", e.name);
            assert_eq!(g.name, e.name);
        }
    }

    #[test]
    fn xla_entries_fit_bucket() {
        for e in suite(Tier::Xla) {
            let g = e.build();
            assert!(g.num_vertices() <= 4096, "{}", e.name);
            assert!(g.max_degree() <= 64, "{} d_max={}", e.name, g.max_degree());
        }
    }

    #[test]
    fn by_name_finds() {
        assert!(by_name("g1").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn deterministic_rebuild() {
        let a = by_name("ba-small").unwrap().build();
        let b = by_name("ba-small").unwrap().build();
        assert_eq!(a, b);
    }
}
