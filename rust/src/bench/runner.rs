//! The measurement loop: warmup, repeated timed runs, one validated
//! instrumented run — so every number a bench prints is backed by an
//! oracle check and carries the paper's iteration/atomic counters.

use crate::core::traits::{DecompositionResult, Decomposer};
use crate::core::verify::check_against_oracle;
use crate::graph::CsrGraph;
use crate::util::timer::{Samples, Timer};

/// Measurement options (env-tunable for the bench binaries).
#[derive(Clone, Debug)]
pub struct BenchOptions {
    pub warmup: usize,
    pub reps: usize,
    pub threads: usize,
    /// Oracle-validate the first run (skipped for huge graphs if needed).
    pub validate: bool,
}

impl Default for BenchOptions {
    fn default() -> Self {
        let reps = std::env::var("PICO_BENCH_REPS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(3);
        Self {
            warmup: 1,
            reps,
            threads: crate::util::default_threads(),
            validate: true,
        }
    }
}

/// One algorithm × dataset measurement.
#[derive(Debug)]
pub struct Measurement {
    pub algorithm: String,
    pub dataset: String,
    pub samples: Samples,
    /// The instrumented (metrics-on) run's result.
    pub instrumented: DecompositionResult,
    pub validated: bool,
}

impl Measurement {
    /// The time a table row reports (min over reps — least scheduler noise).
    pub fn ms(&self) -> f64 {
        self.samples.min_ms()
    }
}

/// Measure `algo` on `g`: warmup, `reps` timed runs (metrics off), then
/// one instrumented run for the counters. Panics on oracle mismatch —
/// a bench must never report a wrong-answer time.
pub fn measure(algo: &dyn Decomposer, g: &CsrGraph, opts: &BenchOptions) -> Measurement {
    for _ in 0..opts.warmup {
        let r = algo.decompose_with(g, opts.threads, false);
        if opts.validate {
            if let Err(e) = check_against_oracle(g, &r.core) {
                panic!("{} produced wrong coreness on {}: {e}", algo.name(), g.name);
            }
        }
    }
    let mut samples = Samples::default();
    for _ in 0..opts.reps.max(1) {
        let t = Timer::start();
        let r = algo.decompose_with(g, opts.threads, false);
        samples.push(t.elapsed());
        std::hint::black_box(&r.core);
    }
    let instrumented = algo.decompose_with(g, opts.threads, true);
    Measurement {
        algorithm: algo.name().to_string(),
        dataset: g.name.clone(),
        samples,
        instrumented,
        validated: opts.validate,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::peel::PoDyn;
    use crate::graph::examples;

    #[test]
    fn measure_g1() {
        let g = examples::g1();
        let m = measure(
            &PoDyn,
            &g,
            &BenchOptions {
                warmup: 1,
                reps: 2,
                threads: 1,
                validate: true,
            },
        );
        assert_eq!(m.samples.runs.len(), 2);
        assert!(m.ms() >= 0.0);
        assert_eq!(m.instrumented.core, examples::g1_coreness());
    }

    #[test]
    #[should_panic(expected = "wrong coreness")]
    fn wrong_answer_panics() {
        struct Liar;
        impl Decomposer for Liar {
            fn name(&self) -> &'static str {
                "Liar"
            }
            fn paradigm(&self) -> crate::core::Paradigm {
                crate::core::Paradigm::Serial
            }
            fn decompose_with(
                &self,
                g: &CsrGraph,
                _t: usize,
                _m: bool,
            ) -> DecompositionResult {
                DecompositionResult {
                    core: vec![9; g.num_vertices()],
                    iterations: 0,
                    launches: 0,
                    metrics: Default::default(),
                }
            }
        }
        measure(&Liar, &examples::g1(), &BenchOptions::default());
    }
}
