//! Layer 3.7 — multi-host cluster serving: remote shards, replica
//! groups, and snapshot catch-up.
//!
//! Layer 3.6 sharded one graph across in-process indices; this layer
//! lets those shards live on other hosts. The PR 2 snapshot wire format
//! and length-prefixed binary protocol are the transport; the missing
//! piece was a remote-shard client — so the pieces are:
//!
//! * [`wire`] — payload codecs for the cluster verbs (routed batches,
//!   exchange rounds, shard manifests), validated as untrusted input
//!   through the shared [`crate::net::codec::Cursor`] (the magics live
//!   in [`crate::net::codec`] with every other wire magic).
//! * [`remote`] — [`remote::RemoteShard`]: a
//!   [`crate::shard::ShardBackend`] that drives a shard hosted by a
//!   remote `pico serve` over the binary protocol, one frame round trip
//!   per operation, on the shared reconnecting
//!   [`crate::net::client::FrameClient`] (re-dial of stale connections,
//!   `AUTH` preamble when the topology configures a token).
//! * [`host`] — [`host::ShardHost`]: the server side; wraps the same
//!   `LocalShard` the in-process router uses, hydrated from a shipped
//!   manifest (`SHARDHOST`) without recomputing anything.
//! * [`config`] — [`config::ClusterConfig`]: the TOML-style topology
//!   file behind `pico serve --cluster` / `pico cluster status`.
//! * [`index`] — [`index::ClusterIndex`]: the router. Same owner map,
//!   routed edits, and warm-started boundary-refinement merge as
//!   `ShardedIndex`, over any mix of local and remote shards; replica
//!   groups per shard with epoch-checked reads, failover, and
//!   journal-first catch-up ([`index::ClusterIndex::sync_replicas`]).
//! * [`journal`] — [`journal::EpochJournal`]: the bounded per-shard
//!   delta log behind incremental catch-up. Each published epoch
//!   records its routed batch plus the refined-coreness diff; a lagging
//!   replica replays the chain (`SHARDDELTA`) and ends byte-identical
//!   to the primary without recomputing, with full-manifest re-ship as
//!   the fallback for gaps, corruption, or chains larger than the
//!   manifest. The serve layer keeps replicas converged from a
//!   background daemon (`pico serve --sync-interval`) instead of on the
//!   flush path.
//!
//! * [`rebalance`] — the elastic-resharding planner/executor: turns
//!   the live per-shard load signals (state bytes, routed-edit heat,
//!   boundary-arc share, replica lag) into a bounded plan of splits and
//!   merges, each driven through the handoff primitive
//!   ([`index::ClusterIndex::move_vertices`]) under the flush fence;
//!   live primary migration ([`index::ClusterIndex::migrate_primary`])
//!   rides the same manifest-ship + delta-chain machinery with an
//!   epoch-verified fenced cutover. The `CLUSTER` admin namespace
//!   (`TOPOLOGY`, `REBALANCE PLAN|APPLY|MIGRATE`, `MOVES`) is the
//!   operator surface.
//!
//! A two-host walkthrough lives in `examples/serve_session.rs`; the
//! loopback-cluster-vs-oracle equivalence and the fault paths (dead
//! replicas, truncated connections, stale-epoch catch-up over both the
//! delta and full-ship paths, multi-process serving) are pinned by
//! `tests/cluster.rs`. Loopback remote-vs-local overhead per query
//! class, per merge round, and per catch-up path is measured by
//! `benches/cluster_overhead.rs`.

pub mod config;
pub mod host;
pub mod index;
pub mod journal;
pub mod rebalance;
pub mod remote;
pub mod wire;

pub use config::{ClusterConfig, Endpoint, ShardSpec};
pub use host::{manifest_for, ShardHost};
pub use index::{
    ClusterIndex, GroupStatus, MoveRecord, Primary, RebalanceBusy, ReplicaGroup, SyncReport,
    SyncStats,
};
pub use journal::{EpochDelta, EpochJournal, DEFAULT_JOURNAL_EPOCHS};
pub use rebalance::{PlannedMove, RebalancePlan, ShardLoad};
pub use remote::RemoteShard;
pub use wire::{HandoffPayload, HandoffVertex, ShardManifest};
