//! The cluster router: one logical core index spread over local and
//! remote shards, with replica groups per shard.
//!
//! A [`ClusterIndex`] is the multi-host sibling of
//! [`crate::shard::ShardedIndex`]: the same owner map, the same routed
//! edits, the same warm-started boundary-refinement merge
//! ([`crate::shard::router`]) — but each shard sits behind the
//! [`ShardBackend`] trait, so a shard may be an in-process
//! [`LocalShard`] or a [`RemoteShard`] driven over the binary protocol.
//! The published merged snapshot is byte-identical to a single
//! `CoreIndex` over the same graph (pinned by `tests/cluster.rs`).
//!
//! # Replica groups
//!
//! Each shard has one primary (which takes writes and refinement) and
//! any number of remote replicas hydrated from shard manifests — no
//! replica ever recomputes a decomposition. Reads fan out across
//! replicas round-robin; a reply is accepted only if its committed
//! cluster epoch matches the router's, so a stale replica (one that
//! missed a flush) is skipped — counted, not trusted — and a dead one
//! fails over. The primary is the authoritative fallback.
//! [`ClusterIndex::sync_replicas`] is catch-up: it probes every replica
//! and brings the stale ones to the published epoch — preferably by
//! replaying the per-shard **epoch journal**'s delta chain
//! ([`crate::cluster::journal`]: routed batch + refined-coreness diff
//! per epoch, so bytes scale with the edits, not the graph), falling
//! back to a full-manifest re-ship on any gap, rejection, or when the
//! chain would be larger than the manifest. Flushes never sync
//! replicas inline — the serve layer runs a background sync daemon
//! ([`crate::service::server::ReplicaSyncDaemon`]) instead, so flush
//! latency is independent of replica health.
//!
//! # Failure semantics
//!
//! A flush that errors midway (a remote primary died between the apply
//! and the merge) consumes its edits and surfaces the error; the caller
//! retries the flush after restoring the host — per-shard state is
//! always internally consistent because shard application and
//! refinement commits are atomic per shard. A failed flush also clears
//! the epoch journals and forces each replicated group through one
//! full-manifest re-ship before delta catch-up may resume: primaries
//! may then hold edits no published epoch accounts for, so a delta
//! chain built on top of them would diverge replicas silently.

use super::config::{ClusterConfig, Endpoint};
use super::host::manifest_for;
use super::journal::{EpochDelta, EpochJournal};
use super::remote::RemoteShard;
use super::wire;
use crate::core::maintenance::EdgeEdit;
use crate::graph::{CsrGraph, GraphBuilder, VertexId};
use crate::obs::{self, names, FlushStages, FlushTrace, Span};
use crate::service::batch::{coalesce, BatchConfig};
use crate::service::index::CoreSnapshot;
use crate::shard::backend::{LocalShard, ShardBackend, ShardStatus};
use crate::shard::partition::partition;
use crate::shard::router::{refine, refine_traced, route, MergeStats};
use crate::shard::ShardedOutcome;
use crate::util::timer::Timer;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// A shard's primary placement.
pub enum Primary {
    Local(Arc<LocalShard>),
    Remote(Arc<RemoteShard>),
}

impl Primary {
    fn backend(&self) -> Arc<dyn ShardBackend> {
        match self {
            Primary::Local(s) => s.clone() as Arc<dyn ShardBackend>,
            Primary::Remote(r) => r.clone() as Arc<dyn ShardBackend>,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Primary::Local(_) => "local",
            Primary::Remote(_) => "remote",
        }
    }

    fn addr(&self) -> String {
        match self {
            Primary::Local(_) => "local".into(),
            Primary::Remote(r) => r.addr().to_string(),
        }
    }

    /// The primary's current manifest (replica catch-up source).
    fn manifest(&self, num_shards: u32) -> Result<Vec<u8>> {
        match self {
            Primary::Local(s) => Ok(manifest_for(s, num_shards)),
            Primary::Remote(r) => r.fetch_manifest(),
        }
    }
}

/// Cumulative replica-sync counters for one group — what the daemon,
/// the `SHARDS` verb, and the tests observe.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SyncStats {
    /// Catch-ups served by a delta chain.
    pub deltas_shipped: u64,
    /// Catch-ups that re-shipped the full manifest.
    pub snapshots_shipped: u64,
    /// Bytes shipped over each path.
    pub delta_bytes: u64,
    pub snapshot_bytes: u64,
    /// Max replica lag (epochs behind the router) observed at the last
    /// sync probe; `want + 1` stands for "never committed / unreachable".
    pub lag_epochs: u64,
}

/// One shard's primary plus its read replicas.
pub struct ReplicaGroup {
    primary: Primary,
    backend: Arc<dyn ShardBackend>,
    replicas: Vec<Arc<RemoteShard>>,
    cursor: AtomicUsize,
    failovers: AtomicU64,
    stale_reads: AtomicU64,
    // replica-sync observability (see SyncStats)
    deltas_shipped: AtomicU64,
    snapshots_shipped: AtomicU64,
    delta_bytes: AtomicU64,
    snapshot_bytes: AtomicU64,
    lag_epochs: AtomicU64,
    /// Size of the last full manifest actually encoded for this group —
    /// the exact byte count a snapshot re-ship would cost, against which
    /// delta chains are compared (0 = none encoded yet: the first
    /// catch-up takes the full path and initialises it).
    manifest_bytes_hint: AtomicU64,
    /// Set when a flush died midway: the primary may then hold edits no
    /// published epoch (and no journal chain) accounts for, so every
    /// replica of the group — *including* ones whose committed epoch
    /// still matches, since epoch equality no longer implies state
    /// equality — must take one full-manifest re-ship before delta
    /// catch-up may resume. Cleared only after a sync pass full-ships
    /// the whole group without failures. Merely clearing the journal is
    /// not enough: the next successful flush would re-seed a contiguous
    /// chain starting exactly at the replicas' epoch, and a delta replay
    /// on top of the diverged base would silently break byte-identity.
    force_full_ship: AtomicBool,
}

impl ReplicaGroup {
    pub fn new(primary: Primary, replicas: Vec<Arc<RemoteShard>>) -> Self {
        let backend = primary.backend();
        Self {
            primary,
            backend,
            replicas,
            cursor: AtomicUsize::new(0),
            failovers: AtomicU64::new(0),
            stale_reads: AtomicU64::new(0),
            deltas_shipped: AtomicU64::new(0),
            snapshots_shipped: AtomicU64::new(0),
            delta_bytes: AtomicU64::new(0),
            snapshot_bytes: AtomicU64::new(0),
            lag_epochs: AtomicU64::new(0),
            manifest_bytes_hint: AtomicU64::new(0),
            force_full_ship: AtomicBool::new(false),
        }
    }

    pub fn backend(&self) -> &Arc<dyn ShardBackend> {
        &self.backend
    }

    /// `"local"` / `"remote"` — the primary's placement (no probing).
    pub fn kind(&self) -> &'static str {
        self.primary.kind()
    }

    /// The primary's endpoint for display (no probing).
    pub fn primary_addr(&self) -> String {
        self.primary.addr()
    }

    pub fn replicas(&self) -> &[Arc<RemoteShard>] {
        &self.replicas
    }

    /// Reads answered by a replica that failed over or was rejected as
    /// stale, cumulatively (observability + fault-path tests).
    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }

    pub fn stale_reads(&self) -> u64 {
        self.stale_reads.load(Ordering::Relaxed)
    }

    /// Cumulative replica-sync counters.
    pub fn sync_stats(&self) -> SyncStats {
        SyncStats {
            deltas_shipped: self.deltas_shipped.load(Ordering::Relaxed),
            snapshots_shipped: self.snapshots_shipped.load(Ordering::Relaxed),
            delta_bytes: self.delta_bytes.load(Ordering::Relaxed),
            snapshot_bytes: self.snapshot_bytes.load(Ordering::Relaxed),
            lag_epochs: self.lag_epochs.load(Ordering::Relaxed),
        }
    }

    /// The primary's current full manifest — the catch-up comparison
    /// baseline (tests pin delta-caught-up replicas byte-identical to
    /// it; benches read its size as the full-ship cost).
    pub fn primary_manifest(&self, num_shards: u32) -> Result<Vec<u8>> {
        self.primary.manifest(num_shards)
    }

    /// The primary's remote endpoint and hosted graph name when the
    /// shard lives on another host — the coordinator's `REDIRECT` hint
    /// for shard-local probes. `None` for in-coordinator primaries
    /// (answered inline; there is no host to redirect to).
    pub fn remote_primary(&self) -> Option<(String, String)> {
        match &self.primary {
            Primary::Remote(r) => Some((r.addr().to_string(), r.graph().to_string())),
            Primary::Local(_) => None,
        }
    }

    /// Run an epoch-stamped read: replicas round-robin first (accepting
    /// only answers committed at `want_epoch`), the primary as the
    /// authoritative fallback.
    pub fn read<T>(
        &self,
        want_epoch: u64,
        f: impl Fn(&dyn ShardBackend) -> Result<(T, u64)>,
    ) -> Result<T> {
        let n = self.replicas.len();
        if n > 0 {
            let start = self.cursor.fetch_add(1, Ordering::Relaxed);
            for i in 0..n {
                let r = &self.replicas[(start + i) % n];
                match f(r.as_ref()) {
                    Ok((val, ce)) if ce == want_epoch => return Ok(val),
                    Ok(_) => {
                        self.stale_reads.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        self.failovers.fetch_add(1, Ordering::Relaxed);
                        obs::events::emit(
                            obs::Severity::Warn,
                            obs::events::kind::REPLICA_FAILOVER,
                            "",
                            format!("replica={} read failed ({e:#}); trying next", r.addr()),
                        );
                    }
                }
            }
        }
        f(self.backend.as_ref()).map(|(v, _)| v)
    }
}

/// Probe results for `pico cluster status` / the `SHARDS` verb.
pub struct GroupStatus {
    pub shard: usize,
    pub kind: &'static str,
    pub primary_addr: String,
    /// `Err` carries the probe failure text (host down).
    pub primary: Result<ShardStatus, String>,
    /// Per-replica `(addr, status)`.
    pub replicas: Vec<(String, Result<ShardStatus, String>)>,
    pub failovers: u64,
    pub stale_reads: u64,
    /// Cumulative delta/snapshot catch-up counters for the group.
    pub sync: SyncStats,
}

/// What one [`ClusterIndex::sync_replicas`] pass did. Ship failures are
/// counted, not fatal — the background sync daemon has to outlive a
/// down host — with `first_error` carrying the first failure's text for
/// callers that want hard errors (initial build does).
#[derive(Debug, Default)]
pub struct SyncReport {
    /// Replicas caught up by a delta chain.
    pub deltas: usize,
    /// Replicas caught up by a full-manifest re-ship.
    pub snapshots: usize,
    pub delta_bytes: u64,
    pub snapshot_bytes: u64,
    /// Replicas that could not be caught up (host or primary down).
    pub failed: usize,
    /// Max lag observed across all groups (epochs; `epoch + 1` stands
    /// for never-committed/unreachable replicas).
    pub max_lag_epochs: u64,
    pub first_error: Option<String>,
}

impl SyncReport {
    /// Replicas brought up to date, over either path.
    pub fn shipped(&self) -> usize {
        self.deltas + self.snapshots
    }

    fn note_failure(&mut self, err: String) {
        self.failed += 1;
        self.first_error.get_or_insert(err);
    }
}

struct Published {
    global: Arc<CoreSnapshot>,
    merge: MergeStats,
    boundary_edges: u64,
}

/// A cluster-served core index: local/remote shards behind one router,
/// exact merged answers at every published epoch.
pub struct ClusterIndex {
    name: String,
    cfg: BatchConfig,
    groups: Vec<ReplicaGroup>,
    owner: Mutex<Vec<u32>>,
    published: RwLock<Arc<Published>>,
    epoch: AtomicU64,
    graph_cache: Mutex<Option<(u64, Arc<CsrGraph>)>>,
    pending: Mutex<Vec<EdgeEdit>>,
    /// When the oldest pending edit arrived — the flush's queue-wait
    /// stage. Lock order: always `pending` first.
    queued_since: Mutex<Option<Instant>>,
    flush_lock: Mutex<()>,
    /// Per-shard epoch journals (delta replica catch-up; bounded by the
    /// topology's `cluster.journal` retention).
    journals: Vec<Mutex<EpochJournal>>,
}

impl ClusterIndex {
    /// Partition `g` per the topology, place every shard (shipping
    /// manifests to remote primaries and replicas), run the initial
    /// merge, and bring replicas up to the committed epoch 0.
    pub fn build(g: &CsrGraph, topo: &ClusterConfig, cfg: BatchConfig) -> Result<Self> {
        let k = topo.num_shards();
        let plan = partition(g, k, topo.partition);
        // every dialer of this topology sends the AUTH preamble when a
        // token is configured — shard hosts run with the same token and
        // gate the shard verbs on it
        let auth = topo.effective_auth_token();
        let mut groups = Vec::with_capacity(k);
        for (i, spec) in topo.shards.iter().enumerate() {
            let local = Arc::new(LocalShard::from_plan(&topo.name, &plan.shards[i], cfg.clone()));
            let graph_name = topo.shard_graph(i);
            let primary = match &spec.primary {
                Endpoint::Local => Primary::Local(local),
                Endpoint::Remote(addr) => {
                    // the manifest is only serialised when it actually
                    // ships (an all-local topology encodes nothing)
                    let manifest = manifest_for(&local, k as u32);
                    let remote = Arc::new(
                        RemoteShard::new(i, addr.clone(), graph_name.clone())
                            .with_auth(auth.clone()),
                    );
                    remote
                        .host(&manifest)
                        .with_context(|| format!("shipping shard {i} to {addr}"))?;
                    Primary::Remote(remote)
                }
            };
            // replicas are NOT shipped here: they take no part in the
            // initial refinement, and shipping a pre-commit manifest
            // would give them an empty refined state. The
            // `sync_replicas` below ships the committed epoch-0 state
            // (an unhosted replica probes as stale).
            let replicas = spec
                .replicas
                .iter()
                .map(|addr| {
                    Arc::new(
                        RemoteShard::new(i, addr.clone(), graph_name.clone())
                            .with_auth(auth.clone()),
                    )
                })
                .collect();
            groups.push(ReplicaGroup::new(primary, replicas));
        }
        let backends: Vec<Arc<dyn ShardBackend>> =
            groups.iter().map(|gr| gr.backend.clone()).collect();
        let refined = refine(&backends, plan.owner.len(), None, 0, cfg.threads)
            .context("initial cluster refinement")?;
        let k_max = refined.core.iter().copied().max().unwrap_or(0);
        let journals = (0..groups.len())
            .map(|_| Mutex::new(EpochJournal::bounded(topo.journal_epochs, topo.journal_bytes)))
            .collect();
        let idx = Self {
            name: topo.name.clone(),
            cfg,
            groups,
            owner: Mutex::new(plan.owner),
            published: RwLock::new(Arc::new(Published {
                global: Arc::new(CoreSnapshot {
                    epoch: 0,
                    core: refined.core,
                    k_max,
                    num_edges: refined.num_edges,
                }),
                merge: refined.stats,
                boundary_edges: refined.boundary_edges,
            })),
            epoch: AtomicU64::new(0),
            graph_cache: Mutex::new(None),
            pending: Mutex::new(Vec::new()),
            queued_since: Mutex::new(None),
            flush_lock: Mutex::new(()),
            journals,
        };
        // the manifests shipped above predate the initial merge commit —
        // bring replicas to the committed epoch 0 state. Build is strict
        // where the sync daemon is tolerant: a replica that cannot be
        // hydrated now is a topology error the operator must see.
        let report = idx.sync_replicas().context("hydrating replicas at epoch 0")?;
        if report.failed > 0 {
            bail!(
                "hydrating replicas at epoch 0: {} replica(s) failed ({})",
                report.failed,
                report.first_error.as_deref().unwrap_or("unknown error")
            );
        }
        Ok(idx)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn num_shards(&self) -> usize {
        self.groups.len()
    }

    pub fn groups(&self) -> &[ReplicaGroup] {
        &self.groups
    }

    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// The merged global snapshot — identical in shape and content to a
    /// single `CoreIndex` snapshot over the same graph.
    pub fn snapshot(&self) -> Arc<CoreSnapshot> {
        self.published.read().unwrap().global.clone()
    }

    pub fn merge_stats(&self) -> MergeStats {
        self.published.read().unwrap().merge
    }

    pub fn boundary_edges(&self) -> u64 {
        self.published.read().unwrap().boundary_edges
    }

    /// Enqueue one edit; returns the pending count after the push.
    pub fn submit(&self, e: EdgeEdit) -> usize {
        let mut p = self.pending.lock().unwrap();
        if p.is_empty() {
            *self.queued_since.lock().unwrap() = Some(Instant::now());
        }
        p.push(e);
        p.len()
    }

    pub fn pending(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// Drain pending edits, route them to their primary shards, merge,
    /// publish one epoch, and journal the per-shard deltas for replica
    /// catch-up. Replicas are *not* synced here — that is
    /// [`Self::sync_replicas`]'s job, which the serve layer runs from
    /// its background sync daemon so the flush path never blocks on a
    /// slow or dead replica.
    pub fn flush(&self) -> Result<ShardedOutcome> {
        let _in_flight = self.flush_lock.lock().unwrap();
        let (edits, queued_at) = {
            let mut p = self.pending.lock().unwrap();
            let edits = std::mem::take(&mut *p);
            let queued_at = self.queued_since.lock().unwrap().take();
            (edits, queued_at)
        };
        if edits.is_empty() {
            return Ok(ShardedOutcome {
                snapshot: self.snapshot(),
                submitted: 0,
                applied: 0,
                coalesced: 0,
                changed: 0,
                recomputed_shards: 0,
                merge: MergeStats::default(),
                merge_elapsed: Duration::ZERO,
                elapsed: Duration::ZERO,
            });
        }
        let out = self.flush_inner(edits, queued_at);
        if let Err(e) = &out {
            obs::events::emit(
                obs::Severity::Error,
                obs::events::kind::FLUSH_FAILED,
                &self.name,
                format!("flush died mid-apply ({e:#}); journals cleared, full re-ship forced"),
            );
        }
        if out.is_err() {
            // A flush that died midway may leave primaries holding edits
            // no recorded chain (and no published epoch) reproduces.
            // Clear the journals AND force each replicated group through
            // one full-manifest re-ship — clearing alone would not do:
            // the next successful flush re-seeds a contiguous chain
            // starting at exactly the replicas' committed epoch, and a
            // delta replay on the diverged base would silently break the
            // byte-identity invariant (see ReplicaGroup::force_full_ship).
            for (j, gr) in self.journals.iter().zip(&self.groups) {
                j.lock().unwrap().clear();
                if !gr.replicas.is_empty() {
                    gr.force_full_ship.store(true, Ordering::SeqCst);
                }
            }
            // disarm any trace scopes the failed flush left armed, so
            // later reads through the same primaries go untagged
            for gr in &self.groups {
                if let Primary::Remote(r) = &gr.primary {
                    r.trace_scope().end();
                }
            }
        }
        out
    }

    fn flush_inner(
        &self,
        edits: Vec<EdgeEdit>,
        queued_at: Option<Instant>,
    ) -> Result<ShardedOutcome> {
        let ft = FlushTrace::new(obs::next_trace_id());
        let queue_wait = queued_at.map(|t| t.elapsed()).unwrap_or(Duration::ZERO);
        if let Some(t) = queued_at {
            // started before the trace: the offset saturates to 0, which
            // is exactly where the queue-wait stage belongs
            ft.stage("queue", t, queue_wait);
        }
        // arm the remote primaries' trace mailboxes: their shard verbs
        // now carry this flush's trace id, and the hosts' measured
        // handler times come back as remote child spans
        for gr in &self.groups {
            if let Primary::Remote(r) = &gr.primary {
                r.trace_scope().begin(ft.id(), ft.t0());
            }
        }
        let timer = Timer::start();
        let batch = coalesce(&edits);
        let applied = batch.len();
        // route under a short critical section, then release the owner
        // map before any network I/O: concurrent point reads route with
        // the (possibly grown) map and stay correct — epoch-checked
        // replica reads serve the old committed epoch, and not-yet-
        // refined vertices read as absent until the publish.
        let route_start = Instant::now();
        let (n, plan) = {
            let mut owner = self.owner.lock().unwrap();
            let plan = route(&mut owner, self.groups.len(), &batch);
            (owner.len(), plan)
        };
        let route_elapsed = route_start.elapsed();
        ft.stage("route", route_start, route_elapsed);
        let apply_start = Instant::now();
        let mut changed = 0usize;
        let mut recomputed_shards = 0usize;
        for (s, gr) in self.groups.iter().enumerate() {
            if !plan.touched[s] {
                continue;
            }
            let shard_start = Instant::now();
            let out = gr
                .backend
                .apply(&plan.per_shard[s])
                .with_context(|| format!("routed batch on shard {s} ({})", gr.primary.addr()))?;
            // coordinator-side wall time; a remote primary additionally
            // reports its own host-side span through the trace scope
            ft.child(
                "apply",
                Span {
                    name: format!("apply shard={s}"),
                    start_us: shard_start.saturating_duration_since(ft.t0()).as_micros() as u64,
                    dur_us: shard_start.elapsed().as_micros() as u64,
                    remote: None,
                    children: Vec::new(),
                },
            );
            changed += out.changed;
            if out.recomputed {
                recomputed_shards += 1;
            }
        }
        let apply_elapsed = apply_start.elapsed();
        ft.stage("apply", apply_start, apply_elapsed);
        let epoch = self.epoch.load(Ordering::SeqCst) + 1;
        let merge_timer = Timer::start();
        let backends: Vec<Arc<dyn ShardBackend>> =
            self.groups.iter().map(|gr| gr.backend.clone()).collect();
        let mut refined = refine_traced(
            &backends,
            n,
            Some(plan.inserts),
            epoch,
            self.cfg.threads,
            Some(&ft),
        )
        .context("cluster refinement")?;
        let merge_elapsed = merge_timer.elapsed();
        let merge = refined.stats;
        let (refine_elapsed, commit_elapsed) = (refined.refine_elapsed, refined.commit_elapsed);
        let k_max = refined.core.iter().copied().max().unwrap_or(0);
        // journal the epoch for delta catch-up — the routed batch plus
        // the commit's refined diff reproduce this epoch exactly on a
        // replica (only groups that actually have replicas pay for it)
        let mut plan = plan;
        for (s, gr) in self.groups.iter().enumerate() {
            if gr.replicas.is_empty() {
                continue;
            }
            self.journals[s].lock().unwrap().record(EpochDelta {
                to_epoch: epoch,
                batch: std::mem::take(&mut plan.per_shard[s]),
                diff: std::mem::take(&mut refined.diffs[s]),
            });
        }
        let publish_start = Instant::now();
        let snapshot = Arc::new(CoreSnapshot {
            epoch,
            core: refined.core,
            k_max,
            num_edges: refined.num_edges,
        });
        *self.published.write().unwrap() = Arc::new(Published {
            global: snapshot.clone(),
            merge,
            boundary_edges: refined.boundary_edges,
        });
        self.epoch.store(epoch, Ordering::SeqCst);
        let publish_elapsed = publish_start.elapsed();
        ft.stage("publish", publish_start, publish_elapsed);
        // stitch: drain the hosts' measured spans into this flush's
        // trace, nested under their stages with the remote addr kept
        for gr in &self.groups {
            if let Primary::Remote(r) = &gr.primary {
                for (stage, span) in r.trace_scope().end() {
                    ft.child(&stage, span);
                }
            }
        }
        let elapsed = timer.elapsed();
        obs::record_flush_stages(
            &self.name,
            &FlushStages {
                queue: queue_wait,
                route: route_elapsed,
                apply: apply_elapsed,
                refine: refine_elapsed,
                commit: commit_elapsed,
                publish: publish_elapsed,
                total: queue_wait + elapsed,
                refine_rounds: merge.rounds as u64,
                boundary_updates: merge.boundary_updates,
                boundary_bytes: merge.boundary_bytes,
                epoch,
            },
        );
        obs::record_trace(ft.finish("flush", &self.name));
        Ok(ShardedOutcome {
            snapshot,
            submitted: edits.len(),
            applied,
            coalesced: edits.len() - applied,
            changed,
            recomputed_shards,
            merge,
            merge_elapsed,
            elapsed,
        })
    }

    /// Catch every lagging replica up to the published epoch —
    /// incrementally where possible, by full re-ship otherwise.
    ///
    /// Per stale replica, the router prefers the journal's encoded delta
    /// chain when it exists **and** its encoding is smaller than a full
    /// manifest (compared against the last manifest this group actually
    /// encoded — exact bytes, refreshed on every full ship; an unknown
    /// size takes the full path once to initialise it). Any journal gap,
    /// size loss, or delta rejection falls back to re-shipping the
    /// primary's full manifest, which repairs whatever state the replica
    /// is in. Ship failures are counted per replica rather than aborting
    /// the pass — the background sync daemon has to outlive a dead host
    /// — and surface in the returned [`SyncReport`].
    pub fn sync_replicas(&self) -> Result<SyncReport> {
        let want = self.epoch();
        let num_shards = self.groups.len() as u32;
        let mut report = SyncReport::default();
        for (s, gr) in self.groups.iter().enumerate() {
            if gr.replicas.is_empty() {
                continue;
            }
            // mirror the group's sync counters into the registry — the
            // atomics behind sync_stats() stay authoritative for the
            // SHARDS verb, the registry feeds the scrapeable exposition
            let shard_label = s.to_string();
            let labels: &[(&str, &str)] = &[("graph", &self.name), ("shard", &shard_label)];
            let mut manifest: Option<Vec<u8>> = None;
            let mut primary_down = false;
            let mut group_lag = 0u64;
            let group_failed_before = report.failed;
            // after a failed flush, epoch equality no longer implies
            // state equality: ship the full manifest to every replica
            // of the group, deltas suspended (see force_full_ship docs)
            let forced = gr.force_full_ship.load(Ordering::SeqCst);
            for r in &gr.replicas {
                let committed = match r.status() {
                    Ok(st) => Some(st.cluster_epoch),
                    Err(_) => None, // down or not hosted yet: full ship
                };
                if !forced && committed == Some(want) {
                    continue;
                }
                group_lag = group_lag.max(match committed {
                    Some(e) if e == want => 0,
                    // the sentinel for never-committed (u64::MAX) and
                    // any ahead-of-router state both need a full ship
                    Some(e) if e <= want => want - e,
                    _ => want + 1,
                });
                let chain = committed
                    .filter(|&e| e < want && !forced)
                    .and_then(|e| self.journals[s].lock().unwrap().encode_chain(e, want));
                if let (Some(bytes), Some(from)) = (chain, committed) {
                    let hint = gr.manifest_bytes_hint.load(Ordering::Relaxed);
                    // a rejected or lost delta ship is not an error: the
                    // full-manifest path below repairs whatever state the
                    // replica is in
                    if hint > 0
                        && (bytes.len() as u64) < hint
                        && r.apply_delta(from, want, &bytes).is_ok()
                    {
                        gr.deltas_shipped.fetch_add(1, Ordering::Relaxed);
                        gr.delta_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
                        obs::global().counter(names::SYNC_DELTAS, labels).inc();
                        obs::global()
                            .counter(names::SYNC_DELTA_BYTES, labels)
                            .add(bytes.len() as u64);
                        report.deltas += 1;
                        report.delta_bytes += bytes.len() as u64;
                        continue;
                    }
                }
                if primary_down {
                    report.note_failure(format!(
                        "shard {} primary unreachable for catch-up",
                        gr.backend.id()
                    ));
                    continue;
                }
                if manifest.is_none() {
                    match gr.primary.manifest(num_shards) {
                        Ok(m) => {
                            gr.manifest_bytes_hint.store(m.len() as u64, Ordering::Relaxed);
                            manifest = Some(m);
                        }
                        Err(e) => {
                            primary_down = true;
                            report.note_failure(format!(
                                "pulling shard {} manifest for catch-up: {e:#}",
                                gr.backend.id()
                            ));
                            continue;
                        }
                    }
                }
                let m = manifest.as_ref().unwrap();
                match r.host(m) {
                    Ok(()) => {
                        gr.snapshots_shipped.fetch_add(1, Ordering::Relaxed);
                        gr.snapshot_bytes.fetch_add(m.len() as u64, Ordering::Relaxed);
                        obs::global().counter(names::SYNC_SNAPSHOTS, labels).inc();
                        obs::global()
                            .counter(names::SYNC_SNAPSHOT_BYTES, labels)
                            .add(m.len() as u64);
                        report.snapshots += 1;
                        report.snapshot_bytes += m.len() as u64;
                        // a replica with real committed state behind the
                        // head should have caught up by delta; a full
                        // ship there (or a forced one) is the fallback
                        // worth journaling — initial hydration is not
                        if forced || matches!(committed, Some(e) if e < want) {
                            obs::events::emit(
                                obs::Severity::Warn,
                                obs::events::kind::SYNC_FULL_SHIP,
                                &self.name,
                                format!(
                                    "replica={} shard={} bytes={}{}",
                                    r.addr(),
                                    gr.backend.id(),
                                    m.len(),
                                    if forced { " forced" } else { "" }
                                ),
                            );
                        }
                    }
                    Err(e) => report.note_failure(format!("ship to {}: {e:#}", r.addr())),
                }
            }
            gr.lag_epochs.store(group_lag, Ordering::Relaxed);
            obs::global().gauge(names::SYNC_LAG_EPOCHS, labels).set(group_lag);
            report.max_lag_epochs = report.max_lag_epochs.max(group_lag);
            if forced && report.failed == group_failed_before {
                // every replica of the group now holds the primary's
                // exact state again — deltas may resume
                gr.force_full_ship.store(false, Ordering::SeqCst);
            }
        }
        // publish how many replicas this pass failed to catch up — the
        // instantaneous signal behind HEALTH's replication rule — and
        // journal transitions only, not every daemon pass
        let failed_gauge =
            obs::global().gauge(names::SYNC_FAILED_REPLICAS, &[("graph", &self.name)]);
        let prev_failed = failed_gauge.get();
        failed_gauge.set(report.failed as u64);
        if report.failed > 0 && prev_failed != report.failed as u64 {
            obs::events::emit(
                obs::Severity::Error,
                obs::events::kind::SYNC_FAILED,
                &self.name,
                format!(
                    "{} replica(s) not caught up: {}",
                    report.failed,
                    report.first_error.as_deref().unwrap_or("unknown error")
                ),
            );
        }
        Ok(report)
    }

    /// The shard owning vertex `v`, if `v` is inside the cluster's
    /// vertex set — what the serve layer redirects shard-local probes
    /// with.
    pub fn owner_of(&self, v: VertexId) -> Option<usize> {
        self.owner
            .lock()
            .unwrap()
            .get(v as usize)
            .map(|&s| s as usize)
    }

    /// Routed point read: the owner shard's replica group answers, with
    /// epoch-checked failover (see module docs).
    pub fn coreness_routed(&self, v: VertexId) -> Result<Option<u32>> {
        let owner = self.owner.lock().unwrap().get(v as usize).copied();
        let Some(s) = owner else {
            return Ok(None);
        };
        let want = self.epoch();
        self.groups[s as usize].read(want, |b| b.refined_coreness(v))
    }

    /// Fan-out read: per-shard histograms summed cell-wise, padded to
    /// the published `k_max`.
    pub fn histogram_routed(&self) -> Result<Vec<u64>> {
        let want = self.epoch();
        let k_max = self.snapshot().k_max;
        let mut hist = vec![0u64; k_max as usize + 1];
        for gr in &self.groups {
            let part = gr.read(want, |b| b.histogram_partial())?;
            for (k, &c) in part.iter().enumerate() {
                if k >= hist.len() {
                    hist.resize(k + 1, 0);
                }
                hist[k] += c;
            }
        }
        Ok(hist)
    }

    /// Fan-out read: k-core members merged into the global ascending
    /// membership list.
    pub fn members_routed(&self, k: u32) -> Result<Vec<VertexId>> {
        let want = self.epoch();
        let mut out = Vec::new();
        for gr in &self.groups {
            out.extend(gr.read(want, |b| b.members_partial(k))?);
        }
        out.sort_unstable();
        Ok(out)
    }

    /// |k-core| from the fan-out histogram.
    pub fn kcore_size_routed(&self, k: u32) -> Result<usize> {
        let hist = self.histogram_routed()?;
        Ok(hist.iter().skip(k as usize).sum::<u64>() as usize)
    }

    /// Global degeneracy at the published epoch.
    pub fn degeneracy(&self) -> u32 {
        self.snapshot().k_max
    }

    /// Probe the whole topology (primaries and replicas).
    pub fn status(&self) -> Vec<GroupStatus> {
        self.groups
            .iter()
            .map(|gr| GroupStatus {
                shard: gr.backend.id(),
                kind: gr.primary.kind(),
                primary_addr: gr.primary.addr(),
                primary: gr.backend.status().map_err(|e| format!("{e:#}")),
                replicas: gr
                    .replicas
                    .iter()
                    .map(|r| {
                        (
                            r.addr().to_string(),
                            r.status().map_err(|e| format!("{e:#}")),
                        )
                    })
                    .collect(),
                failovers: gr.failovers(),
                stale_reads: gr.stale_reads(),
                sync: gr.sync_stats(),
            })
            .collect()
    }

    /// The encoded delta chain `(from, to]` for one shard, if the
    /// journal still holds it (benches read its size; `None` past the
    /// retention window or for an unjournalled shard).
    pub fn journal_chain_bytes(&self, shard: usize, from: u64, to: u64) -> Option<usize> {
        self.journals
            .get(shard)?
            .lock()
            .unwrap()
            .encode_chain(from, to)
            .map(|b| b.len())
    }

    /// Assembled global CSR at the current epoch (cached per epoch;
    /// remote shards ship their manifests). The heavyweight read.
    pub fn graph(&self) -> Result<Arc<CsrGraph>> {
        let _guard = self.flush_lock.lock().unwrap();
        self.graph_inner()
    }

    /// A mutually consistent (merged snapshot, assembled graph) pair.
    pub fn consistent_view(&self) -> Result<(Arc<CoreSnapshot>, Arc<CsrGraph>)> {
        let _guard = self.flush_lock.lock().unwrap();
        let snap = self.snapshot();
        let g = self.graph_inner()?;
        Ok((snap, g))
    }

    fn graph_inner(&self) -> Result<Arc<CsrGraph>> {
        let epoch = self.epoch.load(Ordering::SeqCst);
        {
            let cache = self.graph_cache.lock().unwrap();
            if let Some((e, g)) = cache.as_ref() {
                if *e == epoch {
                    return Ok(g.clone());
                }
            }
        }
        let n = self.owner.lock().unwrap().len();
        let mut b = GraphBuilder::new(n);
        for gr in &self.groups {
            match &gr.primary {
                Primary::Local(s) => {
                    for (u, v) in s.owned_edges() {
                        b.add_edge(u, v);
                    }
                }
                Primary::Remote(r) => {
                    let m = wire::decode_manifest(&r.fetch_manifest()?)
                        .with_context(|| format!("manifest from {}", r.addr()))?;
                    for &l in &m.owned_locals {
                        let gu = m.globals[l as usize];
                        for &w in m.snapshot.graph.neighbors(l) {
                            let gv = m.globals[w as usize];
                            if gu as usize >= n || gv as usize >= n {
                                bail!(
                                    "shard {} names vertex outside the cluster (|V|={n})",
                                    gr.backend.id()
                                );
                            }
                            b.add_edge(gu, gv);
                        }
                    }
                }
            }
        }
        let g = Arc::new(b.build(self.name.as_str()));
        *self.graph_cache.lock().unwrap() = Some((epoch, g.clone()));
        Ok(g)
    }
}

impl std::fmt::Debug for ClusterIndex {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        let remote = self
            .groups
            .iter()
            .filter(|g| matches!(g.primary, Primary::Remote(_)))
            .count();
        write!(
            f,
            "ClusterIndex({} x{} [{} remote] @ epoch {}: |V|={}, |E|={}, k_max={})",
            self.name,
            self.groups.len(),
            remote,
            s.epoch,
            s.num_vertices(),
            s.num_edges,
            s.k_max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::gen;
    use crate::service::index::CoreIndex;

    fn all_local(name: &str, shards: usize) -> ClusterConfig {
        let mut text = format!("[cluster]\nname = {name}\nshards = {shards}\n");
        for i in 0..shards {
            text.push_str(&format!("[shard.{i}]\nprimary = local\n"));
        }
        ClusterConfig::parse(&text).unwrap()
    }

    fn cfg() -> BatchConfig {
        BatchConfig {
            threads: 1,
            ..BatchConfig::default()
        }
    }

    #[test]
    fn all_local_cluster_matches_the_oracle() {
        let g = gen::barabasi_albert(200, 3, 31);
        let single = CoreIndex::new("single", &g);
        let cl = ClusterIndex::build(&g, &all_local("c", 4), cfg()).unwrap();
        let want = single.snapshot();
        assert_eq!(cl.snapshot().core, want.core);
        assert_eq!(cl.snapshot().num_edges, want.num_edges);
        assert_eq!(cl.degeneracy(), want.degeneracy());
        assert_eq!(cl.histogram_routed().unwrap(), want.histogram());
        for v in 0..g.num_vertices() as u32 {
            assert_eq!(cl.coreness_routed(v).unwrap(), want.coreness(v), "v{v}");
        }
        assert_eq!(cl.coreness_routed(g.num_vertices() as u32).unwrap(), None);
        for k in 0..=want.k_max {
            assert_eq!(cl.members_routed(k).unwrap(), want.kcore_members(k));
            assert_eq!(cl.kcore_size_routed(k).unwrap(), want.kcore_size(k));
        }
        let (snap, graph) = cl.consistent_view().unwrap();
        assert_eq!(snap.core, bz_coreness(&graph));
    }

    #[test]
    fn edits_flow_and_epochs_advance() {
        let g = gen::erdos_renyi(100, 300, 7);
        let cl = ClusterIndex::build(&g, &all_local("c", 3), cfg()).unwrap();
        cl.submit(EdgeEdit::Insert(0, 50));
        cl.submit(EdgeEdit::Insert(150, 160)); // grows the vertex set
        let out = cl.flush().unwrap();
        assert_eq!(out.snapshot.epoch, 1);
        assert_eq!(cl.epoch(), 1);
        assert_eq!(out.snapshot.num_vertices(), 161);
        let (snap, graph) = cl.consistent_view().unwrap();
        assert_eq!(snap.core, bz_coreness(&graph));
        assert_eq!(cl.coreness_routed(155).unwrap(), Some(0));
        // empty flush publishes nothing
        assert_eq!(cl.flush().unwrap().submitted, 0);
        assert_eq!(cl.epoch(), 1);
        // no replicas configured: nothing to sync
        let report = cl.sync_replicas().unwrap();
        assert_eq!(report.shipped(), 0);
        assert_eq!(report.failed, 0);
    }

    #[test]
    fn status_covers_every_group() {
        let g = gen::erdos_renyi(60, 150, 3);
        let cl = ClusterIndex::build(&g, &all_local("c", 2), cfg()).unwrap();
        let st = cl.status();
        assert_eq!(st.len(), 2);
        for (i, gs) in st.iter().enumerate() {
            assert_eq!(gs.shard, i);
            assert_eq!(gs.kind, "local");
            let p = gs.primary.as_ref().unwrap();
            assert_eq!(p.cluster_epoch, 0);
            assert!(gs.replicas.is_empty());
        }
    }
}
