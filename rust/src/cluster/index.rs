//! The cluster router: one logical core index spread over local and
//! remote shards, with replica groups per shard.
//!
//! A [`ClusterIndex`] is the multi-host sibling of
//! [`crate::shard::ShardedIndex`]: the same owner map, the same routed
//! edits, the same warm-started boundary-refinement merge
//! ([`crate::shard::router`]) — but each shard sits behind the
//! [`ShardBackend`] trait, so a shard may be an in-process
//! [`LocalShard`] or a [`RemoteShard`] driven over the binary protocol.
//! The published merged snapshot is byte-identical to a single
//! `CoreIndex` over the same graph (pinned by `tests/cluster.rs`).
//!
//! # Replica groups
//!
//! Each shard has one primary (which takes writes and refinement) and
//! any number of remote replicas hydrated from shard manifests — no
//! replica ever recomputes a decomposition. Reads fan out across
//! replicas round-robin; a reply is accepted only if its committed
//! cluster epoch matches the router's, so a stale replica (one that
//! missed a flush) is skipped — counted, not trusted — and a dead one
//! fails over. The primary is the authoritative fallback.
//! [`ClusterIndex::sync_replicas`] is catch-up: it probes every replica
//! and brings the stale ones to the published epoch — preferably by
//! replaying the per-shard **epoch journal**'s delta chain
//! ([`crate::cluster::journal`]: routed batch + refined-coreness diff
//! per epoch, so bytes scale with the edits, not the graph), falling
//! back to a full-manifest re-ship on any gap, rejection, or when the
//! chain would be larger than the manifest. Flushes never sync
//! replicas inline — the serve layer runs a background sync daemon
//! ([`crate::service::server::ReplicaSyncDaemon`]) instead, so flush
//! latency is independent of replica health.
//!
//! # Failure semantics
//!
//! A flush that errors midway (a remote primary died between the apply
//! and the merge) consumes its edits and surfaces the error; the caller
//! retries the flush after restoring the host — per-shard state is
//! always internally consistent because shard application and
//! refinement commits are atomic per shard. A failed flush also clears
//! the epoch journals and forces each replicated group through one
//! full-manifest re-ship before delta catch-up may resume: primaries
//! may then hold edits no published epoch accounts for, so a delta
//! chain built on top of them would diverge replicas silently.

use super::config::{ClusterConfig, Endpoint};
use super::host::manifest_for;
use super::journal::{EpochDelta, EpochJournal};
use super::remote::RemoteShard;
use super::wire;
use crate::core::maintenance::EdgeEdit;
use crate::graph::{CsrGraph, GraphBuilder, VertexId};
use crate::obs::{self, names, FlushStages, FlushTrace, Span};
use crate::service::batch::{coalesce, BatchConfig};
use crate::service::index::CoreSnapshot;
use crate::shard::backend::{LocalShard, ShardBackend, ShardStatus};
use crate::shard::partition::partition;
use crate::shard::router::{refine, refine_traced, route, MergeStats};
use crate::shard::ShardedOutcome;
use crate::util::timer::Timer;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// A shard's primary placement.
pub enum Primary {
    Local(Arc<LocalShard>),
    Remote(Arc<RemoteShard>),
}

impl Primary {
    fn backend(&self) -> Arc<dyn ShardBackend> {
        match self {
            Primary::Local(s) => s.clone() as Arc<dyn ShardBackend>,
            Primary::Remote(r) => r.clone() as Arc<dyn ShardBackend>,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Primary::Local(_) => "local",
            Primary::Remote(_) => "remote",
        }
    }

    fn addr(&self) -> String {
        match self {
            Primary::Local(_) => "local".into(),
            Primary::Remote(r) => r.addr().to_string(),
        }
    }

    /// The primary's current manifest (replica catch-up source).
    fn manifest(&self, num_shards: u32) -> Result<Vec<u8>> {
        match self {
            Primary::Local(s) => Ok(manifest_for(s, num_shards)),
            Primary::Remote(r) => r.fetch_manifest(),
        }
    }
}

/// Cumulative replica-sync counters for one group — what the daemon,
/// the `SHARDS` verb, and the tests observe.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SyncStats {
    /// Catch-ups served by a delta chain.
    pub deltas_shipped: u64,
    /// Catch-ups that re-shipped the full manifest.
    pub snapshots_shipped: u64,
    /// Bytes shipped over each path.
    pub delta_bytes: u64,
    pub snapshot_bytes: u64,
    /// Max replica lag (epochs behind the router) observed at the last
    /// sync probe; `want + 1` stands for "never committed / unreachable".
    pub lag_epochs: u64,
}

/// One shard's primary plus its read replicas. The primary is
/// *swappable*: a live migration ([`ClusterIndex::migrate_primary`])
/// replaces it under the flush fence while reads keep flowing, so both
/// the placement and the erased backend handle sit behind locks —
/// [`ReplicaGroup::backend`] hands out an owned `Arc` clone, never a
/// borrow into the lock.
pub struct ReplicaGroup {
    primary: RwLock<Primary>,
    backend: RwLock<Arc<dyn ShardBackend>>,
    replicas: Vec<Arc<RemoteShard>>,
    cursor: AtomicUsize,
    failovers: AtomicU64,
    stale_reads: AtomicU64,
    // replica-sync observability (see SyncStats)
    deltas_shipped: AtomicU64,
    snapshots_shipped: AtomicU64,
    delta_bytes: AtomicU64,
    snapshot_bytes: AtomicU64,
    lag_epochs: AtomicU64,
    /// Size of the last full manifest actually encoded for this group —
    /// the exact byte count a snapshot re-ship would cost, against which
    /// delta chains are compared (0 = none encoded yet: the first
    /// catch-up takes the full path and initialises it).
    manifest_bytes_hint: AtomicU64,
    /// The hint above no longer matches the primary's state: ownership
    /// changed under it (a flush registered new vertices, a rebalance
    /// moved some, a migration swapped the primary). The next sync pass
    /// re-probes the primary for the exact size *before* comparing delta
    /// chains against it — shipping against a stale hint was the bug
    /// where a grown shard kept taking the (now mis-sized) delta path.
    hint_stale: AtomicBool,
    /// A primary migration is in flight for this shard: flushes must
    /// journal this group's deltas even with zero replicas, because the
    /// mover catches up over exactly those chains.
    migrating: AtomicBool,
    /// Routed edits this group's primary has applied, cumulatively —
    /// the rebalance planner's heat signal.
    edits_routed: AtomicU64,
    /// This shard's boundary-arc count at the last refinement — the
    /// planner's boundary-edge-share signal (cached off
    /// [`crate::shard::router::RefineOutcome::per_shard_boundary_arcs`]).
    boundary_arcs: AtomicU64,
    /// Set when a flush died midway: the primary may then hold edits no
    /// published epoch (and no journal chain) accounts for, so every
    /// replica of the group — *including* ones whose committed epoch
    /// still matches, since epoch equality no longer implies state
    /// equality — must take one full-manifest re-ship before delta
    /// catch-up may resume. Cleared only after a sync pass full-ships
    /// the whole group without failures. Merely clearing the journal is
    /// not enough: the next successful flush would re-seed a contiguous
    /// chain starting exactly at the replicas' epoch, and a delta replay
    /// on top of the diverged base would silently break byte-identity.
    force_full_ship: AtomicBool,
}

impl ReplicaGroup {
    pub fn new(primary: Primary, replicas: Vec<Arc<RemoteShard>>) -> Self {
        let backend = primary.backend();
        Self {
            primary: RwLock::new(primary),
            backend: RwLock::new(backend),
            replicas,
            cursor: AtomicUsize::new(0),
            failovers: AtomicU64::new(0),
            stale_reads: AtomicU64::new(0),
            deltas_shipped: AtomicU64::new(0),
            snapshots_shipped: AtomicU64::new(0),
            delta_bytes: AtomicU64::new(0),
            snapshot_bytes: AtomicU64::new(0),
            lag_epochs: AtomicU64::new(0),
            manifest_bytes_hint: AtomicU64::new(0),
            hint_stale: AtomicBool::new(false),
            migrating: AtomicBool::new(false),
            edits_routed: AtomicU64::new(0),
            boundary_arcs: AtomicU64::new(0),
            force_full_ship: AtomicBool::new(false),
        }
    }

    /// The current primary's erased handle (an owned clone — the
    /// primary may be swapped by a migration the moment this returns,
    /// but the clone stays valid for the caller's whole operation).
    pub fn backend(&self) -> Arc<dyn ShardBackend> {
        self.backend.read().unwrap().clone()
    }

    /// Swap the primary (migration cutover). Callers hold the flush
    /// fence: no flush, merge, or journal write may interleave.
    fn set_primary(&self, primary: Primary) {
        let backend = primary.backend();
        *self.backend.write().unwrap() = backend;
        *self.primary.write().unwrap() = primary;
        self.hint_stale.store(true, Ordering::SeqCst);
    }

    /// `"local"` / `"remote"` — the primary's placement (no probing).
    pub fn kind(&self) -> &'static str {
        self.primary.read().unwrap().kind()
    }

    /// The primary's endpoint for display (no probing).
    pub fn primary_addr(&self) -> String {
        self.primary.read().unwrap().addr()
    }

    pub fn replicas(&self) -> &[Arc<RemoteShard>] {
        &self.replicas
    }

    /// Reads answered by a replica that failed over or was rejected as
    /// stale, cumulatively (observability + fault-path tests).
    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }

    pub fn stale_reads(&self) -> u64 {
        self.stale_reads.load(Ordering::Relaxed)
    }

    /// Whether a primary migration is in flight for this shard.
    pub fn migrating(&self) -> bool {
        self.migrating.load(Ordering::SeqCst)
    }

    /// Routed edits applied by this group's primary, cumulatively.
    pub fn edits_routed(&self) -> u64 {
        self.edits_routed.load(Ordering::Relaxed)
    }

    /// Boundary arcs this shard contributed at the last refinement.
    pub fn boundary_arcs(&self) -> u64 {
        self.boundary_arcs.load(Ordering::Relaxed)
    }

    /// The full-ship byte size the delta/snapshot comparison currently
    /// uses (exact after any sync pass that followed an ownership
    /// change — the hint-refresh tests pin this against
    /// [`Self::primary_manifest`]).
    pub fn manifest_bytes_hint(&self) -> u64 {
        self.manifest_bytes_hint.load(Ordering::Relaxed)
    }

    /// Cumulative replica-sync counters.
    pub fn sync_stats(&self) -> SyncStats {
        SyncStats {
            deltas_shipped: self.deltas_shipped.load(Ordering::Relaxed),
            snapshots_shipped: self.snapshots_shipped.load(Ordering::Relaxed),
            delta_bytes: self.delta_bytes.load(Ordering::Relaxed),
            snapshot_bytes: self.snapshot_bytes.load(Ordering::Relaxed),
            lag_epochs: self.lag_epochs.load(Ordering::Relaxed),
        }
    }

    /// The primary's current full manifest — the catch-up comparison
    /// baseline (tests pin delta-caught-up replicas byte-identical to
    /// it; benches read its size as the full-ship cost).
    pub fn primary_manifest(&self, num_shards: u32) -> Result<Vec<u8>> {
        self.primary.read().unwrap().manifest(num_shards)
    }

    /// The primary's remote endpoint and hosted graph name when the
    /// shard lives on another host — the coordinator's `REDIRECT` hint
    /// for shard-local probes. `None` for in-coordinator primaries
    /// (answered inline; there is no host to redirect to).
    pub fn remote_primary(&self) -> Option<(String, String)> {
        match &*self.primary.read().unwrap() {
            Primary::Remote(r) => Some((r.addr().to_string(), r.graph().to_string())),
            Primary::Local(_) => None,
        }
    }

    /// The remote primary's trace-scope handle, when it has one.
    fn remote_trace(&self) -> Option<Arc<RemoteShard>> {
        match &*self.primary.read().unwrap() {
            Primary::Remote(r) => Some(r.clone()),
            Primary::Local(_) => None,
        }
    }

    /// Run an epoch-stamped read: replicas round-robin first (accepting
    /// only answers committed at `want_epoch`), the primary as the
    /// authoritative fallback. Coded `STALE_EPOCH` rejections count as
    /// stale reads, not failovers — the replica is healthy, merely
    /// behind (or fenced mid-move); everything else is a failover.
    pub fn read<T>(
        &self,
        want_epoch: u64,
        f: impl Fn(&dyn ShardBackend) -> Result<(T, u64)>,
    ) -> Result<T> {
        let n = self.replicas.len();
        if n > 0 {
            let start = self.cursor.fetch_add(1, Ordering::Relaxed);
            for i in 0..n {
                let r = &self.replicas[(start + i) % n];
                match f(r.as_ref()) {
                    Ok((val, ce)) if ce == want_epoch => return Ok(val),
                    Ok(_) => {
                        self.stale_reads.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e)
                        if crate::net::client::remote_err_code(&e)
                            == Some(crate::net::client::ErrCode::StaleEpoch) =>
                    {
                        self.stale_reads.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        self.failovers.fetch_add(1, Ordering::Relaxed);
                        obs::events::emit(
                            obs::Severity::Warn,
                            obs::events::kind::REPLICA_FAILOVER,
                            "",
                            format!("replica={} read failed ({e:#}); trying next", r.addr()),
                        );
                    }
                }
            }
        }
        f(self.backend().as_ref()).map(|(v, _)| v)
    }
}

/// Probe results for `pico cluster status` / the `SHARDS` verb.
pub struct GroupStatus {
    pub shard: usize,
    pub kind: &'static str,
    pub primary_addr: String,
    /// `Err` carries the probe failure text (host down).
    pub primary: Result<ShardStatus, String>,
    /// Per-replica `(addr, status)`.
    pub replicas: Vec<(String, Result<ShardStatus, String>)>,
    pub failovers: u64,
    pub stale_reads: u64,
    /// Cumulative delta/snapshot catch-up counters for the group.
    pub sync: SyncStats,
}

/// What one [`ClusterIndex::sync_replicas`] pass did. Ship failures are
/// counted, not fatal — the background sync daemon has to outlive a
/// down host — with `first_error` carrying the first failure's text for
/// callers that want hard errors (initial build does).
#[derive(Debug, Default)]
pub struct SyncReport {
    /// Replicas caught up by a delta chain.
    pub deltas: usize,
    /// Replicas caught up by a full-manifest re-ship.
    pub snapshots: usize,
    pub delta_bytes: u64,
    pub snapshot_bytes: u64,
    /// Replicas that could not be caught up (host or primary down).
    pub failed: usize,
    /// Max lag observed across all groups (epochs; `epoch + 1` stands
    /// for never-committed/unreachable replicas).
    pub max_lag_epochs: u64,
    pub first_error: Option<String>,
}

impl SyncReport {
    /// Replicas brought up to date, over either path.
    pub fn shipped(&self) -> usize {
        self.deltas + self.snapshots
    }

    fn note_failure(&mut self, err: String) {
        self.failed += 1;
        self.first_error.get_or_insert(err);
    }
}

struct Published {
    global: Arc<CoreSnapshot>,
    merge: MergeStats,
    boundary_edges: u64,
}

/// One completed rebalance step, kept in [`ClusterIndex::moves`]'s
/// bounded history ring (the `CLUSTER MOVES` verb renders it).
#[derive(Clone, Debug)]
pub struct MoveRecord {
    /// `"split"`, `"merge"`, or `"migrate"`.
    pub kind: &'static str,
    /// Source shard.
    pub from: usize,
    /// Destination: `shard<i>` for vertex moves, the host address for a
    /// primary migration.
    pub to: String,
    /// Vertices whose ownership moved (0 for a migration).
    pub vertices: usize,
    /// Payload bytes shipped (handoff or manifest + delta chains).
    pub bytes: u64,
    /// Wall time spent under the flush fence — the cutover pause writers
    /// actually observed.
    pub cutover_us: u64,
    /// The cluster epoch published by (or current at) the move.
    pub epoch: u64,
    /// Wall-clock completion time (ms since the Unix epoch).
    pub unix_ms: u64,
}

/// Completed moves kept in the history ring.
const MOVE_HISTORY: usize = 64;

/// Wall-clock now, as ms since the Unix epoch (0 on a clock before it).
fn now_unix_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// `rebalance_apply` / `migrate_primary` refused because another
/// rebalance is already in flight — one structural change at a time.
/// The serve layer downcasts to this to answer `ERR MIGRATING`.
#[derive(Debug)]
pub struct RebalanceBusy;

impl std::fmt::Display for RebalanceBusy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "a rebalance is already in flight (one at a time)")
    }
}

impl std::error::Error for RebalanceBusy {}

/// RAII reset for the one-at-a-time rebalance latch.
struct BusyGuard<'a>(&'a AtomicBool);

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        self.0.store(false, Ordering::SeqCst);
    }
}

/// A cluster-served core index: local/remote shards behind one router,
/// exact merged answers at every published epoch.
pub struct ClusterIndex {
    name: String,
    cfg: BatchConfig,
    groups: Vec<ReplicaGroup>,
    owner: Mutex<Vec<u32>>,
    published: RwLock<Arc<Published>>,
    epoch: AtomicU64,
    graph_cache: Mutex<Option<(u64, Arc<CsrGraph>)>>,
    pending: Mutex<Vec<EdgeEdit>>,
    /// When the oldest pending edit arrived — the flush's queue-wait
    /// stage. Lock order: always `pending` first.
    queued_since: Mutex<Option<Instant>>,
    flush_lock: Mutex<()>,
    /// Per-shard epoch journals (delta replica catch-up; bounded by the
    /// topology's `cluster.journal` retention).
    journals: Vec<Mutex<EpochJournal>>,
    /// The topology's auth token — every dialer this router creates
    /// later (migration targets included) must send the same preamble
    /// the build-time dialers did.
    auth: Option<String>,
    /// One structural change (rebalance apply / migration) at a time.
    rebalancing: AtomicBool,
    /// Completed [`MoveRecord`]s, newest last, bounded at
    /// [`MOVE_HISTORY`].
    moves: Mutex<Vec<MoveRecord>>,
}

impl ClusterIndex {
    /// Partition `g` per the topology, place every shard (shipping
    /// manifests to remote primaries and replicas), run the initial
    /// merge, and bring replicas up to the committed epoch 0.
    pub fn build(g: &CsrGraph, topo: &ClusterConfig, cfg: BatchConfig) -> Result<Self> {
        let k = topo.num_shards();
        let plan = partition(g, k, topo.partition);
        // every dialer of this topology sends the AUTH preamble when a
        // token is configured — shard hosts run with the same token and
        // gate the shard verbs on it
        let auth = topo.effective_auth_token();
        let mut groups = Vec::with_capacity(k);
        for (i, spec) in topo.shards.iter().enumerate() {
            let local = Arc::new(LocalShard::from_plan(&topo.name, &plan.shards[i], cfg.clone()));
            let graph_name = topo.shard_graph(i);
            let primary = match &spec.primary {
                Endpoint::Local => Primary::Local(local),
                Endpoint::Remote(addr) => {
                    // the manifest is only serialised when it actually
                    // ships (an all-local topology encodes nothing)
                    let manifest = manifest_for(&local, k as u32);
                    let remote = Arc::new(
                        RemoteShard::new(i, addr.clone(), graph_name.clone())
                            .with_auth(auth.clone()),
                    );
                    remote
                        .host(&manifest)
                        .with_context(|| format!("shipping shard {i} to {addr}"))?;
                    Primary::Remote(remote)
                }
            };
            // replicas are NOT shipped here: they take no part in the
            // initial refinement, and shipping a pre-commit manifest
            // would give them an empty refined state. The
            // `sync_replicas` below ships the committed epoch-0 state
            // (an unhosted replica probes as stale).
            let replicas = spec
                .replicas
                .iter()
                .map(|addr| {
                    Arc::new(
                        RemoteShard::new(i, addr.clone(), graph_name.clone())
                            .with_auth(auth.clone()),
                    )
                })
                .collect();
            groups.push(ReplicaGroup::new(primary, replicas));
        }
        let backends: Vec<Arc<dyn ShardBackend>> = groups.iter().map(|gr| gr.backend()).collect();
        let refined = refine(&backends, plan.owner.len(), None, 0, cfg.threads)
            .context("initial cluster refinement")?;
        let k_max = refined.core.iter().copied().max().unwrap_or(0);
        let journals = (0..groups.len())
            .map(|_| Mutex::new(EpochJournal::bounded(topo.journal_epochs, topo.journal_bytes)))
            .collect();
        let idx = Self {
            name: topo.name.clone(),
            cfg,
            groups,
            owner: Mutex::new(plan.owner),
            published: RwLock::new(Arc::new(Published {
                global: Arc::new(CoreSnapshot {
                    epoch: 0,
                    core: refined.core,
                    k_max,
                    num_edges: refined.num_edges,
                }),
                merge: refined.stats,
                boundary_edges: refined.boundary_edges,
            })),
            epoch: AtomicU64::new(0),
            graph_cache: Mutex::new(None),
            pending: Mutex::new(Vec::new()),
            queued_since: Mutex::new(None),
            flush_lock: Mutex::new(()),
            journals,
            auth,
            rebalancing: AtomicBool::new(false),
            moves: Mutex::new(Vec::new()),
        };
        // the manifests shipped above predate the initial merge commit —
        // bring replicas to the committed epoch 0 state. Build is strict
        // where the sync daemon is tolerant: a replica that cannot be
        // hydrated now is a topology error the operator must see.
        let report = idx.sync_replicas().context("hydrating replicas at epoch 0")?;
        if report.failed > 0 {
            bail!(
                "hydrating replicas at epoch 0: {} replica(s) failed ({})",
                report.failed,
                report.first_error.as_deref().unwrap_or("unknown error")
            );
        }
        Ok(idx)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn num_shards(&self) -> usize {
        self.groups.len()
    }

    pub fn groups(&self) -> &[ReplicaGroup] {
        &self.groups
    }

    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// The merged global snapshot — identical in shape and content to a
    /// single `CoreIndex` snapshot over the same graph.
    pub fn snapshot(&self) -> Arc<CoreSnapshot> {
        self.published.read().unwrap().global.clone()
    }

    pub fn merge_stats(&self) -> MergeStats {
        self.published.read().unwrap().merge
    }

    pub fn boundary_edges(&self) -> u64 {
        self.published.read().unwrap().boundary_edges
    }

    /// Enqueue one edit; returns the pending count after the push.
    pub fn submit(&self, e: EdgeEdit) -> usize {
        let mut p = self.pending.lock().unwrap();
        if p.is_empty() {
            *self.queued_since.lock().unwrap() = Some(Instant::now());
        }
        p.push(e);
        p.len()
    }

    pub fn pending(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// Drain pending edits, route them to their primary shards, merge,
    /// publish one epoch, and journal the per-shard deltas for replica
    /// catch-up. Replicas are *not* synced here — that is
    /// [`Self::sync_replicas`]'s job, which the serve layer runs from
    /// its background sync daemon so the flush path never blocks on a
    /// slow or dead replica.
    pub fn flush(&self) -> Result<ShardedOutcome> {
        let _in_flight = self.flush_lock.lock().unwrap();
        let (edits, queued_at) = {
            let mut p = self.pending.lock().unwrap();
            let edits = std::mem::take(&mut *p);
            let queued_at = self.queued_since.lock().unwrap().take();
            (edits, queued_at)
        };
        if edits.is_empty() {
            return Ok(ShardedOutcome {
                snapshot: self.snapshot(),
                submitted: 0,
                applied: 0,
                coalesced: 0,
                changed: 0,
                recomputed_shards: 0,
                merge: MergeStats::default(),
                merge_elapsed: Duration::ZERO,
                elapsed: Duration::ZERO,
            });
        }
        let out = self.flush_inner(edits, queued_at);
        if let Err(e) = &out {
            obs::events::emit(
                obs::Severity::Error,
                obs::events::kind::FLUSH_FAILED,
                &self.name,
                format!("flush died mid-apply ({e:#}); journals cleared, full re-ship forced"),
            );
        }
        if out.is_err() {
            // A flush that died midway may leave primaries holding edits
            // no recorded chain (and no published epoch) reproduces.
            // Clear the journals AND force each replicated group through
            // one full-manifest re-ship — clearing alone would not do:
            // the next successful flush re-seeds a contiguous chain
            // starting at exactly the replicas' committed epoch, and a
            // delta replay on the diverged base would silently break the
            // byte-identity invariant (see ReplicaGroup::force_full_ship).
            for (j, gr) in self.journals.iter().zip(&self.groups) {
                j.lock().unwrap().clear();
                if !gr.replicas.is_empty() {
                    gr.force_full_ship.store(true, Ordering::SeqCst);
                }
            }
            // disarm any trace scopes the failed flush left armed, so
            // later reads through the same primaries go untagged
            for gr in &self.groups {
                if let Some(r) = gr.remote_trace() {
                    r.trace_scope().end();
                }
            }
        }
        out
    }

    fn flush_inner(
        &self,
        edits: Vec<EdgeEdit>,
        queued_at: Option<Instant>,
    ) -> Result<ShardedOutcome> {
        let ft = FlushTrace::new(obs::next_trace_id());
        let queue_wait = queued_at.map(|t| t.elapsed()).unwrap_or(Duration::ZERO);
        if let Some(t) = queued_at {
            // started before the trace: the offset saturates to 0, which
            // is exactly where the queue-wait stage belongs
            ft.stage("queue", t, queue_wait);
        }
        // arm the remote primaries' trace mailboxes: their shard verbs
        // now carry this flush's trace id, and the hosts' measured
        // handler times come back as remote child spans
        for gr in &self.groups {
            if let Some(r) = gr.remote_trace() {
                r.trace_scope().begin(ft.id(), ft.t0());
            }
        }
        let timer = Timer::start();
        let batch = coalesce(&edits);
        let applied = batch.len();
        // route under a short critical section, then release the owner
        // map before any network I/O: concurrent point reads route with
        // the (possibly grown) map and stay correct — epoch-checked
        // replica reads serve the old committed epoch, and not-yet-
        // refined vertices read as absent until the publish.
        let route_start = Instant::now();
        let (n, plan) = {
            let mut owner = self.owner.lock().unwrap();
            let plan = route(&mut owner, self.groups.len(), &batch);
            (owner.len(), plan)
        };
        let route_elapsed = route_start.elapsed();
        ft.stage("route", route_start, route_elapsed);
        let apply_start = Instant::now();
        let mut changed = 0usize;
        let mut recomputed_shards = 0usize;
        for (s, gr) in self.groups.iter().enumerate() {
            if !plan.touched[s] {
                continue;
            }
            // planner heat signal + hint staleness, before the journal
            // loop below takes the batches
            gr.edits_routed
                .fetch_add(plan.per_shard[s].edits.len() as u64, Ordering::Relaxed);
            if !plan.per_shard[s].new_owned.is_empty() {
                // ownership grew: the cached full-ship size is wrong now
                gr.hint_stale.store(true, Ordering::SeqCst);
            }
            let shard_start = Instant::now();
            let out = gr
                .backend()
                .apply(&plan.per_shard[s])
                .with_context(|| format!("routed batch on shard {s} ({})", gr.primary_addr()))?;
            // coordinator-side wall time; a remote primary additionally
            // reports its own host-side span through the trace scope
            ft.child(
                "apply",
                Span {
                    name: format!("apply shard={s}"),
                    start_us: shard_start.saturating_duration_since(ft.t0()).as_micros() as u64,
                    dur_us: shard_start.elapsed().as_micros() as u64,
                    remote: None,
                    children: Vec::new(),
                },
            );
            changed += out.changed;
            if out.recomputed {
                recomputed_shards += 1;
            }
        }
        let apply_elapsed = apply_start.elapsed();
        ft.stage("apply", apply_start, apply_elapsed);
        let epoch = self.epoch.load(Ordering::SeqCst) + 1;
        let merge_timer = Timer::start();
        let backends: Vec<Arc<dyn ShardBackend>> =
            self.groups.iter().map(|gr| gr.backend()).collect();
        let mut refined = refine_traced(
            &backends,
            n,
            Some(plan.inserts),
            epoch,
            self.cfg.threads,
            Some(&ft),
        )
        .context("cluster refinement")?;
        let merge_elapsed = merge_timer.elapsed();
        let merge = refined.stats;
        let (refine_elapsed, commit_elapsed) = (refined.refine_elapsed, refined.commit_elapsed);
        let k_max = refined.core.iter().copied().max().unwrap_or(0);
        // cache each shard's boundary-arc share for the planner
        for (s, gr) in self.groups.iter().enumerate() {
            if let Some(&arcs) = refined.per_shard_boundary_arcs.get(s) {
                gr.boundary_arcs.store(arcs, Ordering::Relaxed);
            }
        }
        // journal the epoch for delta catch-up — the routed batch plus
        // the commit's refined diff reproduce this epoch exactly on a
        // replica. Groups pay for it when they have replicas to serve
        // — or a migration in flight, whose mover catches up over these
        // same chains.
        let mut plan = plan;
        for (s, gr) in self.groups.iter().enumerate() {
            if gr.replicas.is_empty() && !gr.migrating() {
                continue;
            }
            self.journals[s].lock().unwrap().record(EpochDelta {
                to_epoch: epoch,
                batch: std::mem::take(&mut plan.per_shard[s]),
                diff: std::mem::take(&mut refined.diffs[s]),
            });
        }
        let publish_start = Instant::now();
        let snapshot = Arc::new(CoreSnapshot {
            epoch,
            core: refined.core,
            k_max,
            num_edges: refined.num_edges,
        });
        *self.published.write().unwrap() = Arc::new(Published {
            global: snapshot.clone(),
            merge,
            boundary_edges: refined.boundary_edges,
        });
        self.epoch.store(epoch, Ordering::SeqCst);
        let publish_elapsed = publish_start.elapsed();
        ft.stage("publish", publish_start, publish_elapsed);
        // stitch: drain the hosts' measured spans into this flush's
        // trace, nested under their stages with the remote addr kept
        for gr in &self.groups {
            if let Some(r) = gr.remote_trace() {
                for (stage, span) in r.trace_scope().end() {
                    ft.child(&stage, span);
                }
            }
        }
        let elapsed = timer.elapsed();
        obs::record_flush_stages(
            &self.name,
            &FlushStages {
                queue: queue_wait,
                route: route_elapsed,
                apply: apply_elapsed,
                refine: refine_elapsed,
                commit: commit_elapsed,
                publish: publish_elapsed,
                total: queue_wait + elapsed,
                refine_rounds: merge.rounds as u64,
                boundary_updates: merge.boundary_updates,
                boundary_bytes: merge.boundary_bytes,
                epoch,
            },
        );
        obs::record_trace(ft.finish("flush", &self.name));
        Ok(ShardedOutcome {
            snapshot,
            submitted: edits.len(),
            applied,
            coalesced: edits.len() - applied,
            changed,
            recomputed_shards,
            merge,
            merge_elapsed,
            elapsed,
        })
    }

    /// Catch every lagging replica up to the published epoch —
    /// incrementally where possible, by full re-ship otherwise.
    ///
    /// Per stale replica, the router prefers the journal's encoded delta
    /// chain when it exists **and** its encoding is smaller than a full
    /// manifest (compared against the last manifest this group actually
    /// encoded — exact bytes, refreshed on every full ship; an unknown
    /// size takes the full path once to initialise it). Any journal gap,
    /// size loss, or delta rejection falls back to re-shipping the
    /// primary's full manifest, which repairs whatever state the replica
    /// is in. Ship failures are counted per replica rather than aborting
    /// the pass — the background sync daemon has to outlive a dead host
    /// — and surface in the returned [`SyncReport`].
    pub fn sync_replicas(&self) -> Result<SyncReport> {
        let want = self.epoch();
        let num_shards = self.groups.len() as u32;
        let mut report = SyncReport::default();
        for (s, gr) in self.groups.iter().enumerate() {
            if gr.replicas.is_empty() {
                continue;
            }
            // mirror the group's sync counters into the registry — the
            // atomics behind sync_stats() stay authoritative for the
            // SHARDS verb, the registry feeds the scrapeable exposition
            let shard_label = s.to_string();
            let labels: &[(&str, &str)] = &[("graph", &self.name), ("shard", &shard_label)];
            // ownership changed since the hint was last exact (flush
            // registered vertices, rebalance moved some, migration
            // swapped the primary): re-probe the primary for the real
            // full-ship size before any chain-vs-manifest comparison.
            // Shipping against the stale hint was the bug where a grown
            // shard kept comparing deltas to an undersized manifest.
            if gr.hint_stale.swap(false, Ordering::SeqCst) {
                let fresh = gr.backend().status().map(|st| st.state_bytes).unwrap_or(0);
                gr.manifest_bytes_hint.store(fresh, Ordering::Relaxed);
            }
            let mut manifest: Option<Vec<u8>> = None;
            let mut primary_down = false;
            let mut group_lag = 0u64;
            let group_failed_before = report.failed;
            // after a failed flush, epoch equality no longer implies
            // state equality: ship the full manifest to every replica
            // of the group, deltas suspended (see force_full_ship docs)
            let forced = gr.force_full_ship.load(Ordering::SeqCst);
            for r in &gr.replicas {
                let committed = match r.status() {
                    Ok(st) => Some(st.cluster_epoch),
                    Err(_) => None, // down or not hosted yet: full ship
                };
                if !forced && committed == Some(want) {
                    continue;
                }
                group_lag = group_lag.max(match committed {
                    Some(e) if e == want => 0,
                    // the sentinel for never-committed (u64::MAX) and
                    // any ahead-of-router state both need a full ship
                    Some(e) if e <= want => want - e,
                    _ => want + 1,
                });
                let chain = committed
                    .filter(|&e| e < want && !forced)
                    .and_then(|e| self.journals[s].lock().unwrap().encode_chain(e, want));
                if let (Some(bytes), Some(from)) = (chain, committed) {
                    let hint = gr.manifest_bytes_hint.load(Ordering::Relaxed);
                    // a rejected or lost delta ship is not an error: the
                    // full-manifest path below repairs whatever state the
                    // replica is in
                    if hint > 0
                        && (bytes.len() as u64) < hint
                        && r.apply_delta(from, want, &bytes).is_ok()
                    {
                        gr.deltas_shipped.fetch_add(1, Ordering::Relaxed);
                        gr.delta_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
                        obs::global().counter(names::SYNC_DELTAS, labels).inc();
                        obs::global()
                            .counter(names::SYNC_DELTA_BYTES, labels)
                            .add(bytes.len() as u64);
                        report.deltas += 1;
                        report.delta_bytes += bytes.len() as u64;
                        continue;
                    }
                }
                if primary_down {
                    report.note_failure(format!(
                        "shard {} primary unreachable for catch-up",
                        gr.backend().id()
                    ));
                    continue;
                }
                if manifest.is_none() {
                    match gr.primary_manifest(num_shards) {
                        Ok(m) => {
                            gr.manifest_bytes_hint.store(m.len() as u64, Ordering::Relaxed);
                            manifest = Some(m);
                        }
                        Err(e) => {
                            primary_down = true;
                            report.note_failure(format!(
                                "pulling shard {} manifest for catch-up: {e:#}",
                                gr.backend().id()
                            ));
                            continue;
                        }
                    }
                }
                let m = manifest.as_ref().unwrap();
                match r.host(m) {
                    Ok(()) => {
                        gr.snapshots_shipped.fetch_add(1, Ordering::Relaxed);
                        gr.snapshot_bytes.fetch_add(m.len() as u64, Ordering::Relaxed);
                        obs::global().counter(names::SYNC_SNAPSHOTS, labels).inc();
                        obs::global()
                            .counter(names::SYNC_SNAPSHOT_BYTES, labels)
                            .add(m.len() as u64);
                        report.snapshots += 1;
                        report.snapshot_bytes += m.len() as u64;
                        // a replica with real committed state behind the
                        // head should have caught up by delta; a full
                        // ship there (or a forced one) is the fallback
                        // worth journaling — initial hydration is not
                        if forced || matches!(committed, Some(e) if e < want) {
                            obs::events::emit(
                                obs::Severity::Warn,
                                obs::events::kind::SYNC_FULL_SHIP,
                                &self.name,
                                format!(
                                    "replica={} shard={} bytes={}{}",
                                    r.addr(),
                                    gr.backend().id(),
                                    m.len(),
                                    if forced { " forced" } else { "" }
                                ),
                            );
                        }
                    }
                    Err(e) => report.note_failure(format!("ship to {}: {e:#}", r.addr())),
                }
            }
            gr.lag_epochs.store(group_lag, Ordering::Relaxed);
            obs::global().gauge(names::SYNC_LAG_EPOCHS, labels).set(group_lag);
            report.max_lag_epochs = report.max_lag_epochs.max(group_lag);
            if forced && report.failed == group_failed_before {
                // every replica of the group now holds the primary's
                // exact state again — deltas may resume
                gr.force_full_ship.store(false, Ordering::SeqCst);
            }
        }
        // publish how many replicas this pass failed to catch up — the
        // instantaneous signal behind HEALTH's replication rule — and
        // journal transitions only, not every daemon pass
        let failed_gauge =
            obs::global().gauge(names::SYNC_FAILED_REPLICAS, &[("graph", &self.name)]);
        let prev_failed = failed_gauge.get();
        failed_gauge.set(report.failed as u64);
        if report.failed > 0 && prev_failed != report.failed as u64 {
            obs::events::emit(
                obs::Severity::Error,
                obs::events::kind::SYNC_FAILED,
                &self.name,
                format!(
                    "{} replica(s) not caught up: {}",
                    report.failed,
                    report.first_error.as_deref().unwrap_or("unknown error")
                ),
            );
        }
        Ok(report)
    }

    /// The shard owning vertex `v`, if `v` is inside the cluster's
    /// vertex set — what the serve layer redirects shard-local probes
    /// with.
    pub fn owner_of(&self, v: VertexId) -> Option<usize> {
        self.owner
            .lock()
            .unwrap()
            .get(v as usize)
            .map(|&s| s as usize)
    }

    /// Routed point read: the owner shard's replica group answers, with
    /// epoch-checked failover (see module docs).
    pub fn coreness_routed(&self, v: VertexId) -> Result<Option<u32>> {
        let owner = self.owner.lock().unwrap().get(v as usize).copied();
        let Some(s) = owner else {
            return Ok(None);
        };
        let want = self.epoch();
        self.groups[s as usize].read(want, |b| b.refined_coreness(v))
    }

    /// Fan-out read: per-shard histograms summed cell-wise, padded to
    /// the published `k_max`.
    pub fn histogram_routed(&self) -> Result<Vec<u64>> {
        let want = self.epoch();
        let k_max = self.snapshot().k_max;
        let mut hist = vec![0u64; k_max as usize + 1];
        for gr in &self.groups {
            let part = gr.read(want, |b| b.histogram_partial())?;
            for (k, &c) in part.iter().enumerate() {
                if k >= hist.len() {
                    hist.resize(k + 1, 0);
                }
                hist[k] += c;
            }
        }
        Ok(hist)
    }

    /// Fan-out read: k-core members merged into the global ascending
    /// membership list.
    pub fn members_routed(&self, k: u32) -> Result<Vec<VertexId>> {
        let want = self.epoch();
        let mut out = Vec::new();
        for gr in &self.groups {
            out.extend(gr.read(want, |b| b.members_partial(k))?);
        }
        out.sort_unstable();
        Ok(out)
    }

    /// |k-core| from the fan-out histogram.
    pub fn kcore_size_routed(&self, k: u32) -> Result<usize> {
        let hist = self.histogram_routed()?;
        Ok(hist.iter().skip(k as usize).sum::<u64>() as usize)
    }

    /// Global degeneracy at the published epoch.
    pub fn degeneracy(&self) -> u32 {
        self.snapshot().k_max
    }

    /// Probe the whole topology (primaries and replicas).
    pub fn status(&self) -> Vec<GroupStatus> {
        self.groups
            .iter()
            .map(|gr| GroupStatus {
                shard: gr.backend().id(),
                kind: gr.kind(),
                primary_addr: gr.primary_addr(),
                primary: gr.backend().status().map_err(|e| format!("{e:#}")),
                replicas: gr
                    .replicas
                    .iter()
                    .map(|r| {
                        (
                            r.addr().to_string(),
                            r.status().map_err(|e| format!("{e:#}")),
                        )
                    })
                    .collect(),
                failovers: gr.failovers(),
                stale_reads: gr.stale_reads(),
                sync: gr.sync_stats(),
            })
            .collect()
    }

    /// The encoded delta chain `(from, to]` for one shard, if the
    /// journal still holds it (benches read its size; `None` past the
    /// retention window or for an unjournalled shard).
    pub fn journal_chain_bytes(&self, shard: usize, from: u64, to: u64) -> Option<usize> {
        self.journals
            .get(shard)?
            .lock()
            .unwrap()
            .encode_chain(from, to)
            .map(|b| b.len())
    }

    // --- elastic resharding -------------------------------------------

    /// Completed rebalance steps, oldest first (bounded ring).
    pub fn moves(&self) -> Vec<MoveRecord> {
        self.moves.lock().unwrap().clone()
    }

    fn push_move(&self, rec: MoveRecord) {
        obs::global()
            .counter(
                names::REBALANCE_MOVES,
                &[("graph", &self.name), ("kind", rec.kind)],
            )
            .inc();
        let mut moves = self.moves.lock().unwrap();
        moves.push(rec);
        if moves.len() > MOVE_HISTORY {
            let excess = moves.len() - MOVE_HISTORY;
            moves.drain(..excess);
        }
    }

    /// Take the one-at-a-time structural-change latch, or fail with
    /// [`RebalanceBusy`].
    fn begin_structural(&self) -> Result<BusyGuard<'_>> {
        if self
            .rebalancing
            .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
            .is_err()
        {
            return Err(RebalanceBusy.into());
        }
        Ok(BusyGuard(&self.rebalancing))
    }

    /// The per-shard load signals the planner consumes — live counters
    /// already cached on the groups plus one status probe per primary.
    pub fn shard_loads(&self) -> Vec<super::rebalance::ShardLoad> {
        self.groups
            .iter()
            .enumerate()
            .map(|(s, gr)| {
                let (owned, state_bytes, reachable) = match gr.backend().status() {
                    Ok(st) => (st.owned, st.state_bytes, true),
                    Err(_) => (0, 0, false),
                };
                super::rebalance::ShardLoad {
                    shard: s,
                    owned,
                    state_bytes,
                    edits_routed: gr.edits_routed(),
                    boundary_arcs: gr.boundary_arcs(),
                    lag_epochs: gr.sync_stats().lag_epochs,
                    reachable,
                }
            })
            .collect()
    }

    /// Plan (but do not execute) a rebalance over the live load signals.
    pub fn rebalance_plan(&self) -> super::rebalance::RebalancePlan {
        super::rebalance::plan(&self.shard_loads())
    }

    /// Plan and execute a rebalance atomically under the one-at-a-time
    /// latch: the plan is computed against the same load snapshot it is
    /// applied to, so a concurrent admin cannot apply a stale plan.
    pub fn rebalance_apply(
        &self,
    ) -> Result<(super::rebalance::RebalancePlan, Vec<MoveRecord>)> {
        let _latch = self.begin_structural()?;
        let plan = self.rebalance_plan();
        let records = super::rebalance::execute(self, &plan)?;
        Ok((plan, records))
    }

    /// Move `count` owned vertices from shard `from` to shard `to` — the
    /// split/merge primitive. The whole move runs under the flush fence
    /// (writers queue, nothing is lost): export the boundary-heaviest
    /// vertices with their adjacency and committed coreness, adopt them
    /// on the target (which refuses any vertex it already owns — the
    /// double-apply fence), release them on the source, remap the
    /// router, then publish a fresh epoch from a warm refinement so
    /// every stale replica read is rejected by the epoch check until
    /// catch-up. Journals cannot span a move, so both groups' journals
    /// reset and their replicas take one full re-ship.
    ///
    /// This is the raw primitive: [`Self::rebalance_apply`] is the
    /// latched path; callers here coordinate their own exclusion.
    pub fn move_vertices(&self, from: usize, to: usize, count: usize) -> Result<MoveRecord> {
        if from >= self.groups.len() || to >= self.groups.len() {
            bail!(
                "move: shard out of range (have {} shards)",
                self.groups.len()
            );
        }
        if from == to {
            bail!("move: source and destination are both shard {from}");
        }
        if count == 0 {
            bail!("move: zero vertices requested");
        }
        let fence_start = Instant::now();
        let _fence = self.flush_lock.lock().unwrap();
        let src = self.groups[from].backend();
        let dst = self.groups[to].backend();
        let payload = src
            .handoff_export(count)
            .with_context(|| format!("exporting {count} vertices from shard {from}"))?;
        let bytes = payload.len() as u64;
        // adopt before release: if the adopt fails, nothing has changed
        // anywhere (export is a pure read) and the move aborts clean
        let adopted = match dst.handoff_adopt(&payload) {
            Ok(ids) => ids,
            Err(e) => {
                obs::events::emit(
                    obs::Severity::Warn,
                    obs::events::kind::REBALANCE_ABORTED,
                    &self.name,
                    format!("move {from}->{to}: adopt failed ({e:#}); no state changed"),
                );
                return Err(e.context(format!("adopting handoff on shard {to}")));
            }
        };
        src.handoff_release(&adopted)
            .with_context(|| format!("releasing {} moved vertices on shard {from}", adopted.len()))?;
        {
            let mut owner = self.owner.lock().unwrap();
            crate::shard::router::reassign(&mut owner, &adopted, to as u32)?;
        }
        // journals cannot span an ownership move: reset both groups and
        // force their replicas through one full re-ship, exact-size
        // hints refreshed on the next sync pass
        for s in [from, to] {
            self.journals[s].lock().unwrap().clear();
            let gr = &self.groups[s];
            gr.hint_stale.store(true, Ordering::SeqCst);
            if !gr.replicas.is_empty() {
                gr.force_full_ship.store(true, Ordering::SeqCst);
            }
        }
        // publish a fresh epoch from a warm refinement: moved vertices
        // answer from their new owner, and any replica still at the old
        // epoch fails the epoch check until it catches up
        let epoch = self.republish().context("republishing after the move")?;
        let cutover_us = fence_start.elapsed().as_micros() as u64;
        let shard_label = from.to_string();
        obs::global()
            .counter(
                names::MIGRATE_SHIPPED_BYTES,
                &[("graph", &self.name), ("shard", &shard_label)],
            )
            .add(bytes);
        let kind = if self.groups[from]
            .backend()
            .status()
            .map(|st| st.owned == 0)
            .unwrap_or(false)
        {
            "merge"
        } else {
            "split"
        };
        let rec = MoveRecord {
            kind,
            from,
            to: format!("shard{to}"),
            vertices: adopted.len(),
            bytes,
            cutover_us,
            epoch,
            unix_ms: now_unix_ms(),
        };
        obs::events::emit(
            obs::Severity::Info,
            obs::events::kind::REBALANCE_MOVE,
            &self.name,
            format!(
                "{kind} {from}->{to}: vertices={} bytes={bytes} cutover_us={cutover_us} epoch={epoch}",
                adopted.len()
            ),
        );
        self.push_move(rec.clone());
        Ok(rec)
    }

    /// Re-refine (warm, no routed batch) and publish `epoch + 1`.
    /// Callers hold the flush fence.
    fn republish(&self) -> Result<u64> {
        let epoch = self.epoch.load(Ordering::SeqCst) + 1;
        let n = self.owner.lock().unwrap().len();
        let backends: Vec<Arc<dyn ShardBackend>> =
            self.groups.iter().map(|gr| gr.backend()).collect();
        let refined =
            refine(&backends, n, None, epoch, self.cfg.threads).context("post-move refinement")?;
        let k_max = refined.core.iter().copied().max().unwrap_or(0);
        for (s, gr) in self.groups.iter().enumerate() {
            if let Some(&arcs) = refined.per_shard_boundary_arcs.get(s) {
                gr.boundary_arcs.store(arcs, Ordering::Relaxed);
            }
        }
        *self.published.write().unwrap() = Arc::new(Published {
            global: Arc::new(CoreSnapshot {
                epoch,
                core: refined.core,
                k_max,
                num_edges: refined.num_edges,
            }),
            merge: refined.stats,
            boundary_edges: refined.boundary_edges,
        });
        self.epoch.store(epoch, Ordering::SeqCst);
        Ok(epoch)
    }

    /// Live primary migration: move shard `shard`'s primary to the host
    /// at `addr` while routed edits keep flowing.
    ///
    /// Phase 1 (unfenced): flag the shard as migrating (flushes start
    /// journalling it even with no replicas), ship the primary's full
    /// manifest to the target, then loop delta catch-up — each pass
    /// ships the journal chain covering whatever epochs flushes
    /// published meanwhile, re-shipping the manifest on any chain gap.
    /// Phase 2 (fenced, the measured cutover): under the flush fence,
    /// ship the final chain, verify the target sits at the router's
    /// exact epoch, and swap the primary. Writers observe only phase 2
    /// as pause. Any failure before the swap aborts with the old
    /// primary fully intact.
    pub fn migrate_primary(&self, shard: usize, addr: &str) -> Result<MoveRecord> {
        let _latch = self.begin_structural()?;
        if shard >= self.groups.len() {
            bail!(
                "migrate: shard {shard} out of range (have {} shards)",
                self.groups.len()
            );
        }
        let gr = &self.groups[shard];
        gr.migrating.store(true, Ordering::SeqCst);
        let out = self.migrate_inner(shard, addr);
        gr.migrating.store(false, Ordering::SeqCst);
        if let Err(e) = &out {
            obs::events::emit(
                obs::Severity::Warn,
                obs::events::kind::REBALANCE_ABORTED,
                &self.name,
                format!("migrate shard {shard} -> {addr} aborted ({e:#}); old primary intact"),
            );
        }
        out
    }

    fn migrate_inner(&self, shard: usize, addr: &str) -> Result<MoveRecord> {
        let gr = &self.groups[shard];
        let num_shards = self.groups.len() as u32;
        let shard_label = shard.to_string();
        let labels: &[(&str, &str)] = &[("graph", &self.name), ("shard", &shard_label)];
        let graph_name = format!("{}/shard{shard}", self.name);
        let mover = Arc::new(
            RemoteShard::new(shard, addr.to_string(), graph_name).with_auth(self.auth.clone()),
        );
        mover
            .ping()
            .with_context(|| format!("migration target {addr} unreachable"))?;
        let catchup_start = Instant::now();
        let mut shipped_bytes = 0u64;
        // phase 1: ship the manifest, then chase the head with deltas.
        // `at` is the epoch read *before* each ship, so a flush landing
        // mid-ship only means one more catch-up pass, never a gap.
        let mut at = self.epoch();
        let manifest = gr.primary_manifest(num_shards)?;
        shipped_bytes += manifest.len() as u64;
        mover
            .host(&manifest)
            .with_context(|| format!("shipping shard {shard} manifest to {addr}"))?;
        for _attempt in 0..8 {
            let head = self.epoch();
            if head == at {
                break;
            }
            let chain = self.journals[shard].lock().unwrap().encode_chain(at, head);
            match chain {
                Some(bytes) => {
                    mover
                        .apply_delta(at, head, &bytes)
                        .with_context(|| format!("catch-up chain ({at}, {head}] to {addr}"))?;
                    shipped_bytes += bytes.len() as u64;
                }
                None => {
                    // chain gap (journal bounds, or journalling started
                    // after `at`): fall back to a manifest re-ship
                    let head = self.epoch();
                    let m = gr.primary_manifest(num_shards)?;
                    shipped_bytes += m.len() as u64;
                    mover.host(&m).context("manifest re-ship during catch-up")?;
                    at = head;
                    continue;
                }
            }
            at = head;
        }
        obs::global()
            .histogram(names::MIGRATE_CATCHUP_SECONDS, labels)
            .record(catchup_start.elapsed().as_micros() as u64);
        // phase 2: fenced cutover — the only pause writers observe
        let cutover_start = Instant::now();
        let _fence = self.flush_lock.lock().unwrap();
        let head = self.epoch();
        if at < head {
            let chain = self.journals[shard].lock().unwrap().encode_chain(at, head);
            match chain {
                Some(bytes) => {
                    mover
                        .apply_delta(at, head, &bytes)
                        .with_context(|| format!("final chain ({at}, {head}] to {addr}"))?;
                    shipped_bytes += bytes.len() as u64;
                }
                None => {
                    // no flush can interleave under the fence, so one
                    // re-ship is guaranteed to land exactly at `head`
                    let m = gr.primary_manifest(num_shards)?;
                    shipped_bytes += m.len() as u64;
                    mover.host(&m).context("manifest re-ship at cutover")?;
                }
            }
        }
        let st = mover
            .status()
            .with_context(|| format!("verifying {addr} before cutover"))?;
        if st.cluster_epoch != head {
            bail!(
                "refusing cutover: {addr} committed epoch {} but the router is at {head}",
                st.cluster_epoch
            );
        }
        // the swap: reads and writes route to the new primary from here.
        // Journals stay — the mover holds byte-identical state, so every
        // recorded chain still applies; replicas keep syncing unbroken.
        gr.set_primary(Primary::Remote(mover));
        let cutover_us = cutover_start.elapsed().as_micros() as u64;
        drop(_fence);
        obs::global()
            .histogram(names::MIGRATE_CUTOVER_SECONDS, labels)
            .record(cutover_us);
        obs::global()
            .counter(names::MIGRATE_SHIPPED_BYTES, labels)
            .add(shipped_bytes);
        let rec = MoveRecord {
            kind: "migrate",
            from: shard,
            to: addr.to_string(),
            vertices: 0,
            bytes: shipped_bytes,
            cutover_us,
            epoch: head,
            unix_ms: now_unix_ms(),
        };
        obs::events::emit(
            obs::Severity::Info,
            obs::events::kind::PRIMARY_MIGRATED,
            &self.name,
            format!(
                "shard {shard} primary -> {addr}: bytes={shipped_bytes} cutover_us={cutover_us} epoch={head}"
            ),
        );
        self.push_move(rec.clone());
        Ok(rec)
    }

    /// Assembled global CSR at the current epoch (cached per epoch;
    /// remote shards ship their manifests). The heavyweight read.
    pub fn graph(&self) -> Result<Arc<CsrGraph>> {
        let _guard = self.flush_lock.lock().unwrap();
        self.graph_inner()
    }

    /// A mutually consistent (merged snapshot, assembled graph) pair.
    pub fn consistent_view(&self) -> Result<(Arc<CoreSnapshot>, Arc<CsrGraph>)> {
        let _guard = self.flush_lock.lock().unwrap();
        let snap = self.snapshot();
        let g = self.graph_inner()?;
        Ok((snap, g))
    }

    fn graph_inner(&self) -> Result<Arc<CsrGraph>> {
        let epoch = self.epoch.load(Ordering::SeqCst);
        {
            let cache = self.graph_cache.lock().unwrap();
            if let Some((e, g)) = cache.as_ref() {
                if *e == epoch {
                    return Ok(g.clone());
                }
            }
        }
        let n = self.owner.lock().unwrap().len();
        let mut b = GraphBuilder::new(n);
        for gr in &self.groups {
            match &*gr.primary.read().unwrap() {
                Primary::Local(s) => {
                    for (u, v) in s.owned_edges() {
                        b.add_edge(u, v);
                    }
                }
                Primary::Remote(r) => {
                    let m = wire::decode_manifest(&r.fetch_manifest()?)
                        .with_context(|| format!("manifest from {}", r.addr()))?;
                    for &l in &m.owned_locals {
                        let gu = m.globals[l as usize];
                        for &w in m.snapshot.graph.neighbors(l) {
                            let gv = m.globals[w as usize];
                            if gu as usize >= n || gv as usize >= n {
                                bail!(
                                    "shard {} names vertex outside the cluster (|V|={n})",
                                    gr.backend().id()
                                );
                            }
                            b.add_edge(gu, gv);
                        }
                    }
                }
            }
        }
        let g = Arc::new(b.build(self.name.as_str()));
        *self.graph_cache.lock().unwrap() = Some((epoch, g.clone()));
        Ok(g)
    }
}

impl std::fmt::Debug for ClusterIndex {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        let remote = self.groups.iter().filter(|g| g.kind() == "remote").count();
        write!(
            f,
            "ClusterIndex({} x{} [{} remote] @ epoch {}: |V|={}, |E|={}, k_max={})",
            self.name,
            self.groups.len(),
            remote,
            s.epoch,
            s.num_vertices(),
            s.num_edges,
            s.k_max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::bz::bz_coreness;
    use crate::graph::gen;
    use crate::service::index::CoreIndex;

    fn all_local(name: &str, shards: usize) -> ClusterConfig {
        let mut text = format!("[cluster]\nname = {name}\nshards = {shards}\n");
        for i in 0..shards {
            text.push_str(&format!("[shard.{i}]\nprimary = local\n"));
        }
        ClusterConfig::parse(&text).unwrap()
    }

    fn cfg() -> BatchConfig {
        BatchConfig {
            threads: 1,
            ..BatchConfig::default()
        }
    }

    #[test]
    fn all_local_cluster_matches_the_oracle() {
        let g = gen::barabasi_albert(200, 3, 31);
        let single = CoreIndex::new("single", &g);
        let cl = ClusterIndex::build(&g, &all_local("c", 4), cfg()).unwrap();
        let want = single.snapshot();
        assert_eq!(cl.snapshot().core, want.core);
        assert_eq!(cl.snapshot().num_edges, want.num_edges);
        assert_eq!(cl.degeneracy(), want.degeneracy());
        assert_eq!(cl.histogram_routed().unwrap(), want.histogram());
        for v in 0..g.num_vertices() as u32 {
            assert_eq!(cl.coreness_routed(v).unwrap(), want.coreness(v), "v{v}");
        }
        assert_eq!(cl.coreness_routed(g.num_vertices() as u32).unwrap(), None);
        for k in 0..=want.k_max {
            assert_eq!(cl.members_routed(k).unwrap(), want.kcore_members(k));
            assert_eq!(cl.kcore_size_routed(k).unwrap(), want.kcore_size(k));
        }
        let (snap, graph) = cl.consistent_view().unwrap();
        assert_eq!(snap.core, bz_coreness(&graph));
    }

    #[test]
    fn edits_flow_and_epochs_advance() {
        let g = gen::erdos_renyi(100, 300, 7);
        let cl = ClusterIndex::build(&g, &all_local("c", 3), cfg()).unwrap();
        cl.submit(EdgeEdit::Insert(0, 50));
        cl.submit(EdgeEdit::Insert(150, 160)); // grows the vertex set
        let out = cl.flush().unwrap();
        assert_eq!(out.snapshot.epoch, 1);
        assert_eq!(cl.epoch(), 1);
        assert_eq!(out.snapshot.num_vertices(), 161);
        let (snap, graph) = cl.consistent_view().unwrap();
        assert_eq!(snap.core, bz_coreness(&graph));
        assert_eq!(cl.coreness_routed(155).unwrap(), Some(0));
        // empty flush publishes nothing
        assert_eq!(cl.flush().unwrap().submitted, 0);
        assert_eq!(cl.epoch(), 1);
        // no replicas configured: nothing to sync
        let report = cl.sync_replicas().unwrap();
        assert_eq!(report.shipped(), 0);
        assert_eq!(report.failed, 0);
    }

    #[test]
    fn moves_preserve_the_oracle_and_route_edits_after() {
        let g = gen::erdos_renyi(120, 420, 11);
        let single = CoreIndex::new("single", &g);
        let cl = ClusterIndex::build(&g, &all_local("c", 3), cfg()).unwrap();
        let want = single.snapshot();
        // split: move 10 boundary-heavy vertices from shard 0 to 1
        let rec = cl.move_vertices(0, 1, 10).unwrap();
        assert_eq!(rec.vertices, 10);
        assert_eq!(rec.kind, "split");
        assert!(rec.bytes > 0);
        assert_eq!(cl.epoch(), 1, "a move publishes a fresh epoch");
        assert_eq!(cl.snapshot().core, want.core);
        assert_eq!(cl.snapshot().num_edges, want.num_edges);
        for v in 0..g.num_vertices() as u32 {
            assert_eq!(cl.coreness_routed(v).unwrap(), want.coreness(v), "v{v}");
        }
        // routed edits keep flowing after the move
        cl.submit(EdgeEdit::Insert(0, 90));
        cl.submit(EdgeEdit::Insert(3, 117));
        assert_eq!(cl.flush().unwrap().snapshot.epoch, 2);
        let (snap, graph) = cl.consistent_view().unwrap();
        assert_eq!(snap.core, bz_coreness(&graph));
        // merge: empty shard 2 into shard 0 entirely
        let owned2 = cl.groups()[2].backend().status().unwrap().owned;
        assert!(owned2 > 0);
        let rec = cl.move_vertices(2, 0, owned2).unwrap();
        assert_eq!(rec.kind, "merge");
        assert_eq!(rec.vertices, owned2);
        assert_eq!(cl.groups()[2].backend().status().unwrap().owned, 0);
        let (snap, graph) = cl.consistent_view().unwrap();
        assert_eq!(snap.core, bz_coreness(&graph));
        // history ring remembers both, oldest first
        let moves = cl.moves();
        assert_eq!(moves.len(), 2);
        assert_eq!(moves[0].kind, "split");
        assert_eq!(moves[1].to, "shard0");
        // guards: self-move, out-of-range, nothing left to export
        assert!(cl.move_vertices(0, 0, 1).is_err());
        assert!(cl.move_vertices(0, 9, 1).is_err());
        assert!(cl.move_vertices(0, 1, 0).is_err());
        assert!(
            cl.move_vertices(2, 0, 1).is_err(),
            "an emptied shard has nothing to export"
        );
    }

    #[test]
    fn migration_to_an_unreachable_target_aborts_clean() {
        let g = gen::erdos_renyi(80, 200, 5);
        let single = CoreIndex::new("single", &g);
        let cl = ClusterIndex::build(&g, &all_local("c", 2), cfg()).unwrap();
        // reserved port: nothing listens, the target ping must fail
        let err = cl.migrate_primary(0, "127.0.0.1:1").unwrap_err();
        assert!(format!("{err:#}").contains("unreachable"), "{err:#}");
        // old primary fully intact: epoch, answers, flags, history
        assert_eq!(cl.epoch(), 0);
        assert_eq!(cl.snapshot().core, single.snapshot().core);
        assert!(!cl.groups()[0].migrating());
        assert!(cl.moves().is_empty());
        assert_eq!(cl.coreness_routed(3).unwrap(), single.snapshot().coreness(3));
        // the latch released on abort: the retry is admitted (and fails
        // on reachability again), not refused as busy
        let err = cl.migrate_primary(0, "127.0.0.1:1").unwrap_err();
        assert!(err.downcast_ref::<RebalanceBusy>().is_none());
        assert!(cl.migrate_primary(9, "127.0.0.1:1").is_err(), "out of range");
        // edits still flow after the aborted migration
        cl.submit(EdgeEdit::Insert(0, 40));
        assert_eq!(cl.flush().unwrap().snapshot.epoch, 1);
        let (snap, graph) = cl.consistent_view().unwrap();
        assert_eq!(snap.core, bz_coreness(&graph));
    }

    #[test]
    fn status_covers_every_group() {
        let g = gen::erdos_renyi(60, 150, 3);
        let cl = ClusterIndex::build(&g, &all_local("c", 2), cfg()).unwrap();
        let st = cl.status();
        assert_eq!(st.len(), 2);
        for (i, gs) in st.iter().enumerate() {
            assert_eq!(gs.shard, i);
            assert_eq!(gs.kind, "local");
            let p = gs.primary.as_ref().unwrap();
            assert_eq!(p.cluster_epoch, 0);
            assert!(gs.replicas.is_empty());
        }
    }
}
