//! Cluster topology configuration — the TOML-style file behind
//! `pico serve --cluster <cfg>` and `pico cluster status`.
//!
//! Parsed with the in-tree [`KvFile`] (the environment is offline; no
//! serde/toml crates). Format:
//!
//! ```text
//! [cluster]
//! name = social          # hosted graph name (shards become name/shardN)
//! dataset = social-ba    # suite name or graph file path
//! shards = 2
//! partition = hash       # hash | range
//! journal = 64           # epochs of per-shard deltas kept for replica
//!                        # catch-up (0 disables: always full re-ship)
//! journal_bytes = 1048576  # optional byte budget per shard journal
//!                          # (encoded delta bytes; 0 = unbounded)
//! auth_token = s3cret    # optional: the coordinator gates its own
//!                        # shard verbs on it and sends it as the AUTH
//!                        # preamble when dialing. NOTE: it is NOT
//!                        # shipped to remote hosts — start each
//!                        # remote `pico serve` with PICO_AUTH_TOKEN
//!                        # set to the same value, or that host's
//!                        # shard verbs stay open
//!
//! [shard.0]
//! primary = local        # in the coordinator process
//! replicas = 127.0.0.1:7581, 127.0.0.1:7582
//!
//! [shard.1]
//! primary = 127.0.0.1:7591   # a running `pico serve` to ship the shard to
//! ```
//!
//! Every shard needs a `primary` (defaulting to `local`); `replicas` are
//! optional remote hosts that receive the same shard manifest and serve
//! epoch-checked reads with failover.

use crate::config::parser::KvFile;
use crate::service::server::MAX_SHARDS;
use crate::shard::PartitionStrategy;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Where a shard's primary lives.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// In the coordinator process (a plain `LocalShard`).
    Local,
    /// Shipped to (and driven over) a remote `pico serve` at `host:port`.
    Remote(String),
}

/// One shard's placement.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    pub primary: Endpoint,
    /// Remote replica hosts (`host:port`).
    pub replicas: Vec<String>,
}

/// A parsed cluster topology.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterConfig {
    pub name: String,
    pub dataset: String,
    pub partition: PartitionStrategy,
    /// Epochs of per-shard deltas the coordinator journals for replica
    /// catch-up (see [`crate::cluster::journal`]); 0 disables the
    /// journal so every catch-up re-ships the full manifest.
    pub journal_epochs: usize,
    /// Byte budget per shard journal (encoded delta bytes; 0 =
    /// unbounded). Evicts oldest epochs when it trips, independently of
    /// `journal_epochs`.
    pub journal_bytes: usize,
    /// Shared token the *coordinator* gates its shard verbs on and
    /// sends as the `AUTH` preamble when dialing shard hosts; `None`
    /// leaves them open. The token is never shipped over the wire to
    /// configure a host — each remote `pico serve` must be started
    /// with `PICO_AUTH_TOKEN` set to the same value to actually gate
    /// its own verbs (an unguarded host accepts any preamble). The env
    /// var overrides this field at serve/dial time.
    pub auth_token: Option<String>,
    pub shards: Vec<ShardSpec>,
}

impl ClusterConfig {
    pub fn parse(text: &str) -> Result<Self> {
        let kv = KvFile::parse(text)?;
        let name = kv.get("cluster.name").unwrap_or("cluster").to_string();
        if name.is_empty() || name.contains(char::is_whitespace) {
            bail!("cluster.name '{name}' must be non-empty without whitespace");
        }
        let dataset = kv.get("cluster.dataset").unwrap_or("g1").to_string();
        let partition =
            PartitionStrategy::parse(kv.get("cluster.partition").unwrap_or("hash"))?;
        let journal_epochs: usize = match kv.get("cluster.journal") {
            None => super::journal::DEFAULT_JOURNAL_EPOCHS,
            Some(v) => v
                .parse()
                .context("cluster.journal must be a number of epochs (0 disables)")?,
        };
        let journal_bytes: usize = match kv.get("cluster.journal_bytes") {
            None => 0,
            Some(v) => v
                .parse()
                .context("cluster.journal_bytes must be a byte count (0 = unbounded)")?,
        };
        let auth_token = match kv.get("cluster.auth_token") {
            None => None,
            Some(t) if t.is_empty() || t.contains(char::is_whitespace) => {
                bail!("cluster.auth_token must be non-empty without whitespace")
            }
            Some(t) => Some(t.to_string()),
        };
        let n: usize = kv
            .get("cluster.shards")
            .context("cluster.shards is required")?
            .parse()
            .context("cluster.shards must be a number")?;
        if n == 0 || n > MAX_SHARDS {
            bail!("cluster.shards must be 1..={MAX_SHARDS}, got {n}");
        }
        // reject typo'd / out-of-range shard sections instead of
        // silently ignoring them
        for key in kv.keys() {
            if let Some(rest) = key.strip_prefix("shard.") {
                let idx = rest.split('.').next().unwrap_or("");
                match idx.parse::<usize>() {
                    Ok(i) if i < n => {}
                    _ => bail!("config names shard '{idx}' but cluster.shards = {n}"),
                }
            } else if !key.starts_with("cluster.") {
                bail!("unknown config key '{key}'");
            }
        }
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let primary = match kv.get(&format!("shard.{i}.primary")) {
                None | Some("local") => Endpoint::Local,
                Some(addr) => {
                    check_addr(addr)?;
                    Endpoint::Remote(addr.to_string())
                }
            };
            let replicas: Vec<String> = match kv.get(&format!("shard.{i}.replicas")) {
                None => Vec::new(),
                Some(list) => {
                    let mut out = Vec::new();
                    for addr in list.split(',').map(str::trim).filter(|a| !a.is_empty()) {
                        check_addr(addr)?;
                        out.push(addr.to_string());
                    }
                    out
                }
            };
            shards.push(ShardSpec { primary, replicas });
        }
        Ok(Self {
            name,
            dataset,
            partition,
            journal_epochs,
            journal_bytes,
            auth_token,
            shards,
        })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.as_ref().display()))
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The graph name shard `i` is hosted under everywhere.
    pub fn shard_graph(&self, i: usize) -> String {
        format!("{}/shard{i}", self.name)
    }

    /// The auth token this topology dials and serves with: the
    /// `PICO_AUTH_TOKEN` env var when set (non-empty), else the
    /// topology's `auth_token`.
    pub fn effective_auth_token(&self) -> Option<String> {
        crate::net::env_auth_token().or_else(|| self.auth_token.clone())
    }
}

fn check_addr(addr: &str) -> Result<()> {
    if !addr.contains(':') {
        bail!("endpoint '{addr}' is not host:port");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
[cluster]
name = social
dataset = social-ba
shards = 2
partition = hash

[shard.0]
primary = local
replicas = 127.0.0.1:7581, 127.0.0.1:7582

[shard.1]
primary = 127.0.0.1:7591
";

    #[test]
    fn parses_a_mixed_topology() {
        let c = ClusterConfig::parse(GOOD).unwrap();
        assert_eq!(c.name, "social");
        assert_eq!(c.dataset, "social-ba");
        assert_eq!(c.partition, PartitionStrategy::Hash);
        assert_eq!(c.num_shards(), 2);
        assert_eq!(c.shards[0].primary, Endpoint::Local);
        assert_eq!(c.shards[0].replicas.len(), 2);
        assert_eq!(
            c.shards[1].primary,
            Endpoint::Remote("127.0.0.1:7591".into())
        );
        assert!(c.shards[1].replicas.is_empty());
        assert_eq!(c.shard_graph(1), "social/shard1");
    }

    #[test]
    fn defaults_fill_in() {
        let c = ClusterConfig::parse("[cluster]\nshards = 1\n").unwrap();
        assert_eq!(c.name, "cluster");
        assert_eq!(c.dataset, "g1");
        assert_eq!(c.journal_epochs, crate::cluster::journal::DEFAULT_JOURNAL_EPOCHS);
        assert_eq!(c.shards[0].primary, Endpoint::Local);
    }

    #[test]
    fn journal_retention_parses_and_validates() {
        let c = ClusterConfig::parse("[cluster]\nshards = 1\njournal = 0\n").unwrap();
        assert_eq!(c.journal_epochs, 0);
        assert_eq!(c.journal_bytes, 0, "byte budget defaults to unbounded");
        let c =
            ClusterConfig::parse("[cluster]\nshards = 1\njournal = 7\njournal_bytes = 4096\n")
                .unwrap();
        assert_eq!(c.journal_epochs, 7);
        assert_eq!(c.journal_bytes, 4096);
        assert!(ClusterConfig::parse("[cluster]\nshards = 1\njournal = lots\n").is_err());
        assert!(
            ClusterConfig::parse("[cluster]\nshards = 1\njournal_bytes = many\n").is_err()
        );
    }

    #[test]
    fn auth_token_parses_and_validates() {
        let c = ClusterConfig::parse("[cluster]\nshards = 1\n").unwrap();
        assert_eq!(c.auth_token, None);
        let c = ClusterConfig::parse("[cluster]\nshards = 1\nauth_token = s3cret\n").unwrap();
        assert_eq!(c.auth_token.as_deref(), Some("s3cret"));
        assert!(
            ClusterConfig::parse("[cluster]\nshards = 1\nauth_token = two words\n").is_err()
        );
    }

    #[test]
    fn bad_configs_are_rejected() {
        assert!(ClusterConfig::parse("").is_err(), "shards is required");
        assert!(ClusterConfig::parse("[cluster]\nshards = 0\n").is_err());
        assert!(ClusterConfig::parse("[cluster]\nshards = 9999\n").is_err());
        // shard section beyond the declared count
        assert!(
            ClusterConfig::parse("[cluster]\nshards = 1\n[shard.3]\nprimary = local\n").is_err()
        );
        // unknown top-level key
        assert!(ClusterConfig::parse("bogus = 1\n[cluster]\nshards = 1\n").is_err());
        // a primary that is not host:port
        assert!(ClusterConfig::parse(
            "[cluster]\nshards = 1\n[shard.0]\nprimary = nonsense\n"
        )
        .is_err());
        // whitespace in the name would break the protocol
        assert!(ClusterConfig::parse("[cluster]\nname = a b\nshards = 1\n").is_err());
    }
}
