//! The server side of a remote shard: what a `pico serve` process hosts
//! when a cluster coordinator ships it a shard manifest (`SHARDHOST`).
//!
//! A [`ShardHost`] wraps the same [`LocalShard`] the in-process router
//! uses — restored from the manifest via the hydration path (no
//! decomposition runs) — and turns the `SHARD*` verbs into calls on it.
//! Handlers produce complete reply lines/frames so the TCP layer in
//! [`crate::service::server`] stays a pure dispatcher.

use super::wire;
use crate::service::batch::BatchConfig;
use crate::service::index::CoreIndex;
use crate::shard::backend::{LocalShard, ShardBackend};
use anyhow::{Context, Result};
use std::sync::Arc;

/// One hosted shard of some cluster: primary or replica — the role is
/// the coordinator's concern; the host just serves the shard interface.
pub struct ShardHost {
    name: String,
    num_shards: u32,
    shard: LocalShard,
}

/// Serialise a shard's complete current state as a manifest — the
/// payload of initial shipping, `SHARDSNAP`, and replica catch-up.
/// The export is atomic with respect to concurrent applies (see
/// [`LocalShard::export_state`]).
pub fn manifest_for(shard: &LocalShard, num_shards: u32) -> Vec<u8> {
    let (globals, owned_locals, refined, cluster_epoch, snap) = shard.export_state();
    wire::encode_manifest(
        shard.id() as u32,
        num_shards,
        cluster_epoch,
        &globals,
        &owned_locals,
        &refined,
        &snap,
    )
}

impl ShardHost {
    /// Validate manifest bytes and hydrate the shard. Nothing is
    /// installed (and no decomposition runs) on a rejected payload.
    pub fn from_manifest_bytes(name: &str, bytes: &[u8], cfg: BatchConfig) -> Result<Self> {
        let m = wire::decode_manifest(bytes).context("shard manifest")?;
        let index = Arc::new(CoreIndex::hydrate(
            name,
            &m.snapshot.graph,
            m.snapshot.core,
            m.snapshot.epoch,
        ));
        let shard = LocalShard::from_parts(
            m.shard_id as usize,
            index,
            m.globals,
            m.owned_locals,
            m.refined,
            m.cluster_epoch,
            cfg,
        )?;
        Ok(Self {
            name: name.to_string(),
            num_shards: m.num_shards,
            shard,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn num_shards(&self) -> u32 {
        self.num_shards
    }

    pub fn shard_id(&self) -> usize {
        self.shard.id()
    }

    pub fn cluster_epoch(&self) -> u64 {
        self.shard
            .status()
            .expect("local shard status is infallible")
            .cluster_epoch
    }

    /// The underlying shard index (EPOCH/CORENESS/SNAPSHOT on a shard
    /// host read the shard-local view — exact global answers come from
    /// the cluster router's merge).
    pub fn index(&self) -> Arc<CoreIndex> {
        self.shard.index()
    }

    /// `SHARDINFO` — the health / epoch probe. `bytes=` is the exact
    /// size of this shard's full manifest — the cost of a snapshot
    /// re-ship, which `pico cluster status` reports as the full
    /// catch-up price next to the epoch lag.
    pub fn info(&self) -> String {
        let s = self.shard.status().expect("local shard status is infallible");
        format!(
            "OK shard={} shards={} epoch={} cluster={} owned={} kmax={} bytes={}",
            s.id, self.num_shards, s.epoch, s.cluster_epoch, s.owned, s.k_max, s.state_bytes
        )
    }

    /// `SHARDCORE <v>` — committed refined coreness of an owned vertex.
    pub fn core_line(&self, args: &[&str]) -> String {
        let Some(Ok(v)) = args.first().map(|a| a.parse::<u32>()) else {
            return "ERR usage: SHARDCORE <v>".into();
        };
        let (core, cluster) = self
            .shard
            .refined_coreness(v)
            .expect("local shard reads are infallible");
        match core {
            Some(c) => format!("OK core={c} cluster={cluster}"),
            None => format!("OK core=none cluster={cluster}"),
        }
    }

    /// `SHARDHISTO` — committed histogram over owned vertices.
    pub fn histo_line(&self) -> String {
        let (hist, cluster) = self
            .shard
            .histogram_partial()
            .expect("local shard reads are infallible");
        let cells: Vec<String> = hist
            .iter()
            .enumerate()
            .map(|(k, n)| format!("{k}:{n}"))
            .collect();
        format!("OK cluster={cluster} histo={}", cells.join(","))
    }

    /// `SHARDMEMBERS <k>` — owned members frame (head + u32 payload).
    pub fn members_frame(&self, args: &[&str]) -> Vec<u8> {
        let Some(Ok(k)) = args.first().map(|a| a.parse::<u32>()) else {
            return b"ERR usage: SHARDMEMBERS <k>".to_vec();
        };
        let (members, cluster) = self
            .shard
            .members_partial(k)
            .expect("local shard reads are infallible");
        let mut out = format!("OK count={} cluster={cluster}\n", members.len()).into_bytes();
        out.extend_from_slice(&wire::encode_u32s(&members));
        out
    }

    /// `SHARDAPPLY` — a routed batch through the shard's pipeline.
    pub fn apply_frame(&self, payload: &[u8]) -> Vec<u8> {
        let batch = match wire::decode_batch(payload) {
            Ok(b) => b,
            Err(e) => return format!("ERR shardapply: {e:#}").into_bytes(),
        };
        match self.shard.apply(&batch) {
            Ok(out) => format!(
                "OK changed={} recomputed={} epoch={}",
                out.changed, out.recomputed as u8, out.epoch
            )
            .into_bytes(),
            Err(e) => format!("ERR shardapply: {e:#}").into_bytes(),
        }
    }

    /// `SHARDREFINE START <slack|-> | ROUND | COMMIT <epoch>` — the
    /// boundary-exchange sub-verbs.
    pub fn refine_frame(&self, args: &[&str], payload: &[u8]) -> Vec<u8> {
        let sub = args.first().map(|s| s.to_ascii_uppercase()).unwrap_or_default();
        match sub.as_str() {
            "START" => {
                let slack = match args.get(1) {
                    None | Some(&"-") => None,
                    Some(s) => match s.parse::<u32>() {
                        Ok(v) => Some(v),
                        Err(_) => {
                            return format!("ERR bad slack '{s}' (number or -)").into_bytes()
                        }
                    },
                };
                match self.shard.refine_start(slack) {
                    Ok(init) => {
                        let mut out = format!(
                            "OK refine-init owned={} ghosts={}\n",
                            init.owned_est.len(),
                            init.ghosts.len()
                        )
                        .into_bytes();
                        out.extend_from_slice(&wire::encode_refine_init(&init));
                        out
                    }
                    Err(e) => format!("ERR refine start: {e:#}").into_bytes(),
                }
            }
            "ROUND" => {
                let updates = match wire::decode_pairs(payload) {
                    Ok(u) => u,
                    Err(e) => return format!("ERR refine round: {e:#}").into_bytes(),
                };
                match self.shard.refine_round(&updates) {
                    Ok(r) => {
                        let mut out =
                            format!("OK sweeps={} ghosts={}\n", r.sweeps, r.ghost_updates)
                                .into_bytes();
                        out.extend_from_slice(&wire::encode_pairs(&r.changed));
                        out
                    }
                    Err(e) => format!("ERR refine round: {e:#}").into_bytes(),
                }
            }
            "COMMIT" => {
                let Some(Ok(epoch)) = args.get(1).map(|a| a.parse::<u64>()) else {
                    return b"ERR usage: SHARDREFINE COMMIT <epoch>".to_vec();
                };
                match self.shard.refine_commit(epoch) {
                    Ok(diff) => {
                        // the commit's refined diff rides the reply so
                        // the router can journal it for delta catch-up
                        // without another round trip
                        let mut out =
                            format!("OK commit={epoch} changed={}\n", diff.len()).into_bytes();
                        out.extend_from_slice(&wire::encode_pairs(&diff));
                        out
                    }
                    Err(e) => format!("ERR refine commit: {e:#}").into_bytes(),
                }
            }
            other => format!("ERR unknown SHARDREFINE sub-verb '{other}' (START|ROUND|COMMIT)")
                .into_bytes(),
        }
    }

    /// `SHARDHAND EXPORT <count> | ADOPT | RELEASE` — the rebalance
    /// handoff verbs, one per executor step: export encodes this shard's
    /// boundary-heaviest owned vertices (head + handoff payload), adopt
    /// splices a handoff payload in as owned state (head + adopted-id
    /// payload), release demotes previously exported vertices to ghosts
    /// once the adopter confirmed. The ordering guarantee (adopt before
    /// release) lives in the coordinator; each step here is individually
    /// validated and refuses replays.
    pub fn hand_frame(&self, args: &[&str], payload: &[u8]) -> Vec<u8> {
        let sub = args.first().map(|s| s.to_ascii_uppercase()).unwrap_or_default();
        match sub.as_str() {
            "EXPORT" => {
                let Some(Ok(count)) = args.get(1).map(|a| a.parse::<usize>()) else {
                    return b"ERR usage: SHARDHAND EXPORT <count>".to_vec();
                };
                match self.shard.handoff_export(count) {
                    Ok(bytes) => {
                        let mut out = format!(
                            "OK handoff shard={} bytes={}\n",
                            self.shard.id(),
                            bytes.len()
                        )
                        .into_bytes();
                        out.extend_from_slice(&bytes);
                        out
                    }
                    Err(e) => format!("ERR shardhand export: {e:#}").into_bytes(),
                }
            }
            "ADOPT" => match self.shard.handoff_adopt(payload) {
                Ok(adopted) => {
                    let mut out =
                        format!("OK adopted={} shard={}\n", adopted.len(), self.shard.id())
                            .into_bytes();
                    out.extend_from_slice(&wire::encode_u32s(&adopted));
                    out
                }
                Err(e) => format!("ERR shardhand adopt: {e:#}").into_bytes(),
            },
            "RELEASE" => {
                let vertices = match wire::decode_u32s(payload) {
                    Ok(v) => v,
                    Err(e) => return format!("ERR shardhand release: {e:#}").into_bytes(),
                };
                match self.shard.handoff_release(&vertices) {
                    Ok(()) => format!("OK released={}", vertices.len()).into_bytes(),
                    Err(e) => format!("ERR shardhand release: {e:#}").into_bytes(),
                }
            }
            other => {
                format!("ERR unknown SHARDHAND sub-verb '{other}' (EXPORT|ADOPT|RELEASE)")
                    .into_bytes()
            }
        }
    }

    /// `SHARDSNAP` — the full manifest for replica catch-up.
    pub fn snap_frame(&self) -> Vec<u8> {
        let manifest = manifest_for(&self.shard, self.num_shards);
        let mut out = format!("OK shardsnap name={} bytes={}\n", self.name, manifest.len())
            .into_bytes();
        out.extend_from_slice(&manifest);
        out
    }

    /// `SHARDDELTA <from> <to>` + chain payload — delta replica
    /// catch-up. The chain is validated in full (codec + base-epoch
    /// match) before anything is applied; each step then replays the
    /// primary's routed batch through the shard's own apply path and
    /// installs the committed refined diff, so the replica ends
    /// byte-identical to the primary **without recomputing anything**.
    /// Any rejection surfaces as `ERR` and the router falls back to a
    /// full-manifest re-ship.
    pub fn delta_frame(&self, args: &[&str], payload: &[u8]) -> Vec<u8> {
        let (Some(Ok(from)), Some(Ok(to))) = (
            args.first().map(|a| a.parse::<u64>()),
            args.get(1).map(|a| a.parse::<u64>()),
        ) else {
            return b"ERR usage: SHARDDELTA <from_epoch> <to_epoch> (chain bytes follow)".to_vec();
        };
        let (chain_from, chain_to, deltas) = match wire::decode_delta_chain(payload) {
            Ok(c) => c,
            Err(e) => return format!("ERR sharddelta: {e:#}").into_bytes(),
        };
        if (chain_from, chain_to) != (from, to) {
            return format!(
                "ERR sharddelta: payload covers {chain_from}..{chain_to}, command says {from}..{to}"
            )
            .into_bytes();
        }
        let current = self.cluster_epoch();
        if current != from {
            // machine-readable: the rebalance executor's catch-up loop
            // keys off STALE_EPOCH to re-probe instead of string-matching
            return crate::net::conn::err_reply(
                crate::net::conn::code::STALE_EPOCH,
                format!("sharddelta: chain starts at epoch {from} but this replica is at {current}"),
            )
            .into_bytes();
        }
        for d in &deltas {
            // untouched shards never saw an apply on the primary either —
            // skipping keeps the shard-local index epoch in lockstep
            if !d.batch.is_empty() {
                if let Err(e) = self.shard.apply(&d.batch) {
                    return format!("ERR sharddelta: replaying epoch {}: {e:#}", d.to_epoch)
                        .into_bytes();
                }
            }
            if let Err(e) = self.shard.install_refined_diff(&d.diff, d.to_epoch) {
                return format!("ERR sharddelta: committing epoch {}: {e:#}", d.to_epoch)
                    .into_bytes();
            }
        }
        format!("OK sharddelta={} epochs={} cluster={to}", self.name, deltas.len()).into_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::examples;
    use crate::shard::partition::{partition, PartitionStrategy};

    fn cfg() -> BatchConfig {
        BatchConfig {
            threads: 1,
            ..BatchConfig::default()
        }
    }

    fn hosted() -> ShardHost {
        let g = examples::g1();
        let plan = partition(&g, 2, PartitionStrategy::Hash);
        let shard = LocalShard::from_plan("c", &plan.shards[0], cfg());
        shard.refine_start(None).unwrap();
        shard.refine_round(&[]).unwrap();
        shard.refine_commit(3).unwrap();
        let bytes = manifest_for(&shard, 2);
        ShardHost::from_manifest_bytes("c/shard0", &bytes, cfg()).unwrap()
    }

    #[test]
    fn manifest_hosting_preserves_state() {
        let h = hosted();
        let info = h.info();
        assert!(info.starts_with("OK shard=0 shards=2 epoch=0 cluster=3"), "{info}");
        assert_eq!(h.num_shards(), 2);
        // refined reads survived the ship (no recompute ran: the index
        // epoch is still the shard's own epoch 0)
        let s = h.index().snapshot();
        assert_eq!(s.epoch, 0);
        let histo = h.histo_line();
        assert!(histo.starts_with("OK cluster=3 histo="), "{histo}");
    }

    #[test]
    fn verb_error_paths_are_structured() {
        let h = hosted();
        assert!(h.core_line(&[]).starts_with("ERR usage"));
        assert!(h.core_line(&["zzz"]).starts_with("ERR usage"));
        assert!(String::from_utf8(h.members_frame(&[])).unwrap().starts_with("ERR usage"));
        assert!(String::from_utf8(h.apply_frame(b"junk")).unwrap().starts_with("ERR shardapply"));
        assert!(String::from_utf8(h.refine_frame(&["NOPE"], b""))
            .unwrap()
            .starts_with("ERR unknown SHARDREFINE"));
        assert!(String::from_utf8(h.refine_frame(&["START", "x"], b""))
            .unwrap()
            .starts_with("ERR bad slack"));
        assert!(String::from_utf8(h.refine_frame(&["ROUND"], b"junk"))
            .unwrap()
            .starts_with("ERR refine round"));
        assert!(ShardHost::from_manifest_bytes("x", b"garbage", cfg()).is_err());
    }

    #[test]
    fn refine_verbs_drive_the_shard() {
        let h = hosted();
        let start = h.refine_frame(&["START", "-"], b"");
        let nl = start.iter().position(|&b| b == b'\n').unwrap();
        assert!(std::str::from_utf8(&start[..nl]).unwrap().starts_with("OK refine-init"));
        wire::decode_refine_init(&start[nl + 1..]).unwrap();
        let round = h.refine_frame(&["ROUND"], &wire::encode_pairs(&[]));
        let nl = round.iter().position(|&b| b == b'\n').unwrap();
        assert!(std::str::from_utf8(&round[..nl]).unwrap().starts_with("OK sweeps=1"));
        let commit = h.refine_frame(&["COMMIT", "9"], b"");
        let nl = commit.iter().position(|&b| b == b'\n').unwrap();
        assert!(std::str::from_utf8(&commit[..nl]).unwrap().starts_with("OK commit=9 changed="));
        wire::decode_pairs(&commit[nl + 1..]).unwrap();
        assert!(h.info().contains("cluster=9"));
    }

    #[test]
    fn handoff_verbs_move_owned_vertices_between_hosts() {
        let g = examples::g1();
        let plan = partition(&g, 2, PartitionStrategy::Hash);
        let make = |i: usize| {
            let shard = LocalShard::from_plan("c", &plan.shards[i], cfg());
            shard.refine_start(None).unwrap();
            shard.refine_round(&[]).unwrap();
            shard.refine_commit(1).unwrap();
            let bytes = manifest_for(&shard, 2);
            ShardHost::from_manifest_bytes(&format!("c/shard{i}"), &bytes, cfg()).unwrap()
        };
        let (a, b) = (make(0), make(1));
        // usage / structured errors
        assert!(String::from_utf8(a.hand_frame(&[], b"")).unwrap().starts_with("ERR unknown SHARDHAND"));
        assert!(String::from_utf8(a.hand_frame(&["EXPORT"], b"")).unwrap().starts_with("ERR usage"));
        assert!(String::from_utf8(b.hand_frame(&["ADOPT"], b"junk")).unwrap().starts_with("ERR shardhand adopt"));
        assert!(String::from_utf8(b.hand_frame(&["RELEASE"], b"junk")).unwrap().starts_with("ERR shardhand release"));
        // export → adopt → release
        let owned_a = a.shard.status().unwrap().owned;
        let reply = a.hand_frame(&["EXPORT", "2"], b"");
        let nl = reply.iter().position(|&c| c == b'\n').unwrap();
        assert!(std::str::from_utf8(&reply[..nl]).unwrap().starts_with("OK handoff shard=0"));
        let payload = &reply[nl + 1..];
        let adopted = b.hand_frame(&["ADOPT"], payload);
        let nl = adopted.iter().position(|&c| c == b'\n').unwrap();
        assert!(std::str::from_utf8(&adopted[..nl]).unwrap().starts_with("OK adopted=2 shard=1"));
        let ids = wire::decode_u32s(&adopted[nl + 1..]).unwrap();
        assert_eq!(ids.len(), 2);
        let released = a.hand_frame(&["RELEASE"], &wire::encode_u32s(&ids));
        assert!(String::from_utf8(released).unwrap().starts_with("OK released=2"));
        assert_eq!(a.shard.status().unwrap().owned, owned_a - 2);
    }

    #[test]
    fn delta_frames_validate_before_touching_state() {
        use crate::cluster::journal::EpochDelta;

        let h = hosted(); // replica committed at cluster epoch 3
        let info_before = h.info();
        // usage / codec errors
        assert!(String::from_utf8(h.delta_frame(&[], b"")).unwrap().starts_with("ERR usage"));
        assert!(String::from_utf8(h.delta_frame(&["3", "4"], b"garbage"))
            .unwrap()
            .starts_with("ERR sharddelta:"));
        // a chain whose base is not the replica's epoch is refused
        let stale = [EpochDelta {
            to_epoch: 8,
            batch: Default::default(),
            diff: vec![],
        }];
        let refs: Vec<&EpochDelta> = stale.iter().collect();
        let bytes = wire::encode_delta_chain(7, 8, &refs);
        let reply = String::from_utf8(h.delta_frame(&["7", "8"], &bytes)).unwrap();
        assert!(reply.contains("this replica is at 3"), "{reply}");
        // command/payload range disagreement is refused
        let reply = String::from_utf8(h.delta_frame(&["3", "4"], &bytes)).unwrap();
        assert!(reply.contains("command says"), "{reply}");
        // a diff naming an unknown vertex is refused
        let evil = [EpochDelta {
            to_epoch: 4,
            batch: Default::default(),
            diff: vec![(999_999, 1)],
        }];
        let refs: Vec<&EpochDelta> = evil.iter().collect();
        let chain = wire::encode_delta_chain(3, 4, &refs);
        let reply = String::from_utf8(h.delta_frame(&["3", "4"], &chain)).unwrap();
        assert!(reply.starts_with("ERR sharddelta: committing epoch 4"), "{reply}");
        assert_eq!(h.info(), info_before, "rejected deltas must not move the epoch");

        // a well-formed empty-batch, empty-diff step advances the epoch
        let ok = [EpochDelta {
            to_epoch: 4,
            batch: Default::default(),
            diff: vec![],
        }];
        let refs: Vec<&EpochDelta> = ok.iter().collect();
        let chain = wire::encode_delta_chain(3, 4, &refs);
        let reply = String::from_utf8(h.delta_frame(&["3", "4"], &chain)).unwrap();
        assert!(reply.starts_with("OK sharddelta="), "{reply}");
        assert!(h.info().contains("cluster=4"), "{}", h.info());
    }
}
