//! The remote shard client: a [`ShardBackend`] whose shard lives behind
//! a `pico serve` process on another host (or a loopback port — the
//! tests and `benches/cluster_overhead.rs` drive it that way).
//!
//! One `RemoteShard` is one sticky binary-protocol connection to one
//! server, pinned to one hosted shard graph — a thin verb layer over
//! the shared [`crate::net::client::FrameClient`] (dialing, the
//! `BINARY` upgrade, the `AUTH` preamble, graph pinning, and the
//! re-dial-once policy all live there). Every trait method maps to
//! exactly one frame round trip (`SHARDAPPLY`, `SHARDREFINE START/
//! ROUND/COMMIT`, `SHARDINFO`, `SHARDCORE`, …), so a boundary-exchange
//! round over the cluster costs one frame each way per shard regardless
//! of batch size.
//!
//! A connection that dies between calls is re-dialed once — but a lost
//! reply is replayed only for *idempotent* verbs (probes, reads,
//! `REFINE START`, manifest shipping). `SHARDAPPLY`, `REFINE ROUND`,
//! `REFINE COMMIT`, and `SHARDDELTA` mutate state the retry cannot see
//! (a replayed ROUND whose first reply was lost would re-sweep from an
//! already-swept state and report no changes, silently corrupting the
//! router's mailbox; a replayed COMMIT would report an empty refined
//! diff and the journal would ship deltas that skip real changes), so
//! those surface the error to the router instead — which is what
//! replica failover and flush error reporting key off. The client never
//! retries on a *fresh* connection — if a just-dialed socket fails, the
//! host is down and the caller needs to know now.

use super::wire;
use crate::graph::VertexId;
use crate::net::client::{field, field_u64, FrameClient};
use crate::net::codec;
use crate::obs::TraceScope;
use crate::shard::backend::{
    ApplyOutcome, RefineInit, RefineRound, RoutedBatch, ShardBackend, ShardStatus,
};
use anyhow::{anyhow, bail, Context, Result};

/// A shard served by a remote `pico serve` process.
pub struct RemoteShard {
    id: usize,
    client: FrameClient,
    /// Flush-trace mailbox: while the coordinator has armed it, the
    /// mutating shard verbs carry a trailing ` trace=<hex>` token and
    /// the host's measured `us=` comes back as a remote child span
    /// (see [`crate::obs::trace`]).
    scope: TraceScope,
}

impl RemoteShard {
    /// A client for shard `id`, hosted on the server at `addr` under the
    /// graph name `graph` (conventionally `<cluster>/shard<id>`).
    pub fn new(id: usize, addr: impl Into<String>, graph: impl Into<String>) -> Self {
        Self {
            id,
            client: FrameClient::new(addr, graph),
            scope: TraceScope::default(),
        }
    }

    /// Send `AUTH <token>` on every (re)connect — required when the
    /// shard host gates its shard verbs (topology `auth_token` /
    /// `PICO_AUTH_TOKEN`).
    pub fn with_auth(mut self, token: Option<String>) -> Self {
        self.client = self.client.with_auth(token);
        self
    }

    pub fn addr(&self) -> &str {
        self.client.addr()
    }

    pub fn graph(&self) -> &str {
        self.client.graph()
    }

    /// The flush-trace mailbox the cluster router arms around a flush.
    pub fn trace_scope(&self) -> &TraceScope {
        &self.scope
    }

    /// The head line with the active trace id attached, when a flush
    /// trace is in progress.
    fn traced(&self, line: &str) -> String {
        match self.scope.active() {
            Some(id) => codec::attach_trace(line, id),
            None => line.to_string(),
        }
    }

    /// Record the host's measured handler time (the reply's `us=`
    /// field) as a remote child span under `stage`. Pre-trace servers
    /// send no `us=` and record nothing.
    fn note_remote(&self, stage: &str, name_prefix: &str, head: &str) {
        if self.scope.active().is_some() {
            if let Some(us) = codec::reply_us(head) {
                let name = format!("{name_prefix} shard={}", self.id);
                self.scope.record_remote(stage, name, self.addr(), us);
            }
        }
    }

    /// Idempotent line verb (probes, reads): safe to replay.
    fn call_line(&self, line: &str) -> Result<(String, Vec<u8>)> {
        self.client.call_idempotent(line.as_bytes(), true)
    }

    /// Non-idempotent payload verb: never replayed after a lost reply.
    fn call_payload_once(&self, line: &str, payload: &[u8]) -> Result<(String, Vec<u8>)> {
        let mut body = line.as_bytes().to_vec();
        body.push(b'\n');
        body.extend_from_slice(payload);
        self.client.call_once(&body, true)
    }

    /// Liveness probe (needs no hosted graph).
    pub fn ping(&self) -> Result<()> {
        let (head, _) = self.client.call_idempotent(b"PING", false)?;
        if head != "OK pong" {
            bail!("unexpected PING reply '{head}'");
        }
        Ok(())
    }

    /// Install (or overwrite) the shard on the remote from a manifest —
    /// initial shipping and snapshot catch-up both land here. The remote
    /// hydrates without recomputing anything.
    pub fn host(&self, manifest: &[u8]) -> Result<()> {
        // idempotent: re-installing the same manifest reproduces the
        // same hosted state, so a lost reply is safe to replay
        let mut body = format!("SHARDHOST {}", self.client.graph()).into_bytes();
        body.push(b'\n');
        body.extend_from_slice(manifest);
        let (head, _) = self.client.call_idempotent(&body, false)?;
        field(&head, "shardhost")?;
        // the server switched its session to the freshly hosted graph
        self.client.mark_selected();
        Ok(())
    }

    /// Ship a delta chain (`(from, to]` epochs) to a lagging replica —
    /// the incremental alternative to [`Self::host`]. NOT idempotent
    /// (a replayed chain would double-apply its routed batches), so a
    /// lost reply surfaces as an error and the caller falls back to a
    /// full-manifest ship.
    pub fn apply_delta(&self, from: u64, to: u64, chain: &[u8]) -> Result<()> {
        let (head, _) = self.call_payload_once(&format!("SHARDDELTA {from} {to}"), chain)?;
        if field_u64(&head, "cluster")? != to {
            bail!("SHARDDELTA landed on the wrong epoch: '{head}'");
        }
        Ok(())
    }

    /// Pull the remote's current manifest (the replica catch-up source
    /// when this client points at a group's primary).
    pub fn fetch_manifest(&self) -> Result<Vec<u8>> {
        let (head, payload) = self.call_line("SHARDSNAP")?;
        let declared = field_u64(&head, "bytes")? as usize;
        if declared != payload.len() {
            bail!(
                "SHARDSNAP declared {declared} bytes but shipped {}",
                payload.len()
            );
        }
        Ok(payload)
    }
}

impl ShardBackend for RemoteShard {
    fn id(&self) -> usize {
        self.id
    }

    fn kind(&self) -> &'static str {
        "remote"
    }

    fn status(&self) -> Result<ShardStatus> {
        let (head, _) = self.call_line("SHARDINFO")?;
        Ok(ShardStatus {
            id: field_u64(&head, "shard")? as usize,
            epoch: field_u64(&head, "epoch")?,
            cluster_epoch: field_u64(&head, "cluster")?,
            owned: field_u64(&head, "owned")? as usize,
            k_max: field_u64(&head, "kmax")? as u32,
            state_bytes: field_u64(&head, "bytes")?,
        })
    }

    fn apply(&self, batch: &RoutedBatch) -> Result<ApplyOutcome> {
        // NOT idempotent (toggling edits double-apply); never replayed
        let (head, _) =
            self.call_payload_once(&self.traced("SHARDAPPLY"), &wire::encode_batch(batch))?;
        self.note_remote("apply", "apply", &head);
        Ok(ApplyOutcome {
            changed: field_u64(&head, "changed")? as usize,
            recomputed: field_u64(&head, "recomputed")? != 0,
            epoch: field_u64(&head, "epoch")?,
        })
    }

    fn refine_start(&self, slack: Option<u32>) -> Result<RefineInit> {
        let arg = match slack {
            Some(s) => s.to_string(),
            None => "-".to_string(),
        };
        let (_, payload) = self.call_line(&format!("SHARDREFINE START {arg}"))?;
        wire::decode_refine_init(&payload)
    }

    fn refine_round(&self, updates: &[(VertexId, u32)]) -> Result<RefineRound> {
        // NOT idempotent (the first execution clears the dirty flag; a
        // replay would report an empty sweep); never replayed
        let line = self.traced("SHARDREFINE ROUND");
        let (head, payload) = self.call_payload_once(&line, &wire::encode_pairs(updates))?;
        self.note_remote("refine", "round", &head);
        Ok(RefineRound {
            changed: wire::decode_pairs(&payload)?,
            sweeps: field_u64(&head, "sweeps")? as usize,
            ghost_updates: field_u64(&head, "ghosts")?,
        })
    }

    fn refine_commit(&self, cluster_epoch: u64) -> Result<Vec<(VertexId, u32)>> {
        // NOT idempotent: the first execution freezes est into refined,
        // so a replayed COMMIT after a lost reply would report an
        // *empty* diff and the journal would ship a delta that skips
        // real coreness changes; never replayed
        let line = self.traced(&format!("SHARDREFINE COMMIT {cluster_epoch}"));
        let (head, payload) = self.call_payload_once(&line, b"")?;
        self.note_remote("commit", "commit", &head);
        if field_u64(&head, "commit")? != cluster_epoch {
            bail!("commit echoed the wrong epoch: '{head}'");
        }
        let diff = wire::decode_pairs(&payload)?;
        if diff.len() as u64 != field_u64(&head, "changed")? {
            bail!("SHARDREFINE COMMIT changed= disagrees with the diff payload");
        }
        Ok(diff)
    }

    fn refined_coreness(&self, v: VertexId) -> Result<(Option<u32>, u64)> {
        let (head, _) = self.call_line(&format!("SHARDCORE {v}"))?;
        let cluster = field_u64(&head, "cluster")?;
        let core = match field(&head, "core")? {
            "none" => None,
            c => Some(c.parse::<u32>().with_context(|| format!("bad core in '{head}'"))?),
        };
        Ok((core, cluster))
    }

    fn histogram_partial(&self) -> Result<(Vec<u64>, u64)> {
        let (head, _) = self.call_line("SHARDHISTO")?;
        let cluster = field_u64(&head, "cluster")?;
        let mut hist: Vec<u64> = Vec::new();
        for cell in field(&head, "histo")?.split(',').filter(|c| !c.is_empty()) {
            let (k, n) = cell
                .split_once(':')
                .ok_or_else(|| anyhow!("bad histo cell '{cell}'"))?;
            let k: usize = k.parse().with_context(|| format!("bad histo cell '{cell}'"))?;
            let n: u64 = n.parse().with_context(|| format!("bad histo cell '{cell}'"))?;
            if k >= hist.len() {
                hist.resize(k + 1, 0);
            }
            hist[k] += n;
        }
        Ok((hist, cluster))
    }

    fn members_partial(&self, k: u32) -> Result<(Vec<VertexId>, u64)> {
        let (head, payload) = self.call_line(&format!("SHARDMEMBERS {k}"))?;
        let cluster = field_u64(&head, "cluster")?;
        let members = wire::decode_u32s(&payload)?;
        if members.len() as u64 != field_u64(&head, "count")? {
            bail!("SHARDMEMBERS count disagrees with payload");
        }
        Ok((members, cluster))
    }

    fn handoff_export(&self, count: usize) -> Result<Vec<u8>> {
        // idempotent: exporting reads committed state, changes nothing
        // (ownership only moves at ADOPT/RELEASE)
        let (head, payload) = self.call_line(&format!("SHARDHAND EXPORT {count}"))?;
        if field_u64(&head, "bytes")? as usize != payload.len() {
            bail!(
                "SHARDHAND EXPORT declared {} bytes but shipped {}",
                field_u64(&head, "bytes")?,
                payload.len()
            );
        }
        Ok(payload)
    }

    fn handoff_adopt(&self, bytes: &[u8]) -> Result<Vec<VertexId>> {
        // NOT idempotent: the remote refuses already-owned vertices, so
        // a replayed ADOPT whose first reply was lost would error even
        // though the move landed — surface the error and let the
        // rebalance executor probe ownership instead of replaying
        let (head, payload) = self.call_payload_once("SHARDHAND ADOPT", bytes)?;
        let adopted = wire::decode_u32s(&payload)?;
        if adopted.len() as u64 != field_u64(&head, "adopted")? {
            bail!("SHARDHAND ADOPT adopted= disagrees with the id payload");
        }
        Ok(adopted)
    }

    fn handoff_release(&self, vertices: &[VertexId]) -> Result<()> {
        // NOT idempotent: releasing an already-released vertex errors
        let (head, _) =
            self.call_payload_once("SHARDHAND RELEASE", &wire::encode_u32s(vertices))?;
        if field_u64(&head, "released")? as usize != vertices.len() {
            bail!("SHARDHAND RELEASE released= disagrees with the request");
        }
        Ok(())
    }
}

impl std::fmt::Debug for RemoteShard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "RemoteShard(#{} {} '{}')",
            self.id,
            self.client.addr(),
            self.client.graph()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dead_host_fails_fast() {
        // reserved port: nothing listens; the dial must fail, not hang
        let r = RemoteShard::new(0, "127.0.0.1:1", "x/shard0");
        assert!(r.ping().is_err());
        assert!(r.status().is_err());
    }
}
