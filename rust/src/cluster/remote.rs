//! The remote shard client: a [`ShardBackend`] whose shard lives behind
//! a `pico serve` process on another host (or a loopback port — the
//! tests and `benches/cluster_overhead.rs` drive it that way).
//!
//! One `RemoteShard` is one sticky binary-protocol connection to one
//! server, pinned to one hosted shard graph. Every trait method maps to
//! exactly one frame round trip (`SHARDAPPLY`, `SHARDREFINE START/ROUND/
//! COMMIT`, `SHARDINFO`, `SHARDCORE`, …), so a boundary-exchange round
//! over the cluster costs one frame each way per shard regardless of
//! batch size.
//!
//! A connection that dies between calls is re-dialed once — but a lost
//! reply is replayed only for *idempotent* verbs (probes, reads,
//! `REFINE START`, manifest shipping). `SHARDAPPLY`, `REFINE ROUND`,
//! `REFINE COMMIT`, and `SHARDDELTA` mutate state the retry cannot see
//! (a replayed ROUND whose first reply was lost would re-sweep from an
//! already-swept state and report no changes, silently corrupting the
//! router's mailbox; a replayed COMMIT would report an empty refined
//! diff and the journal would ship deltas that skip real changes), so
//! those surface the error to the router instead — which is what
//! replica failover and flush error reporting key off. The client never
//! retries on a *fresh* connection — if a just-dialed socket fails, the
//! host is down and the caller needs to know now.

use super::wire;
use crate::graph::VertexId;
use crate::service::server::{read_frame, write_frame, MAX_FRAME_BYTES};
use crate::shard::backend::{
    ApplyOutcome, RefineInit, RefineRound, RoutedBatch, ShardBackend, ShardStatus,
};
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Mutex;
use std::time::Duration;

/// Dial timeout for (re)connects — a dead host must fail over quickly.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);

struct Conn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    /// Whether the server session is pinned to this client's shard
    /// graph. Until `USE` succeeds (or `SHARDHOST` installs the graph),
    /// shard verbs must NOT be sent — the server session would fall
    /// back to its default graph and silently answer for the wrong
    /// shard.
    selected: bool,
}

/// A shard served by a remote `pico serve` process.
pub struct RemoteShard {
    id: usize,
    addr: String,
    graph: String,
    conn: Mutex<Option<Conn>>,
}

/// `key=value` token lookup in a reply head line.
fn field<'a>(head: &'a str, key: &str) -> Result<&'a str> {
    head.split_whitespace()
        .find_map(|tok| tok.strip_prefix(key).and_then(|r| r.strip_prefix('=')))
        .ok_or_else(|| anyhow!("missing {key}= in reply '{head}'"))
}

fn field_u64(head: &str, key: &str) -> Result<u64> {
    field(head, key)?
        .parse::<u64>()
        .with_context(|| format!("bad {key}= in reply '{head}'"))
}

/// Split a reply frame into its head line and raw payload; `ERR` heads
/// become errors.
fn split_reply(frame: Vec<u8>) -> Result<(String, Vec<u8>)> {
    let (head, payload) = match frame.iter().position(|&b| b == b'\n') {
        Some(i) => (&frame[..i], frame[i + 1..].to_vec()),
        None => (&frame[..], Vec::new()),
    };
    let head = std::str::from_utf8(head)
        .context("reply head not UTF-8")?
        .to_string();
    if head.starts_with("ERR") {
        bail!("remote shard: {head}");
    }
    Ok((head, payload))
}

impl RemoteShard {
    /// A client for shard `id`, hosted on the server at `addr` under the
    /// graph name `graph` (conventionally `<cluster>/shard<id>`).
    pub fn new(id: usize, addr: impl Into<String>, graph: impl Into<String>) -> Self {
        Self {
            id,
            addr: addr.into(),
            graph: graph.into(),
            conn: Mutex::new(None),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn graph(&self) -> &str {
        &self.graph
    }

    fn connect(&self) -> Result<Conn> {
        let sockaddr = self
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {}", self.addr))?
            .next()
            .with_context(|| format!("{} resolves to no address", self.addr))?;
        let stream = TcpStream::connect_timeout(&sockaddr, CONNECT_TIMEOUT)
            .with_context(|| format!("dialing shard host {}", self.addr))?;
        let mut writer = stream.try_clone().context("cloning the connection")?;
        let mut reader = BufReader::new(stream);
        writeln!(writer, "BINARY").context("binary upgrade")?;
        writer.flush().context("binary upgrade")?;
        let mut line = String::new();
        reader.read_line(&mut line).context("binary upgrade reply")?;
        if line.trim_end() != "OK binary" {
            bail!("{} refused the binary upgrade: {}", self.addr, line.trim_end());
        }
        Ok(Conn {
            writer,
            reader,
            selected: false,
        })
    }

    /// Pin the server session to this shard's graph if it isn't yet.
    /// Failing (the graph is not hosted there) surfaces to the caller
    /// instead of letting verbs hit the server's default graph.
    fn ensure_selected(&self, conn: &mut Conn) -> Result<()> {
        if conn.selected {
            return Ok(());
        }
        let reply = Self::exchange(conn, format!("USE {}", self.graph).as_bytes())?;
        let head = String::from_utf8_lossy(&reply);
        if head.starts_with("OK") {
            conn.selected = true;
            Ok(())
        } else {
            bail!(
                "{}: shard graph '{}' is not hosted ({})",
                self.addr,
                self.graph,
                head.trim_end()
            )
        }
    }

    fn exchange(conn: &mut Conn, body: &[u8]) -> Result<Vec<u8>> {
        if body.len() > MAX_FRAME_BYTES {
            bail!(
                "request frame is {} bytes, above the cap ({MAX_FRAME_BYTES})",
                body.len()
            );
        }
        write_frame(&mut conn.writer, body)?;
        read_frame(&mut conn.reader, MAX_FRAME_BYTES)?
            .ok_or_else(|| anyhow!("connection closed mid-reply"))
    }

    /// One selected exchange: pin the session graph, then send.
    fn selected_exchange(&self, conn: &mut Conn, body: &[u8], select: bool) -> Result<Vec<u8>> {
        if select {
            self.ensure_selected(conn)?;
        }
        Self::exchange(conn, body)
    }

    /// One frame round trip; a stale connection gets one re-dial. With
    /// `select`, the session is pinned to the shard graph first (every
    /// verb except the installing `SHARDHOST` needs that). `retry` must
    /// only be passed for idempotent verbs: a retried request may have
    /// already executed on the server once (lost reply).
    fn call_with(&self, body: &[u8], select: bool, retry: bool) -> Result<Vec<u8>> {
        let mut guard = self.conn.lock().unwrap();
        let had_conn = guard.is_some();
        if guard.is_none() {
            *guard = Some(self.connect()?);
        }
        let first = self.selected_exchange(guard.as_mut().unwrap(), body, select);
        match first {
            Ok(reply) => Ok(reply),
            Err(_) if had_conn && retry => {
                // the pooled connection went stale between calls
                *guard = None;
                *guard = Some(self.connect()?);
                match self.selected_exchange(guard.as_mut().unwrap(), body, select) {
                    Ok(reply) => Ok(reply),
                    Err(e) => {
                        *guard = None;
                        Err(e)
                    }
                }
            }
            Err(e) => {
                *guard = None;
                Err(e)
            }
        }
    }

    /// Mark the pooled connection's session as pinned (after a
    /// successful `SHARDHOST`, the server selects the new graph itself).
    fn mark_selected(&self) {
        if let Some(conn) = self.conn.lock().unwrap().as_mut() {
            conn.selected = true;
        }
    }

    /// Idempotent line verb (probes, reads): safe to replay.
    fn call_line(&self, line: &str) -> Result<(String, Vec<u8>)> {
        split_reply(self.call_with(line.as_bytes(), true, true)?)
    }

    /// Non-idempotent payload verb: never replayed after a lost reply.
    fn call_payload_once(&self, line: &str, payload: &[u8]) -> Result<(String, Vec<u8>)> {
        let mut body = line.as_bytes().to_vec();
        body.push(b'\n');
        body.extend_from_slice(payload);
        split_reply(self.call_with(&body, true, false)?)
    }

    /// Liveness probe (needs no hosted graph).
    pub fn ping(&self) -> Result<()> {
        let (head, _) = split_reply(self.call_with(b"PING", false, true)?)?;
        if head != "OK pong" {
            bail!("unexpected PING reply '{head}'");
        }
        Ok(())
    }

    /// Install (or overwrite) the shard on the remote from a manifest —
    /// initial shipping and snapshot catch-up both land here. The remote
    /// hydrates without recomputing anything.
    pub fn host(&self, manifest: &[u8]) -> Result<()> {
        // idempotent: re-installing the same manifest reproduces the
        // same hosted state, so a lost reply is safe to replay
        let mut body = format!("SHARDHOST {}", self.graph).into_bytes();
        body.push(b'\n');
        body.extend_from_slice(manifest);
        let (head, _) = split_reply(self.call_with(&body, false, true)?)?;
        field(&head, "shardhost")?;
        // the server switched its session to the freshly hosted graph
        self.mark_selected();
        Ok(())
    }

    /// Ship a delta chain (`(from, to]` epochs) to a lagging replica —
    /// the incremental alternative to [`Self::host`]. NOT idempotent
    /// (a replayed chain would double-apply its routed batches), so a
    /// lost reply surfaces as an error and the caller falls back to a
    /// full-manifest ship.
    pub fn apply_delta(&self, from: u64, to: u64, chain: &[u8]) -> Result<()> {
        let (head, _) = self.call_payload_once(&format!("SHARDDELTA {from} {to}"), chain)?;
        if field_u64(&head, "cluster")? != to {
            bail!("SHARDDELTA landed on the wrong epoch: '{head}'");
        }
        Ok(())
    }

    /// Pull the remote's current manifest (the replica catch-up source
    /// when this client points at a group's primary).
    pub fn fetch_manifest(&self) -> Result<Vec<u8>> {
        let (head, payload) = self.call_line("SHARDSNAP")?;
        let declared = field_u64(&head, "bytes")? as usize;
        if declared != payload.len() {
            bail!(
                "SHARDSNAP declared {declared} bytes but shipped {}",
                payload.len()
            );
        }
        Ok(payload)
    }
}

impl ShardBackend for RemoteShard {
    fn id(&self) -> usize {
        self.id
    }

    fn kind(&self) -> &'static str {
        "remote"
    }

    fn status(&self) -> Result<ShardStatus> {
        let (head, _) = self.call_line("SHARDINFO")?;
        Ok(ShardStatus {
            id: field_u64(&head, "shard")? as usize,
            epoch: field_u64(&head, "epoch")?,
            cluster_epoch: field_u64(&head, "cluster")?,
            owned: field_u64(&head, "owned")? as usize,
            k_max: field_u64(&head, "kmax")? as u32,
            state_bytes: field_u64(&head, "bytes")?,
        })
    }

    fn apply(&self, batch: &RoutedBatch) -> Result<ApplyOutcome> {
        // NOT idempotent (toggling edits double-apply); never replayed
        let (head, _) = self.call_payload_once("SHARDAPPLY", &wire::encode_batch(batch))?;
        Ok(ApplyOutcome {
            changed: field_u64(&head, "changed")? as usize,
            recomputed: field_u64(&head, "recomputed")? != 0,
            epoch: field_u64(&head, "epoch")?,
        })
    }

    fn refine_start(&self, slack: Option<u32>) -> Result<RefineInit> {
        let arg = match slack {
            Some(s) => s.to_string(),
            None => "-".to_string(),
        };
        let (_, payload) = self.call_line(&format!("SHARDREFINE START {arg}"))?;
        wire::decode_refine_init(&payload)
    }

    fn refine_round(&self, updates: &[(VertexId, u32)]) -> Result<RefineRound> {
        // NOT idempotent (the first execution clears the dirty flag; a
        // replay would report an empty sweep); never replayed
        let (head, payload) =
            self.call_payload_once("SHARDREFINE ROUND", &wire::encode_pairs(updates))?;
        Ok(RefineRound {
            changed: wire::decode_pairs(&payload)?,
            sweeps: field_u64(&head, "sweeps")? as usize,
            ghost_updates: field_u64(&head, "ghosts")?,
        })
    }

    fn refine_commit(&self, cluster_epoch: u64) -> Result<Vec<(VertexId, u32)>> {
        // NOT idempotent any more: the first execution freezes est into
        // refined, so a replayed COMMIT after a lost reply would report
        // an *empty* diff and the journal would ship a delta that skips
        // real coreness changes; never replayed
        let (head, payload) =
            self.call_payload_once(&format!("SHARDREFINE COMMIT {cluster_epoch}"), b"")?;
        if field_u64(&head, "commit")? != cluster_epoch {
            bail!("commit echoed the wrong epoch: '{head}'");
        }
        let diff = wire::decode_pairs(&payload)?;
        if diff.len() as u64 != field_u64(&head, "changed")? {
            bail!("SHARDREFINE COMMIT changed= disagrees with the diff payload");
        }
        Ok(diff)
    }

    fn refined_coreness(&self, v: VertexId) -> Result<(Option<u32>, u64)> {
        let (head, _) = self.call_line(&format!("SHARDCORE {v}"))?;
        let cluster = field_u64(&head, "cluster")?;
        let core = match field(&head, "core")? {
            "none" => None,
            c => Some(c.parse::<u32>().with_context(|| format!("bad core in '{head}'"))?),
        };
        Ok((core, cluster))
    }

    fn histogram_partial(&self) -> Result<(Vec<u64>, u64)> {
        let (head, _) = self.call_line("SHARDHISTO")?;
        let cluster = field_u64(&head, "cluster")?;
        let mut hist: Vec<u64> = Vec::new();
        for cell in field(&head, "histo")?.split(',').filter(|c| !c.is_empty()) {
            let (k, n) = cell
                .split_once(':')
                .ok_or_else(|| anyhow!("bad histo cell '{cell}'"))?;
            let k: usize = k.parse().with_context(|| format!("bad histo cell '{cell}'"))?;
            let n: u64 = n.parse().with_context(|| format!("bad histo cell '{cell}'"))?;
            if k >= hist.len() {
                hist.resize(k + 1, 0);
            }
            hist[k] += n;
        }
        Ok((hist, cluster))
    }

    fn members_partial(&self, k: u32) -> Result<(Vec<VertexId>, u64)> {
        let (head, payload) = self.call_line(&format!("SHARDMEMBERS {k}"))?;
        let cluster = field_u64(&head, "cluster")?;
        let members = wire::decode_u32s(&payload)?;
        if members.len() as u64 != field_u64(&head, "count")? {
            bail!("SHARDMEMBERS count disagrees with payload");
        }
        Ok((members, cluster))
    }
}

impl std::fmt::Debug for RemoteShard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RemoteShard(#{} {} '{}')", self.id, self.addr, self.graph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reply_fields_parse() {
        let head = "OK shard=3 epoch=9 cluster=2 owned=100 kmax=7";
        assert_eq!(field(head, "shard").unwrap(), "3");
        assert_eq!(field_u64(head, "owned").unwrap(), 100);
        assert!(field(head, "missing").is_err());
        // prefix keys must not match longer tokens
        assert!(field("OK clusterx=5", "cluster").is_err());
    }

    #[test]
    fn err_replies_become_errors() {
        assert!(split_reply(b"ERR nope".to_vec()).is_err());
        let (head, payload) = split_reply(b"OK x=1\nabc".to_vec()).unwrap();
        assert_eq!(head, "OK x=1");
        assert_eq!(payload, b"abc");
    }

    #[test]
    fn dead_host_fails_fast() {
        // reserved port: nothing listens; the dial must fail, not hang
        let r = RemoteShard::new(0, "127.0.0.1:1", "x/shard0");
        assert!(r.ping().is_err());
        assert!(r.status().is_err());
    }
}
