//! The per-shard epoch journal — the source of delta catch-up.
//!
//! Snapshot-ship catch-up ([`crate::cluster::ClusterIndex`]'s original
//! `sync_replicas`) re-sends the shard's *entire* manifest — graph,
//! coreness, id tables — so catch-up bytes scale with graph size even
//! when the replica missed a single 10-edit flush. The journal fixes the
//! asymptotics: for every published cluster epoch it keeps what that
//! epoch actually changed on the shard —
//!
//! * the [`RoutedBatch`] the router dispatched to the shard (empty for
//!   shards the flush never touched), and
//! * the refined-coreness **diff** the merge committed: `(global vertex,
//!   new refined value)` pairs for exactly the entries `refine_commit`
//!   changed, plus every vertex the batch newly registered.
//!
//! A replica lagging from epoch `a` to head `b` replays the contiguous
//! chain `(a, b]` through the *same* apply path the primary used
//! (`LocalShard::apply` + `install_refined_diff`), so its state — graph,
//! id tables, shard-local index epoch, refined coreness — ends
//! **byte-identical** to the primary's manifest (`tests/cluster.rs` pins
//! this). The bytes shipped scale with the edit batches and the coreness
//! churn, not with |V| + |E|.
//!
//! The journal is bounded on two axes: `retention` epochs are kept
//! (configured by `cluster.journal` in the topology file; 0 disables
//! journalling), and when a byte budget is set (`cluster.journal_bytes`,
//! 0 = unbounded) the *encoded* sizes of the held deltas may not exceed
//! it — one big epoch can evict many small ones. Either bound tripping
//! drops the oldest entries, and a replica whose lag falls off the tail
//! takes the full-manifest path instead. Entries must stay contiguous —
//! a non-consecutive [`EpochJournal::record`] (or an explicit
//! [`EpochJournal::clear`] after a failed flush) resets the journal
//! rather than ever serving a chain with a hole in it.

use crate::graph::VertexId;
use crate::shard::backend::RoutedBatch;
use std::collections::VecDeque;

/// Default `cluster.journal` retention (epochs kept per shard).
pub const DEFAULT_JOURNAL_EPOCHS: usize = 64;

/// Everything one published cluster epoch changed on one shard.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EpochDelta {
    /// The cluster epoch this delta produces (it applies on top of
    /// `to_epoch - 1`).
    pub to_epoch: u64,
    /// The routed edits the flush dispatched to this shard (possibly
    /// empty: untouched shards still commit a refinement epoch).
    pub batch: RoutedBatch,
    /// Refined-coreness entries this epoch's commit changed, as
    /// `(global vertex, new refined value)` — including every local the
    /// batch newly registered, so a replayer can grow its vector.
    pub diff: Vec<(VertexId, u32)>,
}

impl EpochDelta {
    /// Exact size of this delta's step in an encoded chain
    /// ([`super::wire::encode_delta_chain`]'s per-step layout: epoch +
    /// batch length prefix + encoded batch + diff pairs) — what the
    /// byte-bounded retention accounts against.
    pub fn encoded_size(&self) -> usize {
        let batch = 8 + self.batch.new_owned.len() * 4 + 8 + self.batch.edits.len() * 9;
        8 + 8 + batch + 8 + self.diff.len() * 8
    }
}

/// A bounded, contiguous ring of [`EpochDelta`]s for one shard.
#[derive(Debug)]
pub struct EpochJournal {
    retention: usize,
    /// Encoded-bytes budget across held deltas (0 = unbounded).
    byte_budget: usize,
    /// Running sum of the held deltas' [`EpochDelta::encoded_size`].
    bytes: usize,
    deltas: VecDeque<EpochDelta>,
}

impl EpochJournal {
    /// A journal keeping at most `retention` epochs (0 = disabled: every
    /// `record` is dropped and every chain lookup misses), with no byte
    /// budget.
    pub fn new(retention: usize) -> Self {
        Self::bounded(retention, 0)
    }

    /// A journal bounded by epochs *and* encoded bytes (`byte_budget`
    /// 0 = unbounded). When a freshly recorded delta pushes the held
    /// total past the budget, the oldest epochs are evicted first; a
    /// single delta larger than the whole budget empties the journal.
    pub fn bounded(retention: usize, byte_budget: usize) -> Self {
        Self {
            retention,
            byte_budget,
            bytes: 0,
            deltas: VecDeque::new(),
        }
    }

    pub fn retention(&self) -> usize {
        self.retention
    }

    pub fn byte_budget(&self) -> usize {
        self.byte_budget
    }

    /// Total [`EpochDelta::encoded_size`] of the held epochs.
    pub fn held_bytes(&self) -> usize {
        self.bytes
    }

    /// Epochs currently held.
    pub fn len(&self) -> usize {
        self.deltas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.deltas.is_empty()
    }

    /// Append the delta for a freshly published epoch. A gap (the epoch
    /// is not `last + 1`) resets the journal to just this entry — a
    /// chain with a hole must never be servable. Eviction then enforces
    /// both bounds, oldest epochs first.
    pub fn record(&mut self, delta: EpochDelta) {
        if self.retention == 0 {
            return;
        }
        if let Some(last) = self.deltas.back() {
            if delta.to_epoch != last.to_epoch + 1 {
                self.clear();
            }
        }
        self.bytes += delta.encoded_size();
        self.deltas.push_back(delta);
        while self.deltas.len() > self.retention
            || (self.byte_budget > 0 && self.bytes > self.byte_budget)
        {
            match self.deltas.pop_front() {
                Some(evicted) => self.bytes -= evicted.encoded_size(),
                None => break,
            }
        }
    }

    /// Drop everything — called when a flush fails midway, because the
    /// primary may then hold state no recorded chain reproduces.
    pub fn clear(&mut self) {
        self.deltas.clear();
        self.bytes = 0;
    }

    /// The contiguous chain taking a replica from `from` to `to`
    /// (entries with `to_epoch` in `(from, to]`), or `None` if any part
    /// has been evicted (or `from >= to`).
    pub fn chain(&self, from: u64, to: u64) -> Option<Vec<&EpochDelta>> {
        if from >= to {
            return None;
        }
        let first = self.deltas.front()?.to_epoch;
        let last = self.deltas.back()?.to_epoch;
        if from + 1 < first || to > last {
            return None;
        }
        let skip = (from + 1 - first) as usize;
        let take = (to - from) as usize;
        let out: Vec<&EpochDelta> = self.deltas.iter().skip(skip).take(take).collect();
        debug_assert_eq!(out.len(), take);
        Some(out)
    }

    /// The wire-encoded chain `(from, to]`, if fully retained.
    pub fn encode_chain(&self, from: u64, to: u64) -> Option<Vec<u8>> {
        let chain = self.chain(from, to)?;
        Some(super::wire::encode_delta_chain(from, to, &chain))
    }

    /// Whether the contiguous chain `(from, to]` is fully retained —
    /// what the migration catch-up loop probes before deciding between
    /// a delta ship and a full-manifest re-ship (without paying for the
    /// encoding it may not send).
    pub fn covers(&self, from: u64, to: u64) -> bool {
        self.chain(from, to).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(to_epoch: u64) -> EpochDelta {
        EpochDelta {
            to_epoch,
            batch: RoutedBatch::default(),
            diff: vec![(to_epoch as u32, 1)],
        }
    }

    #[test]
    fn records_serve_contiguous_chains() {
        let mut j = EpochJournal::new(8);
        for e in 1..=5 {
            j.record(delta(e));
        }
        assert_eq!(j.len(), 5);
        assert!(j.covers(2, 5) && !j.covers(2, 6) && !j.covers(5, 5));
        let chain = j.chain(2, 5).unwrap();
        assert_eq!(
            chain.iter().map(|d| d.to_epoch).collect::<Vec<_>>(),
            vec![3, 4, 5]
        );
        assert_eq!(j.chain(0, 5).unwrap().len(), 5);
        // beyond the head, empty ranges, inverted ranges: all misses
        assert!(j.chain(2, 6).is_none());
        assert!(j.chain(5, 5).is_none());
        assert!(j.chain(5, 2).is_none());
    }

    #[test]
    fn retention_evicts_the_tail() {
        let mut j = EpochJournal::new(3);
        for e in 1..=10 {
            j.record(delta(e));
        }
        assert_eq!(j.len(), 3);
        assert!(j.chain(6, 10).is_none(), "epoch 7 was evicted");
        assert_eq!(j.chain(7, 10).unwrap().len(), 3);
    }

    #[test]
    fn gaps_reset_instead_of_lying() {
        let mut j = EpochJournal::new(8);
        j.record(delta(1));
        j.record(delta(2));
        j.record(delta(5)); // skipped 3 and 4
        assert_eq!(j.len(), 1);
        assert!(j.chain(1, 5).is_none());
        assert_eq!(j.chain(4, 5).unwrap().len(), 1);
        j.clear();
        assert!(j.is_empty());
        assert!(j.chain(4, 5).is_none());
    }

    #[test]
    fn encoded_size_matches_the_wire_encoding() {
        let d = EpochDelta {
            to_epoch: 3,
            batch: RoutedBatch {
                new_owned: vec![7, 9],
                edits: vec![(crate::core::maintenance::EdgeEdit::Insert(1, 9), true)],
            },
            diff: vec![(1, 3), (9, 1)],
        };
        // one-step chain = magic + from + to + count + the step
        let encoded = super::super::wire::encode_delta_chain(2, 3, &[&d]);
        assert_eq!(encoded.len(), 8 + 8 + 8 + 8 + d.encoded_size());
    }

    #[test]
    fn byte_budget_evicts_oldest_first() {
        // each test delta encodes to 8+8+(8+0+8+0)+8+8 = 48 bytes
        let per = delta(1).encoded_size();
        let mut j = EpochJournal::bounded(100, 3 * per);
        for e in 1..=5 {
            j.record(delta(e));
        }
        assert_eq!(j.len(), 3, "budget holds exactly three deltas");
        assert_eq!(j.held_bytes(), 3 * per);
        assert!(j.chain(1, 5).is_none(), "epoch 2 fell off the byte budget");
        assert_eq!(j.chain(2, 5).unwrap().len(), 3);

        // a single delta bigger than the whole budget empties the journal
        let mut j = EpochJournal::bounded(100, 10);
        j.record(delta(1));
        assert!(j.is_empty());
        assert_eq!(j.held_bytes(), 0);

        // the epoch bound still applies independently
        let mut j = EpochJournal::bounded(2, 100 * per);
        for e in 1..=5 {
            j.record(delta(e));
        }
        assert_eq!(j.len(), 2);
        assert_eq!(j.held_bytes(), 2 * per);

        // clear() resets the byte accounting too
        j.clear();
        assert_eq!(j.held_bytes(), 0);
        j.record(delta(9));
        assert_eq!(j.held_bytes(), per);
    }

    #[test]
    fn zero_retention_disables_everything() {
        let mut j = EpochJournal::new(0);
        j.record(delta(1));
        assert!(j.is_empty());
        assert!(j.chain(0, 1).is_none());
        assert!(j.encode_chain(0, 1).is_none());
    }
}
