//! The per-shard epoch journal — the source of delta catch-up.
//!
//! Snapshot-ship catch-up ([`crate::cluster::ClusterIndex`]'s original
//! `sync_replicas`) re-sends the shard's *entire* manifest — graph,
//! coreness, id tables — so catch-up bytes scale with graph size even
//! when the replica missed a single 10-edit flush. The journal fixes the
//! asymptotics: for every published cluster epoch it keeps what that
//! epoch actually changed on the shard —
//!
//! * the [`RoutedBatch`] the router dispatched to the shard (empty for
//!   shards the flush never touched), and
//! * the refined-coreness **diff** the merge committed: `(global vertex,
//!   new refined value)` pairs for exactly the entries `refine_commit`
//!   changed, plus every vertex the batch newly registered.
//!
//! A replica lagging from epoch `a` to head `b` replays the contiguous
//! chain `(a, b]` through the *same* apply path the primary used
//! (`LocalShard::apply` + `install_refined_diff`), so its state — graph,
//! id tables, shard-local index epoch, refined coreness — ends
//! **byte-identical** to the primary's manifest (`tests/cluster.rs` pins
//! this). The bytes shipped scale with the edit batches and the coreness
//! churn, not with |V| + |E|.
//!
//! The journal is bounded: `retention` epochs are kept (configured by
//! `cluster.journal` in the topology file; 0 disables journalling), older
//! entries are dropped, and a replica whose lag falls off the tail takes
//! the full-manifest path instead. Entries must stay contiguous — a
//! non-consecutive [`EpochJournal::record`] (or an explicit
//! [`EpochJournal::clear`] after a failed flush) resets the journal
//! rather than ever serving a chain with a hole in it.

use crate::graph::VertexId;
use crate::shard::backend::RoutedBatch;
use std::collections::VecDeque;

/// Default `cluster.journal` retention (epochs kept per shard).
pub const DEFAULT_JOURNAL_EPOCHS: usize = 64;

/// Everything one published cluster epoch changed on one shard.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EpochDelta {
    /// The cluster epoch this delta produces (it applies on top of
    /// `to_epoch - 1`).
    pub to_epoch: u64,
    /// The routed edits the flush dispatched to this shard (possibly
    /// empty: untouched shards still commit a refinement epoch).
    pub batch: RoutedBatch,
    /// Refined-coreness entries this epoch's commit changed, as
    /// `(global vertex, new refined value)` — including every local the
    /// batch newly registered, so a replayer can grow its vector.
    pub diff: Vec<(VertexId, u32)>,
}

/// A bounded, contiguous ring of [`EpochDelta`]s for one shard.
#[derive(Debug)]
pub struct EpochJournal {
    retention: usize,
    deltas: VecDeque<EpochDelta>,
}

impl EpochJournal {
    /// A journal keeping at most `retention` epochs (0 = disabled: every
    /// `record` is dropped and every chain lookup misses).
    pub fn new(retention: usize) -> Self {
        Self {
            retention,
            deltas: VecDeque::new(),
        }
    }

    pub fn retention(&self) -> usize {
        self.retention
    }

    /// Epochs currently held.
    pub fn len(&self) -> usize {
        self.deltas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.deltas.is_empty()
    }

    /// Append the delta for a freshly published epoch. A gap (the epoch
    /// is not `last + 1`) resets the journal to just this entry — a
    /// chain with a hole must never be servable.
    pub fn record(&mut self, delta: EpochDelta) {
        if self.retention == 0 {
            return;
        }
        if let Some(last) = self.deltas.back() {
            if delta.to_epoch != last.to_epoch + 1 {
                self.deltas.clear();
            }
        }
        self.deltas.push_back(delta);
        while self.deltas.len() > self.retention {
            self.deltas.pop_front();
        }
    }

    /// Drop everything — called when a flush fails midway, because the
    /// primary may then hold state no recorded chain reproduces.
    pub fn clear(&mut self) {
        self.deltas.clear();
    }

    /// The contiguous chain taking a replica from `from` to `to`
    /// (entries with `to_epoch` in `(from, to]`), or `None` if any part
    /// has been evicted (or `from >= to`).
    pub fn chain(&self, from: u64, to: u64) -> Option<Vec<&EpochDelta>> {
        if from >= to {
            return None;
        }
        let first = self.deltas.front()?.to_epoch;
        let last = self.deltas.back()?.to_epoch;
        if from + 1 < first || to > last {
            return None;
        }
        let skip = (from + 1 - first) as usize;
        let take = (to - from) as usize;
        let out: Vec<&EpochDelta> = self.deltas.iter().skip(skip).take(take).collect();
        debug_assert_eq!(out.len(), take);
        Some(out)
    }

    /// The wire-encoded chain `(from, to]`, if fully retained.
    pub fn encode_chain(&self, from: u64, to: u64) -> Option<Vec<u8>> {
        let chain = self.chain(from, to)?;
        Some(super::wire::encode_delta_chain(from, to, &chain))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(to_epoch: u64) -> EpochDelta {
        EpochDelta {
            to_epoch,
            batch: RoutedBatch::default(),
            diff: vec![(to_epoch as u32, 1)],
        }
    }

    #[test]
    fn records_serve_contiguous_chains() {
        let mut j = EpochJournal::new(8);
        for e in 1..=5 {
            j.record(delta(e));
        }
        assert_eq!(j.len(), 5);
        let chain = j.chain(2, 5).unwrap();
        assert_eq!(
            chain.iter().map(|d| d.to_epoch).collect::<Vec<_>>(),
            vec![3, 4, 5]
        );
        assert_eq!(j.chain(0, 5).unwrap().len(), 5);
        // beyond the head, empty ranges, inverted ranges: all misses
        assert!(j.chain(2, 6).is_none());
        assert!(j.chain(5, 5).is_none());
        assert!(j.chain(5, 2).is_none());
    }

    #[test]
    fn retention_evicts_the_tail() {
        let mut j = EpochJournal::new(3);
        for e in 1..=10 {
            j.record(delta(e));
        }
        assert_eq!(j.len(), 3);
        assert!(j.chain(6, 10).is_none(), "epoch 7 was evicted");
        assert_eq!(j.chain(7, 10).unwrap().len(), 3);
    }

    #[test]
    fn gaps_reset_instead_of_lying() {
        let mut j = EpochJournal::new(8);
        j.record(delta(1));
        j.record(delta(2));
        j.record(delta(5)); // skipped 3 and 4
        assert_eq!(j.len(), 1);
        assert!(j.chain(1, 5).is_none());
        assert_eq!(j.chain(4, 5).unwrap().len(), 1);
        j.clear();
        assert!(j.is_empty());
        assert!(j.chain(4, 5).is_none());
    }

    #[test]
    fn zero_retention_disables_everything() {
        let mut j = EpochJournal::new(0);
        j.record(delta(1));
        assert!(j.is_empty());
        assert!(j.chain(0, 1).is_none());
        assert!(j.encode_chain(0, 1).is_none());
    }
}
