//! The rebalance planner and executor: turns the per-shard load
//! signals the router already tracks into a bounded plan of ownership
//! moves, and drives each move through the handoff primitive.
//!
//! The planner is deliberately conservative — the same philosophy as
//! [`crate::shard::partition`]'s degree-balanced cost model, whose
//! weights (degree + 1 per vertex) are exactly what a shard's
//! `state_bytes` is a faithful proxy for (the manifest encodes each
//! owned vertex plus its adjacency). One pass emits at most **one
//! split** (shave the hottest shard toward the mean) plus any number of
//! **merges** (empty shards that have shrunk below a floor fraction of
//! the mean into the next-smallest shard), because every move costs a
//! fenced cutover and a replica re-ship — a plan that chases perfect
//! balance in one shot would pause writers for longer than the
//! imbalance costs.
//!
//! Signals, all live on [`crate::cluster::ReplicaGroup`]:
//!
//! * `state_bytes` — the size signal (degree-weighted, like the
//!   partitioner's cost model); the imbalance test runs on it.
//! * `edits_routed` — the heat tiebreak: between two near-equal heavy
//!   shards, shave the one taking more writes.
//! * `boundary_arcs` — reported in the plan (an operator deciding
//!   between split targets cares about cut growth), not yet an
//!   objective term.
//! * `lag_epochs` / `reachable` — shards whose replicas lag, or whose
//!   primary probe failed, are never chosen as *targets*: landing more
//!   vertices on a group that cannot even keep its replicas current
//!   digs the hole deeper.

use super::index::{ClusterIndex, MoveRecord};
use anyhow::{Context, Result};

/// A hot shard splits when its weight exceeds the mean by this factor.
pub const SPLIT_THRESHOLD: f64 = 1.2;

/// A shard merges away when its weight falls below this fraction of the
/// mean (and some other shard can take it).
pub const MERGE_FLOOR: f64 = 0.25;

/// One shard's live load signals, as sampled for a plan.
#[derive(Clone, Debug)]
pub struct ShardLoad {
    pub shard: usize,
    /// Owned vertices (status probe).
    pub owned: usize,
    /// Exact encoded state size — the degree-weighted size signal.
    pub state_bytes: u64,
    /// Routed edits applied by this shard's primary, cumulatively.
    pub edits_routed: u64,
    /// Boundary arcs at the last refinement (cut-share signal).
    pub boundary_arcs: u64,
    /// Replica lag at the last sync probe (0 = all current).
    pub lag_epochs: u64,
    /// Whether the primary answered its status probe.
    pub reachable: bool,
}

/// One planned ownership move.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlannedMove {
    /// `"split"` or `"merge"`.
    pub kind: &'static str,
    pub from: usize,
    pub to: usize,
    /// Vertices to hand off.
    pub count: usize,
    /// Why the planner chose this move (rendered by `CLUSTER REBALANCE
    /// PLAN`).
    pub reason: String,
}

/// A full plan: the load snapshot it was computed from, plus the moves.
#[derive(Clone, Debug)]
pub struct RebalancePlan {
    pub loads: Vec<ShardLoad>,
    pub moves: Vec<PlannedMove>,
}

impl RebalancePlan {
    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }
}

/// Compute a plan from a load snapshot. Pure — callers sample loads
/// (`ClusterIndex::shard_loads`) and apply moves separately, so the
/// planner is unit-testable without a cluster.
pub fn plan(loads: &[ShardLoad]) -> RebalancePlan {
    let mut moves = Vec::new();
    let eligible: Vec<&ShardLoad> = loads.iter().filter(|l| l.reachable).collect();
    if eligible.len() < 2 {
        return RebalancePlan {
            loads: loads.to_vec(),
            moves,
        };
    }
    let mean =
        eligible.iter().map(|l| l.state_bytes).sum::<u64>() as f64 / eligible.len() as f64;
    // a valid landing zone: reachable, replicas current
    let target_of = |exclude: &[usize]| -> Option<usize> {
        eligible
            .iter()
            .filter(|l| l.lag_epochs == 0 && !exclude.contains(&l.shard))
            .min_by_key(|l| (l.state_bytes, l.shard))
            .map(|l| l.shard)
    };
    // shards below the merge floor are about to be emptied — they are
    // merge *sources*, never landing zones for a split
    let shrunken: Vec<usize> = eligible
        .iter()
        .filter(|l| l.owned > 0 && (l.state_bytes as f64) < mean * MERGE_FLOOR)
        .map(|l| l.shard)
        .collect();
    // at most one split per pass: the heaviest shard over the threshold
    // (edits_routed breaks near-equal ties toward the hotter writer)
    let split = eligible
        .iter()
        .filter(|l| l.owned > 1 && (l.state_bytes as f64) > mean * SPLIT_THRESHOLD)
        .max_by_key(|l| (l.state_bytes, l.edits_routed, l.shard));
    if let Some(hot) = split {
        let mut exclude = shrunken.clone();
        exclude.push(hot.shard);
        if let Some(to) = target_of(&exclude) {
            // shave the excess over the mean, scaled into vertices
            let excess = (hot.state_bytes as f64 - mean) / hot.state_bytes as f64;
            let count = ((hot.owned as f64 * excess) as usize).clamp(1, hot.owned - 1);
            moves.push(PlannedMove {
                kind: "split",
                from: hot.shard,
                to,
                count,
                reason: format!(
                    "shard {} carries {} bytes ({}x the {}-byte mean; {} routed edits, {} boundary arcs)",
                    hot.shard,
                    hot.state_bytes,
                    (hot.state_bytes as f64 / mean * 100.0).round() / 100.0,
                    mean as u64,
                    hot.edits_routed,
                    hot.boundary_arcs,
                ),
            });
        }
    }
    // merges: shards shrunk below the floor empty into the next-smallest
    // (never a shard already involved in this pass's split)
    let busy: Vec<usize> = moves.iter().flat_map(|m| [m.from, m.to]).collect();
    for l in &eligible {
        if l.owned == 0 || busy.contains(&l.shard) {
            continue;
        }
        if (l.state_bytes as f64) < mean * MERGE_FLOOR {
            let mut exclude = busy.clone();
            exclude.push(l.shard);
            exclude.extend(moves.iter().map(|m| m.from));
            if let Some(to) = target_of(&exclude) {
                moves.push(PlannedMove {
                    kind: "merge",
                    from: l.shard,
                    to,
                    count: l.owned,
                    reason: format!(
                        "shard {} shrank to {} bytes (under {}% of the {}-byte mean)",
                        l.shard,
                        l.state_bytes,
                        (MERGE_FLOOR * 100.0) as u64,
                        mean as u64,
                    ),
                });
            }
        }
    }
    RebalancePlan {
        loads: loads.to_vec(),
        moves,
    }
}

/// Drive every planned move through [`ClusterIndex::move_vertices`],
/// in order. Each move is atomic (fenced, epoch-published); a failure
/// stops the pass with the completed moves standing — the cluster is
/// consistent after every step, so there is nothing to roll back.
/// Callers hold the one-at-a-time latch (`ClusterIndex::rebalance_apply`
/// is the latched entry point).
pub fn execute(idx: &ClusterIndex, plan: &RebalancePlan) -> Result<Vec<MoveRecord>> {
    let mut records = Vec::with_capacity(plan.moves.len());
    for m in &plan.moves {
        let rec = idx.move_vertices(m.from, m.to, m.count).with_context(|| {
            format!("applying planned {} {}->{} ({} vertices)", m.kind, m.from, m.to, m.count)
        })?;
        records.push(rec);
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(shard: usize, owned: usize, state_bytes: u64) -> ShardLoad {
        ShardLoad {
            shard,
            owned,
            state_bytes,
            edits_routed: 0,
            boundary_arcs: 0,
            lag_epochs: 0,
            reachable: true,
        }
    }

    #[test]
    fn balanced_clusters_plan_nothing() {
        let p = plan(&[load(0, 100, 1000), load(1, 100, 1050), load(2, 100, 980)]);
        assert!(p.is_empty(), "{:?}", p.moves);
        // degenerate inputs
        assert!(plan(&[]).is_empty());
        assert!(plan(&[load(0, 100, 1000)]).is_empty());
    }

    #[test]
    fn a_hot_shard_splits_toward_the_smallest() {
        let p = plan(&[load(0, 200, 4000), load(1, 100, 1000), load(2, 110, 1200)]);
        assert_eq!(p.moves.len(), 1);
        let m = &p.moves[0];
        assert_eq!((m.kind, m.from, m.to), ("split", 0, 1));
        // excess over the mean (~2067) is ~48% of shard 0's weight
        assert!(m.count >= 1 && m.count < 200, "count {}", m.count);
        assert!((80..=120).contains(&m.count), "count {}", m.count);
        assert!(m.reason.contains("shard 0"), "{}", m.reason);
    }

    #[test]
    fn a_shrunken_shard_merges_away() {
        let p = plan(&[load(0, 100, 2000), load(1, 100, 2100), load(2, 5, 100)]);
        assert_eq!(p.moves.len(), 1);
        let m = &p.moves[0];
        assert_eq!((m.kind, m.from, m.count), ("merge", 2, 5));
        assert_eq!(m.to, 0, "merges into the smallest other shard");
    }

    #[test]
    fn lagging_and_unreachable_shards_are_never_targets() {
        // shard 1 is smallest but lags; the split must land on shard 2
        let mut laggy = load(1, 50, 500);
        laggy.lag_epochs = 3;
        let p = plan(&[load(0, 200, 4000), laggy, load(2, 110, 1200)]);
        assert_eq!(p.moves.len(), 1);
        assert_eq!(p.moves[0].to, 2);
        // an unreachable shard neither splits nor receives
        let mut dead = load(0, 0, 0);
        dead.reachable = false;
        let p = plan(&[dead, load(1, 100, 1000), load(2, 100, 1020)]);
        assert!(p.is_empty(), "{:?}", p.moves);
    }

    #[test]
    fn split_and_merge_compose_without_sharing_shards() {
        // shard 0 hot, shard 3 tiny: one split + one merge, landing on
        // different shards than the split pair
        let p = plan(&[
            load(0, 300, 6000),
            load(1, 100, 1500),
            load(2, 120, 1600),
            load(3, 4, 90),
        ]);
        let kinds: Vec<&str> = p.moves.iter().map(|m| m.kind).collect();
        assert_eq!(kinds, vec!["split", "merge"]);
        let split = &p.moves[0];
        let merge = &p.moves[1];
        assert_eq!(split.from, 0);
        assert_eq!(merge.from, 3);
        assert_ne!(merge.to, split.from, "merge must not refill the hot shard");
        assert_ne!(merge.to, merge.from);
    }
}
